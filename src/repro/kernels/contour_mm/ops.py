"""Jit'd wrappers for the contour_mm kernels: backend dispatch + autotune.

Three device backends realise the same MM^h sweep (DESIGN.md §3):

* ``"xla"``           — synchronous scatter-min (`lab.mm_relax`); the only
  backend that *compiles* on a CPU host (Pallas TPU kernels cannot), and
  what `repro.connectivity.distributed` defaults to.
* ``"pallas"``        — the seed fused in-VMEM asynchronous kernel
  (`kernel.mm2_pallas`): whole ``L`` VMEM-resident (ceiling n ≈ 3M),
  scalar sequential inner loop, 2-order only.  Kept as the
  deterministic-async reference.
* ``"pallas_blocked"`` — the label-blocked vectorized kernel
  (`blocked.binned_scatter_min_pallas`): edges are reduced to an update
  stream, radix-binned by ``target // label_block`` on device, and one
  grid step per update chunk runs with ``L`` *tiled* via BlockSpec — no
  vertex ceiling, VPU-vectorized scatter-min, any order.  Per sweep it is
  bit-exact equal to ``"xla"``.

``"auto"`` picks per graph size and platform via :func:`plan_contour_kernel`
— the shared dispatch/autotune layer used by `core.contour`,
`core.distributed` and `benchmarks.connectivity`.

:func:`contour_cc_fixpoint` iterates any backend to the connectivity fixed
point inside a single ``lax.while_loop`` — the convergence flag stays on
device, so there are **zero** per-iteration host syncs (the seed version
pulled ``bool(converged_early(...))`` across the device boundary every
iteration).  ``sampling``/``compact_every`` switch it to the work-adaptive
frontier contraction schedule (``connectivity.frontier``, DESIGN.md §10);
every sweep accepts an ``edge_limit`` frontier bound, which the blocked
kernel realises as skipped grid steps via a dead-bin sort plus a
scalar-prefetched live-chunk count.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.connectivity import frontier as fr
from repro.connectivity import minmap as lab
from repro.connectivity.planner import vmem as _vmem
from repro.connectivity.planner.heuristics import heuristic_plan
from repro.graphs.structs import Graph
from repro.kernels.contour_mm.blocked import (_round_up,
                                              binned_scatter_min_pallas,
                                              fused_relax_pallas)
from repro.kernels.contour_mm.kernel import mm2_pallas

BACKENDS = ("auto", "xla", "pallas", "pallas_blocked")

# Above this vertex count a fully VMEM-resident int32 L no longer fits the
# platform's VMEM budget alongside edge blocks (kernel.py header) — the
# scalar "pallas" backend is invalid and blocking is mandatory.  Derived
# from the queried/declared VMEM budget (planner.vmem), overridable via
# SolveOptions.vmem_limit_bytes or $REPRO_VMEM_BYTES; this module-level
# snapshot exists for back-compat imports (the dispatch path re-derives).
WHOLE_L_VMEM_CEILING = _vmem.whole_l_vmem_ceiling()


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Resolved backend + tile sizes for one graph size (hashable/static).

    Legacy shape — the execution-plan layer
    (:class:`repro.connectivity.planner.ExecutionPlan`) supersedes it,
    adding the compaction schedule, relabel fusion and plan origin.  Kept
    so pinned plans in existing call sites keep working; every consumer
    accepts either (``ExecutionPlan.from_kernel_plan`` lifts this).
    """

    backend: str                # concrete: "xla" | "pallas" | "pallas_blocked"
    block_edges: int = 512      # edge block of the scalar pallas kernel
    label_block: int = 2048     # L tile height of the blocked kernel
    chunk_updates: int = 128    # update-stream chunk of the blocked kernel
    interpret: bool = False     # Pallas interpreter mode (CPU validation)


def plan_contour_kernel(
    n_vertices: int,
    n_edges: int,
    platform: Optional[str] = None,
) -> KernelPlan:
    """Deprecated: use :func:`repro.connectivity.planner.resolve_plan`.

    Thin shim over the planner's heuristic tables, kept for one
    deprecation cycle.  It returns the legacy :class:`KernelPlan` (no
    schedule/fusion fields) and never consults the tuning cache.
    """
    warnings.warn(
        "plan_contour_kernel is deprecated; use "
        "repro.connectivity.planner.resolve_plan (measured, cached) or "
        "planner.heuristic_plan (the same tables, richer plan)",
        DeprecationWarning, stacklevel=2)
    p = heuristic_plan(n_vertices, n_edges, platform)
    return KernelPlan(backend=p.backend, block_edges=p.block_edges,
                      label_block=p.label_block,
                      chunk_updates=p.chunk_updates, interpret=p.interpret)


def _pad_edges(src, dst, multiple: int):
    m = src.shape[0]
    target = _round_up(m, multiple)
    pad = target - m
    if pad:
        src = jnp.concatenate([src, jnp.zeros((pad,), src.dtype)])
        dst = jnp.concatenate([dst, jnp.zeros((pad,), dst.dtype)])
    return src, dst


# The sweep's gather phase lives next to mm_relax so the two realisations
# can never drift apart (bit-exactness is load-bearing — see ref.py).
mm_update_stream = lab.mm_update_stream


def mm_relax_backend(
    L: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    *,
    order: int = 2,
    backend: str = "auto",
    block_edges: Optional[int] = None,
    label_block: Optional[int] = None,
    chunk_updates: Optional[int] = None,
    interpret: Optional[bool] = None,
    platform: Optional[str] = None,
    edge_limit: Optional[jax.Array] = None,
    fuse: Optional[bool] = None,
    vmem_limit_bytes: Optional[int] = None,
) -> jax.Array:
    """One MM^order sweep on the chosen backend (trace-level, not jitted).

    ``None`` tile parameters resolve from the planner's heuristic tables
    (``planner.heuristic_plan``), including ``interpret`` (False on TPU,
    True elsewhere — validation mode).  The tables only — never the
    tuning cache: this resolution happens inside jitted fixpoints, where
    it must stay a pure function of (shape, platform) so compiled
    programs (and the bench HLO-identity gate) are reproducible.  Cache
    hits are applied by ``planner.resolve_plan`` at the solve facade.
    ``platform`` overrides the plan's target platform for AOT lowering
    from a different host (e.g. ``.lower()``-ing a TPU program on a CPU
    dry-run host).  This is the single entry every layer routes sweeps
    through.

    ``fuse`` opts the blocked backend into the fused relabel+scatter-min
    kernel (one Pallas pass instead of XLA gathers + radix binning +
    scatter kernel); it applies in the single-tile order-2 regime and
    falls back to the binned pipeline otherwise.  ``vmem_limit_bytes``
    overrides the platform VMEM budget behind the scalar kernel's
    whole-L ceiling.

    ``edge_limit`` is the work-adaptive frontier bound (a traced int32
    scalar): only the first ``edge_limit`` edges contribute updates.  The
    XLA and scalar-pallas backends mask the suffix to self-loop no-ops
    (same shapes, so the program stays jit-stable); the blocked kernel
    routes the suffix's update stream into a dead tail bin and skips those
    grid steps outright (``blocked.binned_scatter_min_pallas``).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    n = int(L.shape[0])
    m = int(src.shape[0])
    plan = heuristic_plan(n, m, platform)
    if backend == "auto":
        backend = plan.backend
    block_edges = plan.block_edges if block_edges is None else block_edges
    label_block = plan.label_block if label_block is None else label_block
    chunk_updates = (plan.chunk_updates if chunk_updates is None
                     else chunk_updates)
    interpret = plan.interpret if interpret is None else interpret
    fuse = plan.fuse_relabel if fuse is None else fuse

    edge_mask = None
    if edge_limit is not None:
        edge_mask = jnp.arange(m, dtype=jnp.int32) < edge_limit

    if backend == "xla":
        if edge_mask is not None:
            # self-loops at vertex 0 are min-mapping no-ops (structs.Graph
            # padding uses the same trick)
            src = jnp.where(edge_mask, src, 0)
            dst = jnp.where(edge_mask, dst, 0)
        return lab.mm_relax(L, src, dst, order)
    if backend == "pallas":
        if order != 2:
            raise ValueError(
                "the scalar 'pallas' kernel is 2-order only; use "
                "'pallas_blocked' or 'xla' for order != 2")
        ceiling = _vmem.whole_l_vmem_ceiling(platform,
                                             vmem_bytes=vmem_limit_bytes)
        if n > ceiling:
            raise ValueError(
                f"n_vertices={n} exceeds the scalar 'pallas' kernel's "
                f"whole-L VMEM ceiling ({ceiling}); use 'pallas_blocked' "
                "(label-tiled, no ceiling) or 'xla', or raise the budget "
                f"via SolveOptions.vmem_limit_bytes / ${_vmem.ENV_VMEM_BYTES}")
        if edge_mask is not None:
            src = jnp.where(edge_mask, src, 0)
            dst = jnp.where(edge_mask, dst, 0)
        src_p, dst_p = _pad_edges(src, dst, block_edges)
        return mm2_pallas(src_p, dst_p, L, block_edges=block_edges,
                          interpret=interpret)
    # pallas_blocked
    if fuse and order == 2 and max(128, _round_up(n, 128)) <= label_block:
        # single-tile regime: one Pallas pass does gathers (relabel) and
        # all four scatter-min combines — no update-stream materialisation,
        # no radix binning, no argsort
        return fused_relax_pallas(
            L, src, dst, chunk_edges=chunk_updates, interpret=interpret,
            edge_limit=edge_limit)
    t, v = lab.mm_update_stream(L, src, dst, order)
    valid = None
    if edge_mask is not None:
        # the stream is 2*order concatenated [m] segments (targets per
        # Definition 3); each inherits the per-edge liveness
        valid = jnp.tile(edge_mask, 2 * order)
    return binned_scatter_min_pallas(
        L, t, v, label_block=label_block, chunk_updates=chunk_updates,
        interpret=interpret, valid=valid)


@functools.partial(
    jax.jit,
    static_argnames=("backend", "order", "block_edges", "label_block",
                     "chunk_updates", "interpret", "platform", "fuse"),
)
def contour_mm_step(
    src: jax.Array,
    dst: jax.Array,
    L: jax.Array,
    *,
    backend: str = "pallas",
    order: int = 2,
    block_edges: int = 512,
    label_block: Optional[int] = None,
    chunk_updates: Optional[int] = None,
    interpret: Optional[bool] = None,
    platform: Optional[str] = None,
    fuse: Optional[bool] = None,
) -> jax.Array:
    """One MM sweep over all edges. Returns the updated label array."""
    return mm_relax_backend(
        L, src, dst, order=order, backend=backend, block_edges=block_edges,
        label_block=label_block, chunk_updates=chunk_updates,
        interpret=interpret, platform=platform, fuse=fuse)


class _FixState(NamedTuple):
    L: jax.Array
    it: jax.Array          # int32 iteration counter
    done: jax.Array        # bool, lives on device across iterations


@functools.partial(
    jax.jit,
    static_argnames=("backend", "order", "block_edges", "label_block",
                     "chunk_updates", "interpret", "platform", "max_iters",
                     "sampling", "compact_every", "fuse"),
)
def contour_cc_fixpoint(
    graph: Graph,
    *,
    backend: str = "auto",
    order: int = 2,
    block_edges: int = 512,
    label_block: Optional[int] = None,
    chunk_updates: Optional[int] = None,
    interpret: Optional[bool] = None,
    platform: Optional[str] = None,
    max_iters: int = 10_000,
    sampling: int = 0,
    compact_every: int = 0,
    fuse: Optional[bool] = None,
):
    """Iterate the kernel to the connectivity fixed point, fully on device.

    A single ``lax.while_loop`` carries ``(L, it, done)``; the paper's
    early-convergence predicate (§III-B2) is evaluated on device and feeds
    the loop condition directly — no per-iteration device→host readback.
    (The jit around this function is itself the proof: a host-side
    ``bool(converged)`` would fail to trace.)  Returns
    (labels, n_iters, converged, edges_visited) — ``converged`` is the
    loop's own flag, False iff the ``max_iters`` budget ran out;
    ``edges_visited`` is a float32 work counter (``n_iters * m`` for the
    dense schedule).

    ``sampling`` / ``compact_every`` enable the work-adaptive frontier
    contraction schedule (``connectivity.frontier``): sample-prefix
    sweeps, the post-sampling largest-component filter, and periodic
    active-edge contraction — same single while loop, edge arrays and the
    ``active_m`` count carried as loop state.
    """
    L0 = jnp.arange(graph.n_vertices, dtype=graph.src.dtype)
    if sampling < 0 or compact_every < 0:
        raise ValueError("sampling and compact_every must be >= 0, got "
                         f"{sampling} / {compact_every}")

    if sampling > 0 or compact_every > 0:
        def step(L, it, src, dst, limit):
            del it
            L = mm_relax_backend(
                L, src, dst, order=order, backend=backend,
                block_edges=block_edges, label_block=label_block,
                chunk_updates=chunk_updates, interpret=interpret,
                platform=platform, edge_limit=limit, fuse=fuse)
            return lab.pointer_jump(L, rounds=1)

        L, it, done, _, visited = fr.adaptive_fixpoint(
            graph.src, graph.dst, L0, step,
            n_vertices=graph.n_vertices, sampling=sampling,
            compact_every=compact_every, max_iters=max_iters)
        return L, it, done, visited

    def cond(s: _FixState):
        return (~s.done) & (s.it < max_iters)

    def body(s: _FixState):
        L = mm_relax_backend(
            s.L, graph.src, graph.dst, order=order, backend=backend,
            block_edges=block_edges, label_block=label_block,
            chunk_updates=chunk_updates, interpret=interpret,
            platform=platform, fuse=fuse)
        L = lab.pointer_jump(L, rounds=1)
        done = lab.converged_early(L, graph.src, graph.dst)
        return _FixState(L=L, it=s.it + 1, done=done)

    out = jax.lax.while_loop(
        cond, body, _FixState(L=L0, it=jnp.int32(0), done=jnp.array(False)))
    # Interior vertices of padded/isolated chains may be one hop from the
    # star root (same as connectivity.contour's final compression).
    visited = out.it.astype(jnp.float32) * graph.n_edges
    return lab.pointer_jump(out.L, rounds=1), out.it, out.done, visited
