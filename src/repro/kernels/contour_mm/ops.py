"""Jit'd wrappers for the contour_mm kernel with backend selection.

``backend="pallas"`` runs the fused in-VMEM asynchronous kernel
(interpret mode on CPU, compiled on TPU); ``backend="xla"`` runs the
equivalent synchronous scatter-min (what the production dry-run compiles —
Pallas TPU kernels cannot compile on the CPU host platform).

Scaling note: the Pallas path keeps all of ``L`` VMEM-resident, valid to
n ≈ 3M vertices.  Beyond that the intended TPU plan is label-blocking:
radix-bin edges by ``min(L[w], L[v]) // block`` and run one pallas_call per
label block — same kernel body, BlockSpec over ``L`` tiles.  The XLA
backend has no such limit and is what `repro.core.distributed` uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import labels as lab
from repro.graphs.structs import Graph
from repro.kernels.contour_mm.kernel import mm2_pallas


def _pad_edges(src, dst, multiple: int):
    m = src.shape[0]
    target = (m + multiple - 1) // multiple * multiple
    pad = target - m
    if pad:
        src = jnp.concatenate([src, jnp.zeros((pad,), src.dtype)])
        dst = jnp.concatenate([dst, jnp.zeros((pad,), dst.dtype)])
    return src, dst


@functools.partial(
    jax.jit, static_argnames=("backend", "block_edges", "interpret")
)
def contour_mm_step(
    src: jax.Array,
    dst: jax.Array,
    L: jax.Array,
    *,
    backend: str = "pallas",
    block_edges: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """One MM² sweep over all edges. Returns the updated label array."""
    if backend == "pallas":
        src, dst = _pad_edges(src, dst, block_edges)
        return mm2_pallas(src, dst, L, block_edges=block_edges, interpret=interpret)
    elif backend == "xla":
        return lab.mm_relax(L, src, dst, order=2)
    raise ValueError(f"unknown backend {backend!r}")


def contour_cc_fixpoint(
    graph: Graph,
    *,
    backend: str = "pallas",
    block_edges: int = 512,
    interpret: bool = True,
    max_iters: int = 10_000,
):
    """Iterate the kernel to the connectivity fixed point.

    Host-side fixpoint loop (the kernel is the inner hot loop; iteration
    counts are tiny — Theorem 1).  Returns (labels, n_iterations).
    """
    L = jnp.arange(graph.n_vertices, dtype=graph.src.dtype)
    for it in range(max_iters):
        L_new = contour_mm_step(
            graph.src, graph.dst, L,
            backend=backend, block_edges=block_edges, interpret=interpret,
        )
        L_new = lab.pointer_jump(L_new, rounds=1)
        if bool(lab.converged_early(L_new, graph.src, graph.dst)):
            return L_new, it + 1
        L = L_new
    return L, max_iters
