"""Pure-jnp oracle for the contour_mm Pallas kernel.

Replays the kernel's exact semantics — a *sequential* in-place 2-order
minimum-mapping sweep in edge order — using functional ``.at[]`` updates.
The kernel must match this bit-for-bit for every edge order, which pins
down the deterministic-async semantics (not just the fixed point).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mm_block_ref(src: jax.Array, dst: jax.Array, L: jax.Array) -> jax.Array:
    """Sequential async 2-order MM sweep; identical order to the kernel."""

    def body(e, L):
        w = src[e]
        v = dst[e]
        lw = L[w]
        lv = L[v]
        z = jnp.minimum(L[lw], L[lv])
        L = L.at[w].min(z)
        L = L.at[v].min(z)
        L = L.at[lw].min(z)
        L = L.at[lv].min(z)
        return L

    return jax.lax.fori_loop(0, src.shape[0], body, L)


def mm_sync_ref(src: jax.Array, dst: jax.Array, L: jax.Array) -> jax.Array:
    """Synchronous (Alg. 1) sweep — oracle for the XLA scatter-min backend
    *and* the label-blocked Pallas kernel: the blocked path computes the
    identical ``L.at[idx].min(z)`` through binned per-tile segment mins, so
    it must match this bit-for-bit per sweep (not just at the fixed point).
    """
    lw, lv = L[src], L[dst]
    z = jnp.minimum(L[lw], L[lv])
    idx = jnp.concatenate([src, dst, lw, lv])
    return L.at[idx].min(jnp.tile(z, 4))
