"""Label-blocked, vectorized scatter-min Pallas kernel (DESIGN.md §3.4).

The seed kernel (`kernel.py`) keeps the whole label array ``L`` resident in
VMEM and relaxes edges one at a time on the scalar unit — a hard ceiling of
n ≈ 3M vertices and zero VPU utilisation.  This module lifts both limits
with the two-phase *label-blocked* scheme:

Phase 1 — radix binning (device-side XLA, inside the same jit):
  The MM^h sweep is first reduced to an *update stream*: ``2h·m`` pairs
  ``(target, value)`` where ``value = z = min(L^h[w], L^h[v])`` and the
  targets are the conditional-assignment positions ``{w, v, L[w], …}``
  (`ops.mm_update_stream`).  The stream is stably sorted by
  ``target // label_block`` — the radix bin — and each bin's segment is
  padded up to a multiple of ``chunk_updates`` so that **no chunk straddles
  a label-block boundary**.  A chunk→block map is derived with a
  ``searchsorted`` over the padded bin offsets.

Phase 2 — one ``pallas_call`` over update chunks:
  The grid walks the padded stream chunk by chunk; the chunk→block map is
  a *scalar-prefetch* operand, so the BlockSpec index map for ``L`` can
  place exactly the right ``label_block``-sized tile of ``L`` in VMEM for
  each grid step (``lambda c, m: (m[c],)``).  Chunks of the same bin are
  contiguous, so each tile is loaded/flushed once per sweep and revisited
  in place across its chunks (input/output aliasing).  Inside the kernel
  the scatter-min is *vectorized*: a one-hot ``(chunk, label_block)``
  compare + ``jnp.min`` reduction replaces the scalar read-min-write chain
  — pure VPU work, no atomics, no serial dependence.

VMEM budget per grid step is ``4·label_block`` bytes for the tile plus
``4·chunk_updates·label_block`` for the one-hot combine — independent of
``n``, so the vertex ceiling is gone.  The per-sweep result is bit-exact
equal to the synchronous ``lab.mm_relax`` scatter-min (both compute
``L.at[targets].min(values)``), hence identical fixed point.

Index arithmetic uses int32 positions into the update stream; callers keep
``2h·m + n_blocks·chunk_updates < 2^31`` (enforced below).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Padding slots carry this value; min() makes them no-ops and the kernel
# additionally masks them out of the one-hot combine.
_SENTINEL = jnp.iinfo(jnp.int32).max


def _round_up(x, k):
    return (x + k - 1) // k * k


def _scatter_min_kernel(label_block: int, chunk: int):
    """Build the per-chunk kernel body for the given static tile sizes."""

    def kernel(map_ref, live_ref, t_ref, v_ref, l_in_ref, l_ref):
        c = pl.program_id(0)
        b = map_ref[c]
        # Output VMEM windows are uninitialized on each tile's first grid
        # visit — the HBM-level input/output aliasing does not seed them —
        # so start the accumulator from the fetched input tile.  Chunks of
        # a bin are contiguous, so "first visit" is a map transition.
        prev_b = map_ref[jnp.maximum(c - 1, 0)]

        @pl.when((c == 0) | (b != prev_b))
        def _():
            l_ref[...] = l_in_ref[...]

        # Frontier skip: chunks past the live count hold only updates from
        # inactive edges (binned past the last real label block), so the
        # whole combine is elided — the work-adaptive contraction schedule
        # shrinks per-sweep compute, not just the counted edge visits.
        @pl.when(c < live_ref[0])
        def _():
            base = b * label_block
            t_loc = t_ref[...] - base
            v = v_ref[...]
            valid = (t_loc >= 0) & (t_loc < label_block) & (v < _SENTINEL)
            # Vectorized scatter-min: one-hot compare against every tile
            # slot, then a min-reduce over the chunk axis (VPU; no serial
            # chain).
            cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, label_block),
                                            1)
            contrib = jnp.where(valid[:, None] & (cols == t_loc[:, None]),
                                v[:, None], _SENTINEL)
            l_ref[...] = jnp.minimum(l_ref[...], jnp.min(contrib, axis=0))

    return kernel


def binned_scatter_min_pallas(
    L: jax.Array,
    targets: jax.Array,
    values: jax.Array,
    *,
    label_block: int = 2048,
    chunk_updates: int = 128,
    interpret: bool = True,
    valid: jax.Array = None,
) -> jax.Array:
    """``L.at[targets].min(values)`` with ``L`` tiled by label block.

    Args:
      L: int32[n] labels.
      targets: int32[K] update positions, each in ``[0, n)``.
      values: int32[K] update values (``< _SENTINEL``).
      label_block: tile height ``B``; VMEM per step is ``4·B·(chunk+1)`` B.
      chunk_updates: updates processed per grid step.
      interpret: run in interpreter mode (CPU validation); False on TPU.
      valid: optional bool[K] per-update liveness (the work-adaptive
        frontier mask).  Dead updates are radix-binned into a trailing
        *dead bin* past every label block; because bins are contiguous the
        dead updates occupy the tail chunks of the padded stream, and the
        kernel elides the combine for every chunk past the live count
        (scalar-prefetched), skipping whole grid steps of VPU work.
    """
    n = L.shape[0]
    K = targets.shape[0]
    B = int(label_block)
    E = int(chunk_updates)
    n_blocks = (n + B - 1) // B
    # With a frontier mask, dead updates get a bin of their own past the
    # last real block so the stable radix sort pushes them to the tail.
    n_bins = n_blocks + (0 if valid is None else 1)
    n_pad = n_blocks * B
    if K + n_bins * E >= 2**31:
        raise ValueError(
            f"update stream of {K} + {n_bins}*{E} padding overflows int32 "
            "positions; raise label_block or split the sweep")
    L_pad = jnp.pad(L, (0, n_pad - n), constant_values=_SENTINEL)

    # -- Phase 1: radix-bin the update stream by target // B ---------------
    blk = targets // B
    if valid is not None:
        # dead updates: banished to the tail bin AND value-neutralised, so
        # the grid-step skip is an optimisation, not a correctness gate
        blk = jnp.where(valid, blk, n_blocks)
        values = jnp.where(valid, values, _SENTINEL)
    order = jnp.argsort(blk, stable=True)
    t_sorted = targets[order]
    v_sorted = values[order]
    blk_sorted = blk[order]

    counts = jnp.bincount(blk, length=n_bins)
    padded_counts = _round_up(counts, E)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(padded_counts)[:-1]])
    seg_start = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    # position in the boundary-aligned padded layout
    pos = offsets[blk_sorted] + (jnp.arange(K) - seg_start[blk_sorted])

    T = _round_up(K, E) + n_bins * E  # static capacity >= sum(padded)
    t_pad = jnp.zeros((T,), targets.dtype).at[pos].set(t_sorted)
    v_pad = jnp.full((T,), _SENTINEL, values.dtype).at[pos].set(v_sorted)

    n_chunks = T // E
    chunk_block = jnp.clip(
        jnp.searchsorted(offsets, jnp.arange(n_chunks) * E, side="right") - 1,
        0, n_blocks - 1).astype(jnp.int32)
    # Chunks holding live updates end where the dead bin begins; without a
    # mask every chunk is live.  (Dead entries were value-masked to
    # _SENTINEL above, so even a combine that did run would be a no-op —
    # the skip saves compute, it is not load-bearing for correctness.)
    if valid is None:
        live_chunks = jnp.full((1,), n_chunks, jnp.int32)
    else:
        dead_start = jnp.cumsum(padded_counts)[n_blocks - 1]
        live_chunks = (dead_start // E).astype(jnp.int32).reshape((1,))

    # -- Phase 2: one pallas_call over chunks, L tiled by BlockSpec --------
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((E,), lambda c, m, nl: (c,)),
            pl.BlockSpec((E,), lambda c, m, nl: (c,)),
            pl.BlockSpec((B,), lambda c, m, nl: (m[c],)),
        ],
        out_specs=pl.BlockSpec((B,), lambda c, m, nl: (m[c],)),
    )
    out = pl.pallas_call(
        _scatter_min_kernel(B, E),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad,), L.dtype),
        input_output_aliases={4: 0},  # L tile accumulates across chunks
        interpret=interpret,
    )(chunk_block, live_chunks, t_pad, v_pad, L_pad)
    return out[:n]


def _fused_relax_kernel(n_pad: int, chunk: int):
    """Per-edge-chunk body of the fused relabel + scatter-min pass."""

    def kernel(live_ref, s_ref, d_ref, l_in_ref, l_acc_ref, l_ref):
        c = pl.program_id(0)
        # single tile, constant index map: the output window persists
        # across every grid step, so one seed suffices
        @pl.when(c == 0)
        def _():
            l_ref[...] = l_acc_ref[...]

        # frontier skip: chunks wholly past the edge limit are elided
        @pl.when(c < live_ref[0])
        def _():
            l = l_in_ref[...]
            cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, n_pad), 1)

            def gather(idx):
                # one-hot gather L[idx] from the unchanged input tile: the
                # relabel step of the sweep, vectorized on the VPU (no
                # dynamic-index vector loads in Mosaic)
                hot = cols == idx[:, None]
                return jnp.sum(jnp.where(hot, l[None, :], 0), axis=1)

            s = s_ref[...]
            d = d_ref[...]
            ls = gather(s)          # L[src]
            ld = gather(d)          # L[dst]
            z = jnp.minimum(gather(ls), gather(ld))   # min(L²[src], L²[dst])

            # Definition-3 targets {src, dst, L[src], L[dst]} all take z;
            # four sequential one-hot combines bound live VMEM at one
            # (chunk, n_pad) buffer instead of a 4x-wide stream
            acc = l_ref[...]
            for t in (s, d, ls, ld):
                contrib = jnp.where(cols == t[:, None], z[:, None],
                                    _SENTINEL)
                acc = jnp.minimum(acc, jnp.min(contrib, axis=0))
            l_ref[...] = acc

    return kernel


def fused_relax_pallas(
    L: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    *,
    chunk_edges: int = 128,
    interpret: bool = True,
    edge_limit: jax.Array = None,
) -> jax.Array:
    """One fused order-2 MM sweep: relabel gathers + scatter-min, one pass.

    The binned pipeline materialises the ``4m`` update stream in HBM
    (XLA gathers), radix-sorts it, and only then runs the scatter kernel.
    In the single-tile regime (all of ``L`` in one VMEM tile) none of that
    is necessary: this kernel walks the *edge list* directly, performs the
    chain gathers ``L[src], L[dst], L²[src], L²[dst]`` in VMEM via one-hot
    compares, and folds all four conditional assignments of Definition 3
    into the same accumulator — no stream, no sort, no inter-pass HBM
    traffic.  Every gather reads the unchanged input tile, so the sweep is
    synchronous and bit-exact equal to ``lab.mm_relax(L, src, dst, 2)``.

    Args:
      L: int32[n] labels; ``n`` padded to the 128 lane multiple must stay
        within one VMEM tile (the ops-layer router enforces
        ``n_pad <= label_block``).
      src, dst: int32[m] edge endpoints in ``[0, n)``.
      chunk_edges: edges per grid step; VMEM per step is one
        ``(chunk, n_pad)`` one-hot buffer plus three tiles.
      interpret: Pallas interpreter mode (CPU validation); False on TPU.
      edge_limit: optional traced int32 frontier bound — edges past it are
        masked to ``(0, 0)`` self-loops (min-mapping no-ops, the
        structs.Graph padding trick) and chunks wholly past it skip their
        grid step outright.
    """
    n = L.shape[0]
    m = src.shape[0]
    E = int(chunk_edges)
    n_pad = max(128, _round_up(n, 128))
    L_pad = jnp.pad(L, (0, n_pad - n), constant_values=_SENTINEL)

    if edge_limit is not None:
        mask = jnp.arange(m, dtype=jnp.int32) < edge_limit
        src = jnp.where(mask, src, 0)
        dst = jnp.where(mask, dst, 0)
    T = max(E, _round_up(m, E))
    # (0, 0) self-loop padding: relabels to L[0] and scatters z = L²[0]
    # onto vertex 0 — a no-op under the L[v] <= v labelling invariant
    src_p = jnp.zeros((T,), src.dtype).at[:m].set(src)
    dst_p = jnp.zeros((T,), dst.dtype).at[:m].set(dst)
    n_chunks = T // E
    if edge_limit is None:
        live = jnp.full((1,), n_chunks, jnp.int32)
    else:
        lim = jnp.minimum(jnp.asarray(edge_limit, jnp.int32), m)
        live = ((lim + E - 1) // E).reshape((1,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((E,), lambda c, lv: (c,)),
            pl.BlockSpec((E,), lambda c, lv: (c,)),
            pl.BlockSpec((n_pad,), lambda c, lv: (0,)),
            pl.BlockSpec((n_pad,), lambda c, lv: (0,)),
        ],
        out_specs=pl.BlockSpec((n_pad,), lambda c, lv: (0,)),
    )
    # the accumulator operand is aliased to the output; + 0 keeps it a
    # distinct buffer from the gather operand, whose tile must hold the
    # *input* labels for every grid step (synchronous sweep semantics)
    out = pl.pallas_call(
        _fused_relax_kernel(n_pad, E),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad,), L.dtype),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(live, src_p, dst_p, L_pad, L_pad + 0)
    return out[:n]
