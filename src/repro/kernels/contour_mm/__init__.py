from repro.kernels.contour_mm.blocked import binned_scatter_min_pallas
from repro.kernels.contour_mm.ops import (
    BACKENDS,
    KernelPlan,
    contour_cc_fixpoint,
    contour_mm_step,
    mm_relax_backend,
    mm_update_stream,
    plan_contour_kernel,
)
from repro.kernels.contour_mm.ref import mm_block_ref, mm_sync_ref

__all__ = [
    "BACKENDS",
    "KernelPlan",
    "binned_scatter_min_pallas",
    "contour_cc_fixpoint",
    "contour_mm_step",
    "mm_block_ref",
    "mm_relax_backend",
    "mm_sync_ref",
    "mm_update_stream",
    "plan_contour_kernel",
]
