from repro.kernels.contour_mm.ops import contour_mm_step, contour_cc_fixpoint
from repro.kernels.contour_mm.ref import mm_block_ref

__all__ = ["contour_mm_step", "contour_cc_fixpoint", "mm_block_ref"]
