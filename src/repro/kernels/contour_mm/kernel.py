"""Pallas TPU kernel: fused 2-order minimum-mapping edge relaxation.

This is the per-core hot loop of the Contour algorithm (paper Alg. 1 line
6-8 plus the §III-B async-update optimisation).  One ``pallas_call``
processes the whole edge shard: the grid walks edge blocks sequentially
(TPU grid order is sequential per core) while the label array ``L`` stays
resident in VMEM across grid steps via a constant-index output BlockSpec
with input/output aliasing — i.e. labels are updated **in place**, so later
edges observe labels already lowered by earlier edges *within the same
sweep*.  That is precisely the paper's asynchronous-update semantics,
realised deterministically (fixed edge order) instead of racily.

TPU adaptation notes (DESIGN.md §3):
  * the conditional CAS assignment (paper Eq. 4) becomes a scalar
    read-min-write on a VMEM ref — no atomics exist or are needed because
    the per-core loop is sequential on the scalar unit;
  * VMEM budget: ``L`` occupies ``4·n`` bytes and the edge block ``8·BE``
    bytes.  With 16 MiB VMEM this kernel handles shards up to n ≈ 3M
    vertices directly; larger graphs use the label-blocked vectorized
    kernel in ``blocked.py`` (updates radix-binned by ``L``-block, ``L``
    tiled via BlockSpec — DESIGN.md §3.4) or the XLA scatter-min path.
    Backend selection lives in ``ops.plan_contour_kernel``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm2_kernel(src_ref, dst_ref, l_in_ref, l_ref):
    """Sequential 2-order MM over one edge block; L aliased in/out."""
    del l_in_ref  # aliased with l_ref; reads/writes go through l_ref
    block_edges = src_ref.shape[0]

    def body(e, carry):
        w = src_ref[e]
        v = dst_ref[e]
        lw = l_ref[w]
        lv = l_ref[v]
        z = jnp.minimum(l_ref[lw], l_ref[lv])  # z² = min(L²[w], L²[v])
        # conditional vector assignment (Definition 2/3): lower the four
        # mapped positions {w, v, L[w], L[v]} to z if greater.
        l_ref[w] = jnp.minimum(l_ref[w], z)
        l_ref[v] = jnp.minimum(l_ref[v], z)
        l_ref[lw] = jnp.minimum(l_ref[lw], z)
        l_ref[lv] = jnp.minimum(l_ref[lv], z)
        return carry

    jax.lax.fori_loop(0, block_edges, body, 0)


def mm2_pallas(src: jax.Array, dst: jax.Array, L: jax.Array,
               *, block_edges: int = 512, interpret: bool = True) -> jax.Array:
    """One full asynchronous 2-order sweep over all edges; returns new L.

    Args:
      src, dst: int32[m] edge endpoints; m must be a multiple of
        ``block_edges`` (pad with self-loops, which are MM no-ops).
      L: int32[n] current labels.
      interpret: run the kernel body in interpret mode (CPU validation);
        pass False on real TPU hardware.
    """
    m = src.shape[0]
    if m % block_edges != 0:
        raise ValueError(f"m={m} must be a multiple of block_edges={block_edges}")
    n = L.shape[0]
    grid = (m // block_edges,)
    return pl.pallas_call(
        _mm2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_edges,), lambda i: (i,)),
            pl.BlockSpec((block_edges,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),  # whole L, resident in VMEM
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), L.dtype),
        input_output_aliases={2: 0},  # L updated in place across grid steps
        interpret=interpret,
    )(src, dst, L)
