"""Pure-jnp oracle for the flash_attention kernel: exact GQA softmax."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
            causal: bool = True) -> jax.Array:
    """q: (B, H, T, hd); k/v: (B, Hkv, S, hd). fp32 softmax, exact."""
    b, h, t, hd = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, t, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bkgqh,bksh->bkgqs", qg, kf) / jnp.sqrt(hd)
    if causal:
        mask = jnp.arange(s)[None, :] <= jnp.arange(t)[:, None]
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", w, vf)
    return out.reshape(b, h, t, hd).astype(q.dtype)
