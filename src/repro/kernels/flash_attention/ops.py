"""Jit'd wrapper for flash attention with backend selection + padding.

``backend="pallas"`` runs the fused VMEM kernel (interpret mode on CPU,
compiled on TPU); ``backend="xla"`` is the chunked streaming-softmax
expressed at the XLA level (`repro.models.attention.attend_chunked`) —
the path the CPU dry-run compiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_mha
from repro.kernels.flash_attention.ref import mha_ref


def _pad_seq(x, block: int, axis: int):
    t = x.shape[axis]
    target = (t + block - 1) // block * block
    if target == t:
        return x, 0
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - t)
    return jnp.pad(x, pad), target - t


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "backend", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    backend: str = "pallas", interpret: bool = True):
    """q: (B, H, T, hd); k/v: (B, Hkv, S, hd). Returns (B, H, T, hd).

    Handles non-divisible sequence lengths by padding K/V with masked
    positions (causal mask keeps padded keys dead; padded queries are
    sliced off).
    """
    if backend == "xla":
        return mha_ref(q, k, v, causal=causal)
    t = q.shape[2]
    q_p, _ = _pad_seq(q, block_q, 2)
    k_p, pad_k = _pad_seq(k, block_k, 2)
    v_p, _ = _pad_seq(v, block_k, 2)
    if pad_k and not causal:
        raise ValueError("non-causal flash requires S % block_k == 0")
    out = flash_mha(q_p, k_p, v_p, causal=causal,
                    block_q=block_q, block_k=block_k, interpret=interpret)
    return out[:, :, :t, :]
