"""Pallas TPU kernel: GQA flash attention (streaming softmax).

Grid = (B*H, nq, nk) with the KV axis innermost ("arbitrary" semantics):
each (batch*head, q-block) owns fp32 VMEM scratch accumulators (running
max m, normaliser l, output acc) that persist across the nk steps — the
FlashAttention recurrence on the MXU, with HBM traffic O(T*hd) per head
instead of O(T^2).

GQA is handled in the k/v BlockSpec index map: query head h reads KV head
h // (H/Hkv), so K/V are never repeated in memory (the xlstm/yi/nemo
configs would pay 4-8x HBM without this).

VMEM budget per grid step: q block (bq x hd) + k/v blocks (bk x hd) in the
input dtype + 3 fp32 scratch blocks (bq x hd, bq x 1 x 2) — e.g.
bq=bk=512, hd=128, bf16: ~0.72 MB, far under the ~16 MB/core budget, so
block sizes are free to grow toward MXU efficiency (multiples of 128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, bq: int, bk: int,
                  nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    iq = pl.program_id(1)
    q_start = iq * bq
    k_start = ik * bk

    # Causal: whole block is masked out when its first k is past the last q.
    run = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, block_q: int = 128, block_k: int = 128,
              interpret: bool = True) -> jax.Array:
    """q: (B, H, T, hd); k/v: (B, Hkv, S, hd) with Hkv | H. -> (B, H, T, hd).

    T % block_q == 0 and S % block_k == 0 (ops.py pads).
    """
    b, h, t, hd = q.shape
    _, hkv, s, _ = k.shape
    group = h // hkv
    scale = 1.0 / math.sqrt(hd)
    nq, nk = t // block_q, s // block_k

    qf = q.reshape(b * h, t, hd)
    kf = k.reshape(b * hkv, s, hd)
    vf = v.reshape(b * hkv, s, hd)

    def kv_index(bh, iq, ik):
        return (bh // h * hkv + (bh % h) // group, ik, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=block_q, bk=block_k, nk=nk),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, hd), q.dtype),
        scratch_shapes=[
            pltpu_vmem((block_q, 1), jnp.float32),   # running max m
            pltpu_vmem((block_q, 1), jnp.float32),   # normaliser l
            pltpu_vmem((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, t, hd)


def pltpu_vmem(shape, dtype):
    """VMEM scratch allocator (TPU memory space; interpret-mode emulated)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
