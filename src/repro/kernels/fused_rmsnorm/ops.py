"""Jit'd wrapper for fused RMSNorm: reshapes, padding, backend select."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused_rmsnorm.kernel import rmsnorm_rows
from repro.kernels.fused_rmsnorm.ref import rmsnorm_ref


@functools.partial(
    jax.jit, static_argnames=("eps", "backend", "interpret"))
def fused_rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
                  backend: str = "pallas", interpret: bool = True):
    """x: (..., d); w: (d,). RMS-normalise the trailing dim."""
    if backend == "xla":
        return rmsnorm_ref(x, w, eps)
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    # block size: keep the VMEM tile under ~4MB
    block = max(8, min(256, (4 << 20) // max(d * x.dtype.itemsize, 1)))
    target = (rows + block - 1) // block * block
    if target != rows:
        xf = jnp.concatenate(
            [xf, jnp.ones((target - rows, d), x.dtype)], axis=0)
    y = rmsnorm_rows(xf, w, block_rows=block, eps=eps, interpret=interpret)
    return y[:rows].reshape(shape)
