"""Pallas TPU kernel: fused RMSNorm (mean-square + rsqrt + scale, one pass).

Unfused, RMSNorm reads x twice (once for the reduction, once for the
normalisation) and round-trips an fp32 intermediate through HBM; at
d_model 7168 x 1M tokens that's multiple GB per layer.  The kernel tiles
rows into VMEM blocks, does the reduction and the scaled write in one
visit: HBM traffic = read x + write y + read scale, the streaming minimum.

Grid walks row blocks; each block (block_rows x d) lives in VMEM
(block_rows=256, d=8192, bf16 -> 4 MB, within budget; ops.py shrinks the
block for wider models).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)             # (block_rows, d)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm_rows(x: jax.Array, w: jax.Array, *, block_rows: int = 256,
                 eps: float = 1e-5, interpret: bool = True) -> jax.Array:
    """x: (R, d) with R % block_rows == 0; w: (d,). Returns (R, d)."""
    r, d = x.shape
    grid = (r // block_rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, w)
