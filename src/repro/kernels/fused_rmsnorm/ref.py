"""Pure-jnp oracle for the fused_rmsnorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)
