from repro.kernels.fused_rmsnorm.ops import fused_rmsnorm

__all__ = ["fused_rmsnorm"]
