"""Linear-recurrence blocks: Mamba2 (SSD), xLSTM's mLSTM and sLSTM.

One chunked gated-linear-attention core (``gla_chunked``) serves both SSD
and mLSTM — Mamba-2's SSD *is* scalar-decay GLA with ``q=C, k=B, v=Δ·x,
log_f=Δ·A`` (Dao & Gu 2024), and the mLSTM matrix memory is GLA plus a
normaliser row.  The chunked form is the TPU-native adaptation: intra-chunk
work is dense matmuls on the MXU, inter-chunk state is a short scan —
instead of a length-T serial recurrence.

sLSTM has a true hidden-to-gate recurrence (block-diagonal per head) and
admits no parallel form (xLSTM paper §2.3); it is computed with a
``lax.scan`` over time.

Every block exposes a decode path carrying O(1)-per-token state — this is
what makes the ``long_500k`` shape runnable for xlstm/zamba2 (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig, ParamSpec


# ---------------------------------------------------------------------------
# Chunked gated linear attention (shared by SSD and mLSTM)
# ---------------------------------------------------------------------------

def gla_chunked(q, k, v, log_f, *, chunk: int = 128, s0=None):
    """Chunkwise-parallel scalar-gated linear attention.

    q, k: (B, T, H, N); v: (B, T, H, P); log_f: (B, T, H) (<= 0).
    Returns (out (B,T,H,P), final_state (B,H,N,P)).
    Requires T % chunk == 0.
    """
    import math as _math

    b, t, h, n = q.shape
    p = v.shape[-1]
    chunk = min(chunk, t)
    if t % chunk:
        chunk = _math.gcd(t, chunk)
    nc = t // chunk
    f32 = jnp.float32

    qc = q.reshape(b, nc, chunk, h, n)
    kc = k.reshape(b, nc, chunk, h, n)
    vc = v.reshape(b, nc, chunk, h, p)
    fc = log_f.reshape(b, nc, chunk, h).astype(f32)
    cum = jnp.cumsum(fc, axis=2)                     # (b,nc,c,h)
    total = cum[:, :, -1]                            # (b,nc,h)

    if s0 is None:
        s0 = jnp.zeros((b, h, n, p), f32)

    def chunk_step(S, blk):
        qj, kj, vj, cumj, totj = blk                  # (b,c,h,n) ...
        # inter-chunk: q decayed from chunk start attends to carried state
        q_scaled = qj.astype(f32) * jnp.exp(cumj)[..., None]
        inter = jnp.einsum("bchn,bhnp->bchp", q_scaled, S)
        # intra-chunk: masked decayed attention.  The mask is applied to
        # the *exponent*: future (upper-triangle) entries have positive
        # deltas (cum is decreasing), whose exp overflows and then NaNs
        # the backward pass through an inf*0 product if masked only after
        # exponentiation.
        scores = jnp.einsum("bchn,bshn->bhcs", qj.astype(f32), kj.astype(f32))
        ct = cumj.transpose(0, 2, 1)                   # (b,h,c)
        delta = ct[:, :, :, None] - ct[:, :, None, :]  # (b,h,c,s)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        delta = jnp.where(mask[None, None], delta, -1e30)
        a = scores * jnp.exp(delta)
        intra = jnp.einsum("bhcs,bshp->bchp", a, vj.astype(f32))
        # state update: decay old state to chunk end, add decayed kv outer
        k_dec = kj.astype(f32) * jnp.exp(totj[:, None, :] - cumj)[..., None]
        S_new = jnp.exp(totj)[:, :, None, None] * S + jnp.einsum(
            "bshn,bshp->bhnp", k_dec, vj.astype(f32)
        )
        return S_new, inter + intra

    blks = (
        jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
        jnp.moveaxis(cum, 1, 0), jnp.moveaxis(total, 1, 0),
    )
    S, outs = jax.lax.scan(chunk_step, s0, blks)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, t, h, p)
    return out.astype(v.dtype), S


def gla_decode(q, k, v, log_f, state):
    """Single-token GLA step. q/k: (B,H,N); v: (B,H,P); log_f: (B,H)."""
    f32 = jnp.float32
    f = jnp.exp(log_f.astype(f32))[:, :, None, None]
    state = f * state + jnp.einsum("bhn,bhp->bhnp", k.astype(f32), v.astype(f32))
    out = jnp.einsum("bhn,bhnp->bhp", q.astype(f32), state)
    return out.astype(v.dtype), state


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (Mamba/xLSTM stem)
# ---------------------------------------------------------------------------

def conv1d_causal(x, w, b=None, state=None):
    """x: (B,T,C); w: (W,C) depthwise. state: (B,W-1,C) carried for decode.

    Returns (y (B,T,C), new_state (B,W-1,C)).
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)           # (B, T+W-1, C)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    if b is not None:
        y = y + b[None, None, :]
    new_state = xp[:, -(width - 1):, :] if width > 1 else state
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------

class SSMState(NamedTuple):
    conv: jax.Array    # (B, W-1, conv_channels)
    ssd: jax.Array     # (B, H, N, P) fp32


def mamba2_dims(config: ModelConfig):
    d_in = config.ssm_expand * config.d_model
    n = config.ssm_state
    p = 64                                   # head dim (Mamba-2 default)
    h = d_in // p
    return d_in, n, p, h


def mamba2_specs(config: ModelConfig) -> Dict[str, ParamSpec]:
    d = config.d_model
    d_in, n, p, h = mamba2_dims(config)
    conv_ch = d_in + 2 * n
    return {
        "w_in": ParamSpec((d, 2 * d_in + 2 * n + h), ("embed", "ffn"),
                          scale=d ** -0.5),
        "conv_w": ParamSpec((config.ssm_conv, conv_ch), (None, "conv"), scale=0.5),
        "conv_b": ParamSpec((conv_ch,), ("conv",), "zeros"),
        "a_log": ParamSpec((h,), (None,), "zeros"),
        "dt_bias": ParamSpec((h,), (None,), "zeros"),
        "d_skip": ParamSpec((h,), (None,), "ones"),
        "norm_scale": ParamSpec((d_in,), ("ffn",), "ones"),
        "w_out": ParamSpec((d_in, d), ("ffn", "embed"), scale=d_in ** -0.5),
    }


def _mamba2_project(params, x, config: ModelConfig):
    d_in, n, p, h = mamba2_dims(config)
    proj = x @ params["w_in"].astype(x.dtype)
    z, xbc, dt_raw = jnp.split(proj, [d_in, 2 * d_in + 2 * n], axis=-1)
    return z, xbc, dt_raw, (d_in, n, p, h)


def _mamba2_core(params, xbc_conv, dt_raw, dims, config, *, chunk, s0):
    d_in, n, p, h = dims
    bsz, t = xbc_conv.shape[:2]
    xv, bmat, cmat = jnp.split(xbc_conv, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )                                                  # (B,T,H)
    log_f = -dt * jnp.exp(params["a_log"].astype(jnp.float32))
    v = xv.reshape(bsz, t, h, p) * dt[..., None].astype(xv.dtype)
    q = jnp.broadcast_to(cmat[:, :, None, :], (bsz, t, h, n))
    k = jnp.broadcast_to(bmat[:, :, None, :], (bsz, t, h, n))
    out, S = gla_chunked(q, k, v, log_f, chunk=chunk, s0=s0)
    out = out + xv.reshape(bsz, t, h, p) * params["d_skip"].astype(xv.dtype)[None, None, :, None]
    return out.reshape(bsz, t, d_in), S


def mamba2_apply(params, x, config: ModelConfig, *, chunk: int = 128,
                 state: Optional[SSMState] = None, return_state: bool = False):
    """Training / prefill path. x: (B,T,d)."""
    z, xbc, dt_raw, dims = _mamba2_project(params, x, config)
    conv_state = state.conv if state is not None else None
    xbc_c, conv_state = conv1d_causal(
        xbc, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype),
        conv_state,
    )
    xbc_c = jax.nn.silu(xbc_c)
    s0 = state.ssd if state is not None else None
    out, S = _mamba2_core(params, xbc_c, dt_raw, dims, config, chunk=chunk, s0=s0)
    # gated RMS norm then down-projection
    out = out * jax.lax.rsqrt(
        jnp.mean(jnp.square(out.astype(jnp.float32)), -1, keepdims=True) + 1e-5
    ).astype(out.dtype)
    out = out * params["norm_scale"].astype(out.dtype) * jax.nn.silu(z)
    y = out @ params["w_out"].astype(x.dtype)
    if return_state:
        return y, SSMState(conv=conv_state, ssd=S)
    return y


def mamba2_decode(params, x, config: ModelConfig, state: SSMState):
    """x: (B,1,d); O(1) state update."""
    y, new_state = mamba2_apply(
        params, x, config, chunk=1, state=state, return_state=True
    )
    return y, new_state


def mamba2_init_state(batch: int, config: ModelConfig, dtype) -> SSMState:
    d_in, n, p, h = mamba2_dims(config)
    return SSMState(
        conv=jnp.zeros((batch, config.ssm_conv - 1, d_in + 2 * n), dtype),
        ssd=jnp.zeros((batch, h, n, p), jnp.float32),
    )


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------

def mlstm_dims(config: ModelConfig):
    d_in = 2 * config.d_model            # proj factor 2 (xLSTM paper)
    h = config.n_heads
    p = d_in // h
    return d_in, h, p


def mlstm_specs(config: ModelConfig) -> Dict[str, ParamSpec]:
    d = config.d_model
    d_in, h, p = mlstm_dims(config)
    return {
        "w_up": ParamSpec((d, 2 * d_in), ("embed", "ffn"),
                          scale=d ** -0.5),   # x_in, z
        "conv_w": ParamSpec((config.ssm_conv, d_in), (None, "conv"), scale=0.5),
        "conv_b": ParamSpec((d_in,), ("conv",), "zeros"),
        "w_q": ParamSpec((d_in, d_in), ("ffn", None), scale=d_in ** -0.5),
        "w_k": ParamSpec((d_in, d_in), ("ffn", None), scale=d_in ** -0.5),
        "w_v": ParamSpec((d_in, d_in), ("ffn", None), scale=d_in ** -0.5),
        "w_if": ParamSpec((d_in, 2 * h), ("ffn", None), scale=0.02),
        "b_if": ParamSpec((2 * h,), (None,), "zeros"),
        "norm_scale": ParamSpec((d_in,), ("ffn",), "ones"),
        "w_down": ParamSpec((d_in, d), ("ffn", "embed"), scale=d_in ** -0.5),
    }


def _mlstm_qkv(params, x, config: ModelConfig, conv_state):
    d_in, h, p = mlstm_dims(config)
    up = x @ params["w_up"].astype(x.dtype)
    x_in, z = jnp.split(up, 2, axis=-1)
    x_c, conv_state = conv1d_causal(
        x_in, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype),
        conv_state,
    )
    x_c = jax.nn.silu(x_c)
    bsz, t = x.shape[:2]
    q = (x_c @ params["w_q"].astype(x.dtype)).reshape(bsz, t, h, p) * (p ** -0.5)
    k = (x_c @ params["w_k"].astype(x.dtype)).reshape(bsz, t, h, p)
    v = (x_in @ params["w_v"].astype(x.dtype)).reshape(bsz, t, h, p)
    gates = x_c @ params["w_if"].astype(x.dtype) + params["b_if"].astype(x.dtype)
    i_raw, f_raw = jnp.split(gates.astype(jnp.float32), 2, axis=-1)  # (B,T,H)
    # log-sigmoid forget gate; sigmoid input gate folded into k (bounded
    # stand-in for xLSTM's exponential gating — see module docstring).
    log_f = jax.nn.log_sigmoid(f_raw)
    k = k * jax.nn.sigmoid(i_raw)[..., None].astype(k.dtype)
    return q, k, v, log_f, z, conv_state, (d_in, h, p)


def _mlstm_finish(params, out, norm_w, z, x, d_in):
    # per-head RMS norm, gate by silu(z), down-project
    out = out * jax.lax.rsqrt(
        jnp.mean(jnp.square(out.astype(jnp.float32)), -1, keepdims=True) + 1e-5
    ).astype(out.dtype)
    bsz, t = out.shape[:2]
    out = out.reshape(bsz, t, d_in) * norm_w
    out = out * jax.nn.silu(z)
    return out @ params["w_down"].astype(x.dtype)


def mlstm_apply(params, x, config: ModelConfig, *, chunk: int = 128,
                state: Optional[SSMState] = None, return_state: bool = False):
    conv_state = state.conv if state is not None else None
    q, k, v, log_f, z, conv_state, (d_in, h, p) = _mlstm_qkv(
        params, x, config, conv_state
    )
    # normaliser: append a ones column to v, divide at the end (mLSTM n_t)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    s0 = state.ssd if state is not None else None
    out_aug, S = gla_chunked(q, k, v_aug, log_f, chunk=chunk, s0=s0)
    num, den = out_aug[..., :p], out_aug[..., p:]
    out = num / jnp.maximum(jnp.abs(den), 1.0)
    y = _mlstm_finish(params, out, params["norm_scale"].astype(x.dtype), z, x, d_in)
    if return_state:
        return y, SSMState(conv=conv_state, ssd=S)
    return y


def mlstm_decode(params, x, config: ModelConfig, state: SSMState):
    return mlstm_apply(params, x, config, chunk=1, state=state, return_state=True)


def mlstm_init_state(batch: int, config: ModelConfig, dtype) -> SSMState:
    d_in, h, p = mlstm_dims(config)
    return SSMState(
        conv=jnp.zeros((batch, config.ssm_conv - 1, d_in), dtype),
        ssd=jnp.zeros((batch, h, p, p + 1), jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — true recurrence, lax.scan over time
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    h: jax.Array   # (B,H,hd) fp32
    c: jax.Array
    n: jax.Array
    m: jax.Array   # stabiliser


def slstm_dims(config: ModelConfig):
    h = config.n_heads
    hd = config.d_model // h
    return h, hd


def slstm_specs(config: ModelConfig) -> Dict[str, ParamSpec]:
    d = config.d_model
    h, hd = slstm_dims(config)
    return {
        "w_gates": ParamSpec((d, 4, h, hd), ("embed", None, "heads", None), scale=0.02),
        "r_gates": ParamSpec((4, h, hd, hd), (None, "heads", None, None), scale=0.02),
        "b_gates": ParamSpec((4, h, hd), (None, "heads", None), "zeros"),
        "norm_scale": ParamSpec((d,), ("embed",), "ones"),
        "w_down": ParamSpec((d, d), ("embed", "embed"), scale=d ** -0.5),
    }


def _slstm_cell(params, wx_t, state: SLSTMState):
    """wx_t: (B,4,H,hd) precomputed input projections for one step."""
    f32 = jnp.float32
    rh = jnp.einsum("bhd,ghde->bghe", state.h, params["r_gates"].astype(f32))
    g = wx_t.astype(f32) + rh + params["b_gates"].astype(f32)[None]
    z_t = jnp.tanh(g[:, 0])
    i_t = g[:, 1]
    f_t = g[:, 2]
    o_t = jax.nn.sigmoid(g[:, 3])
    m_new = jnp.maximum(f_t + state.m, i_t)            # stabiliser
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + state.m - m_new)
    c_new = f_p * state.c + i_p * z_t
    n_new = f_p * state.n + i_p
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(h=h_new, c=c_new, n=n_new, m=m_new)


def slstm_apply(params, x, config: ModelConfig, *,
                state: Optional[SLSTMState] = None, return_state: bool = False):
    bsz, t, d = x.shape
    h, hd = slstm_dims(config)
    if state is None:
        z = jnp.zeros((bsz, h, hd), jnp.float32)
        state = SLSTMState(h=z, c=z, n=z, m=jnp.full_like(z, -1e30))
    wx = jnp.einsum("btd,dghe->btghe", x, params["w_gates"].astype(x.dtype))

    def step(s, wx_t):
        s_new = _slstm_cell(params, wx_t, s)
        return s_new, s_new.h

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    out = jnp.moveaxis(hs, 0, 1).reshape(bsz, t, d).astype(x.dtype)
    out = out * params["norm_scale"].astype(x.dtype)
    y = out @ params["w_down"].astype(x.dtype)
    if return_state:
        return y, state
    return y


def slstm_decode(params, x, config: ModelConfig, state: SLSTMState):
    return slstm_apply(params, x, config, state=state, return_state=True)


def slstm_init_state(batch: int, config: ModelConfig) -> SLSTMState:
    h, hd = slstm_dims(config)
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return SLSTMState(h=z, c=z, n=z, m=jnp.full_like(z, -1e30))
