"""Shared model machinery: configs, sharding rules, norms, RoPE, init.

Parameters are plain nested dicts of ``jax.Array``.  Every parameter leaf
has a parallel *logical-axes* annotation (a tuple of logical axis names,
one per dim) produced by the same constructor code path, so abstract
(``jax.eval_shape``) and concrete initialisation can never diverge.
Logical axes map to mesh axes through per-config rules (MaxText-style),
with divisibility-aware fallback to replication (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

# Sharding profiles: logical axis -> candidate mesh axes (applied left to
# right, each used at most once per array, only if it divides the dim).
#
#   tp      — Megatron tensor parallelism: batch over (pod, data); heads /
#             ffn / vocab / experts over model; weights otherwise replicated.
#             Right for models whose per-layer residual carries fit HBM.
#   tp_sp   — tp + sequence-parallel residual stream (seq -> model).  The
#             remat-saved per-layer residual shrinks by the model-axis size;
#             GSPMD inserts the Megatron-SP all-gather / reduce-scatter pair
#             around each block.  Needed for mid-size dense models (yi-6b,
#             mistral-nemo-12b) whose 4k x 16-row residual carries blow HBM.
#   fsdp    — flat batch over (pod, data, model); every weight is *storage*
#             sharded (embed->data, ffn/heads->model) and gathered per layer.
#             Right for big dense models (llava-34b) and for hybrids whose
#             recurrent scan cannot be sequence-sharded (zamba2).
#   ep      — MoE expert parallelism: experts->model, expert FFN inner dim
#             storage-sharded over data, grouped local dispatch (see
#             repro.models.mlp), attention as tp + embed->data storage.
#   ep_fsdp — ep + flat batch for activation relief (arctic-480b).
def _profile(batch, *, seq=(), embed=(), expert_inner=()):
    return {
        "batch": batch,
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "ffn": ("model",),
        "experts": ("model",),
        "expert_inner": expert_inner,
        "embed": embed,
        "seq": seq,
        "kv_seq": (),            # overridden when shard_cache_seq is set
        "moe_group": ("pod", "data"),
        "conv": ("model",),
        "state": (),
        "qkv": (),
    }


PROFILES: Dict[str, Dict[str, Tuple[str, ...]]] = {
    "tp": _profile(("pod", "data")),
    "tp_sp": _profile(("pod", "data"), seq=("model",)),
    # ep: expert weights are STATIONARY on their model rank (tokens move via
    # the dispatch all-to-all, weights never do) — per-device expert memory
    # = total_moe/model_size, so it requires the per-rank slice to fit HBM
    # (deepseek-16b: yes, with bf16 params+moments).
    "fsdp": _profile(("pod", "data", "model"), embed=("data",),
                     expert_inner=("data",)),
    "ep": _profile(("pod", "data"), embed=("data",), expert_inner=()),
    # ep_fsdp: expert inner dim additionally storage-sharded over data and
    # FSDP-gathered per layer.  Pays enormous weight-AG traffic; it is the
    # only way 480B of expert weights fit a 256 x 16 GB pod at all (see
    # EXPERIMENTS.md §Roofline for the honest accounting).
    "ep_fsdp": _profile(("pod", "data", "model"), embed=("data",),
                        expert_inner=("data",)),
}

DEFAULT_RULES = PROFILES["tp"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    # block flavour
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm | nonparametric
    act: str = "silu"
    mlp_gated: bool = True           # SwiGLU-style (gate ⊙ up) if True
    rotary_pct: float = 1.0
    rope_theta: float = 10_000.0
    use_qkv_bias: bool = False
    tie_embeddings: bool = False
    # MoE
    moe_style: Optional[str] = None  # None | deepseek | arctic
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0
    first_k_dense: int = 0
    dense_d_ff: int = 0              # dense-layer/residual-FFN width
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_every: int = 0              # zamba2: shared attn block period
    slstm_every: int = 0             # xlstm: sLSTM block period (rest mLSTM)
    # enc-dec
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # modality frontend stub
    frontend: str = "none"           # none | patch_stub | audio_stub
    n_frontend_tokens: int = 0       # e.g. image patches prepended
    # numerics / memory
    param_dtype: Any = jnp.float32
    dtype: Any = jnp.bfloat16
    remat: str = "full"              # none | dots | full
    vocab_pad_multiple: int = 256
    max_seq_len: int = 131_072
    # distribution (see PROFILES above)
    sharding_profile: str = "tp"     # training profile
    serve_profile: str = "tp"        # serving profile (no optimizer state)
    shard_cache_seq: bool = False    # shard KV-cache seq dim over model axis
                                     # (for archs whose kv_heads don't divide it)
    repeat_kv_math: bool = False     # repeat K/V to full heads in train/
                                     # prefill attention (TP-sharding-friendly
                                     # when kv_heads don't divide the axis)
    moe_groups: int = 1              # local-dispatch groups (= data shards)
    # attention impl
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    flash_block_threshold: int = 4096  # use chunked attn when seq >= this
                                   # (4k train would otherwise materialise
                                   #  (heads,4096,4096) fp32 score slabs)
    # which schedule shapes are valid (assignment skip rules)
    supports_decode: bool = True
    supports_long_context: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def seq_parallel(self) -> bool:
        return self.sharding_profile == "tp_sp"

    def for_serving(self) -> "ModelConfig":
        """Serving view: bf16 params, no remat, serve sharding profile."""
        return self.replace(
            sharding_profile=self.serve_profile,
            param_dtype=jnp.bfloat16,
            remat="none",
        )

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Param construction: shapes + logical axes + init, in one spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "normal"            # normal | zeros | ones | scaled
    scale: float = 1.0


def make_dense_spec(d_in: int, d_out: int, axes, scale=None) -> ParamSpec:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return ParamSpec((d_in, d_out), axes, "normal", scale)


def init_param(key, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(dtype)


def init_tree(key, specs, dtype):
    """Initialise a pytree of ParamSpec into arrays (split keys by path)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    arrs = [init_param(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract_tree(specs, dtype):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def logical_axes_tree(specs):
    return jax.tree_util.tree_map(
        lambda s: s.logical_axes, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


# ---------------------------------------------------------------------------
# Logical-axis -> mesh resolution
# ---------------------------------------------------------------------------

def resolve_spec(
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Dict[str, Tuple[str, ...]],
) -> P:
    """Map logical axes to a PartitionSpec, respecting divisibility."""
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    used: set = set()
    out = []
    for dim, lname in zip(shape, logical_axes):
        assigned = []
        if lname is not None:
            for ax in rules.get(lname, ()):  # candidates in priority order
                if ax in used or ax not in mesh.shape:
                    continue
                size = mesh.shape[ax]
                prod = int(np.prod([mesh.shape[a] for a in assigned])) if assigned else 1
                if dim % (prod * size) == 0:
                    assigned.append(ax)
                    used.add(ax)
        if not assigned:
            out.append(None)
        elif len(assigned) == 1:
            out.append(assigned[0])
        else:
            out.append(tuple(assigned))
    # trim trailing Nones for tidier specs
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def make_rules(config: ModelConfig, mesh: Mesh) -> Dict[str, Tuple[str, ...]]:
    rules = dict(PROFILES[config.sharding_profile])
    if config.shard_cache_seq:
        # used-axis bookkeeping in resolve_spec guarantees kv_seq and
        # kv_heads never both take the model axis on one array
        rules["kv_seq"] = ("model",)
    return rules


def shardings_for(specs, config: ModelConfig, mesh: Mesh):
    """Pytree of NamedSharding for a ParamSpec pytree."""
    rules = make_rules(config, mesh)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, resolve_spec(s.shape, s.logical_axes, mesh, rules)),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def constrain(x, mesh: Mesh, config: ModelConfig, *logical_axes):
    """with_sharding_constraint by logical axis names (None = replicated)."""
    rules = make_rules(config, mesh)
    spec = resolve_spec(x.shape, logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Norms / activations / RoPE
# ---------------------------------------------------------------------------

def norm_params(config: ModelConfig, d: int) -> Dict[str, ParamSpec]:
    if config.norm_type == "nonparametric":
        return {}
    p = {"scale": ParamSpec((d,), ("embed",), "ones")}
    if config.norm_type == "layernorm":
        p["bias"] = ParamSpec((d,), ("embed",), "zeros")
    return p


def apply_norm(x, params, config: ModelConfig, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if config.norm_type == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
        x = x * params["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + eps)
        if config.norm_type == "layernorm":
            x = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
        # nonparametric (OLMo): no affine
    return x.astype(dt)


def activate(x, act: str):
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(act)


def rope_angles(positions, rot_dim: int, theta: float):
    """positions: int[...]; returns (cos, sin) with trailing dim rot_dim/2."""
    freqs = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., rot_dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, T, H, hd); cos/sin: (T, rot/2) or (B, T, rot/2)."""
    rot = cos.shape[-1] * 2
    assert rot <= x.shape[-1]
    if cos.ndim == 2:       # (T, r/2) -> (1, T, 1, r/2)
        c, s = cos[None, :, None, :], sin[None, :, None, :]
    else:                   # (B, T, r/2) -> (B, T, 1, r/2)
        c, s = cos[:, :, None, :], sin[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(xr, 2, axis=-1)
    out1 = x1.astype(jnp.float32) * c - x2.astype(jnp.float32) * s
    out2 = x2.astype(jnp.float32) * c + x1.astype(jnp.float32) * s
    return jnp.concatenate(
        [out1.astype(x.dtype), out2.astype(x.dtype), xp], axis=-1
    )
