"""Model facade: embeddings, modality frontends, LM head, loss, serving.

``build_model(config)`` returns an :class:`LM` (decoder-only; dense, MoE,
SSM, hybrid and VLM families) or :class:`Seq2Seq` (audio enc-dec family).
Both expose the same surface:

  * ``param_specs()``          — pytree of ParamSpec
  * ``init(rng)``              — concrete params
  * ``loss(params, batch)``    — scalar LM loss (+ MoE aux)
  * ``prefill(params, batch)`` — (last-position logits, cache)
  * ``decode_step(params, tokens, cache)`` — (logits, cache)

Batches are dicts of arrays; the modality frontends are stubs per the
assignment: ``patch_embeds`` / ``frame_embeds`` arrive pre-computed at
``d_model`` and pass through a learned projection.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import transformer as tfm
from repro.models.common import ModelConfig, ParamSpec


def _embed_specs(config: ModelConfig) -> Dict[str, ParamSpec]:
    d, vp = config.d_model, config.padded_vocab
    s = {"tok_embed": ParamSpec((vp, d), ("vocab", "embed"), scale=0.02)}
    if not config.tie_embeddings:
        s["lm_head"] = ParamSpec((d, vp), ("embed", "vocab"), scale=d ** -0.5)
    if config.frontend == "patch_stub":
        s["patch_proj"] = ParamSpec((d, d), ("embed", "embed"), scale=d ** -0.5)
    if config.frontend == "audio_stub":
        s["frame_proj"] = ParamSpec((d, d), ("embed", "embed"), scale=d ** -0.5)
    return s


def _logits(params, x, config: ModelConfig, mesh=None):
    if config.tie_embeddings:
        w = params["tok_embed"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    logits = x @ w
    if mesh is not None:
        logits = cm.constrain(logits, mesh, config, "batch", None, "vocab")
    # mask the vocab padding rows out of the softmax
    if config.padded_vocab != config.vocab_size:
        pad_mask = jnp.arange(config.padded_vocab) >= config.vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    return logits


def softmax_xent(logits, labels, valid_mask=None):
    """Vocab-sharding-friendly CE: one-hot reduction, no label gather."""
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    shifted = lf - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    label_logit = jnp.sum(shifted * onehot, axis=-1)
    nll = lse - label_logit
    if valid_mask is not None:
        nll = nll * valid_mask
        return nll.sum() / jnp.maximum(valid_mask.sum(), 1.0)
    return nll.mean()


class LM:
    """Decoder-only language model (dense / moe / ssm / hybrid / vlm)."""

    def __init__(self, config: ModelConfig, mesh=None):
        self.config = config
        self.mesh = mesh
        self.plan = tfm.layer_plan(config)

    # -- parameters -------------------------------------------------------
    def param_specs(self):
        return {
            "embed": _embed_specs(self.config),
            "backbone": tfm.backbone_specs(self.config, self.plan),
        }

    def init(self, rng) -> Any:
        return cm.init_tree(rng, self.param_specs(), self.config.param_dtype)

    # -- shared input processing ------------------------------------------
    def _embed_inputs(self, params, batch) -> jax.Array:
        config = self.config
        tokens = batch["tokens"]
        x = params["embed"]["tok_embed"].astype(config.dtype)[tokens]
        if config.frontend == "patch_stub" and "patch_embeds" in batch:
            p = batch["patch_embeds"].astype(config.dtype)
            p = p @ params["embed"]["patch_proj"].astype(config.dtype)
            n = p.shape[1]
            x = jnp.concatenate([p, x[:, n:, :]], axis=1)   # patches prepend
        if self.mesh is not None:
            x = cm.constrain(x, self.mesh, config, "batch", "seq", "embed")
        return x

    # -- training ----------------------------------------------------------
    def loss(self, params, batch) -> tuple[jax.Array, Dict[str, jax.Array]]:
        config = self.config
        x = self._embed_inputs(params, batch)
        ctx = tfm.BlockCtx(
            config=config, mesh=self.mesh, mode="train",
            positions=jnp.arange(x.shape[1]), max_cache_len=0,
        )
        x, _, aux = tfm.backbone_apply(params["backbone"], x, ctx, plan=self.plan)
        logits = _logits(params["embed"], x, config, self.mesh)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        ce = softmax_xent(logits, labels, mask)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    # -- serving -----------------------------------------------------------
    def prefill(self, params, batch, max_len: int = 0):
        """Build the cache; ``max_len`` reserves decode capacity beyond
        the prompt (defaults to prompt length - no decode room)."""
        config = self.config
        x = self._embed_inputs(params, batch)
        ctx = tfm.BlockCtx(
            config=config, mesh=self.mesh, mode="prefill",
            positions=jnp.arange(x.shape[1]),
            max_cache_len=max(max_len, x.shape[1]),
        )
        x, cache, _ = tfm.backbone_apply(params["backbone"], x, ctx, plan=self.plan)
        logits = _logits(params["embed"], x[:, -1:, :], config, self.mesh)
        return logits, cache

    def decode_step(self, params, tokens, cache):
        config = self.config
        x = params["embed"]["tok_embed"].astype(config.dtype)[tokens]
        if self.mesh is not None:
            x = cm.constrain(x, self.mesh, config, "batch", None, "embed")
        ctx = tfm.BlockCtx(
            config=config, mesh=self.mesh, mode="decode",
            positions=None, max_cache_len=0,
        )
        x, cache, _ = tfm.backbone_apply(
            params["backbone"], x, ctx, cache=cache, plan=self.plan
        )
        logits = _logits(params["embed"], x, config, self.mesh)
        return logits, cache

    def init_cache(self, batch: int, max_len: int):
        return tfm.init_cache(self.config, batch, max_len, plan=self.plan)


class Seq2Seq:
    """Encoder-decoder LM (seamless backbone): audio-stub encoder + decoder."""

    def __init__(self, config: ModelConfig, mesh=None):
        self.config = config
        self.mesh = mesh
        n_enc = config.n_enc_layers or config.n_layers
        n_dec = config.n_dec_layers or config.n_layers
        self.enc_plan = tfm.LayerPlan((), ("enc_attn_mlp",), n_enc, None)
        self.dec_plan = tfm.LayerPlan((), ("dec_block",), n_dec, None)

    def param_specs(self):
        return {
            "embed": _embed_specs(self.config),
            "encoder": tfm.backbone_specs(self.config, self.enc_plan),
            "decoder": tfm.backbone_specs(self.config, self.dec_plan),
        }

    def init(self, rng):
        return cm.init_tree(rng, self.param_specs(), self.config.param_dtype)

    def encode(self, params, batch) -> jax.Array:
        config = self.config
        frames = batch["frame_embeds"].astype(config.dtype)
        x = frames @ params["embed"]["frame_proj"].astype(config.dtype)
        if self.mesh is not None:
            x = cm.constrain(x, self.mesh, config, "batch", "seq", "embed")
        ctx = tfm.BlockCtx(
            config=config, mesh=self.mesh, mode="train",
            positions=jnp.arange(x.shape[1]), max_cache_len=0,
        )
        x, _, _ = tfm.backbone_apply(params["encoder"], x, ctx, plan=self.enc_plan)
        return x

    def _decode_embed(self, params, tokens):
        return params["embed"]["tok_embed"].astype(self.config.dtype)[tokens]

    def loss(self, params, batch):
        config = self.config
        enc_out = self.encode(params, batch)
        x = self._decode_embed(params, batch["tokens"])
        ctx = tfm.BlockCtx(
            config=config, mesh=self.mesh, mode="train",
            positions=jnp.arange(x.shape[1]), max_cache_len=0, enc_out=enc_out,
        )
        x, _, _ = tfm.backbone_apply(params["decoder"], x, ctx, plan=self.dec_plan)
        logits = _logits(params["embed"], x, config, self.mesh)
        ce = softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
        return ce, {"ce": ce, "aux": jnp.float32(0.0)}

    def prefill(self, params, batch, max_len: int = 0):
        config = self.config
        enc_out = self.encode(params, batch)
        x = self._decode_embed(params, batch["tokens"])
        ctx = tfm.BlockCtx(
            config=config, mesh=self.mesh, mode="prefill",
            positions=jnp.arange(x.shape[1]),
            max_cache_len=max(max_len, x.shape[1]),
            enc_out=enc_out,
        )
        x, cache, _ = tfm.backbone_apply(params["decoder"], x, ctx, plan=self.dec_plan)
        logits = _logits(params["embed"], x[:, -1:, :], config, self.mesh)
        return logits, cache

    def decode_step(self, params, tokens, cache):
        config = self.config
        x = self._decode_embed(params, tokens)
        ctx = tfm.BlockCtx(
            config=config, mesh=self.mesh, mode="decode",
            positions=None, max_cache_len=0,
        )
        x, cache, _ = tfm.backbone_apply(
            params["decoder"], x, ctx, cache=cache, plan=self.dec_plan
        )
        logits = _logits(params["embed"], x, config, self.mesh)
        return logits, cache

    def init_cache(self, batch: int, max_len: int, src_len: int = 0):
        return tfm.init_cache(self.config, batch, max_len, plan=self.dec_plan,
                              src_len=src_len or max_len)


def build_model(config: ModelConfig, mesh=None):
    if config.family == "audio":
        return Seq2Seq(config, mesh)
    return LM(config, mesh)
