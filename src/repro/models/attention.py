"""GQA attention with RoPE, chunked (flash-style) softmax, and KV cache.

Three execution paths:
  * ``attend_full``    — materialised scores; used for short sequences.
  * ``attend_chunked`` — streaming softmax over KV blocks (scan), never
    materialises the (T, T) score matrix; this is what keeps the 32k
    prefill dry-run inside HBM.  Same math as FlashAttention, expressed at
    the XLA level so it compiles on any backend; the Pallas kernel in
    ``repro.kernels.flash_attention`` is the TPU-fused version of the same
    loop (ops.py selects between them).
  * ``attend_decode``  — one query position against a cache.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig, ParamSpec

NEG_INF = -1e30


def attention_specs(config: ModelConfig, d_in: Optional[int] = None):
    d = d_in or config.d_model
    hd = config.hd
    specs = {
        "wq": ParamSpec((d, config.n_heads, hd), ("embed", "heads", None),
                        scale=d ** -0.5),
        "wk": ParamSpec((d, config.n_kv_heads, hd), ("embed", "kv_heads", None),
                        scale=d ** -0.5),
        "wv": ParamSpec((d, config.n_kv_heads, hd), ("embed", "kv_heads", None),
                        scale=d ** -0.5),
        "wo": ParamSpec((config.n_heads, hd, d), ("heads", None, "embed"),
                        scale=(config.n_heads * hd) ** -0.5),
    }
    if config.use_qkv_bias:
        specs["bq"] = ParamSpec((config.n_heads, hd), ("heads", None), "zeros")
        specs["bk"] = ParamSpec((config.n_kv_heads, hd), ("kv_heads", None), "zeros")
        specs["bv"] = ParamSpec((config.n_kv_heads, hd), ("kv_heads", None), "zeros")
    return specs


def _project_qkv(params, x, config: ModelConfig):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(x.dtype))
    if config.use_qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    return q, k, v


def _group_q(q, n_kv: int):
    """(B,T,H,hd) -> (B,T,Hkv,G,hd): GQA groups without repeating K/V."""
    b, t, h, hd = q.shape
    return q.reshape(b, t, n_kv, h // n_kv, hd)


def attend_full(q, k, v, *, causal: bool, q_offset: int = 0):
    """q: (B,Tq,H,hd); k/v: (B,Tk,Hkv,hd), Hkv | H. Returns (B,Tq,H,hd).

    Grouped einsums keep K/V at Hkv heads — no ``repeat`` materialisation
    (a 4-8x activation saving for the kv<=8 GQA architectures).
    """
    b, tq, h, hd = q.shape
    n_kv = k.shape[2]
    scale = hd ** -0.5
    qg = _group_q(q, n_kv)
    logits = jnp.einsum("bqkgh,btkh->bkgqt", qg, k).astype(jnp.float32) * scale
    if causal:
        tk = k.shape[1]
        qpos = jnp.arange(tq)[:, None] + q_offset
        kpos = jnp.arange(tk)[None, :]
        logits = jnp.where(kpos <= qpos, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqt,btkh->bqkgh", w, v)
    return out.reshape(b, tq, h, hd)


def attend_chunked(q, k, v, *, causal: bool, q_chunk: int, kv_chunk: int):
    """Streaming-softmax attention; O(q_chunk * kv_chunk) score memory.

    q: (B,T,H,hd); k/v: (B,T,Hkv,hd). Requires T % chunk == 0 (config picks
    divisors).  Same math as FlashAttention, expressed at the XLA level.
    """
    b, tq, h, hd = q.shape
    tk, n_kv = k.shape[1], k.shape[2]
    g = h // n_kv
    nq, nk = tq // q_chunk, tk // kv_chunk
    scale = hd ** -0.5
    qb = q.reshape(b, nq, q_chunk, n_kv, g, hd)
    kb = k.reshape(b, nk, kv_chunk, n_kv, hd)
    vb = v.reshape(b, nk, kv_chunk, n_kv, hd)

    def kv_step(carry, blk):
        m, l, acc = carry          # (b,nq,kv,g,qc,1), same, (...,qc,hd)
        kj, vj, j = blk            # kj/vj: (b,kvc,kv,hd)
        s = jnp.einsum("bnqkgh,btkh->bnkgqt", qb, kj).astype(jnp.float32) * scale
        if causal:
            qpos = (jnp.arange(nq)[:, None] * q_chunk + jnp.arange(q_chunk)[None, :])
            kpos = j * kv_chunk + jnp.arange(kv_chunk)
            mask = kpos[None, None, :] <= qpos[:, :, None]     # (nq,qc,kvc)
            s = jnp.where(mask[:, None, None, :, :][None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.einsum(
            "bnkgqt,btkh->bnkgqh", p.astype(q.dtype), vj
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, nq, n_kv, g, q_chunk, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nq, n_kv, g, q_chunk, 1), jnp.float32)
    a0 = jnp.zeros((b, nq, n_kv, g, q_chunk, hd), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)   # (nk, b, kvc, n_kv, hd)
    vb_t = jnp.moveaxis(vb, 1, 0)
    (m, l, acc), _ = jax.lax.scan(
        kv_step, (m0, l0, a0), (kb_t, vb_t, jnp.arange(nk))
    )
    out = acc / jnp.maximum(l, 1e-30)          # (b,nq,kv,g,qc,hd)
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(b, tq, h, hd)
    return out.astype(q.dtype)


class KVCache(NamedTuple):
    k: jax.Array        # (B, max_len, Hkv, hd)
    v: jax.Array
    length: jax.Array   # int32 scalar: tokens currently valid


def init_kv_cache(batch: int, max_len: int, config: ModelConfig, dtype) -> KVCache:
    shape = (batch, max_len, config.n_kv_heads, config.hd)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        length=jnp.zeros((), jnp.int32),
    )


def attention_block(
    params, x, config: ModelConfig, *,
    positions=None, causal: bool = True,
    cache: Optional[KVCache] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
):
    """Full attention sub-block: project, rope, attend, out-project.

    Modes:
      * train/prefill (cache None): full-sequence causal attention; returns
        (out, (k, v)) so prefill can build the cache.
      * decode (cache given): append one (or a few) positions, attend over
        cache; returns (out, new_cache).
      * cross-attention (cross_kv given): encoder K/V precomputed.
    """
    b, t, _ = x.shape
    rot = int(config.hd * config.rotary_pct)
    if positions is None:
        positions = jnp.arange(t)

    if cross_kv is not None:
        q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
        k, v = cross_kv
        out = attend_full(q, k, v, causal=False)
        new_state = None
    elif cache is None:
        q, k, v = _project_qkv(params, x, config)
        if rot > 0:
            cos, sin = cm.rope_angles(positions, rot, config.rope_theta)
            q = cm.apply_rope(q, cos, sin)
            k = cm.apply_rope(k, cos, sin)
        # repeat_kv_math: archs whose kv head count doesn't divide the
        # model axis (yi kv=4, nemo kv=8 vs 16-way TP) repeat K/V to full
        # heads for the *compute* — the GQA grouped reshape (H -> Hkv x G)
        # otherwise breaks the 16-way head sharding and GSPMD reshards
        # every chunk step (measured 10x collective bytes on yi train).
        # The cache still stores the compact Hkv form.
        if config.repeat_kv_math and config.n_kv_heads != config.n_heads:
            reps = config.n_heads // config.n_kv_heads
            kf, vf = jnp.repeat(k, reps, axis=2), jnp.repeat(v, reps, axis=2)
        else:
            kf, vf = k, v
        if t >= config.flash_block_threshold and t % config.attn_chunk_q == 0 \
                and t % config.attn_chunk_kv == 0:
            out = attend_chunked(
                q, kf, vf, causal=causal,
                q_chunk=config.attn_chunk_q, kv_chunk=config.attn_chunk_kv,
            )
        else:
            out = attend_full(q, kf, vf, causal=causal)
        new_state = (k, v)
    else:
        # decode: t new tokens (usually 1) against cache
        q, k, v = _project_qkv(params, x, config)
        pos = cache.length + jnp.arange(t)
        if rot > 0:
            cos, sin = cm.rope_angles(pos, rot, config.rope_theta)
            q = cm.apply_rope(q, cos, sin)
            k = cm.apply_rope(k, cos, sin)
        k_all = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), cache.length, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), cache.length, axis=1)
        n_kv = k_all.shape[2]
        qg = _group_q(q, n_kv)
        scale = config.hd ** -0.5
        logits = jnp.einsum(
            "bqkgh,btkh->bkgqt", qg, k_all.astype(q.dtype)
        ).astype(jnp.float32) * scale
        valid = jnp.arange(k_all.shape[1])[None, :] <= (
            cache.length + jnp.arange(t))[:, None]
        logits = jnp.where(valid[None, None, None, :, :], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqt,btkh->bqkgh", w, v_all.astype(q.dtype))
        out = out.reshape(b, t, config.n_heads, config.hd)
        new_state = KVCache(k=k_all, v=v_all, length=cache.length + t)

    y = jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(x.dtype))
    return y, new_state
