"""Decoder-only LM assembly: block registry, layer plan, scan-over-layers.

Every architecture is expressed as a *layer plan*: an optional unrolled
``prefix`` (e.g. DeepSeek-MoE's first dense layer), a repeating ``unit`` of
block types scanned ``n_repeat`` times (params stacked on a leading layer
axis — keeps HLO size O(unit) instead of O(layers), essential for the
480B-compile), and an optional ``shared`` block applied after each unit
repetition with *unshared-cache/shared-weights* semantics (Zamba2's shared
attention).  Remat wraps the unit body.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mlp as mlp_mod
from repro.models import ssm as ssm_mod
from repro.models.common import ModelConfig, ParamSpec


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------

class LayerPlan(NamedTuple):
    prefix: Tuple[str, ...]    # unrolled leading blocks
    unit: Tuple[str, ...]      # repeated block pattern (params stacked)
    n_repeat: int
    shared: Optional[str]      # block applied after each unit repetition


def layer_plan(config: ModelConfig) -> LayerPlan:
    L = config.n_layers
    if config.family in ("dense", "vlm"):
        return LayerPlan((), ("attn_mlp",), L, None)
    if config.family == "moe":
        k = config.first_k_dense
        return LayerPlan(("attn_dense_mlp",) * k, ("attn_moe",), L - k, None)
    if config.family == "ssm":           # xLSTM
        se = config.slstm_every
        if se > 0:
            assert L % se == 0
            unit = ("mlstm",) * (se - 1) + ("slstm",)
            return LayerPlan((), unit, L // se, None)
        return LayerPlan((), ("mlstm",), L, None)
    if config.family == "hybrid":        # Zamba2
        ae = config.attn_every
        assert ae > 0 and L % ae == 0
        shared = "shared_attn_mlp" if config.d_ff > 0 else "shared_attn"
        return LayerPlan((), ("mamba",) * ae, L // ae, shared)
    raise ValueError(config.family)


# ---------------------------------------------------------------------------
# Block registry: specs(config) and apply(params, x, ctx) per block type
# ---------------------------------------------------------------------------

class BlockCtx(NamedTuple):
    config: ModelConfig
    mesh: Optional[Any]
    mode: str                  # train | prefill | decode
    positions: Optional[jax.Array]
    max_cache_len: int
    enc_out: Optional[jax.Array] = None   # encoder memory (enc-dec models)


def _attn_mlp_specs(config: ModelConfig, dense_ff: bool = False):
    d_ff = config.dense_d_ff if dense_ff and config.dense_d_ff else config.d_ff
    return {
        "ln_attn": cm.norm_params(config, config.d_model),
        "attn": attn.attention_specs(config),
        "ln_mlp": cm.norm_params(config, config.d_model),
        "mlp": mlp_mod.mlp_specs(config, d_ff=d_ff),
    }


def _pad_cache_len(k, max_len: int):
    """Grow the cache seq dim to capacity (prefill must leave decode room)."""
    pad = max_len - k.shape[1]
    if pad <= 0:
        return k
    return jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))


def _apply_attn(params, x, ctx: BlockCtx, cache):
    config = ctx.config
    h = cm.apply_norm(x, params["ln_attn"], config)
    if ctx.mode == "train":
        out, _ = attn.attention_block(
            params["attn"], h, config, positions=ctx.positions, cache=None
        )
        new_cache = None
    elif ctx.mode == "prefill":
        out, (k, v) = attn.attention_block(
            params["attn"], h, config, positions=ctx.positions, cache=None
        )
        new_cache = attn.KVCache(
            k=_pad_cache_len(k.astype(config.dtype), ctx.max_cache_len),
            v=_pad_cache_len(v.astype(config.dtype), ctx.max_cache_len),
            length=jnp.int32(x.shape[1]),
        )
    else:  # decode
        out, new_cache = attn.attention_block(params["attn"], h, config, cache=cache)
    return x + out, new_cache


def _apply_attn_mlp(params, x, ctx: BlockCtx, cache):
    x, new_cache = _apply_attn(params, x, ctx, cache)
    h = cm.apply_norm(x, params["ln_mlp"], ctx.config)
    x = x + mlp_mod.mlp_apply(params["mlp"], h, ctx.config)
    return x, new_cache, jnp.float32(0.0)


def _attn_moe_specs(config: ModelConfig):
    return {
        "ln_attn": cm.norm_params(config, config.d_model),
        "attn": attn.attention_specs(config),
        "ln_mlp": cm.norm_params(config, config.d_model),
        "moe": mlp_mod.moe_specs(config),
    }


def _apply_attn_moe(params, x, ctx: BlockCtx, cache):
    x, new_cache = _apply_attn(params, x, ctx, cache)
    h = cm.apply_norm(x, params["ln_mlp"], ctx.config)
    y, aux = mlp_mod.moe_apply(params["moe"], h, ctx.config, mesh=ctx.mesh)
    return x + y, new_cache, aux


def _mamba_specs(config: ModelConfig):
    return {
        "ln": cm.norm_params(config, config.d_model),
        "mamba": ssm_mod.mamba2_specs(config),
    }


def _apply_mamba(params, x, ctx: BlockCtx, cache):
    config = ctx.config
    h = cm.apply_norm(x, params["ln"], config)
    if ctx.mode == "train":
        y = ssm_mod.mamba2_apply(params["mamba"], h, config)
        new_cache = None
    elif ctx.mode == "prefill":
        y, new_cache = ssm_mod.mamba2_apply(
            params["mamba"], h, config, return_state=True
        )
    else:
        y, new_cache = ssm_mod.mamba2_decode(params["mamba"], h, config, cache)
    return x + y, new_cache, jnp.float32(0.0)


def _mlstm_specs(config: ModelConfig):
    return {
        "ln": cm.norm_params(config, config.d_model),
        "mlstm": ssm_mod.mlstm_specs(config),
    }


def _apply_mlstm(params, x, ctx: BlockCtx, cache):
    config = ctx.config
    h = cm.apply_norm(x, params["ln"], config)
    if ctx.mode == "train":
        y = ssm_mod.mlstm_apply(params["mlstm"], h, config)
        new_cache = None
    elif ctx.mode == "prefill":
        y, new_cache = ssm_mod.mlstm_apply(
            params["mlstm"], h, config, return_state=True
        )
    else:
        y, new_cache = ssm_mod.mlstm_decode(params["mlstm"], h, config, cache)
    return x + y, new_cache, jnp.float32(0.0)


def _slstm_specs(config: ModelConfig):
    return {
        "ln": cm.norm_params(config, config.d_model),
        "slstm": ssm_mod.slstm_specs(config),
    }


def _apply_slstm(params, x, ctx: BlockCtx, cache):
    config = ctx.config
    h = cm.apply_norm(x, params["ln"], config)
    if ctx.mode == "train":
        y = ssm_mod.slstm_apply(params["slstm"], h, config)
        new_cache = None
    else:
        y, new_cache = ssm_mod.slstm_apply(
            params["slstm"], h, config,
            state=None if ctx.mode == "prefill" else cache,
            return_state=True,
        )
    return x + y, new_cache, jnp.float32(0.0)


def _shared_attn_specs(config: ModelConfig):
    return {
        "ln": cm.norm_params(config, config.d_model),
        "attn": attn.attention_specs(config),
    }


def _apply_shared_attn(params, x, ctx: BlockCtx, cache):
    x, new_cache = _apply_attn(
        {"ln_attn": params["ln"], "attn": params["attn"]}, x, ctx, cache
    )
    return x, new_cache, jnp.float32(0.0)


def _shared_attn_mlp_specs(config: ModelConfig):
    """Zamba2-style shared transformer block: attention + MLP, one set of
    weights applied after every unit repetition (caches stay per-use)."""
    return _attn_mlp_specs(config)


def _apply_shared_attn_mlp(params, x, ctx: BlockCtx, cache):
    x, new_cache, aux = _apply_attn_mlp(params, x, ctx, cache)
    return x, new_cache, aux


def _enc_attn_mlp_specs(config: ModelConfig):
    return _attn_mlp_specs(config)


def _apply_enc_attn_mlp(params, x, ctx: BlockCtx, cache):
    """Bidirectional encoder block — never cached."""
    config = ctx.config
    h = cm.apply_norm(x, params["ln_attn"], config)
    out, _ = attn.attention_block(
        params["attn"], h, config, positions=ctx.positions, causal=False, cache=None
    )
    x = x + out
    h = cm.apply_norm(x, params["ln_mlp"], config)
    x = x + mlp_mod.mlp_apply(params["mlp"], h, config)
    return x, None, jnp.float32(0.0)


def _dec_block_specs(config: ModelConfig):
    return {
        "ln_self": cm.norm_params(config, config.d_model),
        "self_attn": attn.attention_specs(config),
        "ln_cross": cm.norm_params(config, config.d_model),
        "cross_attn": attn.attention_specs(config),
        "ln_mlp": cm.norm_params(config, config.d_model),
        "mlp": mlp_mod.mlp_specs(config),
    }


def _cross_kv(params, enc_out, config: ModelConfig):
    k = jnp.einsum("btd,dhk->bthk", enc_out, params["wk"].astype(enc_out.dtype))
    v = jnp.einsum("btd,dhk->bthk", enc_out, params["wv"].astype(enc_out.dtype))
    return k, v


def _apply_dec_block(params, x, ctx: BlockCtx, cache):
    """Decoder block: causal self-attn (cached) + cross-attn + MLP.

    Cache layout: {"self": KVCache, "cross_k": ..., "cross_v": ...} — the
    cross K/V are computed once from the encoder memory at prefill and
    reused every decode step.
    """
    config = ctx.config
    h = cm.apply_norm(x, params["ln_self"], config)
    if ctx.mode == "train":
        out, _ = attn.attention_block(
            params["self_attn"], h, config, positions=ctx.positions, cache=None
        )
        self_cache = None
    elif ctx.mode == "prefill":
        out, (k, v) = attn.attention_block(
            params["self_attn"], h, config, positions=ctx.positions, cache=None
        )
        self_cache = attn.KVCache(
            k=_pad_cache_len(k.astype(config.dtype), ctx.max_cache_len),
            v=_pad_cache_len(v.astype(config.dtype), ctx.max_cache_len),
            length=jnp.int32(x.shape[1]),
        )
    else:
        out, self_cache = attn.attention_block(
            params["self_attn"], h, config, cache=cache["self"]
        )
    x = x + out

    h = cm.apply_norm(x, params["ln_cross"], config)
    if ctx.mode == "decode":
        ck, cv = cache["cross_k"].astype(h.dtype), cache["cross_v"].astype(h.dtype)
    else:
        ck, cv = _cross_kv(params["cross_attn"], ctx.enc_out, config)
    out, _ = attn.attention_block(
        params["cross_attn"], h, config, cross_kv=(ck, cv)
    )
    x = x + out

    h = cm.apply_norm(x, params["ln_mlp"], config)
    x = x + mlp_mod.mlp_apply(params["mlp"], h, config)
    if ctx.mode == "train":
        return x, None, jnp.float32(0.0)
    new_cache = {
        "self": self_cache,
        "cross_k": ck.astype(config.dtype),
        "cross_v": cv.astype(config.dtype),
    }
    return x, new_cache, jnp.float32(0.0)


BLOCKS = {
    "attn_mlp": (_attn_mlp_specs, _apply_attn_mlp),
    "enc_attn_mlp": (_enc_attn_mlp_specs, _apply_enc_attn_mlp),
    "dec_block": (_dec_block_specs, _apply_dec_block),
    "attn_dense_mlp": (
        functools.partial(_attn_mlp_specs, dense_ff=True), _apply_attn_mlp),
    "attn_moe": (_attn_moe_specs, _apply_attn_moe),
    "mamba": (_mamba_specs, _apply_mamba),
    "mlstm": (_mlstm_specs, _apply_mlstm),
    "slstm": (_slstm_specs, _apply_slstm),
    "shared_attn": (_shared_attn_specs, _apply_shared_attn),
    "shared_attn_mlp": (_shared_attn_mlp_specs, _apply_shared_attn_mlp),
}

_HAS_CACHE = {"attn_mlp", "attn_dense_mlp", "attn_moe", "mamba", "mlstm",
              "slstm", "shared_attn", "shared_attn_mlp"}
_ATTN_BLOCKS = {"attn_mlp", "attn_dense_mlp", "attn_moe", "shared_attn",
                "shared_attn_mlp"}


def _stack_spec(spec: ParamSpec, n: int) -> ParamSpec:
    return ParamSpec((n,) + spec.shape, (None,) + spec.logical_axes,
                     spec.init, spec.scale)


def _stack_tree(specs, n: int):
    return jax.tree_util.tree_map(
        lambda s: _stack_spec(s, n), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def init_block_cache(btype: str, batch: int, max_len: int, config: ModelConfig,
                     src_len: int = 0):
    if btype in _ATTN_BLOCKS:
        return attn.init_kv_cache(batch, max_len, config, config.dtype)
    if btype == "dec_block":
        kv_shape = (batch, src_len, config.n_kv_heads, config.hd)
        return {
            "self": attn.init_kv_cache(batch, max_len, config, config.dtype),
            "cross_k": jnp.zeros(kv_shape, config.dtype),
            "cross_v": jnp.zeros(kv_shape, config.dtype),
        }
    if btype == "mamba":
        return ssm_mod.mamba2_init_state(batch, config, config.dtype)
    if btype == "mlstm":
        return ssm_mod.mlstm_init_state(batch, config, config.dtype)
    if btype == "slstm":
        return ssm_mod.slstm_init_state(batch, config)
    raise ValueError(btype)


class Ax:
    """Logical-axes annotation wrapper.

    Deliberately *not* a pytree container so an axes pytree can be zipped
    against a cache pytree of arrays with ``tree_map`` (a plain tuple leaf
    would be flattened into the structure and break the zip).
    """

    def __init__(self, *axes):
        self.axes = axes

    def __repr__(self):
        return f"Ax{self.axes}"

    def __eq__(self, other):
        return isinstance(other, Ax) and self.axes == other.axes


def block_cache_axes(btype: str, config: ModelConfig):
    """Logical axes for one block's cache, mirroring init_block_cache.

    KV caches carry ("batch", "kv_seq", "kv_heads", None): with
    ``shard_cache_seq`` the seq dim takes the model axis (for archs whose
    kv head count doesn't divide it); otherwise kv_heads does —
    resolve_spec's used-axis bookkeeping makes the two mutually exclusive.
    """
    kv = Ax("batch", "kv_seq", "kv_heads", None)
    if btype in _ATTN_BLOCKS:
        return attn.KVCache(k=kv, v=kv, length=Ax())
    if btype == "dec_block":
        cross = Ax("batch", None, "kv_heads", None)
        return {
            "self": attn.KVCache(k=kv, v=kv, length=Ax()),
            "cross_k": cross,
            "cross_v": cross,
        }
    if btype == "mamba":
        return ssm_mod.SSMState(conv=Ax("batch", None, "ffn"),
                                ssd=Ax("batch", "heads", None, None))
    if btype == "mlstm":
        return ssm_mod.SSMState(conv=Ax("batch", None, "ffn"),
                                ssd=Ax("batch", "heads", None, None))
    if btype == "slstm":
        s = Ax("batch", "heads", None)
        return ssm_mod.SLSTMState(h=s, c=s, n=s, m=s)
    raise ValueError(btype)


def cache_axes(config: ModelConfig, plan: Optional[LayerPlan] = None):
    """Logical-axes pytree matching ``init_cache`` (Ax leaves)."""
    plan = plan or layer_plan(config)

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda a: Ax(None, *a.axes), tree,
            is_leaf=lambda x: isinstance(x, Ax),
        )

    axes = {
        "prefix": [block_cache_axes(b, config) for b in plan.prefix],
        "unit": [stack(block_cache_axes(b, config)) for b in plan.unit],
    }
    if plan.shared is not None:
        axes["shared"] = stack(block_cache_axes(plan.shared, config))
    return axes


def cache_shardings(config: ModelConfig, mesh, plan: Optional[LayerPlan] = None):
    """NamedSharding pytree for the model cache (zip with an eval_shape)."""
    from jax.sharding import NamedSharding

    plan = plan or layer_plan(config)
    rules = cm.make_rules(config, mesh)
    axes = cache_axes(config, plan)
    return jax.tree_util.tree_map(
        lambda a: _AxResolver(a, mesh, rules), axes,
        is_leaf=lambda x: isinstance(x, Ax),
    )


class _AxResolver:
    """Deferred sharding: resolves logical axes against a concrete shape.

    ``cache_shardings`` can't produce NamedShardings directly because
    divisibility depends on array shapes; the dry-run zips this resolver
    tree against an ``eval_shape`` of the cache.
    """

    def __init__(self, ax: "Ax", mesh, rules):
        self.ax, self.mesh, self.rules = ax, mesh, rules

    def resolve(self, shape):
        from jax.sharding import NamedSharding

        axes = self.ax.axes
        if len(axes) != len(shape):   # scalar length fields etc.
            axes = (None,) * len(shape)
        return NamedSharding(
            self.mesh, cm.resolve_spec(shape, axes, self.mesh, self.rules)
        )


def resolve_cache_shardings(resolvers, cache_shapes):
    """Zip an _AxResolver tree with a ShapeDtypeStruct tree."""
    return jax.tree_util.tree_map(
        lambda r, s: r.resolve(s.shape), resolvers, cache_shapes,
        is_leaf=lambda x: isinstance(x, _AxResolver),
    )


def init_cache(config: ModelConfig, batch: int, max_len: int,
               plan: Optional[LayerPlan] = None, src_len: int = 0):
    """Full-model cache pytree matching the layer plan."""
    plan = plan or layer_plan(config)
    cache = {
        "prefix": [init_block_cache(b, batch, max_len, config, src_len)
                   for b in plan.prefix],
        "unit": [
            jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (plan.n_repeat,) + x.shape),
                init_block_cache(b, batch, max_len, config, src_len),
            )
            for b in plan.unit
        ],
    }
    if plan.shared is not None:
        cache["shared"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (plan.n_repeat,) + x.shape),
            init_block_cache(plan.shared, batch, max_len, config),
        )
    return cache


# ---------------------------------------------------------------------------
# Backbone specs / apply
# ---------------------------------------------------------------------------

def backbone_specs(config: ModelConfig,
                   plan: Optional[LayerPlan] = None) -> Dict[str, Any]:
    plan = plan or layer_plan(config)
    specs: Dict[str, Any] = {
        "prefix": [BLOCKS[b][0](config) for b in plan.prefix],
        "unit": [_stack_tree(BLOCKS[b][0](config), plan.n_repeat) for b in plan.unit],
        "final_norm": cm.norm_params(config, config.d_model),
    }
    if plan.shared is not None:
        specs["shared"] = BLOCKS[plan.shared][0](config)
    return specs


def backbone_apply(params, x, ctx: BlockCtx, cache=None,
                   plan: Optional[LayerPlan] = None):
    """Run all layers. Returns (x, new_cache, aux_loss_sum)."""
    config = ctx.config
    plan = plan or layer_plan(config)
    new_cache: Dict[str, Any] = {"prefix": [], "unit": None}
    aux_total = jnp.float32(0.0)
    use_cache = ctx.mode != "train"

    for i, btype in enumerate(plan.prefix):
        c_in = cache["prefix"][i] if use_cache and cache else None
        x, c_out, aux = BLOCKS[btype][1](params["prefix"][i], x, ctx, c_in)
        aux_total = aux_total + aux
        new_cache["prefix"].append(c_out)

    # Residual-stream constraint between layers: anchors the batch (and,
    # under tp_sp, the sequence) sharding at every scan step so the remat-
    # saved per-layer carry is stored sharded — this is where the tp_sp /
    # fsdp profiles realise their activation-memory win.
    def _anchor(x):
        if ctx.mesh is None or ctx.mode == "decode":
            return x
        return cm.constrain(x, ctx.mesh, config, "batch", "seq", "embed")

    # --- repeated unit, scanned over the layer axis ----------------------
    def unit_body(carry, layer_in):
        x, aux_sum = carry
        layer_params, layer_cache, shared_cache = layer_in
        caches_out = []
        for j, btype in enumerate(plan.unit):
            c_in = layer_cache[j] if use_cache and layer_cache is not None else None
            x, c_out, aux = BLOCKS[btype][1](layer_params[j], x, ctx, c_in)
            aux_sum = aux_sum + aux
            caches_out.append(c_out)
        shared_out = None
        if plan.shared is not None:
            x, shared_out, _ = BLOCKS[plan.shared][1](
                params["shared"], x, ctx, shared_cache if use_cache else None
            )
        x = _anchor(x)
        if ctx.mode == "train":
            return (x, aux_sum), None
        return (x, aux_sum), (caches_out, shared_out)

    if config.remat != "none":
        policy = (
            jax.checkpoint_policies.nothing_saveable
            if config.remat == "full"
            else jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
        unit_body = jax.checkpoint(unit_body, policy=policy, prevent_cse=False)

    unit_caches = cache["unit"] if use_cache and cache else [None] * len(plan.unit)
    shared_caches = cache.get("shared") if use_cache and cache else None
    xs = (params["unit"], unit_caches if use_cache else None,
          shared_caches if plan.shared is not None else None)
    (x, aux_total), ys = jax.lax.scan(unit_body, (x, aux_total), xs,
                                      length=plan.n_repeat)
    if use_cache:
        new_cache["unit"], shared_new = ys
        if plan.shared is not None:
            new_cache["shared"] = shared_new
    x = cm.apply_norm(x, params["final_norm"], config)
    return x, (new_cache if use_cache else None), aux_total
