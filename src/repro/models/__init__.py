from repro.models.common import ModelConfig, ParamSpec
from repro.models.model import LM, Seq2Seq, build_model

__all__ = ["ModelConfig", "ParamSpec", "LM", "Seq2Seq", "build_model"]
