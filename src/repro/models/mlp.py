"""Dense MLP and Mixture-of-Experts blocks.

MoE uses the sort-based token-permutation dispatch (MegaBlocks-style,
TPU-friendly): assignments are sorted by expert, ranked within expert via
``searchsorted``, scattered into an (E, C, d) capacity buffer that is
sharded over the ``experts`` logical axis (EP on the ``model`` mesh axis),
batch-matmul'd against stacked expert weights, and gathered back.  This
avoids every (tokens × experts × capacity) dense combine tensor — the thing
that would OOM a fine-grained 64-expert layer at 1M tokens.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig, ParamSpec


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def mlp_specs(config: ModelConfig, d_ff: int | None = None) -> Dict[str, ParamSpec]:
    d, f = config.d_model, d_ff or config.d_ff
    s = {
        "w_up": ParamSpec((d, f), ("embed", "ffn"), scale=d ** -0.5),
        "w_down": ParamSpec((f, d), ("ffn", "embed"), scale=f ** -0.5),
    }
    if config.mlp_gated:
        s["w_gate"] = ParamSpec((d, f), ("embed", "ffn"), scale=d ** -0.5)
    return s


def mlp_apply(params, x, config: ModelConfig):
    up = x @ params["w_up"].astype(x.dtype)
    if config.mlp_gated:
        gate = cm.activate(x @ params["w_gate"].astype(x.dtype), config.act)
        h = gate * up
    else:
        h = cm.activate(up, config.act)
    return h @ params["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def moe_specs(config: ModelConfig) -> Dict[str, ParamSpec]:
    d, fe, E = config.d_model, config.d_expert, config.n_experts
    s = {
        "w_router": ParamSpec((d, E), (None, "experts"), scale=0.02),
        # experts -> model (EP); inner FFN dim storage-sharded over data in
        # the ep/ep_fsdp profiles (gathered per layer, FSDP-style)
        "w_up_e": ParamSpec((E, d, fe), ("experts", None, "expert_inner"),
                            scale=d ** -0.5),
        "w_gate_e": ParamSpec((E, d, fe), ("experts", None, "expert_inner"),
                              scale=d ** -0.5),
        "w_down_e": ParamSpec((E, fe, d), ("experts", "expert_inner", None),
                              scale=fe ** -0.5),
    }
    if config.n_shared_experts > 0:
        fs = config.n_shared_experts * fe
        s["shared"] = mlp_specs(config, d_ff=fs)
    if config.moe_style == "arctic":
        s["residual"] = mlp_specs(config, d_ff=config.dense_d_ff)
    return s


def _capacity(n_tokens: int, config: ModelConfig) -> int:
    c = int(math.ceil(n_tokens * config.top_k / config.n_experts
                      * config.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8


def _dispatch_group(xg, probs_g, config: ModelConfig, C: int):
    """Sort-based dispatch for one group of tokens.

    xg: (ntg, d); probs_g: (ntg, E) fp32 router probabilities.
    Returns (buf (E, C, d), dest, keep, gate_vals, tok_idx).
    """
    ntg, d = xg.shape
    E, K = config.n_experts, config.top_k
    gate_vals, expert_idx = jax.lax.top_k(probs_g, K)      # (ntg, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)            # renormalise top-k

    e_flat = expert_idx.reshape(-1)                        # (ntg*K,)
    order = jnp.argsort(e_flat)                            # stable
    e_sorted = e_flat[order]
    first = jnp.searchsorted(e_sorted, e_sorted, side="left")
    rank_sorted = jnp.arange(ntg * K, dtype=jnp.int32) - first.astype(jnp.int32)
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    keep = rank < C                                        # capacity drop
    rank_c = jnp.minimum(rank, C)                          # row C = trash slot

    tok_idx = jnp.repeat(jnp.arange(ntg), K)               # token of each slot
    # 2-D scatter keeps E a real tensor dim through the dispatch, so GSPMD
    # can shard the buffer on (experts -> model) directly — the implicit
    # MoE all-to-all — instead of materialising a flat (E*C, d) slab.
    buf = jnp.zeros((E, C + 1, d), xg.dtype)
    buf = buf.at[e_flat, rank_c].add(
        xg[tok_idx] * keep[:, None].astype(xg.dtype))
    return buf[:, :C], (e_flat, rank_c), keep, gate_vals, tok_idx


def _combine_group(out, dest, keep, gate_vals, tok_idx, ntg: int):
    """Gather expert outputs back to token order for one group."""
    e_flat, rank_c = dest
    C = out.shape[1]
    gathered = out[e_flat, jnp.minimum(rank_c, C - 1)]     # (ntg*K, d)
    w = (keep[:, None] * gate_vals.reshape(-1)[:, None]).astype(out.dtype)
    return jax.ops.segment_sum(gathered * w, tok_idx, num_segments=ntg)


def moe_apply(params, x, config: ModelConfig, mesh=None):
    """x: (B, T, d). Returns (y, aux_loss).

    Dispatch is *grouped*: tokens split into ``config.moe_groups`` groups
    (sized to the data-parallel shard count), with top-k / sort /
    capacity-scatter running independently per group under ``vmap``.  With
    the group axis sharded over (pod, data) and experts over model, every
    sort and scatter is shard-local; the only cross-device traffic is the
    (G x E)-blocked buffer flowing through the expert einsums — the
    all-to-all of a classic EP implementation, inserted by GSPMD.
    """
    b, t, d = x.shape
    E, K = config.n_experts, config.top_k
    nt = b * t
    G = config.moe_groups if nt % config.moe_groups == 0 else 1
    ntg = nt // G
    C = _capacity(ntg, config)
    xf = x.reshape(nt, d)

    logits = (xf @ params["w_router"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)

    xg = xf.reshape(G, ntg, d)
    pg = probs.reshape(G, ntg, E)
    if mesh is not None:
        xg = cm.constrain(xg, mesh, config, "moe_group", None, None)
        pg = cm.constrain(pg, mesh, config, "moe_group", None, None)

    buf, dest, keep, gate_vals, tok_idx = jax.vmap(
        lambda xi, pi: _dispatch_group(xi, pi, config, C)
    )(xg, pg)
    if mesh is not None:
        buf = cm.constrain(buf, mesh, config, "moe_group", "experts", None, None)

    # ---- expert FFN (batched over experts; EP-sharded) -----------------
    up = jnp.einsum("gecd,edf->gecf", buf, params["w_up_e"].astype(x.dtype))
    gate = cm.activate(
        jnp.einsum("gecd,edf->gecf", buf, params["w_gate_e"].astype(x.dtype)),
        config.act,
    )
    hidden = gate * up
    out = jnp.einsum("gecf,efd->gecd", hidden, params["w_down_e"].astype(x.dtype))
    out = out.astype(x.dtype)   # keep the resharded slab in bf16 (CPU XLA
                                # otherwise carries f32 dot outputs into the
                                # collective — 2x the wire bytes)
    if mesh is not None:
        out = cm.constrain(out, mesh, config, "moe_group", "experts", None, None)
        # Explicit reshard to group-local before the combine gather: one
        # all-gather of the (E, C, d) slab per group instead of the masked
        # all-reduce GSPMD otherwise emits for a cross-shard gather (the
        # measured difference is ~8x collective bytes on deepseek-16b).
        out = cm.constrain(out, mesh, config, "moe_group", None, None, None)

    # ---- combine --------------------------------------------------------
    y = jax.vmap(lambda o, de, ke, gv, ti: _combine_group(o, de, ke, gv, ti, ntg))(
        out, dest, keep, gate_vals, tok_idx
    ).reshape(nt, d).astype(x.dtype)
    if config.n_shared_experts > 0:
        y = y + mlp_apply(params["shared"], xf, config)
    if config.moe_style == "arctic":
        y = y + mlp_apply(params["residual"], xf, config)

    # ---- load-balance aux loss (Switch-style) ---------------------------
    me = probs.mean(axis=0)                                # mean router prob
    _, expert_idx = jax.lax.top_k(probs, K)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (nt * K)
    aux = E * jnp.sum(me * ce)
    return y.reshape(b, t, d), aux
