"""Batched serving driver: continuous-batching prefill + decode loop.

A minimal but real serving runtime over the model zoo's prefill/decode
surface: requests arrive with prompts, get prefilled into per-slot KV/state
caches, and a fixed-width decode batch greedily samples until each request
hits its token budget.  Slot reuse = continuous batching (new requests take
freed slots between decode steps).

Usage:
  python -m repro.launch.serve --arch xlstm-125m --smoke --requests 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.model import build_model
from repro.serving.primitives import BoundedQueue, SlotPool


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # int32 tokens
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Slot-based continuous batching on top of prefill/decode_step.

    The decode batch is fixed-width (``n_slots``); per-request caches are
    prefilled one by one and stacked into the slot dimension.  This mirrors
    the cache layout of the decode dry-run cells, so the serving path and
    the production lowering agree.

    Admission and slot management use the shared serving primitives
    (``repro.serving.primitives``) — the same queue/slot idiom the
    connectivity engine is built on, so the repo has one queueing
    vocabulary across both servers.
    """

    def __init__(self, config, params=None, *, n_slots: int = 4,
                 max_len: int = 256, rng_seed: int = 0):
        self.config = config
        self.model = build_model(config)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(rng_seed))
        self.n_slots = n_slots
        self.max_len = max_len
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(self.model.prefill,
                                static_argnames=("max_len",))

    # -- single-request prefill -> slot cache ------------------------------
    def _prefill_one(self, req: Request):
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        batch = {"tokens": tokens}
        if self.config.frontend == "patch_stub":
            n = min(self.config.n_frontend_tokens, tokens.shape[1])
            batch["patch_embeds"] = jnp.zeros(
                (1, n, self.config.d_model), jnp.float32)
        if self.config.frontend == "audio_stub":
            batch["frame_embeds"] = jnp.zeros(
                (1, max(tokens.shape[1] // 2, 4), self.config.d_model),
                jnp.float32)
        logits, cache = self._prefill(self.params, batch,
                                      max_len=self.max_len)
        next_tok = int(jnp.argmax(logits[0, -1]))
        return next_tok, cache

    def serve(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Run all requests to completion; returns rid -> generated tokens."""
        admission = BoundedQueue(name="admission")   # serve-to-completion
        for req in requests:
            admission.put(req)
        slots = SlotPool(self.n_slots)
        active: List[Optional[Request]] = [None] * self.n_slots
        caches: List[Any] = [None] * self.n_slots

        def retire(s: int) -> None:
            active[s].done = True
            active[s] = caches[s] = None
            slots.release(s)

        def admit():
            # freed decode slots take the next queued request (continuous
            # batching): acquire hands out the lowest free slot until the
            # pool or the queue is exhausted
            while len(admission):
                s = slots.acquire()
                if s is None:
                    return
                req = admission.get_nowait()
                tok, cache = self._prefill_one(req)
                req.out_tokens.append(tok)
                active[s], caches[s] = req, cache
                if len(req.out_tokens) >= req.max_new_tokens:
                    retire(s)

        admit()
        while slots.n_busy or len(admission):
            # batched decode over occupied slots (slot-by-slot caches are
            # decoded per-slot here; the production decode cell lowers the
            # fully stacked version — same math, batch=slots)
            for s in range(self.n_slots):
                req = active[s]
                if req is None:
                    continue
                last = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
                logits, caches[s] = self._decode(self.params, last, caches[s])
                tok = int(jnp.argmax(logits[0, -1]))
                req.out_tokens.append(tok)
                if len(req.out_tokens) >= req.max_new_tokens:
                    retire(s)
            admit()
        return {r.rid: r.out_tokens for r in requests}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    config = arch.smoke_config() if args.smoke else arch.config
    server = BatchedServer(config, n_slots=args.slots,
                           max_len=args.prompt_len + args.max_new)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, config.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    out = server.serve(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(f"served {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")
    for rid, toks in sorted(out.items()):
        print(f"  req {rid}: {toks}")


if __name__ == "__main__":
    main()
