import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the host
# device count at first initialisation, and the production meshes below
# need 512 placeholder devices.  Only the dry-run gets this flag — tests,
# benches and examples see the real device count.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the exact production step function — train_step
(loss + AdamW update, donated state), serve prefill, or serve decode —
against ``ShapeDtypeStruct`` inputs (no allocation), compiles it for the
16x16 single-pod and 2x16x16 multi-pod meshes, prints
``compiled.memory_analysis()`` (proof it fits) and derives the roofline
terms for EXPERIMENTS.md.

The paper's own workload — distributed Contour connectivity over a
paper-scale graph (2^28 vertices, 2^31 edges) — runs as an extra "arch"
(``contour-cc``) through the same harness.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
  python -m repro.launch.dryrun --list
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_arch, input_specs
from repro.configs.base import ArchSpec
from repro.launch.mesh import make_production_mesh
from repro.models import common as cm
from repro.models import transformer as tfm
from repro.models.model import build_model
from repro.optim.adamw import OptConfig
from repro.roofline import analyze_compiled, model_flops
from repro.train.step import make_train_step

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

# Paper-scale contour graph for the contour-cc cells.
CONTOUR_N_VERTICES = 1 << 28          # 268M vertices (kmer_V1r: 214M)
CONTOUR_N_EDGES = 1 << 31             # 2.1B directed relaxations


def _mesh_and_name(which: str):
    if which == "single":
        return make_production_mesh(multi_pod=False), "pod1x16x16"
    return make_production_mesh(multi_pod=True), "pod2x16x16"


def _resolve_tree(specs_tree, config, mesh, axes_fn):
    """NamedShardings for a dict of ShapeDtypeStructs via logical axes."""
    rules = cm.make_rules(config, mesh)
    out = {}
    for key, sds in specs_tree.items():
        axes = axes_fn(key, sds)
        out[key] = NamedSharding(
            mesh, cm.resolve_spec(sds.shape, axes, mesh, rules))
    return out


def _batch_axes(key: str, sds) -> tuple:
    if key in ("tokens", "labels", "loss_mask"):
        return ("batch",) + (None,) * (len(sds.shape) - 1)
    # patch_embeds / frame_embeds: (B, T, d)
    return ("batch", None, None)


def _abstract_params(model, dtype):
    return cm.abstract_tree(model.param_specs(), dtype)


def _cache_shardings(model, config, mesh, cache_shapes):
    plan = getattr(model, "plan", None)
    if plan is None:                       # Seq2Seq
        plan = model.dec_plan
    resolvers = tfm.cache_shardings(config, mesh, plan)
    return tfm.resolve_cache_shardings(resolvers, cache_shapes)


# ---------------------------------------------------------------------------
# Cell builders: return (lowered, kind, model_flops, n_devices)
# ---------------------------------------------------------------------------

def lower_train(arch: ArchSpec, shape, mesh) -> Any:
    config = arch.config
    model = build_model(config, mesh)
    opt = OptConfig(moment_dtype=(jnp.bfloat16
                                  if config.param_dtype == jnp.bfloat16
                                  else jnp.float32))
    multi = "pod" in mesh.axis_names
    step = make_train_step(model, opt, grad_accum=arch.accum_for(multi))

    pspecs = model.param_specs()
    pshard = cm.shardings_for(pspecs, config, mesh)
    pshapes = _abstract_params(model, config.param_dtype)
    mshapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, opt.moment_dtype), pshapes)
    state_shapes = {
        "params": pshapes,
        "opt": {"m": mshapes, "v": mshapes,
                "step": jax.ShapeDtypeStruct((), jnp.int32)},
    }
    mshard = jax.tree_util.tree_map(lambda s: s, pshard)
    state_shard = {
        "params": pshard,
        "opt": {"m": mshard, "v": mshard, "step": NamedSharding(mesh, P())},
    }
    from repro.train.step import TrainState
    state_shapes = TrainState(params=state_shapes["params"],
                              opt=state_shapes["opt"])
    state_shard = TrainState(params=state_shard["params"],
                             opt=state_shard["opt"])

    bshapes = input_specs(arch, shape.name)
    bshard = _resolve_tree(bshapes, config, mesh, _batch_axes)

    jitted = jax.jit(step,
                     in_shardings=(state_shard, bshard),
                     out_shardings=(state_shard, None),
                     donate_argnums=(0,))
    return jitted.lower(state_shapes, bshapes)


def lower_prefill(arch: ArchSpec, shape, mesh) -> Any:
    config = arch.config.for_serving()
    model = build_model(config, mesh)
    pshard = cm.shardings_for(model.param_specs(), config, mesh)
    pshapes = _abstract_params(model, config.param_dtype)
    bshapes = input_specs(arch, shape.name)
    bshard = _resolve_tree(bshapes, config, mesh, _batch_axes)

    def prefill(params, batch):
        return model.prefill(params, batch)

    cache_shapes = jax.eval_shape(prefill, pshapes, bshapes)[1]
    cshard = _cache_shardings(model, config, mesh, cache_shapes)
    jitted = jax.jit(prefill,
                     in_shardings=(pshard, bshard),
                     out_shardings=(None, cshard))
    return jitted.lower(pshapes, bshapes)


def lower_decode(arch: ArchSpec, shape, mesh) -> Any:
    config = arch.config.for_serving()
    model = build_model(config, mesh)
    pshard = cm.shardings_for(model.param_specs(), config, mesh)
    pshapes = _abstract_params(model, config.param_dtype)
    b = shape.global_batch
    if config.family == "audio":
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(b, shape.seq_len,
                                     src_len=arch.src_frames))
    else:
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(b, shape.seq_len))
    cshard = _cache_shardings(model, config, mesh, cache_shapes)
    tshapes = input_specs(arch, shape.name)["tokens"]
    tshard = NamedSharding(
        mesh, cm.resolve_spec(tshapes.shape, ("batch", None), mesh,
                              cm.make_rules(config, mesh)))

    def decode(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    jitted = jax.jit(decode,
                     in_shardings=(pshard, tshard, cshard),
                     out_shardings=(None, cshard),
                     donate_argnums=(2,))
    return jitted.lower(pshapes, tshapes, cache_shapes)


def lower_contour(mesh, mesh_name: str) -> Any:
    """The paper's workload: one distributed Contour solve, edge-sharded."""
    from repro.connectivity.distributed import distributed_contour_step_fn

    edge_axes = ("pod", "data") if "pod2" in mesh_name else ("data",)
    m = CONTOUR_N_EDGES
    sds = jax.ShapeDtypeStruct((m,), jnp.int32)
    # max_iters=8: Theorem-1 round budget for suite-scale diameters (Fig. 1
    # shows C-2 <= 7 everywhere); the roofline's loop-aware cost model
    # multiplies the while body by this trip count, so it must be the
    # *expected* convergence rounds, not a runaway safety bound.
    fn = lambda s, d: distributed_contour_step_fn(
        s, d, CONTOUR_N_VERTICES, mesh, edge_axes=edge_axes, local_rounds=1,
        max_iters=8)
    spec = P(edge_axes if len(edge_axes) > 1 else edge_axes[0])
    shard = NamedSharding(mesh, spec)
    return jax.jit(fn, in_shardings=(shard, shard)).lower(sds, sds)


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_cell(arch_name: str, shape_name: str, mesh_which: str,
             out_dir: str, hw=None) -> Dict[str, Any]:
    mesh, mesh_name = _mesh_and_name(mesh_which)
    n_dev = mesh.size
    rec: Dict[str, Any] = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "status": "ok",
    }
    t0 = time.time()
    try:
        if arch_name == "contour-cc":
            lowered = lower_contour(mesh, mesh_name)
            kind = "contour"
            mf = 0.0
            note = ("paper kernel: per-round work is O(m) scatter-min, "
                    "MODEL_FLOPS n/a (memory/collective bound by design)")
        else:
            arch = get_arch(arch_name)
            skip = arch.skip_reason(shape_name)
            if skip:
                rec.update(status="skipped", reason=skip)
                _write(rec, out_dir)
                return rec
            shape = SHAPES[shape_name]
            model = build_model(arch.config)
            mf = model_flops(model, shape.kind, shape.seq_len,
                             shape.global_batch)
            note = ""
            if shape.kind == "train":
                lowered = lower_train(arch, shape, mesh)
            elif shape.kind == "prefill":
                lowered = lower_prefill(arch, shape, mesh)
            else:
                lowered = lower_decode(arch, shape, mesh)
            kind = shape.kind
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        print(f"[{arch_name} | {shape_name} | {mesh_name}] memory_analysis:")
        print(f"  {ma}")
        report = analyze_compiled(
            compiled, arch=arch_name, shape=shape_name, mesh_name=mesh_name,
            kind=kind, n_devices=n_dev, model_flops_global=mf, note=note)
        print(f"  cost_analysis flops/dev={report.hlo_flops:.3e} "
              f"bytes/dev={report.hlo_bytes:.3e} "
              f"coll_link_bytes/dev={report.collective_link_bytes:.3e}")
        print(f"  roofline: compute={report.t_compute*1e3:.2f}ms "
              f"memory={report.t_memory*1e3:.2f}ms "
              f"collective={report.t_collective*1e3:.2f}ms "
              f"-> dominant={report.dominant}")

        rec.update(
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "code_bytes": ma.generated_code_size_in_bytes,
                "peak_bytes": (ma.argument_size_in_bytes
                               + ma.output_size_in_bytes
                               + ma.temp_size_in_bytes
                               - ma.alias_size_in_bytes),
            },
            roofline=report.to_dict(),
        )
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[{arch_name} | {shape_name} | {mesh_which}] FAILED: {e}")
    _write(rec, out_dir)
    return rec


def _write(rec: Dict[str, Any], out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def all_cells():
    for arch_name in list(ARCHS) + ["contour-cc"]:
        shapes = list(SHAPES) if arch_name != "contour-cc" else ["graph_2e31"]
        for shape_name in shapes:
            yield arch_name, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(DEFAULT_OUT))
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.list:
        for a, s in all_cells():
            print(a, s)
        return

    cells = (list(all_cells()) if args.all
             else [(args.arch, args.shape or "train_4k")])
    n_ok = n_skip = n_err = 0
    for arch_name, shape_name in cells:
        for mw in meshes:
            mesh_name = "pod1x16x16" if mw == "single" else "pod2x16x16"
            path = os.path.join(
                args.out, f"{arch_name}__{shape_name}__{mesh_name}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("status") == "ok":
                        continue
            rec = run_cell(arch_name, shape_name, mw, args.out)
            n_ok += rec["status"] == "ok"
            n_skip += rec["status"] == "skipped"
            n_err += rec["status"] == "error"
    print(f"dry-run: ok={n_ok} skipped={n_skip} error={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
