"""Production meshes.

Mesh construction is a FUNCTION (never a module-level constant) so merely
importing this module can't touch jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before *any* jax
initialisation, and tests/benches must keep seeing 1 device.

Topology rationale (DESIGN.md §4): the ``pod`` axis only ever carries
data-parallel all-reduces (DCN-tolerant); every tensor/expert-parallel
collective stays on the ``model`` axis inside one pod's ICI.  That
separation is what lets the same config scale past 2 pods to 1000+ nodes:
adding pods adds only DCN all-reduce participants, never ICI pressure.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np

from repro import jax_compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """The assignment's production mesh: 16x16 single pod / 2x16x16 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax_compat.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1,
                   devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """Best-effort (data, model) mesh over whatever devices exist locally.

    Used by tests/examples on CPU (1..8 interpreted host devices)."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    if n % model_parallel:
        raise ValueError(f"{n} devices not divisible by tp={model_parallel}")
    shape = (n // model_parallel, model_parallel)
    dev = np.asarray(devices).reshape(shape)
    return jax_compat.device_mesh(dev, ("data", "model"))
