"""Training driver: data pipeline + recovery loop + checkpointing + metrics.

Runs real steps on whatever devices exist (CPU in this container; the same
code path drives the production mesh — shardings come from the config's
profile).  Fault tolerance is exercised end-to-end: atomic keep-k
checkpoints, restore-on-crash, seekable data (batch k is a pure function of
k), straggler monitoring.

Usage:
  python -m repro.launch.train --arch xlstm-125m --smoke --steps 50
  python -m repro.launch.train --arch <id> --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.data.pipeline import SyntheticTokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import common as cm
from repro.models.model import build_model
from repro.optim.adamw import OptConfig
from repro.runtime.straggler import StragglerMonitor
from repro.train.step import TrainState, init_train_state, make_train_step


def build_batch_fn(config, batch: int, seq: int, seed: int = 0):
    pipe = SyntheticTokenPipeline(
        vocab_size=config.vocab_size, batch=batch, seq_len=seq, seed=seed)

    def batch_at(step: int) -> Dict[str, Any]:
        b = pipe.batch_at(step)
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if config.frontend == "patch_stub":
            n = min(config.n_frontend_tokens, seq)
            rng = np.random.default_rng([7, seed, step])
            out["patch_embeds"] = jnp.asarray(
                rng.standard_normal((batch, n, config.d_model), np.float32))
        if config.frontend == "audio_stub":
            rng = np.random.default_rng([11, seed, step])
            out["frame_embeds"] = jnp.asarray(
                rng.standard_normal((batch, max(seq // 2, 4), config.d_model),
                                    np.float32))
        return out

    return batch_at


def train_loop(
    config,
    *,
    steps: int,
    batch: int,
    seq: int,
    ckpt_dir: Optional[str] = None,
    checkpoint_every: int = 20,
    grad_accum: int = 1,
    mesh: Optional[jax.sharding.Mesh] = None,
    opt: Optional[OptConfig] = None,
    seed: int = 0,
    log_every: int = 10,
    on_step=None,
) -> Dict[str, Any]:
    """Run `steps` steps; returns summary metrics (resumes from ckpt_dir)."""
    opt = opt or OptConfig(warmup_steps=max(steps // 10, 1),
                           decay_steps=max(steps, 2))
    model = build_model(config, mesh)
    step_fn = jax.jit(make_train_step(model, opt, grad_accum=grad_accum),
                      donate_argnums=(0,))
    batch_at = build_batch_fn(config, batch, seq, seed)

    state = init_train_state(model, jax.random.PRNGKey(seed), opt)
    start = 0
    manager = None
    if ckpt_dir is not None:
        manager = CheckpointManager(ckpt_dir, keep=3, async_save=False)
        latest = manager.latest_step()
        if latest is not None:
            state, restored = manager.restore(state)
            start = restored + 1

    monitor = StragglerMonitor()
    losses = []
    t0 = time.time()
    for k in range(start, steps):
        monitor.start_step()
        state, metrics = step_fn(state, batch_at(k))
        loss = float(metrics["loss"])
        action = monitor.end_step()
        losses.append(loss)
        if on_step is not None:
            on_step(k, state, metrics)
        if manager is not None and ((k + 1) % checkpoint_every == 0
                                    or k == steps - 1):
            manager.save(k, state)
            manager.wait()
        if log_every and (k % log_every == 0 or k == steps - 1):
            print(f"step {k:5d} loss {loss:8.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"[{action}]")
    wall = time.time() - t0
    return {
        "steps_run": steps - start,
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "wall_s": wall,
        "state": state,
        "step_times": monitor.history,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    config = arch.smoke_config() if args.smoke else arch.config
    mesh = make_host_mesh(args.tp) if len(jax.devices()) > 1 else None
    out = train_loop(config, steps=args.steps, batch=args.batch,
                     seq=args.seq, ckpt_dir=args.ckpt_dir,
                     grad_accum=args.grad_accum, mesh=mesh)
    out.pop("state")
    print(json.dumps({k: v for k, v in out.items() if k != "step_times"},
                     indent=1))


if __name__ == "__main__":
    main()
