"""AdamW with warmup-cosine schedule, global-norm clipping and
dtype-configurable moments (bf16 moments = the gradient-compression knob
used for the 480B config — halves optimizer HBM at negligible quality cost,
recorded in DESIGN.md §4)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32   # bf16 for memory-tight configs


def learning_rate(step, config: OptConfig):
    step = step.astype(jnp.float32)
    warm = config.peak_lr * step / jnp.maximum(config.warmup_steps, 1)
    prog = jnp.clip(
        (step - config.warmup_steps)
        / jnp.maximum(config.decay_steps - config.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = config.min_lr + 0.5 * (config.peak_lr - config.min_lr) * (
        1.0 + jnp.cos(jnp.pi * prog)
    )
    return jnp.where(step < config.warmup_steps, warm, cos)


def init_opt_state(params, config: OptConfig) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, config.moment_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def apply_updates(params, grads, opt_state, config: OptConfig):
    """One AdamW step. Returns (params, opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, config.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = learning_rate(step, config)
    bc1 = 1.0 - config.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - config.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = config.b1 * m.astype(jnp.float32) + (1 - config.b1) * g
        v_new = config.b2 * v.astype(jnp.float32) + (1 - config.b2) * g * g
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + config.eps)
        update = update + config.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * update
        return (
            p_new.astype(p.dtype),
            m_new.astype(config.moment_dtype),
            v_new.astype(config.moment_dtype),
        )

    out = jax.tree_util.tree_map(upd, params, grads, opt_state["m"], opt_state["v"])
    # unzip the 3-tuples
    new_params = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(
        lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
