from repro.optim.adamw import (
    OptConfig,
    init_opt_state,
    apply_updates,
    learning_rate,
    global_norm,
)

__all__ = [
    "OptConfig", "init_opt_state", "apply_updates", "learning_rate",
    "global_norm",
]
