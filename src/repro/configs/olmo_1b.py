"""olmo-1b — [arXiv:2402.00838; hf:allenai/OLMo-1B].

Assignment: [dense] 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304,
non-parametric LayerNorm (no affine), SwiGLU, tied embeddings, full rotary.
"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50_304,
    norm_type="nonparametric",
    rotary_pct=1.0,
    rope_theta=10_000.0,
    act="silu",
    mlp_gated=True,
    tie_embeddings=True,
    sharding_profile="fsdp",   # 1.3B on 256 chips: DP-dominant (see §Perf)
    serve_profile="tp",
)

ARCH = ArchSpec(config=CONFIG, source="arXiv:2402.00838")
