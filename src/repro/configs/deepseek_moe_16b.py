"""deepseek-moe-16b — [arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base].

Assignment: [moe] 28L d_model=2048 16H (kv=16) d_ff=1408 vocab=102400,
MoE 64 experts top-6, fine-grained, 2 shared experts, first layer dense.
d_ff=1408 is the per-expert width; the first dense layer uses the model's
published 10944.  Activated width per token = (6 routed + 2 shared) x 1408.

Sharding: ep — expert weights STATIONARY on their model rank (4 experts per
chip at 16-way EP; tokens move through the dispatch all-to-all, weights
never do), grouped local dispatch over the data axis.  bf16 params and
optimizer moments keep the per-rank expert slice (16B/16 x {p,m,v}) inside
16 GB — the fp32 variant doesn't fit, see EXPERIMENTS.md §Dry-run.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    norm_type="rmsnorm",
    rotary_pct=1.0,
    act="silu",
    mlp_gated=True,
    moe_style="deepseek",
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    d_expert=1408,
    first_k_dense=1,
    dense_d_ff=10944,
    capacity_factor=1.25,
    moe_groups=32,   # divides data(16) and pod*data(32)
    param_dtype=jnp.bfloat16,
    sharding_profile="ep",
    serve_profile="ep",
)

ARCH = ArchSpec(config=CONFIG, source="arXiv:2401.06066", grad_accum=8, grad_accum_multipod=8)
