"""zamba2-2.7b — [arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B].

Assignment: [hybrid] 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64 — Mamba2 backbone + one *shared* attention+MLP block (single
weight set) applied every 6 Mamba2 blocks, with per-use KV caches.

Sharding: fsdp — the Mamba2 chunk scan is sequential over time, so the
sequence axis cannot shard; flat-batch FSDP supplies the activation relief
instead.  Mamba-2 state & linear decode => ``long_500k`` runs.
"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,            # shared block's MLP width
    vocab_size=32_000,
    norm_type="rmsnorm",
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    attn_every=6,          # 9 unit repetitions of 6 mamba blocks
    sharding_profile="fsdp",
    serve_profile="tp",
    supports_long_context=True,
)

ARCH = ArchSpec(config=CONFIG, source="arXiv:2411.15242")
