"""yi-6b — [arXiv:2403.04652; hf:01-ai/Yi-6B].

Assignment: [dense] 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-architecture GQA.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64_000,
    norm_type="rmsnorm",
    rotary_pct=1.0,
    rope_theta=10_000.0,
    act="silu",
    mlp_gated=True,
    param_dtype=jnp.bfloat16,   # fsdp weight AGs in bf16 (f32 doubles wire)
    sharding_profile="fsdp",    # kv=4 GQA cannot TP-shard on 16 (see §Perf it.8)
    serve_profile="tp",
    shard_cache_seq=True,
)

ARCH = ArchSpec(config=CONFIG, source="arXiv:2403.04652", grad_accum=1)
