"""mistral-nemo-12b — [hf:mistralai/Mistral-Nemo-Base-2407].

Assignment: [dense] 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072,
128k context.  head_dim=128 (not d_model/n_heads), rope_theta=1e6.

Sharding: tp_sp — the 40-layer 4k-seq residual carries need the sequence-
parallel residual stream; kv=8 doesn't divide the 16-way model axis, so the
KV cache shards its seq dim instead (shard_cache_seq).
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131_072,
    norm_type="rmsnorm",
    rotary_pct=1.0,
    rope_theta=1_000_000.0,
    act="silu",
    mlp_gated=True,
    max_seq_len=131_072,
    param_dtype=jnp.bfloat16,   # fsdp weight AGs in bf16
    sharding_profile="fsdp",    # kv=8 GQA cannot TP-shard on 16 (see §Perf it.8)
    serve_profile="tp",
    shard_cache_seq=True,
)

ARCH = ArchSpec(config=CONFIG, source="hf:mistralai/Mistral-Nemo-Base-2407",
                grad_accum=1)
