"""arctic-480b — [hf:Snowflake/snowflake-arctic-base].

Assignment: [moe] 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 PLUS a dense residual FFN in every layer
(dense-MoE hybrid: y = moe(x) + dense_ffn(x)).

480B total / ~17B active.  Numerics: bf16 params and bf16 optimizer
moments — at 256 x 16 GB chips a 480B model is capacity-critical (see
EXPERIMENTS.md §Dry-run for the honest accounting; it truly needs 2 pods
for comfortable training).  grad_accum=8 keeps the per-microbatch
activation live-set bounded on both meshes.

Sharding: ep_fsdp — flat batch over (pod, data, model); experts -> model;
expert inner dim + attention storage-sharded over data.  56 heads don't
divide 16, so attention weights shard on the embed dim instead (FSDP
gathers per layer); KV cache shards its seq dim (kv=8).
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    norm_type="rmsnorm",
    rotary_pct=1.0,
    act="silu",
    mlp_gated=True,
    moe_style="arctic",
    n_experts=128,
    top_k=2,
    d_expert=4864,
    dense_d_ff=4864,
    capacity_factor=1.25,
    moe_groups=32,   # divides data(16) and pod*data(32)
    param_dtype=jnp.bfloat16,
    sharding_profile="ep_fsdp",
    serve_profile="ep_fsdp",  # serving params 960GB bf16: must storage-shard
    shard_cache_seq=True,
)

ARCH = ArchSpec(config=CONFIG, source="hf:Snowflake/snowflake-arctic-base",
                grad_accum=1, grad_accum_multipod=8)
