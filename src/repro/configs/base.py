"""Architecture/shape registry machinery for the assigned (arch x shape) grid.

Every assigned architecture ships one module exporting an :class:`ArchSpec`;
the four assignment shapes are global.  ``input_specs`` produces weak-type-
correct ``ShapeDtypeStruct`` stand-ins for every model input of a cell — the
dry-run lowers against these, so no giant array is ever allocated.

Shape semantics (assignment):
  * ``train_4k``    — ``train_step``  (loss + AdamW update)
  * ``prefill_32k`` — ``serve_step``  prefill: build the KV cache
  * ``decode_32k``  — ``serve_step``  decode: one new token against a
                      ``seq_len``-deep cache
  * ``long_500k``   — decode at 512k context; only sub-quadratic
                      architectures run it (ssm / hybrid), per assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """One assigned architecture: exact config + grid metadata."""

    config: ModelConfig
    source: str = ""                   # public-literature citation tag
    grad_accum: int = 1                # training microbatch split (single pod)
    grad_accum_multipod: int = 0       # override for the 2-pod mesh: batch
                                       # 256 flat-shards 256 chips exactly,
                                       # but needs microbatching at 512
    src_frames: int = 4_096            # enc-dec: encoder frames at serving
    smoke_overrides: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def accum_for(self, multi_pod: bool) -> int:
        if multi_pod and self.grad_accum_multipod:
            return self.grad_accum_multipod
        return self.grad_accum

    @property
    def name(self) -> str:
        return self.config.name

    def skip_reason(self, shape_name: str) -> Optional[str]:
        shape = SHAPES[shape_name]
        if shape.name == "long_500k" and not self.config.supports_long_context:
            return ("full quadratic attention: 512k decode cache/score is "
                    "out of scope per assignment (sub-quadratic archs only)")
        if shape.kind in ("decode", "prefill") and not self.config.supports_decode:
            return "encoder-only architecture has no decode step"
        return None

    def cells(self):
        """[(shape_name, skip_reason | None)] over the full grid."""
        return [(s, self.skip_reason(s)) for s in SHAPES]

    # -- reduced config for CPU smoke tests --------------------------------
    def smoke_config(self) -> ModelConfig:
        c = self.config
        ratio = max(1, c.n_heads // max(c.n_kv_heads, 1))
        heads = 4
        kv = max(1, heads // ratio)
        over = dict(
            n_layers=4 if c.family in ("ssm", "hybrid") else 2,
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=0 if c.d_ff == 0 else 128,
            vocab_size=512,
            vocab_pad_multiple=64,
            max_seq_len=512,
            remat="none",
            param_dtype=jnp.float32,
        )
        if c.n_experts:
            over.update(
                n_experts=8,
                top_k=min(c.top_k, 4),
                d_expert=32,
                n_shared_experts=min(c.n_shared_experts, 1),
                first_k_dense=min(c.first_k_dense, 1),
                dense_d_ff=128 if c.dense_d_ff else 0,
                moe_groups=2,
            )
        if c.family == "hybrid":
            over.update(attn_every=2, ssm_state=16)
        if c.family == "ssm" and c.slstm_every:
            over.update(slstm_every=4)
        if c.n_enc_layers:
            over.update(n_enc_layers=2, n_dec_layers=2)
        if c.frontend == "patch_stub":
            over.update(n_frontend_tokens=4)
        over.update(self.smoke_overrides)
        return c.replace(**over)


def _token_spec(batch: int, seq: int):
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(arch: ArchSpec, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the model inputs of one grid cell.

    For ``train``/``prefill`` this is the full batch dict; for ``decode``
    it is the one-token batch (the cache is built separately via
    ``eval_shape`` on the model's ``init_cache``).
    """
    c = arch.config
    shape = SHAPES[shape_name]
    b = shape.global_batch
    emb_dtype = c.dtype

    if shape.kind == "train":
        specs = {
            "tokens": _token_spec(b, shape.seq_len),
            "labels": _token_spec(b, shape.seq_len),
        }
        if c.frontend == "patch_stub":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, c.n_frontend_tokens, c.d_model), emb_dtype)
        if c.frontend == "audio_stub":
            specs["frame_embeds"] = jax.ShapeDtypeStruct(
                (b, shape.seq_len // 2, c.d_model), emb_dtype)
        return specs

    if shape.kind == "prefill":
        specs = {"tokens": _token_spec(b, shape.seq_len)}
        if c.frontend == "patch_stub":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, c.n_frontend_tokens, c.d_model), emb_dtype)
        if c.frontend == "audio_stub":
            specs["frame_embeds"] = jax.ShapeDtypeStruct(
                (b, arch.src_frames, c.d_model), emb_dtype)
        return specs

    # decode: one new token; the seq_len lives in the cache
    return {"tokens": _token_spec(b, 1)}
