"""xlstm-125m — [arXiv:2405.04517].

Assignment: [ssm] 12L d_model=768 4H (kv=4) d_ff=0 vocab=50304, sLSTM +
mLSTM blocks.  d_ff=0: xLSTM blocks carry their own up/down projections
(factor-2 mLSTM, gated sLSTM) instead of a separate FFN.  Every 4th block
is sLSTM (true recurrence, lax.scan), the rest mLSTM (chunked matrix
memory — parallel over time).

Linear-time recurrence => ``long_500k`` runs (O(1) decode state).
"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    norm_type="layernorm",
    slstm_every=4,
    ssm_conv=4,
    sharding_profile="fsdp",   # 125M: model axis folds into flat DP
    serve_profile="tp",
    supports_long_context=True,
)

ARCH = ArchSpec(config=CONFIG, source="arXiv:2405.04517")
