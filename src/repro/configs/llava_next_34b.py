"""llava-next-34b — [hf:llava-hf/llava-v1.6-34b-hf; unverified].

Assignment: [vlm] 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000,
anyres tiling.  Per assignment the modality frontend is a STUB: the
backbone receives precomputed patch embeddings (anyres 5 tiles x 576
patches = 2880 frontend tokens) through ``input_specs``; a learned
projection maps them into the residual stream.

Sharding: fsdp (flat batch) — 60 x (4k x 7168) residual carries exceed
HBM under plain tp; grad_accum=8 bounds the multi-pod microbatch.
"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64_000,
    norm_type="rmsnorm",
    rotary_pct=1.0,
    rope_theta=5_000_000.0,
    act="silu",
    mlp_gated=True,
    frontend="patch_stub",
    n_frontend_tokens=2880,    # anyres: 5 tiles x 576 patches
    sharding_profile="fsdp",
    serve_profile="ep",   # = tp + embed->data storage: 56 heads don't TP-shard,
                          # so attention weights must storage-shard over data
    shard_cache_seq=True,
)

ARCH = ArchSpec(config=CONFIG, source="hf:llava-hf/llava-v1.6-34b-hf",
                grad_accum=1, grad_accum_multipod=8)
