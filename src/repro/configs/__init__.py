"""Assigned-architecture registry: ``--arch <id>`` resolution.

Ten architectures from the public pool (see per-module docstrings for the
exact assignment line and citation) plus the paper's own workload config
(`contour_cc`) for the graph-connectivity engine.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import SHAPES, ArchSpec, ShapeSpec, input_specs

from repro.configs.stablelm_1_6b import ARCH as _stablelm
from repro.configs.olmo_1b import ARCH as _olmo
from repro.configs.mistral_nemo_12b import ARCH as _nemo
from repro.configs.yi_6b import ARCH as _yi
from repro.configs.xlstm_125m import ARCH as _xlstm
from repro.configs.zamba2_2_7b import ARCH as _zamba
from repro.configs.deepseek_moe_16b import ARCH as _dsmoe
from repro.configs.arctic_480b import ARCH as _arctic
from repro.configs.llava_next_34b import ARCH as _llava
from repro.configs.seamless_m4t_large_v2 import ARCH as _seamless

ARCHS: Dict[str, ArchSpec] = {
    a.name: a
    for a in (
        _stablelm, _olmo, _nemo, _yi, _xlstm,
        _zamba, _dsmoe, _arctic, _llava, _seamless,
    )
}


def get_arch(name: str) -> ArchSpec:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; one of {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "SHAPES", "ArchSpec", "ShapeSpec", "get_arch",
           "input_specs"]
