"""stablelm-1.6b — [hf:stabilityai/stablelm-2-1_6b; unverified].

Assignment: [dense] 24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352.
StableLM-2 flavour: parametric LayerNorm, partial rotary (25%), qkv biases,
SwiGLU MLP.
"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100_352,
    norm_type="layernorm",
    rotary_pct=0.25,
    rope_theta=10_000.0,
    use_qkv_bias=True,
    act="silu",
    mlp_gated=True,
    sharding_profile="fsdp",   # 1.6B on 256 chips: DP-dominant (see §Perf)
    serve_profile="tp",
)

ARCH = ArchSpec(config=CONFIG, source="hf:stabilityai/stablelm-2-1_6b")
