"""seamless-m4t-large-v2 — [arXiv:2308.11596].

Assignment: [audio] 24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206,
encoder-decoder, multimodal.  Per assignment the speech frontend is a
STUB: ``input_specs`` supplies precomputed frame embeddings (already at
d_model) to the bidirectional encoder; the autoregressive text decoder
(self-attn + cross-attn + MLP) carries the decode shapes.

24 encoder + 24 decoder layers.  Training pairs ``seq_len/2`` encoder
frames with ``seq_len`` decoder tokens; serving uses ``src_frames``
encoder frames with the decoder KV cache at ``seq_len``.
"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    n_enc_layers=24,
    n_dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    norm_type="layernorm",
    rotary_pct=0.0,            # seamless uses learned/relative positions;
                               # the backbone stub runs position-free decoder
    act="gelu",
    mlp_gated=False,
    frontend="audio_stub",
    sharding_profile="fsdp",   # 2.3B enc-dec: DP-dominant (see §Perf)
    serve_profile="tp",
)

ARCH = ArchSpec(config=CONFIG, source="arXiv:2308.11596", src_frames=4096)
