"""Checkpointing: atomic, keep-k, async-capable, elastic on restore.

Layout: ``<dir>/step_<k>/`` holds one ``.npy`` per pytree leaf (path-encoded
file names) plus a ``manifest.json`` with the treedef, shapes and dtypes.
Commit protocol: write into ``step_<k>.tmp`` then ``os.rename`` — readers
never observe a partial checkpoint, and a crash mid-save leaves the
previous step intact (restart-safety half of fault tolerance; the data
pipeline's seekability is the other half).

Elasticity: leaves are saved as *global* arrays (host-gathered), so a
restore may target a different mesh/device count — ``restore_checkpoint``
re-places every leaf against the shardings the new job provides.  At real
multi-pod scale the same protocol runs per-host with a shard index in the
manifest; the commit/rename logic is identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        name = "__".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        names.append(name)
        leaves.append(leaf)
    return names, leaves, jax.tree_util.tree_structure(tree)


def save_checkpoint(directory: str, step: int, state: Any) -> str:
    """Atomically save ``state`` at ``step``. Returns the final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    names, leaves, _ = _flatten_with_names(state)
    manifest = {"step": step, "leaves": []}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(leaf)       # host-gather (global array)
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)            # atomic commit
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str, like: Any, step: Optional[int] = None, shardings: Any = None
) -> tuple[Any, int]:
    """Restore into the structure of ``like``; re-shard if ``shardings``.

    ``like`` may be concrete arrays or ShapeDtypeStructs — only the
    treedef is used.  Elastic restores (different device count/mesh) work
    because the on-disk arrays are global.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    names, _, treedef = _flatten_with_names(like)
    arrs = [np.load(os.path.join(path, n + ".npy")) for n in names]
    restored = jax.tree_util.tree_unflatten(treedef, arrs)
    if shardings is not None:
        restored = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), restored, shardings
        )
    return restored, step


class CheckpointManager:
    """Keep-k manager with optional async (background-thread) saves."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d))

    def save(self, step: int, state: Any):
        # snapshot to host *now* (cheap; avoids racing the training step),
        # write in the background
        names_leaves = jax.tree_util.tree_map(np.asarray, state)

        def work():
            save_checkpoint(self.directory, step, names_leaves)
            self._gc()

        if self.async_save:
            self.wait()
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, like: Any, step: Optional[int] = None, shardings: Any = None):
        self.wait()
        return restore_checkpoint(self.directory, like, step, shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)
