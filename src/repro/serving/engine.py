"""Connectivity-as-a-service: the async request-batching engine.

:class:`ConnectivityEngine` turns one
:class:`~repro.connectivity.streaming.StreamingConnectivity` into a
multi-client service, in the JetStream/continuous-batching mold the LM
server (``repro.launch.serve``) uses for decode slots:

* **Two bounded queues, one worker.**  Clients submit edge-ingest and
  ``same_component``/``component_of``/``n_components`` requests into
  separate :class:`~repro.serving.primitives.BoundedQueue`\\ s; a single
  worker thread owns the stream, so every mutation is serialised and
  every answer comes from a *committed* snapshot (snapshot isolation for
  free — concurrent readers can never observe a mid-ingest state,
  because mid-ingest states only ever exist inside the worker's call
  frame, and a failed ingest rolls back atomically before anyone else
  runs).  Full queues reject with a ``retry_after`` hint instead of
  blocking (backpressure must shed load at the edge).

* **Coalesced, bucketed query batches.**  Each tick the worker drains
  every pending query, packs the gather-shaped ones
  (``same_component``/``component_of``) into one ``(u, v)`` pair batch
  padded to a power-of-two bucket, and answers them with a single
  jitted device gather against the engine's label array *at capacity*
  — so the compile cache holds one program per (label-capacity, batch-
  bucket) pair, not one per batch size (FastSV's lesson: batch all
  pending work into one vectorized sweep).  ``n_components`` answers
  ride the snapshot's cached component decomposition.

* **Deadlines and cancellation.**  A request cancelled while queued is
  dropped unanswered (``Future`` cancel protocol); one whose deadline
  passed before the coalescer reached it resolves to
  :class:`DeadlineExceeded` without paying for a gather slot.

* **Recovery without dropping acks.**  With a ``CheckpointManager`` the
  engine checkpoints the stream every ``checkpoint_every`` committed
  batches (or immediately when a straggler monitor escalates) and keeps
  the committed-but-not-yet-checkpointed suffix in a host-side WAL.  A
  recoverable fault during ingest (PR-5's crash class) discards the
  live engine, restores the last checkpoint, replays the WAL suffix,
  and retries — so an ingest whose future resolved OK (an *ack*) can
  never be lost, and the recovered stream is bit-identical to an
  uninterrupted one (DESIGN.md §12's atomic-ingest + deterministic-
  replay argument, applied to a live service).  Without a manager,
  ingest atomicity alone makes recoverable faults plain retries.

Queries are validated host-side against the committed vertex count
before they reach the device, because the XLA gather otherwise *clamps*
out-of-range ids to valid indices and silently answers for the wrong
vertex — the PR-3 negative-warm-start failure class.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import List, Optional, Sequence, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.connectivity.options import SolveOptions
from repro.connectivity.result import ComponentResult
from repro.connectivity.streaming import StreamingConnectivity
from repro.runtime.recovery import (FaultInjector, SimulatedFault,
                                    backoff_delay)
from repro.runtime.straggler import StragglerMonitor
from repro.serving.metrics import ServingMetrics
from repro.serving.primitives import (BoundedQueue, QueueFull, ServeRequest,
                                      pow2_bucket)

QUERY_KINDS = ("same_component", "component_of", "n_components")
# floor for the query-batch compile bucket: tiny batches all share one
# program instead of compiling 1/2/4/8... separately
MIN_QUERY_BUCKET = 64


class EngineClosed(RuntimeError):
    """The engine is shut down; the request was not (or will not be)
    served."""


class DeadlineExceeded(TimeoutError):
    """The request's deadline passed before the engine answered it."""


@dataclasses.dataclass(frozen=True)
class IngestAck:
    """Successful-ingest acknowledgement (the ingest future's value).

    Once a client holds an ack, the batch is committed and — when the
    engine checkpoints — durable: recovery replays it, never drops it.

    Attributes:
      batch_index: position of the batch in the stream (0-based).
      n_vertices: logical vertex count after the batch.
      n_edges: real edges ingested so far (cumulative).
      visibility_lag_s: submit-to-committed wall time — how stale a
        query issued at submit time could have been.
    """

    batch_index: int
    n_vertices: int
    n_edges: int
    visibility_lag_s: float


@dataclasses.dataclass(frozen=True)
class _Query:
    kind: str
    u: int = 0
    v: int = 0


@dataclasses.dataclass(frozen=True)
class _Ingest:
    src: np.ndarray
    dst: np.ndarray
    n_vertices: Optional[int]


@jax.jit
def _gather_pair_labels(labels: jax.Array, u: jax.Array, v: jax.Array):
    """One device gather for a whole coalesced query batch.

    ``labels`` is the stream's label array at pow2 *capacity* and
    ``u``/``v`` are pow2-bucketed, so the jit cache holds one program
    per (capacity, bucket) pair.  Bounds are validated host-side before
    this call — XLA's clamp semantics must never be reachable.
    """
    return labels[u], labels[v]


class ConnectivityEngine:
    """Async request-batching service over a streaming connectivity core.

    Example::

        eng = ConnectivityEngine(n_vertices=1_000_000)
        eng.start()
        ack = eng.submit_ingest(src, dst).result()     # committed
        fut = eng.submit_query("same_component", 0, 42)
        connected = fut.result()
        eng.close()

    Most callers want the :class:`~repro.serving.client.ConnectivityClient`
    façade instead of raw futures.

    Args:
      n_vertices: initial vertex count of the stream.
      options / overrides: engine :class:`SolveOptions`, as for
        :class:`StreamingConnectivity`.
      max_pending_ingests / max_pending_queries: queue depth bounds;
        full queues reject with :class:`~repro.serving.primitives.QueueFull`
        carrying a ``retry_after`` estimate.
      max_query_batch: coalescer drain bound per tick (also the largest
        compile bucket).
      manager: optional :class:`~repro.checkpoint.manager.CheckpointManager`
        enabling crash-restart recovery (checkpoint cadence + WAL replay).
      checkpoint_every: checkpoint cadence in committed batches.
      recoverable: exception types treated as engine crashes (restore +
        replay + retry); anything else fails the ingest future and the
        stream stays intact (ingest is atomic).
      max_restarts: recovery budget across the engine's lifetime.
      backoff_base / backoff_factor / backoff_cap / sleep_fn: restart
        backoff schedule (0 = none), injectable for tests.
      straggler: optional :class:`StragglerMonitor` fed per-ingest wall
        time; a ``"checkpoint"``/``"evict"`` escalation forces an
        immediate out-of-cadence checkpoint.
      fault_injector: chaos hook threaded to the stream's ingest sites.
      metrics: a :class:`ServingMetrics` to record into (fresh if None).
    """

    def __init__(
        self,
        n_vertices: int,
        options: Optional[SolveOptions] = None,
        *,
        max_pending_ingests: int = 256,
        max_pending_queries: int = 8192,
        max_query_batch: int = 4096,
        manager=None,
        checkpoint_every: int = 64,
        recoverable: Tuple[Type[BaseException], ...] = (SimulatedFault,),
        max_restarts: int = 5,
        backoff_base: float = 0.0,
        backoff_factor: float = 2.0,
        backoff_cap: float = 30.0,
        sleep_fn=time.sleep,
        straggler: Optional[StragglerMonitor] = None,
        fault_injector: Optional[FaultInjector] = None,
        metrics: Optional[ServingMetrics] = None,
        **overrides,
    ):
        if max_query_batch < 1:
            raise ValueError(
                f"max_query_batch must be >= 1, got {max_query_batch}")
        if checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {checkpoint_every}")
        self._options = options
        self._overrides = dict(overrides)
        self._fault_injector = fault_injector
        self._initial_n = int(n_vertices)
        self._stream = self._fresh_stream(n_vertices)
        self._ingest_q = BoundedQueue(max_pending_ingests, name="ingest")
        self._query_q = BoundedQueue(max_pending_queries, name="query")
        self.max_query_batch = int(max_query_batch)
        self._manager = manager
        self._checkpoint_every = int(checkpoint_every)
        self._recoverable = tuple(recoverable)
        self._max_restarts = int(max_restarts)
        self._restarts = 0
        self._backoff = (float(backoff_base), float(backoff_factor),
                         float(backoff_cap))
        self._sleep_fn = sleep_fn
        self._straggler = straggler
        self.metrics = metrics if metrics is not None else ServingMetrics()
        # committed-but-not-checkpointed suffix: [(batch_idx, _Ingest)]
        self._wal: List[Tuple[int, _Ingest]] = []
        self._ewma_tick = 1e-3          # service-rate estimate (s/tick)
        self._closed = False
        self._wake = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._worker: Optional[threading.Thread] = None
        self._worker_error: Optional[BaseException] = None

    def _fresh_stream(self, n_vertices: int) -> StreamingConnectivity:
        return StreamingConnectivity(
            n_vertices, self._options,
            fault_injector=self._fault_injector, **self._overrides)

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "ConnectivityEngine":
        """Spawn the worker thread (idempotent)."""
        if self._worker is None:
            self._worker = threading.Thread(
                target=self._run, name="connectivity-engine", daemon=True)
            self._worker.start()
        return self

    def __enter__(self) -> "ConnectivityEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self, drain: bool = True) -> None:
        """Stop accepting requests; by default serve what is queued first.

        ``drain=False`` fails all still-pending requests with
        :class:`EngineClosed` instead.
        """
        self._closed = True
        if not drain:
            for q in (self._ingest_q, self._query_q):
                for req in q.drain():
                    self._resolve_exc(req, EngineClosed("engine closed"))
        self._wake.set()
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._worker_error is not None:
            raise self._worker_error

    def flush(self, timeout: float = 60.0) -> None:
        """Block until both queues are empty and the worker is idle."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self._worker_error is not None:
                raise self._worker_error
            if (len(self._ingest_q) == 0 and len(self._query_q) == 0
                    and self._idle.is_set()):
                return
            time.sleep(50e-6)
        raise TimeoutError(f"engine did not drain within {timeout}s")

    # -- introspection ---------------------------------------------------
    @property
    def n_vertices(self) -> int:
        return self._stream.n_vertices

    @property
    def n_batches(self) -> int:
        return self._stream.n_batches

    @property
    def restarts(self) -> int:
        return self._restarts

    def snapshot(self) -> ComponentResult:
        """Committed-state snapshot (worker-thread coherent: callers see
        some committed prefix, never a mid-ingest state)."""
        return self._stream.snapshot()

    # -- submission (client threads) -------------------------------------
    def _retry_after(self, queue: BoundedQueue) -> float:
        # service-rate heuristic: pending work / coalesced throughput,
        # floored at one tick
        pending = len(queue)
        ticks = 1.0 + pending / max(self.max_query_batch, 1)
        return self._ewma_tick * ticks

    def _submit(self, queue: BoundedQueue, payload,
                timeout: Optional[float]) -> Future:
        if self._closed:
            raise EngineClosed("engine closed")
        if self._worker_error is not None:
            raise self._worker_error
        now = time.perf_counter()
        req = ServeRequest(
            payload=payload, submitted=now,
            deadline=None if timeout is None else now + timeout)
        try:
            queue.put(req, retry_after=self._retry_after(queue))
        except QueueFull:
            self.metrics.bump("rejected")
            raise
        self._wake.set()
        return req.future

    def submit_query(self, kind: str, u: Optional[int] = None,
                     v: Optional[int] = None, *,
                     timeout: Optional[float] = None) -> Future:
        """Enqueue one query; the future resolves to its answer.

        ``same_component(u, v)`` -> bool; ``component_of(u)`` -> int
        (min vertex id of the component); ``n_components`` -> int.
        ``timeout`` is a *deadline*: if the coalescer reaches the
        request later than that, the future fails with
        :class:`DeadlineExceeded` instead of answering stale.
        """
        if kind not in QUERY_KINDS:
            raise ValueError(f"kind {kind!r} not one of {QUERY_KINDS}")
        if kind == "same_component":
            q = _Query(kind, int(u), int(v))
        elif kind == "component_of":
            if v is not None:
                raise ValueError("component_of takes a single vertex")
            q = _Query(kind, int(u), int(u))
        else:
            if u is not None or v is not None:
                raise ValueError("n_components takes no vertices")
            q = _Query(kind)
        return self._submit(self._query_q, q, timeout)

    def submit_ingest(self, src, dst, n_vertices: Optional[int] = None, *,
                      timeout: Optional[float] = None) -> Future:
        """Enqueue one edge micro-batch; resolves to an :class:`IngestAck`.

        The arrays are snapshotted to host NumPy at submit time (the WAL
        must be able to replay them after the caller mutates its
        buffers).
        """
        src = np.ascontiguousarray(np.asarray(src, np.int32))
        dst = np.ascontiguousarray(np.asarray(dst, np.int32))
        if src.shape != dst.shape or src.ndim != 1:
            raise ValueError(
                f"src/dst must be equal-length 1-D, got {src.shape} vs "
                f"{dst.shape}")
        return self._submit(
            self._ingest_q, _Ingest(src, dst, n_vertices), timeout)

    # -- worker loop -----------------------------------------------------
    def _run(self) -> None:
        # idle is only truthful while the worker is actually parked in
        # the wait branch below; it starts cleared so flush() cannot
        # return while the first tick is in flight
        self._idle.clear()
        try:
            while True:
                t0 = time.perf_counter()
                self.metrics.ingest_queue_depth.observe(len(self._ingest_q))
                self.metrics.query_queue_depth.observe(len(self._query_q))
                # queries first: reads coalesce against the committed
                # snapshot between ingest ticks
                batch = self._query_q.drain(self.max_query_batch)
                ingest = self._ingest_q.get_nowait()
                if batch:
                    self._answer_queries(batch)
                if ingest is not None:
                    self._ingest_tick(ingest)
                if batch or ingest is not None:
                    dt = time.perf_counter() - t0
                    self._ewma_tick = 0.9 * self._ewma_tick + 0.1 * dt
                    continue
                if self._closed:
                    return
                self._idle.set()
                self._wake.wait(timeout=5e-3)
                self._wake.clear()
                self._idle.clear()
        except BaseException as exc:  # noqa: BLE001 — fail loudly via futures
            self._worker_error = exc
            for q in (self._ingest_q, self._query_q):
                for req in q.drain():
                    self._resolve_exc(req, EngineClosed(
                        f"engine worker died: {exc!r}"))
            raise
        finally:
            self._idle.set()

    @staticmethod
    def _resolve_exc(req: ServeRequest, exc: Exception) -> None:
        if req.begin():
            req.future.set_exception(exc)

    # -- query coalescer -------------------------------------------------
    def _answer_queries(self, batch: Sequence[ServeRequest]) -> None:
        now = time.perf_counter()
        live: List[ServeRequest] = []
        for req in batch:
            if req.expired(now):
                self.metrics.bump("deadline_missed")
                self._resolve_exc(req, DeadlineExceeded(
                    "query deadline passed before the coalescer reached it"))
            elif req.begin():
                live.append(req)
            else:
                self.metrics.bump("cancelled")
        if not live:
            return
        self.metrics.bump("query_batches")
        n = self._stream.n_vertices
        gathers = [r for r in live if r.payload.kind != "n_components"]
        counts = [r for r in live if r.payload.kind == "n_components"]
        if counts:
            # one cached host decomposition per committed snapshot
            k = self._stream.snapshot().n_components
            for req in counts:
                req.future.set_result(k)
        if gathers:
            us = np.fromiter((r.payload.u for r in gathers), np.int32,
                             len(gathers))
            vs = np.fromiter((r.payload.v for r in gathers), np.int32,
                             len(gathers))
            # host-side bounds check against the committed vertex count:
            # the device gather would clamp, answering for the wrong
            # vertex (see module docstring)
            bad = (us < 0) | (us >= n) | (vs < 0) | (vs >= n)
            if bad.any():
                ok: List[ServeRequest] = []
                for req, is_bad in zip(gathers, bad):
                    if is_bad:
                        req.future.set_exception(IndexError(
                            f"vertex id out of range for n_vertices={n} "
                            f"(query {req.payload.kind}({req.payload.u}, "
                            f"{req.payload.v}))"))
                    else:
                        ok.append(req)
                gathers = ok
                us, vs = us[~bad], vs[~bad]
        if gathers:
            bucket = pow2_bucket(len(gathers), MIN_QUERY_BUCKET)
            self.metrics.batch_sizes.observe(len(gathers))
            up = np.zeros(bucket, np.int32)
            vp = np.zeros(bucket, np.int32)
            up[:len(gathers)] = us
            vp[:len(gathers)] = vs
            lu, lv = _gather_pair_labels(self._stream._labels,
                                         jnp.asarray(up), jnp.asarray(vp))
            lu = np.asarray(lu)[:len(gathers)]
            lv = np.asarray(lv)[:len(gathers)]
            for i, req in enumerate(gathers):
                if req.payload.kind == "same_component":
                    req.future.set_result(bool(lu[i] == lv[i]))
                else:
                    req.future.set_result(int(lu[i]))
        done = time.perf_counter()
        self.metrics.query_latency.record_many(
            [done - r.submitted for r in live])
        self.metrics.bump("queries_answered", len(live))

    # -- ingest tick + recovery ------------------------------------------
    def _ingest_tick(self, req: ServeRequest) -> None:
        if req.expired():
            self.metrics.bump("deadline_missed")
            self._resolve_exc(req, DeadlineExceeded(
                "ingest deadline passed before the engine reached it"))
            return
        if not req.begin():
            self.metrics.bump("cancelled")
            return
        self.metrics.bump("ingest_ticks")
        ing: _Ingest = req.payload
        batch_idx = self._stream.n_batches
        while True:
            try:
                if self._straggler is not None:
                    self._straggler.start_step()
                self._stream.ingest(ing.src, ing.dst,
                                    n_vertices=ing.n_vertices)
                break
            except self._recoverable as exc:
                # crash class: the live engine is gone — restore the
                # last checkpoint, replay the acked suffix, retry
                self._restarts += 1
                self.metrics.bump("restarts")
                if self._restarts > self._max_restarts:
                    req.future.set_exception(exc)
                    raise
                base, factor, cap = self._backoff
                delay = backoff_delay(self._restarts, base=base,
                                      factor=factor, cap=cap)
                if delay > 0:
                    self._sleep_fn(delay)
                self._restore_and_replay()
                batch_idx = self._stream.n_batches
            except Exception as exc:  # noqa: BLE001 — per-request failure
                # caller-bug class (bad ids, shapes): ingest rolled back
                # atomically, the stream is intact — fail this request
                # only
                req.future.set_exception(exc)
                return
        if self._manager is not None:
            self._wal.append((batch_idx, ing))
        committed = self._stream.n_batches
        forced = False
        if self._straggler is not None:
            action = self._straggler.end_step()
            if action in ("checkpoint", "evict"):
                self.metrics.bump("straggler_events")
                forced = True
        if self._manager is not None and (
                forced or committed % self._checkpoint_every == 0):
            self._checkpoint(committed)
        lag = time.perf_counter() - req.submitted
        self.metrics.ingest_visibility.record(lag)
        self.metrics.bump("ingests_committed")
        self.metrics.bump("edges_ingested", int(ing.src.shape[0]))
        req.future.set_result(IngestAck(
            batch_index=batch_idx,
            n_vertices=self._stream.n_vertices,
            n_edges=self._stream.n_edges,
            visibility_lag_s=lag))

    def _checkpoint(self, committed: int) -> None:
        self._stream.save(self._manager, committed)
        self._manager.wait()
        self.metrics.bump("checkpoints")
        # checkpointed batches no longer need host-side replay state
        self._wal = [(i, b) for i, b in self._wal if i >= committed]

    def _restore_and_replay(self) -> None:
        """Rebuild the stream after a crash-class fault.

        With a manager: restore the last checkpoint and replay the WAL
        suffix (every committed batch after it) — acks are never lost.
        Without one, ingest atomicity means the in-memory stream is
        still exactly the committed state; there is nothing to rebuild.
        """
        if self._manager is None:
            return
        if self._manager.latest_step() is not None:
            self._stream, step = StreamingConnectivity.restore(
                self._manager, self._options,
                fault_injector=self._fault_injector, **self._overrides)
        else:
            # no checkpoint yet: the WAL holds *every* committed batch,
            # so a cold rebuild from the engine's initial vertex count
            # replays the whole committed prefix
            self._stream, step = self._fresh_stream(self._initial_n), 0
        for _, b in sorted(((i, b) for i, b in self._wal if i >= step),
                           key=lambda e: e[0]):
            self._stream.ingest(b.src, b.dst, n_vertices=b.n_vertices)
            self.metrics.bump("replayed_batches")
