"""Connectivity-as-a-service: async request-batching over the stream.

Public surface::

    from repro.serving import ConnectivityEngine, ConnectivityClient

    with ConnectivityEngine(n_vertices=1_000_000) as eng:
        client = ConnectivityClient(eng)
        client.ingest(src, dst)                 # blocks for the ack
        client.same_component(0, 42)            # coalesced device gather

See DESIGN.md §13 for the architecture (queues, coalescing, compile-
cache buckets, backpressure, recovery story) and
``repro.serving.simulate`` for the heavy-traffic harness behind
``BENCH_serving.json``.
"""
from repro.serving.client import ConnectivityClient
from repro.serving.engine import (ConnectivityEngine, DeadlineExceeded,
                                  EngineClosed, IngestAck)
from repro.serving.metrics import ServingMetrics
from repro.serving.primitives import (BoundedQueue, QueueFull, ServeRequest,
                                      SlotPool, pow2_bucket)

__all__ = [
    "BoundedQueue",
    "ConnectivityClient",
    "ConnectivityEngine",
    "DeadlineExceeded",
    "EngineClosed",
    "IngestAck",
    "QueueFull",
    "ServeRequest",
    "ServingMetrics",
    "SlotPool",
    "pow2_bucket",
]
