"""Serving observability: latency percentiles, histograms, counters.

The metrics layer is deliberately boring and allocation-light — it sits
on the engine's hot loop.  Latencies append to a growable float array
(amortised O(1), 8 bytes/sample — a million-query run costs 8 MB);
histograms count into power-of-two buckets (the same bucketing rule the
compile caches use, so the batch-size histogram doubles as a compile-
cache census); counters take a tiny lock because producers increment
them from client threads.

``ServingMetrics.summary()`` flattens everything into the plain-dict
shape ``BENCH_serving.json`` records, so the bench artifact and the
engine's live introspection cannot drift apart.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, Optional

import numpy as np

from repro.serving.primitives import pow2_bucket

PERCENTILES = (50.0, 95.0, 99.0)


class LatencyRecorder:
    """Append-only latency samples (seconds) with percentile summaries."""

    def __init__(self):
        self._buf = np.empty(1024, np.float64)
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def record(self, dt: float) -> None:
        if self._n == self._buf.shape[0]:
            self._buf = np.concatenate([self._buf, np.empty_like(self._buf)])
        self._buf[self._n] = dt
        self._n += 1

    def record_many(self, dts: Iterable[float]) -> None:
        dts = np.asarray(list(dts) if not isinstance(dts, np.ndarray)
                         else dts, np.float64)
        need = self._n + dts.shape[0]
        if need > self._buf.shape[0]:
            self._buf = np.concatenate(
                [self._buf, np.empty(max(need, self._buf.shape[0]),
                                     np.float64)])
        self._buf[self._n:need] = dts
        self._n = need

    def samples(self) -> np.ndarray:
        return self._buf[:self._n]

    def summary_ms(self) -> Dict[str, float]:
        """p50/p95/p99 + mean/max in milliseconds (zeros when empty)."""
        if self._n == 0:
            return {"p50": 0.0, "p95": 0.0, "p99": 0.0,
                    "mean": 0.0, "max": 0.0, "count": 0}
        s = self.samples() * 1e3
        pcts = np.percentile(s, PERCENTILES)
        return {"p50": float(pcts[0]), "p95": float(pcts[1]),
                "p99": float(pcts[2]), "mean": float(s.mean()),
                "max": float(s.max()), "count": int(self._n)}


class Pow2Histogram:
    """Counting histogram over power-of-two buckets.

    ``observe(v)`` counts ``v`` into bucket ``pow2_bucket(v)`` (0 gets
    its own bucket, so an idle queue is visible as such).  Serialises to
    ``{bucket: count}`` with string keys for JSON.
    """

    def __init__(self):
        self._counts: Dict[int, int] = {}

    def observe(self, value: int, count: int = 1) -> None:
        b = 0 if value <= 0 else pow2_bucket(value)
        self._counts[b] = self._counts.get(b, 0) + count

    @property
    def total(self) -> int:
        return sum(self._counts.values())

    def to_dict(self) -> Dict[str, int]:
        return {str(k): self._counts[k] for k in sorted(self._counts)}


class ServingMetrics:
    """All engine observability in one bag (see module docstring).

    Single-writer fields (latency recorders, histograms) are touched
    only by the engine worker thread; the counters are incremented from
    client threads too and take ``_lock``.
    """

    COUNTERS = ("queries_answered", "ingests_committed", "edges_ingested",
                "rejected", "deadline_missed", "cancelled",
                "query_batches", "ingest_ticks", "restarts", "checkpoints",
                "replayed_batches", "straggler_events")

    def __init__(self):
        self.query_latency = LatencyRecorder()
        # submit -> commit-visible: the ingest-to-visibility lag
        self.ingest_visibility = LatencyRecorder()
        self.batch_sizes = Pow2Histogram()       # coalesced query batches
        self.ingest_queue_depth = Pow2Histogram()  # sampled once per tick
        self.query_queue_depth = Pow2Histogram()
        self._lock = threading.Lock()
        self._counters = {k: 0 for k in self.COUNTERS}

    def bump(self, counter: str, by: int = 1) -> None:
        with self._lock:
            self._counters[counter] += by

    def count(self, counter: str) -> int:
        with self._lock:
            return self._counters[counter]

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counters)

    def summary(self, wall_s: Optional[float] = None) -> dict:
        """Flatten to the ``BENCH_serving.json`` results shape."""
        c = self.counters()
        out = {
            "latency_ms": self.query_latency.summary_ms(),
            "ingest_visibility_ms": self.ingest_visibility.summary_ms(),
            "batch_size_hist": self.batch_sizes.to_dict(),
            "queue_depth_hist": {
                "ingest": self.ingest_queue_depth.to_dict(),
                "query": self.query_queue_depth.to_dict(),
            },
            "counters": c,
        }
        if wall_s is not None and wall_s > 0:
            out["wall_s"] = float(wall_s)
            out["throughput_qps"] = c["queries_answered"] / wall_s
            out["ingest_batches_per_s"] = c["ingests_committed"] / wall_s
        return out
