"""Client façade over :class:`~repro.serving.engine.ConnectivityEngine`.

The engine speaks futures; most callers want one of two ergonomic
surfaces on top:

* **Sync** — ``client.same_component(u, v)`` blocks until the coalescer
  answers (optionally bounded by ``timeout``, which doubles as the
  server-side deadline: a request the engine cannot reach in time fails
  with :class:`~repro.serving.engine.DeadlineExceeded` rather than
  answering stale).

* **Async** — the ``*_async`` variants return
  :class:`concurrent.futures.Future`\\ s so a client thread can keep
  hundreds of requests in flight (the whole point of the coalescer:
  concurrent pending queries become one vmapped gather).  ``Future.
  cancel()`` works while the request is still queued.

``retries`` makes the client cooperate with engine backpressure: a
:class:`~repro.serving.primitives.QueueFull` rejection sleeps the
suggested ``retry_after`` (doubled per consecutive rejection, capped at
``RETRY_CAP_S`` — the engine's hint is an EWMA of recent tick times,
which undershoots badly during cold-start jit compiles) and resubmits,
up to the budget.
"""
from __future__ import annotations

import time
from concurrent.futures import Future
from typing import List, Optional

from repro.serving.engine import ConnectivityEngine, IngestAck
from repro.serving.primitives import QueueFull

RETRY_CAP_S = 0.25


class ConnectivityClient:
    """Sync/async request surface for one :class:`ConnectivityEngine`.

    Args:
      engine: the (started) engine to talk to.
      retries: resubmission budget when the engine rejects with
        backpressure; 0 = surface :class:`QueueFull` immediately.
      retry_sleep: sleep function (injectable for tests); receives the
        backed-off ``retry_after`` hint.
    """

    def __init__(self, engine: ConnectivityEngine, *, retries: int = 12,
                 retry_sleep=time.sleep):
        self.engine = engine
        self.retries = int(retries)
        self._sleep = retry_sleep

    def _with_backpressure(self, submit) -> Future:
        attempt = 0
        while True:
            try:
                return submit()
            except QueueFull as exc:
                attempt += 1
                if attempt > self.retries:
                    raise
                self._sleep(min(max(exc.retry_after, 1e-4)
                                * 2.0 ** (attempt - 1), RETRY_CAP_S))

    # -- async surface ---------------------------------------------------
    def same_component_async(self, u: int, v: int, *,
                             timeout: Optional[float] = None) -> Future:
        return self._with_backpressure(
            lambda: self.engine.submit_query("same_component", u, v,
                                             timeout=timeout))

    def component_of_async(self, v: int, *,
                           timeout: Optional[float] = None) -> Future:
        return self._with_backpressure(
            lambda: self.engine.submit_query("component_of", v,
                                             timeout=timeout))

    def n_components_async(self, *,
                           timeout: Optional[float] = None) -> Future:
        return self._with_backpressure(
            lambda: self.engine.submit_query("n_components",
                                             timeout=timeout))

    def ingest_async(self, src, dst, n_vertices: Optional[int] = None, *,
                     timeout: Optional[float] = None) -> Future:
        return self._with_backpressure(
            lambda: self.engine.submit_ingest(src, dst, n_vertices,
                                              timeout=timeout))

    # -- sync surface ----------------------------------------------------
    def same_component(self, u: int, v: int, *,
                       timeout: Optional[float] = None) -> bool:
        return self.same_component_async(u, v, timeout=timeout).result(
            timeout)

    def component_of(self, v: int, *,
                     timeout: Optional[float] = None) -> int:
        return self.component_of_async(v, timeout=timeout).result(timeout)

    def n_components(self, *, timeout: Optional[float] = None) -> int:
        return self.n_components_async(timeout=timeout).result(timeout)

    def ingest(self, src, dst, n_vertices: Optional[int] = None, *,
               timeout: Optional[float] = None) -> IngestAck:
        """Submit one edge micro-batch and block for its ack.

        A returned :class:`IngestAck` means the batch is committed —
        subsequent queries observe it, and with checkpointing enabled a
        crash-restarted engine replays it (zero acked-ingest loss).
        """
        return self.ingest_async(src, dst, n_vertices,
                                 timeout=timeout).result(timeout)

    def map_component_of(self, vertices, *,
                         timeout: Optional[float] = None) -> List[int]:
        """Bulk helper: fan a vertex list into in-flight queries, gather
        the answers in order (exercises the coalescer from one thread)."""
        futs = [self.component_of_async(int(v), timeout=timeout)
                for v in vertices]
        return [f.result(timeout) for f in futs]
