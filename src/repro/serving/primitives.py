"""Generic request/queue/slot primitives shared by the serving layers.

One queueing idiom for the whole repo: the connectivity engine
(``repro.serving.engine``) and the LM continuous-batching server
(``repro.launch.serve.BatchedServer``) both build on these pieces
instead of growing private variants.

* :class:`BoundedQueue` — thread-safe FIFO with **reject-not-block**
  admission: a full queue raises :class:`QueueFull` carrying a
  ``retry_after`` hint instead of blocking the producer, the JetStream
  backpressure idiom (an overloaded engine must shed load at the edge,
  not wedge every client thread).  Consumers drain in batches
  (``drain``/``get_batch``) so a coalescer takes everything pending in
  one lock acquisition.

* :class:`SlotPool` — fixed set of integer slots with acquire/release,
  the continuous-batching resource model (a freed decode slot admits
  the next queued request).

* :class:`ServeRequest` — payload + :class:`concurrent.futures.Future`
  + submit timestamp + optional deadline.  The future carries the
  answer to sync *and* async callers; ``begin()`` resolves the
  cancellation race (a request cancelled while queued is never
  answered).

* :func:`pow2_bucket` — the repo-wide compile-cache bucketing rule
  (ring-buffer sizes, ingest padding, query-batch shapes all quantise
  to powers of two so each shape compiles once).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any, List, Optional


def pow2_bucket(k: int, lo: int = 1) -> int:
    """Smallest power of two >= max(k, lo).

    The shared bucketing rule for jit compile caches: padding every
    dynamic extent (ingest batch, query batch, ring capacity) to a
    power-of-two bucket keeps the number of distinct compiled shapes
    logarithmic in the largest extent ever seen.
    """
    k = max(int(k), int(lo), 1)
    return 1 << (k - 1).bit_length()


class QueueFull(Exception):
    """Admission rejected: the queue is at capacity (backpressure).

    Attributes:
      name: queue name (e.g. ``"ingest"`` / ``"query"``).
      depth: capacity at rejection time.
      retry_after: suggested client wait in seconds before retrying
        (an engine-side service-rate estimate; 0.0 when unknown).
    """

    def __init__(self, name: str, depth: int, retry_after: float = 0.0):
        super().__init__(
            f"{name} queue full (depth {depth}); retry after "
            f"{retry_after * 1e3:.1f} ms")
        self.name = name
        self.depth = depth
        self.retry_after = float(retry_after)


class BoundedQueue:
    """Thread-safe bounded FIFO with reject-not-block admission.

    ``maxsize=None`` disables the bound (e.g. a serve-to-completion
    admission queue that holds the whole request list).
    """

    def __init__(self, maxsize: Optional[int] = None, name: str = "queue"):
        if maxsize is not None and maxsize < 1:
            raise ValueError(f"maxsize must be >= 1 or None, got {maxsize}")
        self.maxsize = maxsize
        self.name = name
        self._items: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def put(self, item: Any, retry_after: float = 0.0) -> None:
        """Append ``item``; raises :class:`QueueFull` at capacity."""
        with self._lock:
            if self.maxsize is not None and len(self._items) >= self.maxsize:
                raise QueueFull(self.name, self.maxsize, retry_after)
            self._items.append(item)
            self._not_empty.notify()

    def get_nowait(self) -> Optional[Any]:
        """Pop the head, or None when empty (never blocks)."""
        with self._lock:
            return self._items.popleft() if self._items else None

    def drain(self, max_items: Optional[int] = None) -> List[Any]:
        """Pop up to ``max_items`` (all, when None) in FIFO order.

        One lock acquisition for the whole batch — the coalescer's
        fast path.
        """
        with self._lock:
            k = len(self._items) if max_items is None \
                else min(max_items, len(self._items))
            return [self._items.popleft() for _ in range(k)]

    def get_batch(self, max_items: int, timeout: float) -> List[Any]:
        """Block until >= 1 item (or ``timeout``), then drain a batch."""
        deadline = time.monotonic() + timeout
        with self._lock:
            while not self._items:
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self._not_empty.wait(remaining):
                    if not self._items:
                        return []
            k = min(max_items, len(self._items))
            return [self._items.popleft() for _ in range(k)]


class SlotPool:
    """Fixed pool of integer slots (continuous-batching resource model).

    ``acquire`` hands out the lowest free slot id or None; ``release``
    returns it.  Thread-safe, though the LM server and the connectivity
    engine both drive it from a single worker thread.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self._free = list(range(n_slots - 1, -1, -1))  # pop() -> lowest id
        self._lock = threading.Lock()

    def acquire(self) -> Optional[int]:
        with self._lock:
            return self._free.pop() if self._free else None

    def release(self, slot: int) -> None:
        with self._lock:
            if not 0 <= slot < self.n_slots or slot in self._free:
                raise ValueError(f"bad release of slot {slot}")
            self._free.append(slot)
            self._free.sort(reverse=True)

    @property
    def n_free(self) -> int:
        with self._lock:
            return len(self._free)

    @property
    def n_busy(self) -> int:
        return self.n_slots - self.n_free


@dataclasses.dataclass
class ServeRequest:
    """A queued request: payload + future + timing metadata.

    ``submitted`` is a ``time.perf_counter`` stamp (latency measurement);
    ``deadline`` is an absolute ``perf_counter`` deadline or None.
    """

    payload: Any
    future: Future = dataclasses.field(default_factory=Future)
    submitted: float = dataclasses.field(default_factory=time.perf_counter)
    deadline: Optional[float] = None

    def expired(self, now: Optional[float] = None) -> bool:
        if self.deadline is None:
            return False
        return (time.perf_counter() if now is None else now) > self.deadline

    def begin(self) -> bool:
        """Claim the request for execution.

        Returns False when the client cancelled it while queued — the
        worker must then drop it unanswered.  After a True return the
        request can no longer be cancelled (the standard
        ``Future.set_running_or_notify_cancel`` protocol).
        """
        return self.future.set_running_or_notify_cancel()
