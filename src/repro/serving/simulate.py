"""Heavy-traffic simulation harness for the serving engine.

Drives a real :class:`~repro.serving.engine.ConnectivityEngine` (real
threads, real queues, real device gathers) with a synthetic workload
shaped like the interactive-analytics traffic the paper positions
Contour for (Arachne/Arkouda clients):

* **Zipf-skewed vertices** — queries concentrate on hot vertices
  (``zipf_a``), the regime where coalescing pays (many pending queries
  gather the same few cache lines).
* **Bursty arrivals** — producers emit Poisson-sized bursts back to
  back; ``target_qps`` (optional) spaces bursts with exponential gaps,
  otherwise the harness runs open-loop at capacity with a bounded
  in-flight window (the standard saturation-throughput measurement).
* **Mixed read/write** — a dedicated writer thread interleaves edge
  micro-batch ingests (``write_ratio`` of total operations) whose edge
  endpoints are drawn from the same skewed distribution, so queries
  race commits the way a live service's do.
* **Fault schedule** — an optional
  :class:`~repro.runtime.recovery.FaultInjector` kills ingests
  mid-load; with a ``CheckpointManager`` the engine recovers via
  restore-and-replay and the run's final labels must be bit-identical
  to an uninterrupted run (the ``BENCH_serving.json`` recovery gate).

``run_simulation`` returns ``(report, labels)``: the metrics summary in
the artifact's shape plus the final committed label array (NumPy) for
bit-exactness comparisons.  The workload is a pure function of
``spec.seed`` — two runs with the same spec commit identical ingest
sequences, which is what makes the recovery comparison meaningful.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from typing import Optional

import numpy as np

from repro.serving.client import ConnectivityClient
from repro.serving.engine import ConnectivityEngine

# query-kind mix: overwhelmingly point reads, a sliver of whole-graph
# aggregation (each n_components answer rides the snapshot's cached
# decomposition, so the sliver stays cheap)
P_SAME, P_COMPONENT_OF, P_NCOMP = 0.849, 0.15, 0.001


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Parameters of one simulated traffic run (all seeded)."""

    n_vertices: int = 100_000
    n_queries: int = 1_000_000
    zipf_a: float = 1.3              # vertex skew (lower = hotter head)
    burst_mean: float = 64.0         # mean Poisson burst size
    write_ratio: float = 0.01        # ingest batches / total operations
    edges_per_batch: int = 256
    n_query_threads: int = 4
    window: int = 4096               # per-thread in-flight bound
    target_qps: Optional[float] = None  # None = open-loop at capacity
    query_timeout: Optional[float] = None  # per-request deadline (s)
    seed: int = 0

    @property
    def n_ingest_batches(self) -> int:
        return max(1, int(self.n_queries * self.write_ratio))


def _zipf_vertices(rng: np.random.Generator, a: float, n: int,
                   size: int) -> np.ndarray:
    """Zipf-skewed vertex ids in ``[0, n)`` (rank = vertex id)."""
    z = rng.zipf(a, size=size).astype(np.int64)
    return ((z - 1) % n).astype(np.int32)


def make_query_plan(spec: WorkloadSpec):
    """Precompute the full query stream: kinds, endpoints, burst sizes.

    Precomputing keeps the producer threads' steady-state loop free of
    RNG calls — the harness measures the engine, not NumPy.
    """
    rng = np.random.default_rng(spec.seed)
    q = spec.n_queries
    r = rng.random(q)
    kinds = np.where(r < P_SAME, 0, np.where(r < P_SAME + P_COMPONENT_OF,
                                             1, 2)).astype(np.int8)
    us = _zipf_vertices(rng, spec.zipf_a, spec.n_vertices, q)
    vs = _zipf_vertices(rng, spec.zipf_a, spec.n_vertices, q)
    n_bursts = max(1, int(q / max(spec.burst_mean, 1.0)))
    bursts = rng.poisson(spec.burst_mean, size=2 * n_bursts) + 1
    gaps = (rng.exponential(spec.burst_mean / spec.target_qps,
                            size=2 * n_bursts)
            if spec.target_qps else np.zeros(2 * n_bursts))
    return kinds, us, vs, bursts, gaps


def make_ingest_plan(spec: WorkloadSpec):
    """Precompute the deterministic ingest schedule (seeded off-stream
    from the query RNG so query volume never perturbs the committed
    edge sequence)."""
    rng = np.random.default_rng(spec.seed + 0x5EED)
    k = spec.n_ingest_batches
    src = _zipf_vertices(rng, spec.zipf_a, spec.n_vertices,
                         k * spec.edges_per_batch)
    dst = rng.integers(0, spec.n_vertices, size=k * spec.edges_per_batch,
                       dtype=np.int32)
    return [(src[i * spec.edges_per_batch:(i + 1) * spec.edges_per_batch],
             dst[i * spec.edges_per_batch:(i + 1) * spec.edges_per_batch])
            for i in range(k)]


KIND_NAMES = ("same_component", "component_of", "n_components")


def _query_producer(client: ConnectivityClient, spec: WorkloadSpec,
                    kinds, us, vs, bursts, gaps, failures: list):
    engine = client.engine
    outstanding = []
    i, n = 0, kinds.shape[0]
    bi = 0
    while i < n:
        take = int(bursts[bi % bursts.shape[0]])
        gap = float(gaps[bi % gaps.shape[0]])
        bi += 1
        for j in range(i, min(i + take, n)):
            kind = KIND_NAMES[kinds[j]]
            try:
                if kind == "same_component":
                    fut = client.same_component_async(
                        int(us[j]), int(vs[j]), timeout=spec.query_timeout)
                elif kind == "component_of":
                    fut = client.component_of_async(
                        int(us[j]), timeout=spec.query_timeout)
                else:
                    fut = client.n_components_async(
                        timeout=spec.query_timeout)
            except Exception as exc:  # noqa: BLE001 — report, keep loading
                failures.append(("submit", kind, repr(exc)))
                continue
            outstanding.append(fut)
            if len(outstanding) >= spec.window:
                drain = outstanding[:spec.window // 2]
                del outstanding[:spec.window // 2]
                for f in drain:
                    _settle(f, failures)
        i += take
        if gap > 0:
            time.sleep(gap)
        if engine._worker_error is not None:
            break
    for f in outstanding:
        _settle(f, failures)


def _settle(fut, failures: list) -> None:
    try:
        fut.result(timeout=120)
    except Exception as exc:  # noqa: BLE001 — tallied, not fatal
        failures.append(("result", type(exc).__name__, str(exc)[:80]))


def _ingest_producer(client: ConnectivityClient, plan, acked: list,
                     failures: list, pace_s: float):
    for bi, (src, dst) in enumerate(plan):
        try:
            ack = client.ingest(src, dst, timeout=None)
            acked.append(ack.batch_index)
        except Exception as exc:  # noqa: BLE001 — a lost ack is the signal
            failures.append(("ingest", bi, repr(exc)))
        if pace_s > 0:
            time.sleep(pace_s)


def run_simulation(
    spec: WorkloadSpec,
    *,
    engine: Optional[ConnectivityEngine] = None,
    manager=None,
    fault_injector=None,
    ingest_pace_s: float = 0.0,
    **engine_kwargs,
) -> tuple[dict, np.ndarray]:
    """Run one traffic simulation; returns ``(report, final_labels)``.

    ``engine_kwargs`` reach the :class:`ConnectivityEngine` constructor
    (checkpoint cadence, recoverable set, solver options...).  Pass a
    pre-built ``engine`` to drive a custom one instead.
    """
    own_engine = engine is None
    if own_engine:
        engine = ConnectivityEngine(
            spec.n_vertices, manager=manager,
            fault_injector=fault_injector, **engine_kwargs)
    engine.start()
    client = ConnectivityClient(engine, retries=1_000)

    kinds, us, vs, bursts, gaps = make_query_plan(spec)
    ingest_plan = make_ingest_plan(spec)
    shares = np.array_split(np.arange(spec.n_queries),
                            spec.n_query_threads)
    acked: list = []
    failures: list = []
    threads = [threading.Thread(
        target=_query_producer,
        args=(client, spec, kinds[s], us[s], vs[s], bursts, gaps, failures),
        name=f"query-producer-{t}", daemon=True)
        for t, s in enumerate(shares)]
    threads.append(threading.Thread(
        target=_ingest_producer,
        args=(client, ingest_plan, acked, failures, ingest_pace_s),
        name="ingest-producer", daemon=True))

    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    engine.flush(timeout=300.0)
    wall = time.perf_counter() - t0

    labels = np.asarray(engine.snapshot().labels)
    report = engine.metrics.summary(wall)
    report["spec"] = dataclasses.asdict(spec)
    report["final"] = {
        "n_batches": engine.n_batches,
        "n_vertices": engine.n_vertices,
        "n_edges": engine._stream.n_edges,
        "n_components": int(engine.snapshot().n_components),
        "labels_crc32": int(zlib.crc32(labels.tobytes())),
    }
    report["acked_batches"] = len(acked)
    report["failures"] = len(failures)
    report["failure_sample"] = [list(f) for f in failures[:5]]
    if own_engine:
        engine.close()
    return report, labels
