"""Version tolerance for the small jax API surface this repo leans on.

The codebase targets the current mesh/shard_map API (``jax.shard_map``,
``jax.sharding.AxisType``, ``AbstractMesh(sizes, names)``, dict-valued
``compiled.cost_analysis()``).  The baked accelerator toolchain may ship an
older jax where those live under experimental names or older signatures
(e.g. 0.4.x: ``jax.experimental.shard_map``, no ``AxisType``,
``AbstractMesh(((name, size), ...))``, list-valued ``cost_analysis``).
Importing the symbols from here keeps every call site version-agnostic —
and keeps the whole distributed/sharding layer *runnable* instead of
failing on import-time attribute errors.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.5: experimental home, whose static replication checker
    # predates a `while` rule — disable it (validation only, not semantics)
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, **kwargs):
        kwargs.setdefault("check_rep", False)
        return _shard_map_legacy(f, **kwargs)


def mesh_axis_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto, ...)`` where supported; ``{}`` on older jax
    (whose meshes behave as Auto for shard_map/jit purposes anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return {"axis_types": (axis_type.Auto,) * n_axes}
    return {}


def make_mesh(axis_shapes: Sequence[int],
              axis_names: Sequence[str]) -> jax.sharding.Mesh:
    """`jax.make_mesh` with Auto axis types where the kwarg exists."""
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                         **mesh_axis_kwargs(len(axis_names)))


def device_mesh(devices, axis_names: Sequence[str]) -> jax.sharding.Mesh:
    """`jax.sharding.Mesh` over an explicit device array, Auto-typed."""
    return jax.sharding.Mesh(devices, tuple(axis_names),
                             **mesh_axis_kwargs(len(axis_names)))


def abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]):
    """`AbstractMesh` across the signature change.

    Current jax: ``AbstractMesh(axis_sizes, axis_names)``; 0.4.x:
    ``AbstractMesh(shape_tuple)`` with (name, size) pairs.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def cost_analysis(compiled) -> dict:
    """Dict-valued ``compiled.cost_analysis()`` on every jax version
    (0.4.x returned a one-element list of dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)
