"""Reproduction of "Contour Algorithm for Connectivity" on JAX/Pallas.

The unified connectivity API re-exported at top level::

    from repro import solve, SolveOptions, ComponentResult, Graph

    result = solve(graph)          # Contour C-2, auto kernel dispatch
    result.n_components
    result.same_component(u, v)

See ``repro.connectivity`` for the full surface (solver registry, warm
starts, batched solving) and README.md for a quickstart.
"""
from repro.connectivity import (
    ComponentResult,
    Graph,
    SolveOptions,
    StreamingConnectivity,
    list_solvers,
    register_solver,
    solve,
    solve_batch,
    stack_graphs,
)

__all__ = [
    "ComponentResult",
    "Graph",
    "SolveOptions",
    "StreamingConnectivity",
    "list_solvers",
    "register_solver",
    "solve",
    "solve_batch",
    "stack_graphs",
]
