"""Training step builder: grad, clip, AdamW, optional microbatch accumulation.

``make_train_step(model, opt_config, grad_accum)`` returns a pure
``step(state, batch) -> (state, metrics)`` suitable for ``jax.jit`` with
explicit in/out shardings (see ``repro.launch.dryrun``).  Gradient
accumulation splits the global batch into ``grad_accum`` microbatches and
folds them with a ``lax.scan`` — the standard memory/throughput knob.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import OptConfig, apply_updates, init_opt_state


class TrainState(NamedTuple):
    params: Any
    opt: Dict[str, Any]


def init_train_state(model, rng, opt_config: OptConfig) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=init_opt_state(params, opt_config))


def _split_microbatches(batch, n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree_util.tree_map(split, batch)


def make_train_step(model, opt_config: OptConfig, grad_accum: int = 1):
    loss_fn = lambda p, b: model.loss(p, b)

    def step(state: TrainState, batch) -> tuple[TrainState, Dict[str, jax.Array]]:
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            micro = _split_microbatches(batch, grad_accum)

            def accum(carry, mb):
                g_sum, l_sum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb)
                g_sum = jax.tree_util.tree_map(jnp.add, g_sum, g)
                return (g_sum, l_sum + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), _ = jax.lax.scan(accum, (g0, jnp.float32(0)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
            metrics = {}

        new_params, new_opt, opt_metrics = apply_updates(
            state.params, grads, state.opt, opt_config)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(params=new_params, opt=new_opt), metrics

    return step


def make_eval_step(model):
    def step(params, batch):
        loss, metrics = model.loss(params, batch)
        return {"loss": loss, **metrics}
    return step
