"""Out-of-core multi-round contraction: device memory bounds the *chunk*,
not the graph (DESIGN.md §15).

Every other solver in the repo materialises the full edge list on device;
the ROADMAP's billion-edge target cannot (open item 2).  This module
decouples problem size from device memory following *Near-Optimal
Massively Parallel Graph Connectivity* (Behnezhad et al.) and ConnectIt's
multi-round sample-then-finish design (PAPERS.md):

* **Edges live on the host** — as arrays (:class:`ArrayChunks`) or
  generated on the fly (:class:`~repro.graphs.generators.RmatChunks`,
  which never holds the full list).  The device holds only the O(n)
  label array plus one power-of-two edge chunk at a time.

* **Round structure.**  Each round streams every surviving chunk through
  a **double-buffered** host→device pipeline: the ``jax.device_put`` of
  chunk ``k+1`` is issued *before* the fold of chunk ``k`` is dispatched,
  so the transfer overlaps the sweep (both are async), and the resident
  label array is donated through each fold (no per-chunk copy).  A fold
  (:func:`_fold_chunk`) rewrites the chunk to current supervertex roots
  and runs a **bounded** number of local min-mapping sweeps
  (``SolveOptions.oocore_local_iters``) under the §10 frontier schedule —
  bounded, not to convergence: per-chunk convergence would reach the
  global fixpoint in round 1 (the streaming engine's soundness theorem,
  DESIGN.md §11) and the multi-round structure would be vacuous; bounded
  local work per machine per round is exactly the MPC model's constraint.
  One compiled program per (n, chunk-bucket) pair, chunks padded with
  ``(0, 0)`` self-loop no-ops and swept only up to their real edge count
  — the same jit-stability discipline as ``streaming.py``.

* **Host-side contraction between rounds.**  After a round the labels are
  pulled once; every edge of the round's input is relabeled to its
  endpoints' roots, intra-supervertex edges (``L[u] == L[v]``) are
  retired, and the survivors are deduped on the unordered root pair — so
  round ``k+1`` streams only surviving inter-supervertex edges.

  **Soundness:** retiring ``(u, v)`` because ``L[u] == L[v]`` is
  *permanent* here, unlike inside a device fixpoint (DESIGN.md §10's
  rewrite-vs-drop hazard): a min-mapping merge never splits, so two
  vertices that share a root share it forever.  Rewriting survivors to
  roots is the streaming engine's supervertex rewrite — every kept
  adjacency connects current roots, and the final star forest resolves
  retired vertices through their (monotone) pointer chains.  Dedup is
  sound because edge multiplicity never affects a min-mapping fixpoint.

  **Termination:** a round that streams a non-empty survivor set sweeps
  at least one inter-root edge, and that scatter-min strictly decreases
  some label — so at least two roots merge, the swept edge retires, and
  the deduped survivor count **strictly decreases** every round (the
  decay the bench artifact gates on).

* **In-core handoff.**  Once the survivors fit one chunk bucket — the
  planner's VMEM-derived ceiling, ``ExecutionPlan.chunk_bucket``,
  resolved by :func:`planner.oocore_chunk_bucket` — the ordinary in-core
  adaptive fixpoint finishes the solve warm-started from the resident
  labels (sound for the usual monotone-label reason).  The device
  therefore never holds more than ``chunk_bucket`` edges.  If
  ``oocore_round_cap`` rounds pass first, the finish is forced anyway:
  labels stay correct, only the memory bound is waived (and the waiver
  recorded in provenance).

Recovery (``resilience.oocore_with_recovery``) checkpoints at round
boundaries — labels plus the surviving-chunk manifest — so a mid-round
crash replays one round, not the stream: ``chunk(k)`` purity makes the
replay bit-exact.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.connectivity import frontier as fr
from repro.connectivity import minmap as lab
from repro.connectivity import planner as _planner
from repro.connectivity.contour import _make_step
from repro.connectivity.options import SolveOptions
from repro.connectivity.result import ComponentResult
from repro.connectivity.solve import make_result, resolve_warm_start
from repro.graphs.generators import ArrayChunks, EdgeChunks
from repro.graphs.structs import Graph

# Host-fallback peak-memory model (bytes, int32 everywhere): the device
# working set is the resident labels (plus pointer-jump/gather
# temporaries) and one chunk — double-buffered src/dst pairs plus the
# fold's rewrite/contraction/convergence temporaries.  Deliberately an
# over-count: the bench gate needs an upper estimate that is still far
# below the full edge list.
LABEL_ARRAYS = 3    # labels + compress double-buffer + gather temp
CHUNK_ARRAYS = 28   # 2x2 double-buffered src/dst + sweep temporaries
EDGE_BYTES = 8      # one int32 (src, dst) pair — the in-core cost/edge


def estimate_peak_bytes(n_vertices: int, chunk_bucket: int) -> int:
    """Deterministic host-side upper estimate of the resident device
    bytes of an out-of-core solve (labels + one double-buffered chunk)."""
    return 4 * (LABEL_ARRAYS * int(n_vertices)
                + CHUNK_ARRAYS * int(chunk_bucket))


def device_peak_bytes(device=None) -> Optional[int]:
    """``peak_bytes_in_use`` from the device allocator, when the backend
    exposes it (TPU/GPU); None on hosts without memory stats (CPU)."""
    try:
        dev = device if device is not None else jax.local_devices()[0]
        stats = dev.memory_stats()
        if stats and "peak_bytes_in_use" in stats:
            return int(stats["peak_bytes_in_use"])
    except Exception:
        pass
    return None


@functools.partial(
    jax.jit,
    donate_argnums=(0,),
    static_argnames=("variant", "backend", "plan", "warmup",
                     "async_compress", "local_iters"),
)
def _fold_chunk(
    labels: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    n_active: jax.Array,
    *,
    variant: str = "C-2",
    backend: str = "xla",
    plan=None,
    warmup: int = 2,
    async_compress: int = 1,
    local_iters: int = 4,
):
    """Fold one edge chunk into the resident labels (bounded local work).

    The supervertex rewrite makes the bounded sweeps ordinary Contour on
    the root graph (as in ``streaming.delta_converge``); ``max_iters``
    caps them at ``local_iters`` — partial convergence is fine, the
    host-side inter-round contraction and the final in-core finish carry
    global convergence.  ``labels`` is donated: the caller rebinds it to
    the result, so the O(n) array updates in place every chunk.  Returns
    ``(labels', sweeps, edges_visited)`` with ``labels'`` compressed back
    to a star forest (the rewrite's precondition for the next chunk).
    """
    src = labels[src]
    dst = labels[dst]
    step = _make_step(variant, warmup, async_compress, backend, plan)
    L, it, _, _, visited = fr.adaptive_fixpoint(
        src, dst, labels, step,
        n_vertices=labels.shape[0],
        sampling=0,
        compact_every=1,
        max_iters=local_iters,
        active_m0=n_active)
    return L, it, visited


def _pad_chunk(src: np.ndarray, dst: np.ndarray, bucket: int):
    """Pad a host chunk to its pow2 bucket with (0, 0) self-loop no-ops
    and cast to the device's int32 edge dtype."""
    m = int(src.shape[0])
    ps = np.zeros(bucket, np.int32)
    pd = np.zeros(bucket, np.int32)
    ps[:m] = src
    pd[:m] = dst
    return ps, pd, m


class OutOfCoreContraction:
    """Round-structured out-of-core solver (module docstring for theory).

    The round-level API exists so three consumers can share one engine:
    the registry solver (:func:`oocore_labels` / ``algorithm="oocore"``)
    just calls :meth:`run`; ``resilience.oocore_with_recovery`` drives
    :meth:`run_round` with round-boundary checkpoints; the bench reads
    :attr:`round_counts` and the peak-memory accounting.
    """

    def __init__(self, chunks, options: Optional[SolveOptions] = None,
                 *, init_labels=None, fault_injector=None, **overrides):
        if not isinstance(chunks, EdgeChunks):
            raise TypeError(
                f"chunks must be an EdgeChunks source, got "
                f"{type(chunks).__name__}; wrap host arrays in ArrayChunks "
                f"or use graphs.rmat_chunks")
        opts = options if options is not None else SolveOptions()
        if overrides:
            opts = opts.replace(**overrides)
        opts.validate()
        variant = opts.variant or "C-2"
        if variant == "C-Syn":
            raise ValueError(
                "C-Syn is the Alg.-1-verbatim reference and cannot take "
                "the out-of-core schedule; use C-2/C-m or any async "
                "variant")
        if chunks.n_vertices >= 1 << 31:
            raise ValueError(
                f"n_vertices={chunks.n_vertices} exceeds the int32 vertex "
                f"id space")
        self.chunks = chunks
        self.n_vertices = chunks.n_vertices
        self.fault_injector = fault_injector
        # plan resolution through the same funnel as every planned solver;
        # lazy import (solvers registers this module's solver)
        from repro.connectivity.solvers import resolve_backend_plan
        backend, plan = resolve_backend_plan(
            chunks.n_vertices, chunks.n_edges, opts)
        if plan.chunk_bucket == 0:
            plan = plan.replace(chunk_bucket=_planner.oocore_chunk_bucket(
                chunks.n_edges,
                vmem_limit_bytes=opts.vmem_limit_bytes,
                requested=opts.oocore_chunk_edges))
        # a chunk source dictates its own round-0 granularity; the plan
        # records what actually streams (honest provenance > the table)
        if chunks.chunk_edges != plan.chunk_bucket:
            plan = plan.replace(chunk_bucket=chunks.chunk_edges)
        self.backend = backend
        self.plan = plan
        self.bucket = plan.chunk_bucket
        self.opts = opts.replace(plan=plan)
        self.round_cap = opts.oocore_round_cap
        self._statics = dict(
            variant=variant,
            backend=backend,
            plan=plan,
            warmup=opts.warmup,
            async_compress=opts.async_compress,
            local_iters=opts.oocore_local_iters,
        )
        init = resolve_warm_start(
            init_labels if init_labels is not None else opts.warm_start,
            chunks.n_vertices)
        self._init_np = (None if init is None
                         else np.asarray(init, np.int32))
        self.reset()

    # -- state -----------------------------------------------------------
    def reset(self) -> None:
        """Back to the pre-round-0 state (labels = warm start or
        identity, stream = the source).  Round-0 crash recovery: the
        source's ``chunk(k)`` purity makes the replay bit-exact."""
        init = (None if self._init_np is None
                else jnp.asarray(self._init_np))
        self.labels = lab.resolve_init_labels(init, self.n_vertices,
                                              jnp.int32)
        self.round_index = 0
        self.iterations = 0
        self.visited = 0.0
        self.round_counts: list = []   # deduped survivors after each round
        self.survivors_src: Optional[np.ndarray] = None
        self.survivors_dst: Optional[np.ndarray] = None
        self.finished_streaming = False
        self.round_cap_exhausted = False
        self._chunk_counter = 0

    def state_dict(self) -> dict:
        """Round-boundary snapshot: labels + surviving-chunk manifest +
        counters.  Everything needed to resume at ``round_index``."""
        empty = np.zeros(0, np.int32)
        return {
            "labels": np.asarray(self.labels),
            "src": (empty if self.survivors_src is None
                    else self.survivors_src),
            "dst": (empty if self.survivors_dst is None
                    else self.survivors_dst),
            "round": np.int64(self.round_index),
            "iterations": np.int64(self.iterations),
            "visited": np.float64(self.visited),
            "counts": np.asarray(self.round_counts, np.int64),
            "finished": np.int64(self.finished_streaming),
            "exhausted": np.int64(self.round_cap_exhausted),
        }

    def load_state_dict(self, state: dict) -> None:
        self.labels = jnp.asarray(state["labels"], jnp.int32)
        self.round_index = int(state["round"])
        self.iterations = int(state["iterations"])
        self.visited = float(state["visited"])
        self.round_counts = [int(c) for c in state["counts"]]
        self.finished_streaming = bool(int(state["finished"]))
        self.round_cap_exhausted = bool(int(state["exhausted"]))
        if self.round_index == 0:
            self.survivors_src = self.survivors_dst = None
        else:
            self.survivors_src = np.asarray(state["src"], np.int32)
            self.survivors_dst = np.asarray(state["dst"], np.int32)

    def save(self, manager) -> None:
        manager.save(self.round_index, self.state_dict())

    def restore(self, manager, step: Optional[int] = None) -> None:
        state, _ = manager.restore(self.state_dict(), step)
        self.load_state_dict(state)

    # -- the rounds ------------------------------------------------------
    def _round_source(self) -> EdgeChunks:
        if self.round_index == 0:
            return self.chunks
        return ArrayChunks(self.survivors_src, self.survivors_dst,
                           self.n_vertices, self.bucket)

    def _stream(self, source: EdgeChunks) -> None:
        """One double-buffered pass of every chunk of ``source`` through
        :func:`_fold_chunk`."""
        n_chunks = source.n_chunks
        if n_chunks == 0:
            return
        its = jnp.int32(0)
        visited = jnp.float32(0)
        # prefetch chunk 0; inside the loop chunk k+1's transfer is
        # issued before chunk k's fold dispatches, so host->device copy
        # overlaps the sweep (device_put and jit dispatch are both async)
        ps, pd, m = _pad_chunk(*source.chunk(0), self.bucket)
        nxt = (jax.device_put(ps), jax.device_put(pd), m)
        for k in range(n_chunks):
            cur = nxt
            if k + 1 < n_chunks:
                ps, pd, m = _pad_chunk(*source.chunk(k + 1), self.bucket)
                nxt = (jax.device_put(ps), jax.device_put(pd), m)
            if self.fault_injector is not None:
                self.fault_injector.maybe_fail(self._chunk_counter,
                                               "oocore_chunk")
            self._chunk_counter += 1
            src, dst, n_active = cur
            self.labels, it, v = _fold_chunk(
                self.labels, src, dst, jnp.int32(n_active),
                **self._statics)
            its = its + it
            visited = visited + v
        # the only per-round host syncs (contraction pulls labels anyway)
        self.iterations += int(its)
        self.visited += float(visited)

    def _contract(self, source: EdgeChunks) -> tuple:
        """Relabel ``source`` to current roots, drop intra-supervertex
        edges, dedup on the unordered root pair — host-side, chunk by
        chunk, so peak host memory is O(chunk + survivors)."""
        L = np.asarray(self.labels)
        parts_s, parts_d = [], []
        for s, d in source:
            rs, rd = L[s], L[d]
            keep = rs != rd
            if keep.any():
                parts_s.append(rs[keep].astype(np.int64))
                parts_d.append(rd[keep].astype(np.int64))
        if not parts_s:
            return (np.zeros(0, np.int32), np.zeros(0, np.int32))
        rs = np.concatenate(parts_s)
        rd = np.concatenate(parts_d)
        lo = np.minimum(rs, rd)
        hi = np.maximum(rs, rd)
        _, first = np.unique(lo * np.int64(self.n_vertices) + hi,
                             return_index=True)
        first.sort()  # keep the stream order of first occurrences
        return rs[first].astype(np.int32), rd[first].astype(np.int32)

    def run_round(self) -> dict:
        """Stream every surviving chunk, then contract host-side.

        Returns the round record ``{"round", "edges_in", "survivors",
        "chunks"}`` and flips :attr:`finished_streaming` once the
        survivors fit the chunk bucket (or the round cap is spent).
        """
        if self.finished_streaming:
            raise RuntimeError("streaming already finished; call finish()")
        if self.fault_injector is not None:
            self.fault_injector.maybe_fail(self.round_index, "oocore_round")
        source = self._round_source()
        edges_in = source.n_edges
        self._stream(source)
        ssrc, sdst = self._contract(source)
        self.survivors_src, self.survivors_dst = ssrc, sdst
        n_surv = int(ssrc.shape[0])
        prev = self.round_counts[-1] if self.round_counts else None
        self.round_counts.append(n_surv)
        self.round_index += 1
        if n_surv <= self.bucket:
            self.finished_streaming = True
        elif self.round_index >= self.round_cap or (prev is not None
                                                    and n_surv >= prev):
            # cap spent (or, defensively, a round that made no progress —
            # provably impossible while survivors are inter-root, see
            # module docstring, but never spin on a broken invariant):
            # finish in-core anyway.  Labels stay correct; only the
            # memory bound is waived, and provenance records the waiver.
            self.finished_streaming = True
            self.round_cap_exhausted = True
        return {"round": self.round_index - 1, "edges_in": edges_in,
                "survivors": n_surv, "chunks": source.n_chunks}

    def finish(self):
        """In-core adaptive finish on the surviving edges, warm-started
        from the resident labels (monotone min-mapping labels make any
        intermediate state a valid init).  Returns the registry 4-tuple
        ``(labels, iterations, converged, edges_visited)``.
        """
        if not self.finished_streaming:
            raise RuntimeError("streaming rounds still pending; call "
                               "run_round() until finished_streaming")
        if int(self.survivors_src.shape[0]) == 0:
            # every edge retired: the star forest is the global fixpoint
            self.labels = fr.compress_full(self.labels)
            return (self.labels, jnp.int32(self.iterations),
                    jnp.array(True), jnp.float32(self.visited))
        from repro.connectivity.solvers import _contour_solver
        graph = Graph.from_numpy(self.survivors_src, self.survivors_dst,
                                 self.n_vertices)
        finish_opts = self.opts.replace(
            algorithm="contour", plan=None, warm_start=None,
            # the handoff keeps the caller's frontier schedule; dense
            # callers still get periodic contraction — the survivors are
            # exactly the frontier, contracting them is the whole point
            compact_every=self.opts.compact_every or 1,
            max_iters=self.opts.max_iters or 100_000)
        # [:4] drops the static provenance tuple (5th element) the
        # contour solver returns for the registry facade
        labels, it, done, visited = _contour_solver(graph, finish_opts,
                                                    self.labels)[:4]
        self.labels = labels
        self.iterations += int(it)
        self.visited += float(visited)
        return (labels, jnp.int32(self.iterations), done,
                jnp.float32(self.visited))

    def run(self):
        """Rounds to the handoff point, then the in-core finish."""
        while not self.finished_streaming:
            self.run_round()
        return self.finish()

    # -- reporting -------------------------------------------------------
    def peak_bytes_estimate(self) -> int:
        bucket = self.bucket
        if self.round_cap_exhausted and self.survivors_src is not None:
            # waived bound: the forced finish materialised the survivors
            bucket = max(bucket,
                         _planner.next_pow2(self.survivors_src.shape[0]))
        return estimate_peak_bytes(self.n_vertices, bucket)

    def round_provenance(self) -> tuple:
        """The oocore-specific provenance entries — without the plan
        entry, which ``solve()`` records from its own resolved plan (the
        registry solver returns these as the optional 5th element)."""
        entries = [f"oocore:rounds={len(self.round_counts)} "
                   f"bucket={self.bucket} "
                   f"decay={','.join(map(str, self.round_counts))}"]
        if self.round_cap_exhausted:
            entries.append("oocore_round_cap_exhausted")
        return tuple(entries)

    def provenance(self) -> tuple:
        return (self.plan.provenance_entry(),) + self.round_provenance()


def oocore_labels(chunks, options: Optional[SolveOptions] = None,
                  *, init_labels=None, **overrides):
    """Functional form: solve an :class:`EdgeChunks` source out-of-core.

    Returns the registry 4-tuple plus the optional 5th static-provenance
    element (the round decay), which ``solve()`` merges into the result;
    :func:`solve_chunks` wraps everything in a :class:`ComponentResult`.
    """
    engine = OutOfCoreContraction(chunks, options, init_labels=init_labels,
                                  **overrides)
    return engine.run() + (engine.round_provenance(),)


def solve_chunks(chunks, options: Optional[SolveOptions] = None,
                 *, warm_start=None, **overrides) -> ComponentResult:
    """``solve()`` for edge streams: out-of-core facade entry.

    Example::

        chunks = rmat_chunks(scale=26, edge_factor=16, chunk_edges=1 << 20)
        result = solve_chunks(chunks)        # never holds all edges

    ``warm_start``/``SolveOptions`` behave as in :func:`solve`; the
    resolved plan (including the chunk bucket) and the per-round survivor
    decay land in ``result.provenance``.
    """
    engine = OutOfCoreContraction(chunks, options, init_labels=warm_start,
                                  **overrides)
    labels, iterations, converged, visited = engine.run()
    return make_result(labels, iterations, converged, visited,
                       provenance=engine.provenance())
