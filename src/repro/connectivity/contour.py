"""The Contour connectivity algorithm (paper Alg. 1) and its six variants.

Variants (paper §III-B4):

* ``C-Syn``  — Alg. 1 verbatim: synchronous 2-order sweeps, double
  buffered, plain no-change convergence test.
* ``C-1``    — 1-order operator + async recompaction + early check.
* ``C-2``    — 2-order operator + async recompaction + early check
  (the paper's default).
* ``C-m``    — high-order operator: realised as a 2-order edge sweep
  followed by ``log2(m)`` pointer-jump rounds (same fixed point as the
  literal L^m chain; DESIGN.md §3).
* ``C-11mm`` — ``warmup`` iterations of C-1 then C-m until convergence.
* ``C-1m1m`` — alternate C-1 and C-m per iteration.

Every variant is a pure function of the edge list, runs under ``jax.jit``
with a ``lax.while_loop``, and returns ``(labels, n_iterations)``.

The MM sweep itself is routed through the ``kernels.contour_mm`` dispatch
layer: ``backend="xla"`` (default) is the scatter-min realisation,
``backend="pallas_blocked"`` the label-blocked vectorized TPU kernel and
``backend="auto"`` picks per platform/graph size
(`ops.plan_contour_kernel`) — so every variant can run on every backend.
A resolved :class:`~repro.kernels.contour_mm.ops.KernelPlan` can be passed
explicitly (``plan=``) to pin tile sizes; the ``repro.connectivity.solve``
facade threads the plan it resolves this way.

``init_labels`` warm-starts the fixpoint from a previous solve's labels
(see :func:`repro.connectivity.minmap.resolve_init_labels` for why that is
correct); labels decrease monotonically from the given start.

``sampling`` / ``compact_every`` enable the work-adaptive frontier
contraction schedule of ``repro.connectivity.frontier`` (sample-prefix
sweeps, the post-sampling largest-component filter, periodic active-edge
contraction) — same fixed point bit-for-bit, but sweeps and the
early-convergence check only touch the live edge prefix.  ``C-Syn`` is
kept Alg.-1-verbatim and rejects the adaptive schedule.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.connectivity import frontier as fr
from repro.connectivity import minmap as lab
from repro.graphs.structs import Graph
from repro.kernels.contour_mm import ops as mm_ops

VARIANTS = ("C-Syn", "C-1", "C-2", "C-m", "C-11mm", "C-1m1m")

# C-m's effective order: the paper uses m = 1024; log2(1024) = 10 jump
# rounds after the 2-order edge sweep covers the same mapping depth.
_CM_JUMP_ROUNDS = 10


class ContourState(NamedTuple):
    L: jax.Array
    it: jax.Array          # int32 iteration counter
    done: jax.Array        # bool


def _make_relax(backend, plan, vmem_limit_bytes=None):
    """relax(L, src, dst, order, limit) on the chosen backend/tile plan."""
    if plan is None:
        def relax(L, src, dst, order, limit):
            return mm_ops.mm_relax_backend(L, src, dst, order=order,
                                           backend=backend,
                                           edge_limit=limit,
                                           vmem_limit_bytes=vmem_limit_bytes)
    else:
        # legacy KernelPlan carries no fusion field; ExecutionPlan does
        fuse = getattr(plan, "fuse_relabel", False)

        def relax(L, src, dst, order, limit):
            return mm_ops.mm_relax_backend(
                L, src, dst, order=order, backend=backend,
                block_edges=plan.block_edges, label_block=plan.label_block,
                chunk_updates=plan.chunk_updates, interpret=plan.interpret,
                edge_limit=limit, fuse=fuse,
                vmem_limit_bytes=vmem_limit_bytes)
    return relax


def _make_step(variant: str, warmup: int, async_compress: int,
               backend: str = "xla", plan=None, vmem_limit_bytes=None):
    """Return step(L, it, src, dst, limit) -> L_new for the chosen variant.

    ``limit`` is the work-adaptive frontier bound (None for the dense
    schedule: every edge, every sweep).
    """
    relax = _make_relax(backend, plan, vmem_limit_bytes)

    def sweep_sync(L, src, dst, order, limit):
        """Alg. 1 body: one synchronous MM^order sweep."""
        return relax(L, src, dst, order, limit)

    def sweep_async(L, src, dst, order, jump_rounds, limit):
        """Optimised sweep: MM^order + pointer-jump recompaction.

        ``jump_rounds`` realises high-order variants; ``async_compress``
        is the async-update adaptation (spreads freshly lowered labels
        inside the same iteration, mirroring the paper's in-place
        updates).
        """
        L = relax(L, src, dst, order, limit)
        return lab.pointer_jump(L, rounds=jump_rounds + async_compress)

    if variant == "C-Syn":
        def step(L, it, src, dst, limit=None):
            del it
            return sweep_sync(L, src, dst, 2, limit)
    elif variant == "C-1":
        def step(L, it, src, dst, limit=None):
            del it
            return sweep_async(L, src, dst, 1, 0, limit)
    elif variant == "C-2":
        def step(L, it, src, dst, limit=None):
            del it
            return sweep_async(L, src, dst, 2, 0, limit)
    elif variant == "C-m":
        def step(L, it, src, dst, limit=None):
            del it
            return sweep_async(L, src, dst, 2, _CM_JUMP_ROUNDS, limit)
    elif variant == "C-11mm":
        def step(L, it, src, dst, limit=None):
            return jax.lax.cond(
                it < warmup,
                lambda L: sweep_async(L, src, dst, 1, 0, limit),
                lambda L: sweep_async(L, src, dst, 2, _CM_JUMP_ROUNDS,
                                      limit),
                L,
            )
    elif variant == "C-1m1m":
        def step(L, it, src, dst, limit=None):
            return jax.lax.cond(
                it % 2 == 0,
                lambda L: sweep_async(L, src, dst, 1, 0, limit),
                lambda L: sweep_async(L, src, dst, 2, _CM_JUMP_ROUNDS,
                                      limit),
                L,
            )
    elif variant.startswith("C-") and variant[2:].isdigit():
        # literal h-order minimum-mapping operator (Definition 3): the
        # length-h gather chain per edge, exactly as written in the paper.
        # The named C-m variant realises high orders via pointer jumping
        # instead (same fixed point, TPU-vectorisable — DESIGN.md §3);
        # this literal form exists to validate that equivalence.
        order = int(variant[2:])

        def step(L, it, src, dst, limit=None):
            del it
            return sweep_async(L, src, dst, order, 0, limit)
    else:
        raise ValueError(f"unknown variant {variant!r}; one of {VARIANTS} "
                         "or literal 'C-<h>'")
    return step


@functools.partial(
    jax.jit,
    static_argnames=("n_vertices", "variant", "max_iters", "warmup",
                     "async_compress", "backend", "plan", "sampling",
                     "compact_every", "sampling_strategy", "sampling_k",
                     "vmem_limit_bytes"),
)
def contour_labels(
    src: jax.Array,
    dst: jax.Array,
    n_vertices: int,
    init_labels: Optional[jax.Array] = None,
    *,
    variant: str = "C-2",
    max_iters: int = 100_000,
    warmup: int = 2,
    async_compress: int = 1,
    backend: str = "xla",
    plan=None,
    sampling: int = 0,
    compact_every: int = 0,
    sampling_strategy: str = "prefix",
    sampling_k: int = fr.DEFAULT_SAMPLING_K,
    vmem_limit_bytes: Optional[int] = None,
):
    """Run Contour; returns (labels[n], n_iterations, converged, visited).

    Labels converge to the minimum vertex id of each component;
    ``converged`` is the loop's own fixed-point flag (False iff the
    ``max_iters`` budget ran out first).  ``init_labels`` warm-starts
    from a previous solve (labels only ever decrease from the given
    start); ``plan`` pins kernel tile sizes.  ``visited`` is a float32
    cumulative edges-swept counter: ``n_iterations * m`` for the dense
    schedule, the sum of per-sweep frontier sizes when ``sampling`` /
    ``compact_every`` enable the work-adaptive contraction schedule
    (``repro.connectivity.frontier``).  ``sampling_strategy`` picks the
    sampling phase's :class:`~repro.connectivity.frontier
    .SamplingStrategy` (``"prefix"`` / ``"kout"`` / ``"bfs"``;
    ``sampling_k`` is the k-out fan-in) — every strategy reduces to a
    permutation of the edge list plus a prefix width, so the fixed point
    is strategy-independent.
    """
    if warmup < 0 or async_compress < 0:
        raise ValueError("warmup and async_compress must be >= 0, got "
                         f"{warmup} / {async_compress}")
    if sampling < 0 or compact_every < 0:
        raise ValueError("sampling and compact_every must be >= 0, got "
                         f"{sampling} / {compact_every}")
    adaptive = sampling > 0 or compact_every > 0
    sync = variant == "C-Syn"
    if adaptive and sync:
        raise ValueError(
            "C-Syn is the Alg.-1-verbatim reference and does not take the "
            "work-adaptive schedule; use C-2/C-m (or any async variant) "
            "with sampling/compact_every")
    step = _make_step(variant, warmup, async_compress, backend, plan,
                      vmem_limit_bytes)
    L0 = lab.resolve_init_labels(init_labels, n_vertices, src.dtype)

    if adaptive:
        sample_m = None
        if sampling > 0 and sampling_strategy != "prefix":
            src, dst, sample_m = fr.prepare_sampling(
                sampling_strategy, src, dst, n_vertices, sampling_k)
        L, it, done, _, visited = fr.adaptive_fixpoint(
            src, dst, L0, step, n_vertices=n_vertices, sampling=sampling,
            compact_every=compact_every, max_iters=max_iters,
            sample_m0=sample_m)
        return L, it, done, visited

    def cond(s: ContourState):
        return (~s.done) & (s.it < max_iters)

    def body(s: ContourState):
        L_new = step(s.L, s.it, src, dst)
        if sync:
            done = jnp.all(L_new == s.L)  # Alg. 1 line 10: no label change
        else:
            done = lab.converged_early(L_new, src, dst)  # paper §III-B2
        return ContourState(L=L_new, it=s.it + 1, done=done)

    init = ContourState(L=L0, it=jnp.int32(0), done=jnp.array(False))
    out = jax.lax.while_loop(cond, body, init)
    # Final compression: at the early-convergence point the pointer graph
    # restricted to edge endpoints is a star forest; interior tree vertices
    # of padded/isolated chains may still be one hop away.
    L = lab.pointer_jump(out.L, rounds=1)
    visited = out.it.astype(jnp.float32) * src.shape[0]
    return L, out.it, out.done, visited


def contour(graph: Graph, **kw):
    """Convenience wrapper over :func:`contour_labels`."""
    return contour_labels(graph.src, graph.dst, graph.n_vertices, **kw)


def connected_components(graph: Graph, variant: str = "C-2") -> jax.Array:
    """Min-vertex-id component labels (prefer ``repro.connectivity.solve``)."""
    L, _, _, _ = contour(graph, variant=variant)
    return L
