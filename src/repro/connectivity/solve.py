"""The unified connectivity facade: ``solve(graph, options) -> ComponentResult``.

One entry point for every algorithm family the reproduction implements
(all Contour variants, FastSV, label propagation, host-side Rem
union-find, and the ``shard_map`` distributed path), with:

* **typed options** — :class:`~repro.connectivity.options.SolveOptions`
  replaces per-algorithm string kwargs;
* **automatic dispatch** — ``backend="auto"`` resolves kernels through
  ``plan_contour_kernel``; setting ``SolveOptions.mesh`` routes a Contour
  solve through the distributed path;
* **warm starts** — pass a previous :class:`ComponentResult` (or a raw
  label array) to continue after ``Graph.add_edges``: min-mapping labels
  only decrease, so the old fixed point is a correct head start
  (``minmap.resolve_init_labels``).

Example::

    from repro import solve, SolveOptions, Graph

    result = solve(graph)                               # Contour C-2
    result = solve(graph, SolveOptions(algorithm="fastsv"))
    result = solve(graph, algorithm="contour", variant="C-m")

    bigger = graph.add_edges(new_src, new_dst)
    result2 = solve(bigger, warm_start=result)          # incremental
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.connectivity import minmap
from repro.connectivity import planner as _planner
from repro.connectivity.options import SolveOptions
from repro.runtime.recovery import is_transient_error
from repro.connectivity.registry import SolverSpec, get_solver
from repro.connectivity.result import ComponentResult
from repro.graphs.structs import Graph

# Solver families that route sweeps through the kernel dispatch layer and
# therefore carry a resolved ExecutionPlan (recorded in provenance).
# "oocore" additionally gets the VMEM-derived streaming chunk bucket
# stamped into the plan (solvers.resolve_backend_plan).
_PLANNED_SOLVERS = ("contour", "distributed", "oocore")


def resolve_warm_start(warm_start, n_vertices: int):
    """Normalise a warm start to a label array (or None).

    Accepts a previous :class:`ComponentResult`, any array-like of labels,
    or None.  Only the *shape class* is checked here; length/validity
    normalisation (graph growth, the ``L[v] <= v`` invariant, the
    too-long error) lives in :func:`minmap.resolve_init_labels` — the
    single validator every solver funnels through.
    """
    del n_vertices  # length is validated by minmap.resolve_init_labels
    if warm_start is None:
        return None
    if isinstance(warm_start, ComponentResult):
        if warm_start.is_batched:
            raise ValueError(
                "warm_start is a batched ComponentResult; unstack() it or "
                "use solve_batch")
        warm_start = warm_start.labels
    labels = jnp.asarray(warm_start)
    if labels.ndim != 1:
        raise ValueError(
            f"warm_start labels must be 1-D, got shape {labels.shape}")
    # Negative-label check at the facade: device solvers reach
    # minmap.resolve_init_labels only from inside jit, where the values
    # are tracers and the eager check cannot fire.
    minmap.check_labels_nonnegative(labels)
    return labels


def solver_output(out):
    """Normalise a registry solver's return to a uniform 4-tuple.

    Solvers return ``(labels, iterations, converged)`` or the same plus a
    float32 ``edges_visited`` work counter (see ``registry``); both
    ``solve`` and ``solve_batch`` funnel through here.  A host-driven
    solver may append a 5th element — a static tuple of provenance
    strings (e.g. the out-of-core round decay) — which ``solve`` merges
    into the result's provenance and batching ignores (it cannot cross a
    ``vmap``).
    """
    labels, iterations, converged = out[:3]
    edges_visited = out[3] if len(out) > 3 else None
    return labels, iterations, converged, edges_visited


def make_result(labels, iterations, converged, edges_visited=None,
                batch_sizes=None, provenance=None) -> ComponentResult:
    """Canonical dtype normalisation into a :class:`ComponentResult`.

    The single constructor funnel for ``solve``, ``solve_batch`` and the
    streaming engine's ``snapshot()``, so the result dtypes (int32
    iterations, bool converged, float32 work counter) cannot drift between
    entry points.  ``provenance`` is the static degradation/recovery
    event tuple (empty/None = clean solve).
    """
    return ComponentResult(
        labels=labels,
        iterations=jnp.asarray(iterations, jnp.int32),
        converged=jnp.asarray(converged, bool),
        batch_sizes=batch_sizes,
        edges_visited=(None if edges_visited is None
                       else jnp.asarray(edges_visited, jnp.float32)),
        provenance=(tuple(provenance) if provenance else None))


def _resolve(options: Optional[SolveOptions],
             overrides) -> tuple[SolveOptions, SolverSpec]:
    """Validate options and pick the solver (mesh-aware)."""
    opts = options if options is not None else SolveOptions()
    if not isinstance(opts, SolveOptions):
        raise TypeError(
            f"options must be SolveOptions, got {type(opts).__name__}")
    if overrides:
        opts = opts.replace(**overrides)
    opts.validate()
    spec = get_solver(opts.algorithm)
    if opts.mesh is not None:
        if not spec.supports_mesh:
            raise ValueError(
                f"solver {spec.name!r} does not run on a mesh; use "
                "algorithm='contour' (or 'distributed')")
        if spec.name == "contour":
            # automatic single-device vs mesh dispatch
            spec = get_solver("distributed")
    opts = opts.replace(
        variant=spec.validate_variant(opts.variant),
        # registry default is the single source of per-solver budgets
        max_iters=(spec.default_max_iters if opts.max_iters is None
                   else opts.max_iters),
    )
    return opts, spec


def solve(
    graph: Graph,
    options: Optional[SolveOptions] = None,
    *,
    warm_start: Union[None, ComponentResult, jax.Array] = None,
    **overrides,
) -> ComponentResult:
    """Solve connectivity on ``graph``; returns a :class:`ComponentResult`.

    Args:
      graph: edge-list :class:`Graph` (each undirected edge once).
      options: a :class:`SolveOptions`; defaults to Contour C-2 with
        automatic kernel dispatch.
      warm_start: previous labels (array or :class:`ComponentResult`) to
        continue from — e.g. after :meth:`Graph.add_edges`.  Overrides
        ``options.warm_start``.
      **overrides: per-call :class:`SolveOptions` field overrides, e.g.
        ``solve(g, algorithm="fastsv")``.

    Returns:
      :class:`ComponentResult` with min-vertex-id ``labels``, the solver's
      ``iterations`` count, and a ``converged`` flag (each solver's own
      fixed-point test from its final loop state — the paper's §III-B2
      predicate for the min-mapping family; False iff the ``max_iters``
      budget ran out first).
    """
    opts, spec = _resolve(options, overrides)
    init = resolve_warm_start(
        warm_start if warm_start is not None else opts.warm_start,
        graph.n_vertices)
    if init is not None and not spec.supports_warm_start:
        raise ValueError(f"solver {spec.name!r} does not support warm "
                         "starts")
    plan = None
    if spec.name in _PLANNED_SOLVERS:
        # Resolve the execution plan once at the facade (pinned > tuning
        # cache for "auto" > heuristic tables) and pin it into the options
        # so the solver, the provenance record and any retry all see the
        # same plan.
        from repro.connectivity.solvers import resolve_backend_plan
        _, plan = resolve_backend_plan(graph.n_vertices, graph.n_edges,
                                       opts)
        opts = opts.replace(plan=plan)
    provenance = []
    try:
        out = spec.fn(graph, opts, init)
        if plan is not None:
            provenance.append(plan.provenance_entry())
    except Exception as exc:
        # Graceful degradation (DESIGN.md §12): a failed non-XLA kernel
        # launch (Pallas lowering/compile/launch error on a host without
        # the toolchain) falls back to the XLA reference path instead of
        # failing the request.  Caller bugs (ValueError/TypeError/...)
        # and injected SimulatedFaults propagate untouched.
        backend = (plan.backend if plan is not None
                   and opts.backend == "auto" else opts.backend)
        if (not opts.kernel_fallback or backend == "xla"
                or spec.runs_on != "device" or not is_transient_error(exc)):
            raise
        try:
            # demote this size bucket to XLA in the tuning cache — with a
            # TTL, so the failed backend is retried/retuned later instead
            # of being pinned out forever
            _planner.record_kernel_failure(
                graph.n_vertices, graph.n_edges, failed_backend=backend)
        except Exception:
            pass  # a cache write must never break the degradation path
        retry_opts = opts.replace(backend="xla", plan=None)
        out = spec.fn(graph, retry_opts, init)
        provenance.append(
            f"kernel_fallback:{backend}->xla "
            f"({type(exc).__name__}: {str(exc)[:120]})")
        if spec.name in _PLANNED_SOLVERS:
            from repro.connectivity.solvers import resolve_backend_plan
            _, retry_plan = resolve_backend_plan(
                graph.n_vertices, graph.n_edges, retry_opts)
            provenance.append(
                retry_plan.replace(origin="fallback").provenance_entry())
    labels, iterations, converged, edges_visited = solver_output(out)
    if len(out) > 4 and out[4]:
        provenance.extend(out[4])
    return make_result(labels, iterations, converged, edges_visited,
                       provenance=provenance)
