"""Typed options for the unified connectivity ``solve()`` facade.

:class:`SolveOptions` replaces the scattered string/int kwargs of the old
per-algorithm entry points (``contour_labels``, ``fastsv_labels``, ...)
with one frozen dataclass that every registered solver understands.  The
fields mirror the three decision layers of the system:

* **algorithm selection** — ``algorithm`` (registry name or alias) and
  ``variant`` (Contour's ``C-Syn``/``C-1``/``C-2``/``C-m``/``C-11mm``/
  ``C-1m1m`` or a literal ``C-<h>``);
* **kernel dispatch** — ``backend`` (``"auto"`` resolves through
  ``repro.connectivity.planner.resolve_plan``: tuning cache first, then
  the heuristic tables) or an explicit pinned
  :class:`~repro.connectivity.planner.ExecutionPlan` (a legacy
  :class:`~repro.kernels.contour_mm.ops.KernelPlan` is also accepted)
  in ``plan``;
* **work schedule** — ``sampling``/``compact_every`` enable the
  work-adaptive frontier contraction of ``repro.connectivity.frontier``
  (sample-prefix sweeps, largest-component filter, periodic active-edge
  contraction); both default to 0 = the paper's dense every-edge sweeps;
* **placement** — ``mesh``/``edge_axes``/``local_rounds`` route the solve
  through the ``shard_map`` distributed path; ``mesh=None`` (default) is
  single-device.

``warm_start`` carries the previous solve's labels (or a whole
:class:`~repro.connectivity.result.ComponentResult`) for incremental
solving; it may equivalently be passed per-call to ``solve()``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax

from repro.kernels.contour_mm.ops import BACKENDS, KernelPlan


@dataclasses.dataclass(frozen=True, eq=False)
class SolveOptions:
    """Options for :func:`repro.connectivity.solve`.

    ``eq=False`` keeps instances identity-hashed: ``warm_start`` may hold
    a device array, which has no value equality.
    """

    algorithm: str = "contour"
    variant: Optional[str] = None          # per-algorithm default if None
    backend: str = "auto"
    # explicit pinned ExecutionPlan (or legacy KernelPlan); None = resolve
    # via the planner (tuning cache for "auto", heuristic tables otherwise)
    plan: Optional[Any] = None
    mesh: Optional[jax.sharding.Mesh] = None
    edge_axes: Tuple[str, ...] = ("data",)
    local_rounds: int = 1
    max_iters: Optional[int] = None        # per-algorithm default if None
    warmup: int = 2                        # C-11mm's C-1 prefix length
    async_compress: int = 1                # in-iteration pointer-jump rounds
    sampling: int = 0                      # frontier sample-prefix sweeps
    compact_every: int = 0                 # contraction cadence (0 = dense)
    # sampling-phase strategy (frontier.SAMPLING_STRATEGIES): None = the
    # per-solver default ("prefix"); an explicit value is treated as
    # *pinned* by the solver="auto" cost model (costmodel.resolve_strategy)
    sampling_strategy: Optional[str] = None
    sampling_k: int = 2                    # k-out sampler fan-in per vertex
    warm_start: Optional[Any] = None       # labels array or ComponentResult
    # graceful degradation (DESIGN.md §12): when a non-XLA kernel launch
    # fails with a transient error, retry the solve on the XLA reference
    # backend and record the fallback in ComponentResult.provenance
    # instead of failing the request.  False = fail loudly.
    kernel_fallback: bool = True
    # per-core VMEM budget override (bytes) behind the scalar kernel's
    # whole-L ceiling; None = $REPRO_VMEM_BYTES, device query, or the
    # per-platform table (planner.vmem)
    vmem_limit_bytes: Optional[int] = None
    # out-of-core streaming (algorithm="oocore", DESIGN.md §15): device
    # edge-chunk budget (0 = derive from the VMEM budget via
    # planner.oocore_chunk_bucket; rounded up to a power of two), cap on
    # host-contraction rounds before the in-core finish is forced, and
    # bounded local min-mapping sweeps folded per chunk per round
    oocore_chunk_edges: int = 0
    oocore_round_cap: int = 64
    oocore_local_iters: int = 4

    def replace(self, **updates) -> "SolveOptions":
        """Return a copy with the given fields replaced."""
        return dataclasses.replace(self, **updates)

    def validate(self) -> None:
        """Cheap structural checks; registry-level checks live in solve()."""
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend {self.backend!r} not one of {BACKENDS}")
        if self.local_rounds < 1:
            raise ValueError(f"local_rounds must be >= 1, got "
                             f"{self.local_rounds}")
        if self.max_iters is not None and self.max_iters < 1:
            raise ValueError(f"max_iters must be >= 1, got {self.max_iters}")
        # negative counts would silently change the iteration math instead
        # of failing: e.g. async_compress=-1 cancels C-m's jump rounds in
        # pointer_jump(rounds=jump_rounds + async_compress)
        for field in ("warmup", "async_compress", "sampling",
                      "compact_every"):
            value = getattr(self, field)
            if value < 0:
                raise ValueError(f"{field} must be >= 0, got {value}")
        if self.sampling_strategy is not None:
            # deferred: frontier pulls in jax.numpy helpers; keep the
            # options module import-light
            from repro.connectivity.frontier import get_sampling_strategy
            get_sampling_strategy(self.sampling_strategy)  # raises on typo
        if self.sampling_k < 1:
            raise ValueError(
                f"sampling_k must be >= 1, got {self.sampling_k}")
        if self.mesh is not None and not self.edge_axes:
            raise ValueError("edge_axes must be non-empty when a mesh is "
                             "given")
        if self.vmem_limit_bytes is not None and self.vmem_limit_bytes <= 0:
            raise ValueError(f"vmem_limit_bytes must be > 0, got "
                             f"{self.vmem_limit_bytes}")
        if self.oocore_chunk_edges:
            # deferred: planner.staged pulls in frontier/minmap, and the
            # planner package itself reaches solve() via autotune
            from repro.connectivity.planner.staged import MIN_STAGE_EDGES
            if self.oocore_chunk_edges < MIN_STAGE_EDGES:
                raise ValueError(
                    f"oocore_chunk_edges must be 0 (auto) or >= "
                    f"MIN_STAGE_EDGES ({MIN_STAGE_EDGES}); a chunk of "
                    f"{self.oocore_chunk_edges} edges would thrash "
                    f"per-bucket compiles")
        if self.oocore_round_cap < 1:
            raise ValueError(f"oocore_round_cap must be >= 1, got "
                             f"{self.oocore_round_cap}")
        if self.oocore_local_iters < 1:
            raise ValueError(f"oocore_local_iters must be >= 1, got "
                             f"{self.oocore_local_iters}")
