"""The measuring autotuner: time candidate plans, cache the winner.

ConnectIt's central observation (PAPERS.md) is that the right dispatch
choice is a *per-graph-family measurement*, not a table.  This module
makes the plan layer measured:

* :func:`candidate_plans` enumerates a bounded set of (backend,
  label_block, chunk, compact-schedule) configs for a graph size — the
  heuristic prior is always candidate zero;
* :func:`autotune` times each candidate on the caller's actual graph
  (best-of-k wall clock through the real ``solve`` facade, so the
  measurement includes exactly what a user pays) and persists the winner
  to the on-disk cache (``planner.cache``) keyed by
  (platform, n-bucket, m-bucket);
* **hysteresis**: a non-heuristic candidate is committed only when it
  beats the heuristic by more than ``margin`` (default 5%) — near-ties
  resolve to the prior, so the bench ``autotune_gate``'s re-measurement
  cannot flip a coin-toss into a regression.

Tuning never happens implicitly: ``solve()`` only *reads* the cache
(through ``planner.resolve_plan``).  Timing is injectable (``measure=``)
so the decision logic is unit-testable without wall-clock noise.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import jax

from repro.connectivity.planner import cache as _cache
from repro.connectivity.planner.heuristics import heuristic_plan
from repro.connectivity.planner.plan import ExecutionPlan

# Fallback demotions expire after this long; past it the bucket resolves
# back to the heuristic (or a fresh tuning) and the failed backend is
# retried — a flaky kernel launch must not pin XLA forever.
FALLBACK_TTL_S = 3600.0


def plan_label(plan: ExecutionPlan) -> str:
    """Short human key for timing tables."""
    return (f"{plan.backend}/{plan.compact_schedule}"
            f"/lb{plan.label_block}/cu{plan.chunk_updates}"
            f"{'/fused' if plan.fuse_relabel else ''}")


def candidate_plans(n_vertices: int, m_edges: int,
                    platform: Optional[str] = None) -> List[ExecutionPlan]:
    """Bounded candidate set; the heuristic prior is always first."""
    platform = platform or jax.default_backend()
    base = heuristic_plan(n_vertices, m_edges, platform)
    cands = [base]

    def add(p: ExecutionPlan):
        if not any(p.config_equal(c) for c in cands):
            cands.append(p)

    for schedule in ("masked", "staged"):
        add(base.replace(compact_schedule=schedule))
    if platform == "tpu":
        # tile-size neighbourhood of the prior (the one-hot combine cost
        # is ∝ label_block·chunk; bin padding waste is ∝ blocks·chunk)
        for lb in (1024, 2048, 4096):
            for cu in (64, 128, 256):
                if lb * cu <= 1 << 20:   # cap the one-hot buffer at 4 MiB
                    add(base.replace(label_block=lb, chunk_updates=cu,
                                     fuse_relabel=False))
        if base.fuse_relabel:
            add(base.replace(fuse_relabel=False))
    return cands


def _measure_solve(graph, plan: ExecutionPlan, opts,
                   repeats: int = 3) -> float:
    """Best-of-k wall clock of ``solve`` under a pinned plan."""
    from repro.connectivity.solve import solve  # lazy: avoid import cycle

    pinned = opts.replace(plan=plan.replace(origin="pinned"),
                          backend=plan.backend)

    def run():
        res = solve(graph, pinned)
        res.labels.block_until_ready()

    run()                                   # warmup / compile
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return best


def autotune(
    graph,
    opts=None,
    *,
    platform: Optional[str] = None,
    repeats: int = 3,
    margin: float = 0.05,
    measure: Optional[Callable] = None,
    cache_path: Optional[str] = None,
    write: bool = True,
) -> Tuple[ExecutionPlan, Dict[str, float]]:
    """Measure candidates on ``graph``; cache and return the winner.

    Returns ``(plan, timings)`` where ``plan`` has ``origin="tuned"`` and
    ``timings`` maps :func:`plan_label` to best-of-k seconds.  ``measure``
    overrides the timing function (``measure(graph, plan, opts) -> s``)
    for deterministic tests; ``write=False`` skips the cache write.
    """
    from repro.connectivity.options import SolveOptions  # lazy

    platform = platform or jax.default_backend()
    if opts is None:
        # the workload shape tuning certifies: the work-adaptive schedule
        # (where masked-vs-staged matters) on the default variant
        opts = SolveOptions(sampling=2, compact_every=2)
    if measure is None:
        measure = lambda g, p, o: _measure_solve(g, p, o, repeats=repeats)

    n, m = graph.n_vertices, graph.n_edges
    cands = candidate_plans(n, m, platform)
    timings: Dict[str, float] = {}
    best_plan, best_t = None, float("inf")
    for p in cands:
        t = float(measure(graph, p, opts))
        timings[plan_label(p)] = t
        if t < best_t:
            best_plan, best_t = p, t
    heur = cands[0]
    heur_t = timings[plan_label(heur)]
    # hysteresis: commit a non-prior config only on a clear win
    if not best_plan.config_equal(heur) and best_t >= heur_t * (1 - margin):
        best_plan, best_t = heur, heur_t
    tuned = best_plan.replace(origin="tuned")
    if write:
        _cache.store(n, m, platform, tuned, time_s=best_t, timings=timings,
                     origin="tuned", path=cache_path)
    return tuned, timings


def record_kernel_failure(
    n_vertices: int,
    m_edges: int,
    platform: Optional[str] = None,
    *,
    failed_backend: str = "",
    ttl_s: float = FALLBACK_TTL_S,
    cache_path: Optional[str] = None,
) -> ExecutionPlan:
    """Demote a bucket to XLA after a kernel-launch failure — with a TTL.

    The resilience fallback path (``solve``/streaming) calls this so the
    *next* solve in the bucket resolves straight to XLA instead of
    re-failing; once ``ttl_s`` lapses the entry expires and the bucket
    retunes, so a transient failure never pins XLA permanently.
    """
    platform = platform or jax.default_backend()
    plan = ExecutionPlan(backend="xla",
                         interpret=(platform != "tpu"),
                         compact_schedule=heuristic_plan(
                             n_vertices, m_edges, platform).compact_schedule,
                         origin="fallback")
    _cache.store(n_vertices, m_edges, platform, plan, origin="fallback",
                 ttl_s=ttl_s, path=cache_path,
                 timings={"demoted_from": failed_backend} if failed_backend
                 else None)
    return plan
