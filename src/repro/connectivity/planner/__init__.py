"""Execution-plan layer: how a connectivity solve actually runs.

The seed buried dispatch policy in ``kernels.contour_mm.ops`` as a frozen
``KernelPlan`` plus hand-tuned heuristic tables.  This package lifts it
into a first-class, *measured* layer:

* :mod:`~repro.connectivity.planner.plan` — :class:`ExecutionPlan`, the
  hashable value threaded (as a jit-static argument) through every solver
  path: backend, tile sizes, frontier compaction schedule
  (masked-in-loop vs physically staged), relabel fusion, and its origin
  (heuristic / tuned / pinned / fallback).
* :mod:`~repro.connectivity.planner.heuristics` — the cold-start tables
  (the autotuner's prior, and the only policy used under ``jit`` tracing
  or when the cache is unusable).
* :mod:`~repro.connectivity.planner.autotune` /
  :mod:`~repro.connectivity.planner.cache` — the measuring autotuner and
  its on-disk cache keyed by (platform, n-bucket, m-bucket).
* :mod:`~repro.connectivity.planner.vmem` — per-platform VMEM budget and
  the whole-L ceiling derived from it (was a hard-coded constant).
* :mod:`~repro.connectivity.planner.staged` — the physically-sliced
  staged frontier driver (the grid really shrinks with the frontier).
* :mod:`~repro.connectivity.planner.costmodel` — the ``solver="auto"``
  strategy cost model (pinned > fitted from the bench artifact >
  heuristic), DESIGN.md §16.

:func:`resolve_plan` is the single resolution point::

    pinned plan argument  >  tuning cache (only for backend="auto")
                          >  heuristic tables

The cache is consulted *only* when the caller left the backend on
``"auto"``: an explicit backend choice is a statement of intent (and the
bench HLO-identity gate depends on forced backends staying deterministic).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.connectivity.planner import cache
from repro.connectivity.planner.costmodel import (
    ENV_BENCH_ARTIFACT,
    StrategyChoice,
    resolve_strategy,
)
from repro.connectivity.planner.autotune import (
    autotune,
    candidate_plans,
    plan_label,
    record_kernel_failure,
)
from repro.connectivity.planner.heuristics import (
    OOCORE_BYTES_PER_EDGE,
    SINGLE_TILE_MAX_N,
    STAGED_MIN_EDGES,
    heuristic_plan,
    oocore_chunk_bucket,
)
from repro.connectivity.planner.plan import (
    BACKENDS,
    COMPACT_SCHEDULES,
    ORIGINS,
    ExecutionPlan,
    next_pow2,
    plan_key,
    size_bucket,
)
from repro.connectivity.planner.vmem import (
    ENV_VMEM_BYTES,
    vmem_budget_bytes,
    whole_l_vmem_ceiling,
)

__all__ = [
    "BACKENDS",
    "COMPACT_SCHEDULES",
    "ENV_BENCH_ARTIFACT",
    "ENV_VMEM_BYTES",
    "StrategyChoice",
    "resolve_strategy",
    "OOCORE_BYTES_PER_EDGE",
    "ORIGINS",
    "SINGLE_TILE_MAX_N",
    "STAGED_MIN_EDGES",
    "ExecutionPlan",
    "autotune",
    "cache",
    "candidate_plans",
    "heuristic_plan",
    "next_pow2",
    "oocore_chunk_bucket",
    "plan_key",
    "plan_label",
    "record_kernel_failure",
    "resolve_plan",
    "size_bucket",
    "vmem_budget_bytes",
    "whole_l_vmem_ceiling",
]


def resolve_plan(
    n_vertices: int,
    m_edges: int,
    *,
    backend: str = "auto",
    plan=None,
    platform: Optional[str] = None,
    use_cache: bool = True,
) -> ExecutionPlan:
    """Resolve the :class:`ExecutionPlan` for one solve.

    ``plan`` pinned by the caller wins outright (lifted from a legacy
    ``KernelPlan`` if needed).  Otherwise, with ``backend="auto"``, a
    valid non-expired tuning-cache entry for this size bucket is used;
    on a miss — or with any *forced* backend — the heuristic tables
    decide (with the forced backend substituted in).
    """
    if plan is not None:
        return ExecutionPlan.from_kernel_plan(plan)
    platform = platform or jax.default_backend()
    if backend == "auto":
        if use_cache:
            cached = cache.lookup(n_vertices, m_edges, platform)
            if cached is not None:
                return cached
        return heuristic_plan(n_vertices, m_edges, platform)
    p = heuristic_plan(n_vertices, m_edges, platform)
    if p.backend != backend:
        # forced off the table's choice: pallas kernels off-TPU only run
        # interpreted, and the interpret flag must follow the platform
        p = p.replace(backend=backend,
                      interpret=(platform != "tpu" and
                                 backend.startswith("pallas")))
    return p
