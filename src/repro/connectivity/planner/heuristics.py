"""Cold-start heuristic tables: the autotuner's prior.

These are the (slightly extended) tables that used to live in
``kernels.contour_mm.ops.plan_contour_kernel``.  They remain the
deterministic fallback whenever the tuning cache has no (valid) entry for
a bucket, and the reference side of the bench ``autotune_gate`` — the
tuned plan must measure no slower than this prior.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.connectivity.planner.plan import ExecutionPlan, next_pow2
from repro.connectivity.planner.vmem import vmem_budget_bytes

# Past this many edges the staged frontier schedule is worth its extra
# per-stage compiles on the XLA path: each stage re-enters the while loop
# over a physically sliced (pow2-bucketed) edge array, so sweeps and
# contractions stop paying full-m masked work.  Below it the masked
# single-loop schedule wins (one compile, tiny arrays).
STAGED_MIN_EDGES = 1 << 15

# Single-tile regime: the blocked kernel holds all of L in one tile and
# the fused relabel+scatter-min pass is eligible (no update-stream
# materialisation, no radix binning).
SINGLE_TILE_MAX_N = 4096

# Out-of-core chunk sizing: per-edge device cost of one resident chunk.
# A chunk holds int64 src/dst (16 B/edge) double-buffered (32 B/edge),
# plus the sweep's relabeled copies and contraction temporaries — call it
# 128 B/edge so the derived chunk plus the O(n) label array stay well
# inside the VMEM-scale working-set budget the planner already owns.
OOCORE_BYTES_PER_EDGE = 128


def _round_up(x: int, k: int) -> int:
    return (x + k - 1) // k * k


def heuristic_plan(
    n_vertices: int,
    n_edges: int,
    platform: Optional[str] = None,
) -> ExecutionPlan:
    """Pick backend + tile sizes + schedule for a graph size, by table.

    Off-TPU the only compilable backend is XLA scatter-min.  On TPU the
    blocked kernel is always eligible (no ceiling); tile sizes balance the
    one-hot combine work (∝ ``label_block`` per update) against per-bin
    padding waste (∝ ``n_blocks·chunk_updates``):

    * small graphs waste least with one or two tiles spanning all of L —
      and in the single-tile regime the fused relabel+scatter-min pass
      skips the update-stream materialisation entirely;
    * large graphs hold ``label_block`` at 2048 (8 KiB tile, 1 MiB one-hot
      buffer at chunk 128) and scale ``chunk_updates`` with edge density
      so sparse bins do not drown in padding.

    ``compact_schedule`` only matters when the caller also enables the
    work-adaptive frontier (``sampling``/``compact_every``): big edge
    lists get the ``"staged"`` physically-sliced realisation, small ones
    keep the masked in-loop schedule.
    """
    platform = platform or jax.default_backend()
    compact = "staged" if n_edges >= STAGED_MIN_EDGES else "masked"
    if platform != "tpu":
        # Pallas TPU kernels cannot compile here; if a caller forces a
        # pallas backend anyway it runs in interpret (validation) mode.
        return ExecutionPlan(backend="xla", interpret=True,
                             compact_schedule=compact, origin="heuristic")
    if n_vertices <= SINGLE_TILE_MAX_N:
        # single tile: the blocked kernel degenerates to a whole-L
        # vectorized sweep with zero binning waste, and the fused
        # gather+scatter-min pass applies
        label_block = max(256, _round_up(n_vertices, 128))
        chunk = 128
        fuse = True
    else:
        label_block = 2048
        # denser update streams amortise more padding; cap the one-hot
        # buffer at chunk*label_block = 512Ki elements (2 MiB)
        chunk = 64 if n_edges < 8 * n_vertices else 256
        fuse = False
    block_edges = 512 if n_edges < 1 << 20 else 2048
    return ExecutionPlan(
        backend="pallas_blocked",
        block_edges=block_edges,
        label_block=label_block,
        chunk_updates=chunk,
        interpret=False,
        compact_schedule=compact,
        fuse_relabel=fuse,
        origin="heuristic",
    )


def oocore_chunk_bucket(
    n_edges: int,
    platform: Optional[str] = None,
    vmem_limit_bytes: Optional[int] = None,
    requested: int = 0,
) -> int:
    """The pow2 edge-chunk bucket the out-of-core streamer runs at.

    ``requested`` (``SolveOptions.oocore_chunk_edges``) wins when set,
    rounded up to a power of two; otherwise the bucket is derived from
    the platform VMEM budget at :data:`OOCORE_BYTES_PER_EDGE`.  Either
    way the result is clamped to ``[MIN_STAGE_EDGES, next_pow2(m)]`` —
    chunks below the stage floor would thrash compiles, and a chunk
    larger than the whole graph is just the in-core path.
    """
    from repro.connectivity.planner.staged import MIN_STAGE_EDGES
    if requested and requested > 0:
        bucket = next_pow2(requested)
    else:
        budget = vmem_budget_bytes(platform, override=vmem_limit_bytes)
        # round *down* to pow2: never exceed the derived byte budget
        bucket = next_pow2(max(budget // OOCORE_BYTES_PER_EDGE, 1))
        if bucket * OOCORE_BYTES_PER_EDGE > budget:
            bucket //= 2
    ceiling = max(next_pow2(n_edges), MIN_STAGE_EDGES)
    return max(MIN_STAGE_EDGES, min(bucket, ceiling))
