"""Staged realisation of the work-adaptive frontier: the grid really shrinks.

The masked schedule (``frontier.adaptive_fixpoint``) keeps every array at
its original static shape inside one ``lax.while_loop`` — sound, zero host
syncs, composable with ``vmap``/``shard_map`` — but on the XLA path a
"skipped" edge still flows through full-shape masked tiles, which is
exactly why the counted-work savings of DESIGN.md §10 never showed up as
wall clock (ROADMAP open item 1).

This module is the physical counterpart, per Sutton et al.'s
*Adaptive Work-Efficient Connected Components on the GPU* (PAPERS.md):
the fixpoint is split into **stages**.  Each stage is the same on-device
while loop, but over edge arrays *physically sliced* to a power-of-two
bucket of the live frontier; when the frontier drops below half the
stage's capacity the loop exits early, the host slices the ``[active |
retired]`` prefix (one device-side slice, no gather), and re-enters at
the smaller static shape.  XLA shapes are static *per program*, so "the
grid shrinks inside the while loop" is realised as a chain of while loops
at geometrically shrinking shapes — at most ``log2(m)`` stages, each
compiled once per pow2 bucket and cached across graphs.

Soundness of dropping the suffix: the layout invariant of
``frontier.contract_edges`` puts every live edge in the ``active_m``
prefix; positions past it are never swept (``frontier_limit``), never
checked (``masked_converged_early``), and never re-activated (contraction
only retires).  The sliced-off suffix is therefore provably dead weight —
the fixed point is unchanged, and it equals the oracle min-vertex-id
labelling exactly as the masked schedule's does (property-tested
masked == staged == dense == oracle in ``tests/test_planner.py``).

The sampling phase gets the same treatment: the first ``sampling`` sweeps
touch only the deterministic ``m // 4`` edge prefix, so they run over a
*static slice* of the edge arrays — bit-equivalent to the masked limit
(the masked-out suffix contributes only ``(0, 0)`` self-loop no-ops) at a
quarter of the sweep cost.

This driver is host-side by construction (it reads ``active_m`` between
stages), so it only runs from an eager ``solve()``; under an enclosing
trace (``vmap``/``solve_batch``/user ``jit``) the caller keeps the masked
schedule.  The streaming engine also stays masked: its per-batch delta
solves are latency-bound single programs and their bit-identical
conformance gate is frozen.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.connectivity import frontier as fr
from repro.connectivity import minmap as lab
from repro.connectivity.planner.plan import next_pow2

# Below this capacity a stage runs to convergence instead of re-slicing:
# the residual arrays are small enough that another compile costs more
# than the masked work it would save.
MIN_STAGE_EDGES = 1024


class _StageState(NamedTuple):
    L: jax.Array
    it: jax.Array
    done: jax.Array
    src: jax.Array
    dst: jax.Array
    active_m: jax.Array
    visited: jax.Array


def _build_step(variant, warmup, async_compress, backend, plan,
                vmem_limit_bytes=None):
    from repro.connectivity.contour import _make_step  # lazy: import cycle
    return _make_step(variant, warmup, async_compress, backend, plan,
                      vmem_limit_bytes)


@functools.partial(
    jax.jit,
    static_argnames=("variant", "warmup", "async_compress", "backend",
                     "plan", "sampling", "max_iters", "n_vertices",
                     "vmem_limit_bytes"),
)
def _sampling_stage(
    src_s: jax.Array,
    dst_s: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    L0: jax.Array,
    *,
    variant: str,
    warmup: int,
    async_compress: int,
    backend: str,
    plan,
    sampling: int,
    max_iters: int,
    n_vertices: int,
    vmem_limit_bytes=None,
):
    """The ``sampling`` sweeps over the *sliced* sample arrays, with the
    masked path's convergence check and compaction schedule per body.

    Bit-equivalent to the masked path's first ``sampling`` iterations:
    there the limit masks everything past ``sample_m`` to ``(0, 0)``
    self-loops (scatter-min no-ops, since ``L[0] == 0`` under the
    ``L[v] <= v`` invariant); here the sweep runs over the physical
    slice at the same limit.  The §III-B2 check runs over the *full*
    active prefix (``masked_converged_early``), so a graph that reaches
    its fixed point mid-sampling exits with ``done`` — same iteration
    and visited counters as the masked loop.  ``apply_compaction`` with
    ``compact_every=0`` fires exactly the one largest-component filter
    at ``it1 == sampling`` (the masked schedule's ``do_gen`` is also
    inert while ``it1 <= sampling``).  The sliced sample arrays never
    need recompaction: they are last swept at ``it == sampling - 1``,
    before the filter fires.

    Returns ``(L, it, done, src, dst, active_m, visited)``.
    """
    step = _build_step(variant, warmup, async_compress, backend, plan,
                       vmem_limit_bytes)
    sample_m = jnp.int32(src_s.shape[0])
    iters = min(sampling, max_iters)

    def cond(s: _StageState):
        return (~s.done) & (s.it < iters)

    def body(s: _StageState):
        limit = fr.frontier_limit(s.it, s.active_m, sample_m, sampling)
        L = step(s.L, s.it, src_s, dst_s, limit)
        visited = s.visited + limit.astype(jnp.float32)
        done = fr.gate_sampling_done(
            fr.masked_converged_early(L, s.src, s.dst, s.active_m),
            s.it, sampling)
        it1 = s.it + 1
        src2, dst2, active2 = fr.apply_compaction(
            L, s.src, s.dst, s.active_m, it1, sampling=sampling,
            compact_every=0, n_vertices=n_vertices)
        return _StageState(L=L, it=it1, done=done, src=src2, dst=dst2,
                           active_m=active2, visited=visited)

    out = jax.lax.while_loop(
        cond, body,
        _StageState(L=L0, it=jnp.int32(0), done=jnp.array(False),
                    src=src, dst=dst,
                    active_m=jnp.int32(src.shape[0]),
                    visited=jnp.float32(0)))
    return (out.L, out.it, out.done, out.src, out.dst, out.active_m,
            out.visited)


@functools.partial(
    jax.jit,
    static_argnames=("variant", "warmup", "async_compress", "backend",
                     "plan", "sampling", "compact_every", "max_iters",
                     "n_vertices", "allow_exit", "vmem_limit_bytes"),
)
def _stage_fixpoint(
    src: jax.Array,
    dst: jax.Array,
    L0: jax.Array,
    it0: jax.Array,
    visited0: jax.Array,
    active0: jax.Array,
    *,
    variant: str,
    warmup: int,
    async_compress: int,
    backend: str,
    plan,
    sampling: int,
    compact_every: int,
    max_iters: int,
    n_vertices: int,
    allow_exit: bool,
    vmem_limit_bytes=None,
):
    """One stage: the adaptive while loop at this (pow2) edge capacity.

    Identical body to ``frontier.adaptive_fixpoint`` (same limit, same
    convergence gate, same compaction schedule — shared helpers, so the
    two schedules cannot drift), plus an early *stage exit* once the live
    frontier fits in half this capacity — the driver then re-enters at
    the smaller static shape.  Exit is gated on ``it >= sampling``: the
    sampling phase's limit depends on the original ``m``, so it must
    complete inside the first stage.
    """
    m = src.shape[0]
    sample_m = jnp.int32(fr.sample_prefix_m(m))
    half = m // 2
    stop = half if (allow_exit and half >= MIN_STAGE_EDGES) else 0
    step = _build_step(variant, warmup, async_compress, backend, plan,
                       vmem_limit_bytes)

    def shrunk(s: _StageState):
        if stop <= 0:
            return jnp.array(False)
        return (s.active_m <= stop) & (s.it >= sampling)

    def cond(s: _StageState):
        return (~s.done) & (s.it < max_iters) & ~shrunk(s)

    def body(s: _StageState):
        limit = fr.frontier_limit(s.it, s.active_m, sample_m, sampling)
        L = step(s.L, s.it, s.src, s.dst, limit)
        visited = s.visited + limit.astype(jnp.float32)
        done = fr.gate_sampling_done(
            fr.masked_converged_early(L, s.src, s.dst, s.active_m),
            s.it, sampling)
        it1 = s.it + 1
        src2, dst2, active2 = fr.apply_compaction(
            L, s.src, s.dst, s.active_m, it1, sampling=sampling,
            compact_every=compact_every, n_vertices=n_vertices)
        return _StageState(L=L, it=it1, done=done, src=src2, dst=dst2,
                           active_m=active2, visited=visited)

    out = jax.lax.while_loop(
        cond, body,
        _StageState(L=L0, it=jnp.asarray(it0, jnp.int32),
                    done=jnp.array(False), src=src, dst=dst,
                    active_m=jnp.asarray(active0, jnp.int32),
                    visited=jnp.asarray(visited0, jnp.float32)))
    # compress between stages too: idempotent at the fixed point, and a
    # shallower pointer forest only speeds the next stage's gathers
    return (fr.compress_full(out.L), out.it, out.done, out.src, out.dst,
            out.active_m, out.visited)


def staged_adaptive_labels(
    src: jax.Array,
    dst: jax.Array,
    n_vertices: int,
    init_labels: Optional[jax.Array] = None,
    *,
    variant: str = "C-2",
    max_iters: int = 100_000,
    warmup: int = 2,
    async_compress: int = 1,
    backend: str = "xla",
    plan=None,
    sampling: int = 0,
    compact_every: int = 0,
    sampling_strategy: str = "prefix",
    sampling_k: int = fr.DEFAULT_SAMPLING_K,
    vmem_limit_bytes: Optional[int] = None,
):
    """Host-driven staged fixpoint; same contract as ``contour_labels``.

    Returns ``(labels, n_iterations, converged, edges_visited)``.  Must be
    called eagerly (it reads ``active_m`` between stages); callers under a
    trace use the masked schedule instead (``solvers._contour_solver``
    guards on tracers).  ``sampling_strategy``/``sampling_k`` pick the
    sampling phase's edge permutation (``frontier.prepare_sampling``) —
    being eager, this driver can slice the strategy's data-dependent
    sample width into a physical prefix.
    """
    if variant == "C-Syn":
        raise ValueError(
            "C-Syn is the Alg.-1-verbatim reference and does not take the "
            "work-adaptive schedule; use C-2/C-m (or any async variant) "
            "with sampling/compact_every")
    if sampling < 0 or compact_every < 0:
        raise ValueError("sampling and compact_every must be >= 0, got "
                         f"{sampling} / {compact_every}")
    statics = dict(variant=variant, warmup=warmup,
                   async_compress=async_compress, backend=backend,
                   plan=plan, sampling=sampling, max_iters=max_iters,
                   n_vertices=n_vertices,
                   vmem_limit_bytes=vmem_limit_bytes)
    L = lab.resolve_init_labels(init_labels, n_vertices, src.dtype)
    it = jnp.int32(0)
    visited = jnp.float32(0)
    active = jnp.int32(src.shape[0])

    if sampling > 0:
        if sampling_strategy != "prefix":
            src, dst, sample_m = fr.prepare_sampling(
                sampling_strategy, src, dst, n_vertices, sampling_k)
            sm = int(sample_m)  # eager driver: slice the traced width
        else:
            sm = fr.sample_prefix_m(int(src.shape[0]))
        L, it, done, src, dst, active, visited = _sampling_stage(
            src[:sm], dst[:sm], src, dst, L, **statics)
        if bool(done) or int(it) >= max_iters:
            return fr.compress_full(L), it, done, visited

    # slice straight away when the filter already collapsed the frontier
    first = True
    while True:
        m_cur = int(src.shape[0])
        if not first or sampling > 0:
            am = int(active)
            new_m = max(MIN_STAGE_EDGES, next_pow2(am))
            if new_m < m_cur:
                src, dst = src[:new_m], dst[:new_m]
        first = False
        L, it, done, src, dst, active, visited = _stage_fixpoint(
            src, dst, L, it, visited, active, compact_every=compact_every,
            allow_exit=True, **statics)
        if bool(done) or int(it) >= max_iters:
            return L, it, done, visited
        am = int(active)
        new_m = max(MIN_STAGE_EDGES, next_pow2(am))
        if new_m >= int(src.shape[0]):
            # cannot shrink further — finish at this capacity
            L, it, done, src, dst, active, visited = _stage_fixpoint(
                src, dst, L, it, visited, active,
                compact_every=compact_every, allow_exit=False, **statics)
            return L, it, done, visited
