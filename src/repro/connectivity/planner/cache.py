"""On-disk tuning cache: measured plans per (platform, n-bucket, m-bucket).

A single JSON file maps :func:`~repro.connectivity.planner.plan.plan_key`
buckets to the config the measuring autotuner found fastest, plus the
timing evidence.  Design constraints, in order:

* **solve() stays deterministic and fast** — lookups are an in-process
  dict hit (the file is re-read only when its mtime changes); tuning
  itself happens only when explicitly requested (``benchmarks/run.py
  --retune``, :func:`planner.autotune.autotune`), never implicitly on a
  user's solve.
* **corrupt or stale entries can never crash a solve** — any parse
  error, schema mismatch, unknown field, wrong type, or invalid backend
  makes :func:`lookup` return ``None`` and the caller falls back to the
  heuristic prior (property-tested in ``tests/test_planner.py``).
* **fallback demotions expire** — when a kernel launch fails, the
  resilience path records an ``origin="fallback"`` XLA entry with a TTL
  instead of pinning XLA forever; once it lapses the bucket resolves back
  to the heuristic (or a fresh tuning) and the original backend gets
  retried/retuned.

Location: ``$REPRO_TUNING_CACHE`` if set, else
``~/.cache/repro/contour_tuning.json``.  Delete the file (or point the
env var at an empty path) to clear every tuned plan.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Dict, Optional, Tuple

from repro.connectivity.planner.plan import ExecutionPlan, plan_key

ENV_CACHE_PATH = "REPRO_TUNING_CACHE"
CACHE_SCHEMA = 1

# In-process mirror: path -> (mtime_ns or None, entries dict)
_LOADED: Dict[str, Tuple[Optional[int], dict]] = {}


def cache_path() -> str:
    env = os.environ.get(ENV_CACHE_PATH)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "contour_tuning.json")


def _read(path: str) -> dict:
    """Entries dict from disk; {} on any corruption (never raises)."""
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        _LOADED[path] = (None, {})
        return {}
    cached = _LOADED.get(path)
    if cached is not None and cached[0] == mtime:
        return cached[1]
    try:
        with open(path) as f:
            payload = json.load(f)
        if not isinstance(payload, dict) or \
                payload.get("schema") != CACHE_SCHEMA:
            entries: dict = {}
        else:
            entries = payload.get("entries", {})
            if not isinstance(entries, dict):
                entries = {}
    except (OSError, ValueError):
        entries = {}
    _LOADED[path] = (mtime, entries)
    return entries


def _write(path: str, entries: dict) -> None:
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".contour_tuning.")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump({"schema": CACHE_SCHEMA, "entries": entries}, f,
                      indent=2, sort_keys=True)
        os.replace(tmp, path)  # atomic publish, same protocol as §12
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    _LOADED.pop(path, None)


def entries(path: Optional[str] = None) -> dict:
    """A copy of the raw cache entries (for the bench artifact)."""
    return dict(_read(path or cache_path()))


def lookup(
    n_vertices: int,
    m_edges: int,
    platform: str,
    path: Optional[str] = None,
    now: Optional[float] = None,
) -> Optional[ExecutionPlan]:
    """The cached plan for this bucket, or None (miss/corrupt/expired)."""
    path = path or cache_path()
    entry = _read(path).get(plan_key(platform, n_vertices, m_edges))
    if not isinstance(entry, dict):
        return None
    origin = entry.get("origin", "tuned")
    if origin not in ("tuned", "fallback"):
        return None
    if origin == "fallback":
        expires = entry.get("expires_at")
        if not isinstance(expires, (int, float)):
            return None  # malformed demotion: treat as expired
        if (time.time() if now is None else now) >= expires:
            return None  # lapsed: retune instead of pinning XLA forever
    try:
        return ExecutionPlan.from_config(entry.get("config"), origin=origin)
    except (ValueError, TypeError):
        return None  # stale/corrupt entry: heuristic prior takes over


def store(
    n_vertices: int,
    m_edges: int,
    platform: str,
    plan: ExecutionPlan,
    *,
    time_s: Optional[float] = None,
    timings: Optional[dict] = None,
    origin: str = "tuned",
    ttl_s: Optional[float] = None,
    path: Optional[str] = None,
    now: Optional[float] = None,
) -> dict:
    """Persist a measured (or demoted) plan for this bucket; returns the
    stored entry."""
    path = path or cache_path()
    now = time.time() if now is None else now
    entry = {
        "config": plan.to_config(),
        "origin": origin,
        "measured_at": now,
    }
    if time_s is not None:
        entry["time_s"] = float(time_s)
    if timings is not None:
        entry["timings"] = timings
    if ttl_s is not None:
        entry["expires_at"] = now + float(ttl_s)
    ents = dict(_read(path))
    ents[plan_key(platform, n_vertices, m_edges)] = entry
    _write(path, ents)
    return entry


def clear(path: Optional[str] = None) -> None:
    """Drop every cached plan (used by ``--retune`` and tests)."""
    path = path or cache_path()
    try:
        os.unlink(path)
    except OSError:
        pass
    _LOADED.pop(path, None)
