"""The resolved execution plan every kernel-backed solve runs under.

:class:`ExecutionPlan` is the first-class replacement for the old frozen
``KernelPlan`` + heuristic tables that lived inside
``kernels.contour_mm.ops``.  One plan answers every dispatch question a
solve path has to settle before tracing:

* which **backend** realises the MM sweep (``"xla"`` scatter-min, the
  scalar ``"pallas"`` kernel, or the label-blocked ``"pallas_blocked"``
  kernel — same names as ``ops.BACKENDS``);
* the **tile sizes** of that backend (``block_edges`` / ``label_block`` /
  ``chunk_updates``) and whether Pallas runs in ``interpret`` mode;
* how the work-adaptive frontier is **realised physically**:
  ``compact_schedule="masked"`` keeps the single in-jit ``lax.while_loop``
  with full-shape masked tiles (the only legal choice under an enclosing
  trace — ``vmap``/``shard_map``/user jit), ``"staged"`` re-enters the
  loop at physically sliced, power-of-two-bucketed edge shapes so the
  launched grid actually shrinks with the frontier
  (``planner.staged``, DESIGN.md §14);
* whether the single-tile **fused relabel + scatter-min** Pallas pass is
  eligible (``fuse_relabel`` — ``blocked.fused_relax_pallas``);
* where the plan **came from** (``origin``): ``"heuristic"`` cold-start
  tables, ``"tuned"`` from the measuring autotuner's on-disk cache,
  ``"pinned"`` by the caller, or ``"fallback"`` after a kernel-launch
  failure demoted the bucket (with an expiry, so XLA is retuned rather
  than pinned forever).  ``origin`` is provenance, not semantics: two
  plans equal up to origin trace to identical programs.

The dataclass is frozen and hashable so it can ride through every jitted
entry point as a static argument, exactly like ``KernelPlan`` did.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

BACKENDS = ("auto", "xla", "pallas", "pallas_blocked")
COMPACT_SCHEDULES = ("masked", "staged")
ORIGINS = ("heuristic", "tuned", "pinned", "fallback")

# Cache / bucket keys use power-of-two size buckets: plans generalise
# across graphs of similar scale, and the jit cache cannot be fragmented
# by one entry per exact (n, m).
_CONFIG_FIELDS = ("backend", "block_edges", "label_block", "chunk_updates",
                  "interpret", "compact_schedule", "fuse_relabel",
                  "chunk_bucket")


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    return 1 << max(int(x) - 1, 0).bit_length()


def size_bucket(x: int) -> int:
    """The pow2 bucket a vertex/edge count falls in (for plan keys)."""
    return next_pow2(max(int(x), 1))


def plan_key(platform: str, n_vertices: int, m_edges: int) -> str:
    """Tuning-cache key: (platform, n-bucket, m-bucket)."""
    return f"{platform}/n{size_bucket(n_vertices)}/m{size_bucket(m_edges)}"


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Resolved backend + tile sizes + schedule for one solve (static)."""

    backend: str                    # concrete: "xla"|"pallas"|"pallas_blocked"
    block_edges: int = 512          # edge block of the scalar pallas kernel
    label_block: int = 2048         # L tile height of the blocked kernel
    chunk_updates: int = 128        # update-stream chunk of the blocked kernel
    interpret: bool = False         # Pallas interpreter mode (CPU validation)
    compact_schedule: str = "masked"  # frontier realisation: masked | staged
    fuse_relabel: bool = False      # single-tile fused gather+scatter-min pass
    chunk_bucket: int = 0           # out-of-core pow2 edge chunk (0 = n/a)
    origin: str = "heuristic"       # heuristic | tuned | pinned | fallback

    def validate(self) -> "ExecutionPlan":
        if self.backend not in BACKENDS[1:]:
            raise ValueError(
                f"ExecutionPlan.backend must be concrete, one of "
                f"{BACKENDS[1:]}; got {self.backend!r}")
        if self.compact_schedule not in COMPACT_SCHEDULES:
            raise ValueError(
                f"compact_schedule {self.compact_schedule!r} not one of "
                f"{COMPACT_SCHEDULES}")
        if self.origin not in ORIGINS:
            raise ValueError(f"origin {self.origin!r} not one of {ORIGINS}")
        for f in ("block_edges", "label_block", "chunk_updates"):
            v = getattr(self, f)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{f} must be a positive int, got {v!r}")
        cb = self.chunk_bucket
        if not isinstance(cb, int) or cb < 0 or (cb and cb & (cb - 1)):
            raise ValueError(
                f"chunk_bucket must be 0 or a power of two, got {cb!r}")
        return self

    def replace(self, **updates) -> "ExecutionPlan":
        return dataclasses.replace(self, **updates)

    # -- serialisation (tuning cache / bench artifact) --------------------
    def to_config(self) -> dict:
        """JSON-safe config dict (origin excluded — it is per-resolution)."""
        return {f: getattr(self, f) for f in _CONFIG_FIELDS}

    @classmethod
    def from_config(cls, config: dict, origin: str = "tuned"
                    ) -> "ExecutionPlan":
        """Rebuild a plan from :meth:`to_config` output; raises on any
        unknown/malformed field (the cache layer turns that into a
        heuristic fallback)."""
        if not isinstance(config, dict):
            raise ValueError(f"plan config must be a dict, got "
                             f"{type(config).__name__}")
        unknown = set(config) - set(_CONFIG_FIELDS)
        if unknown:
            raise ValueError(f"unknown plan config fields {sorted(unknown)}")
        kwargs = dict(config)
        for f in ("interpret", "fuse_relabel"):
            if f in kwargs and not isinstance(kwargs[f], bool):
                raise ValueError(f"{f} must be a bool")
        return cls(origin=origin, **kwargs).validate()

    def config_equal(self, other: Optional["ExecutionPlan"]) -> bool:
        """True when the two plans trace to the same program (origin and
        provenance aside)."""
        return other is not None and self.to_config() == other.to_config()

    def provenance_entry(self) -> str:
        """The ``plan:`` line recorded in ``ComponentResult.provenance``."""
        oc = f" chunk={self.chunk_bucket}" if self.chunk_bucket else ""
        return (f"plan:{self.backend} origin={self.origin} "
                f"schedule={self.compact_schedule} "
                f"lb={self.label_block} cu={self.chunk_updates} "
                f"be={self.block_edges} fused={int(self.fuse_relabel)} "
                f"interpret={int(self.interpret)}{oc}")

    @classmethod
    def from_kernel_plan(cls, plan, origin: str = "pinned"
                         ) -> "ExecutionPlan":
        """Lift a legacy ``KernelPlan`` (or any duck-typed plan) into an
        :class:`ExecutionPlan`; an ExecutionPlan passes through with its
        origin re-stamped only if it has none."""
        if isinstance(plan, cls):
            return plan
        return cls(
            backend=plan.backend,
            block_edges=int(plan.block_edges),
            label_block=int(plan.label_block),
            chunk_updates=int(plan.chunk_updates),
            interpret=bool(plan.interpret),
            compact_schedule=getattr(plan, "compact_schedule", "masked"),
            fuse_relabel=bool(getattr(plan, "fuse_relabel", False)),
            origin=origin,
        ).validate()
