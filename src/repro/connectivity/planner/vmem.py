"""Per-platform VMEM budget and the whole-L ceiling derived from it.

The scalar ``"pallas"`` kernel keeps the entire int32 label array resident
in VMEM alongside its edge blocks, so its vertex ceiling is a function of
the *platform's* VMEM size — not the magic ``3_000_000`` the seed
hard-coded.  This module owns that derivation:

* :func:`vmem_budget_bytes` — the per-core VMEM budget.  Resolution
  order: explicit ``override`` argument (threaded from
  ``SolveOptions.vmem_limit_bytes``), the ``REPRO_VMEM_BYTES`` environment
  variable, a device-reported value when the runtime exposes one, then
  the per-platform table (16 MiB — TPU v2–v5 all ship >= 16 MiB/core;
  non-TPU hosts only ever run Pallas in interpret mode, where the number
  gates shape sanity, not real memory).
* :func:`whole_l_vmem_ceiling` — the max ``n_vertices`` whose whole-L
  int32 array still leaves room for edge blocks: three quarters of the
  budget for ``L`` (the kernel double-buffers edge blocks in the rest),
  four bytes per label.  At the default 16 MiB budget this lands at
  3,145,728 — the same regime as the seed's hand-picked 3M constant, now
  derived instead of asserted.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

ENV_VMEM_BYTES = "REPRO_VMEM_BYTES"

# Conservative per-core VMEM for platforms we can meet; the TPU figure is
# the v2/v3 baseline (newer cores have more — report it via the env var
# or SolveOptions.vmem_limit_bytes to raise the ceiling).
_PLATFORM_VMEM_BYTES = {
    "tpu": 16 * 1024 * 1024,
    "gpu": 16 * 1024 * 1024,   # shared-memory-sized stand-in
    "cpu": 16 * 1024 * 1024,   # interpret mode: shape sanity only
}
_DEFAULT_VMEM_BYTES = 16 * 1024 * 1024

# Fraction of the budget the whole-L tile may occupy; the rest holds the
# kernel's double-buffered edge blocks and scratch.
_WHOLE_L_FRACTION_NUM = 3
_WHOLE_L_FRACTION_DEN = 4
_LABEL_BYTES = 4  # int32 labels


def _device_vmem_bytes(platform: str) -> Optional[int]:
    """Runtime-reported VMEM when the backend exposes it (best effort)."""
    try:
        for dev in jax.local_devices():
            if dev.platform != platform:
                continue
            for attr in ("vmem_size_bytes", "core_memory_size_bytes"):
                v = getattr(dev, attr, None)
                if isinstance(v, int) and v > 0:
                    return v
    except RuntimeError:
        pass  # no backend initialised (e.g. AOT planning host)
    return None


def vmem_budget_bytes(platform: Optional[str] = None,
                      override: Optional[int] = None) -> int:
    """Resolved per-core VMEM budget in bytes (always > 0)."""
    if override is not None:
        if int(override) <= 0:
            raise ValueError(f"vmem budget override must be > 0, got "
                             f"{override}")
        return int(override)
    env = os.environ.get(ENV_VMEM_BYTES)
    if env:
        try:
            val = int(env)
        except ValueError as exc:
            raise ValueError(
                f"{ENV_VMEM_BYTES}={env!r} is not an integer byte count"
            ) from exc
        if val <= 0:
            raise ValueError(f"{ENV_VMEM_BYTES} must be > 0, got {val}")
        return val
    platform = platform or jax.default_backend()
    reported = _device_vmem_bytes(platform)
    if reported is not None:
        return reported
    return _PLATFORM_VMEM_BYTES.get(platform, _DEFAULT_VMEM_BYTES)


def whole_l_vmem_ceiling(platform: Optional[str] = None,
                         vmem_bytes: Optional[int] = None) -> int:
    """Max ``n_vertices`` the scalar whole-L-resident kernel can take."""
    budget = vmem_budget_bytes(platform, override=vmem_bytes)
    return (budget * _WHOLE_L_FRACTION_NUM
            // _WHOLE_L_FRACTION_DEN) // _LABEL_BYTES
