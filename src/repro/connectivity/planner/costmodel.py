"""The ``solver="auto"`` cost model: measured strategy choice per graph.

ConnectIt's central result (PAPERS.md) is that the *sampling strategy x
finish algorithm* choice dominates connectivity performance per graph
family.  This module owns that choice so the facade, not the caller,
answers "which algorithm wins where":

* **features** — ``(n, m, m/n, degree skew)``; skew is max/mean degree
  (``graphs.stats.degree_skew``), the cheap separator between regular
  families (paths, grids: skew ~ 1-2) and hub-dominated ones (stars,
  R-MAT: skew >> 1).
* **fitted model** — a 1-nearest-neighbour predictor in log-feature
  space over the accumulated ``BENCH_connectivity.json`` strategy-matrix
  rows (schema >= 7): each benchmarked graph contributes its feature
  vector and the fixed strategy that actually won wall clock there.
  1-NN is deliberate: a handful of measured graphs, wildly nonlinear
  regime boundaries, and an artifact that must stay inspectable — the
  "model" is just "copy the choice of the most similar measured graph".
* **precedence** — pinned > fitted > heuristic, the same discipline as
  ``planner.resolve_plan``: an explicit ``SolveOptions.sampling_strategy``
  (or ``variant``) always wins; the fitted model applies when a usable
  artifact exists; otherwise a heuristic table keyed on m/n and skew.

The chosen (solver, strategy) is recorded in
``ComponentResult.provenance`` as ``auto:solver=... strategy=...
origin=...`` so every auto solve is auditable after the fact.

The artifact path comes from ``$REPRO_BENCH_ARTIFACT`` (tests pin this
to a nonexistent file for hermeticity) and defaults to the committed
``BENCH_connectivity.json`` at the repo root.  Loading is corrupt-safe:
a missing, truncated, or pre-schema-7 artifact silently falls back to
the heuristic table — a broken benchmark file must never break a solve.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from pathlib import Path
from typing import List, Optional, Tuple

ENV_BENCH_ARTIFACT = "REPRO_BENCH_ARTIFACT"

# Strategy rows appear in artifacts from this schema on.
_MIN_SCHEMA = 7

# repo-root default: src/repro/connectivity/planner/costmodel.py -> repo
_DEFAULT_ARTIFACT = Path(__file__).resolve().parents[4] / \
    "BENCH_connectivity.json"

# Heuristic regime boundaries (used only below the fitted model):
# hub-dominated graphs (skew >> 1) with real average degree benefit from
# the k-out sampler bounding per-vertex sample work; everything else
# keeps the deterministic prefix (zero preparation cost).
_KOUT_MIN_AVG_DEGREE = 16.0
_KOUT_MIN_SKEW = 8.0


@dataclasses.dataclass(frozen=True)
class StrategyChoice:
    """A resolved (solver family, sampling strategy) decision."""

    solver: str
    variant: Optional[str]
    sampling_strategy: str
    sampling: int
    compact_every: int
    origin: str                      # "pinned" | "fitted" | "heuristic"
    neighbor: Optional[str] = None   # fitted: the measured graph copied

    def provenance_entry(self) -> str:
        entry = (f"auto:solver={self.solver} "
                 f"strategy={self.sampling_strategy} origin={self.origin}")
        if self.neighbor:
            entry += f" nn={self.neighbor}"
        return entry


def artifact_path(bench_path=None) -> Path:
    """Resolve the artifact path: explicit > $REPRO_BENCH_ARTIFACT > repo."""
    if bench_path is not None:
        return Path(bench_path)
    env = os.environ.get(ENV_BENCH_ARTIFACT)
    return Path(env) if env else _DEFAULT_ARTIFACT


def _features(n: int, m: int, skew: float) -> Tuple[float, ...]:
    """Log1p-scaled feature vector; log space keeps the 1-NN distance
    scale-free across the orders of magnitude n/m span."""
    density = m / n if n > 0 else 0.0
    return (math.log1p(float(n)), math.log1p(float(m)),
            math.log1p(density), math.log1p(max(0.0, float(skew))))


def _fit_examples(payload) -> List[Tuple[Tuple[float, ...], str, str]]:
    """(features, winning fixed strategy, graph name) per measured graph.

    The winner is re-derived from the raw per-side best seconds — the
    model never trusts a summary field that ``check_artifact.py`` would
    itself recompute.
    """
    gate = payload.get("strategy_gate")
    if not isinstance(gate, dict):
        return []
    examples = []
    for name, row in sorted(gate.items()):
        if not isinstance(row, dict):
            continue
        sides = row.get("sides", {})
        fixed = {s: d for s, d in sides.items() if s != "auto"}
        timed = {}
        for s, d in fixed.items():
            secs = d.get("seconds") or []
            if secs and all(isinstance(x, (int, float)) and x > 0
                            for x in secs):
                timed[s] = min(secs)
        if not timed:
            continue
        winner = min(timed, key=timed.get)
        feats = _features(int(row.get("n", 0)), int(row.get("m", 0)),
                          float(row.get("degree_skew", 0.0)))
        examples.append((feats, winner, name))
    return examples


# (path, mtime) -> fitted examples; refits automatically when the bench
# artifact is regenerated, costs one json parse per solve otherwise.
_FIT_CACHE: dict = {}


def load_fitted(bench_path=None):
    """Fitted examples from the artifact, or None when unusable."""
    path = artifact_path(bench_path)
    try:
        mtime = path.stat().st_mtime_ns
    except OSError:
        return None
    key = (str(path), mtime)
    if key in _FIT_CACHE:
        return _FIT_CACHE[key]
    try:
        payload = json.loads(path.read_text())
        if int(payload.get("schema", 0)) < _MIN_SCHEMA:
            examples = None
        else:
            examples = _fit_examples(payload) or None
    except (OSError, ValueError, TypeError):
        examples = None  # corrupt artifact: fall through to the heuristic
    _FIT_CACHE.clear()  # one artifact in play at a time; stay bounded
    _FIT_CACHE[key] = examples
    return examples


def _predict_1nn(examples, n: int, m: int, skew: float):
    """Nearest measured graph's winning strategy (name for provenance)."""
    target = _features(n, m, skew)
    best = None
    for feats, winner, name in examples:
        dist = sum((a - b) ** 2 for a, b in zip(feats, target))
        if best is None or dist < best[0]:
            best = (dist, winner, name)
    return best[1], best[2]


def _heuristic(n: int, m: int, skew: float) -> StrategyChoice:
    """Fallback table keyed on m/n and skew (no artifact available)."""
    if m <= 0 or n <= 1:
        # nothing to sample; dense sweeps converge in O(1) anyway
        return StrategyChoice("contour", "C-2", "prefix", 0, 0, "heuristic")
    avg_degree = 2.0 * m / n
    if avg_degree >= _KOUT_MIN_AVG_DEGREE and skew >= _KOUT_MIN_SKEW:
        strategy = "kout"
    else:
        strategy = "prefix"
    return StrategyChoice("contour", "C-2", strategy, 2, 2, "heuristic")


def resolve_strategy(
    n: int,
    m: int,
    *,
    degree_skew: Optional[float] = None,
    platform: Optional[str] = None,
    pinned_strategy: Optional[str] = None,
    pinned_variant: Optional[str] = None,
    bench_path=None,
) -> StrategyChoice:
    """Pick (solver, sampling strategy) for a graph: pinned > fitted >
    heuristic.

    ``degree_skew=None`` (e.g. under a tracer, where degrees cannot be
    read) is treated as 0 — the regular-graph regime, which biases
    toward the zero-preparation prefix strategy.  ``platform`` is
    accepted for parity with ``resolve_plan``'s keying; the current
    tables are platform-free (kernel choice is the *plan* layer's job).
    """
    del platform
    skew = 0.0 if degree_skew is None else float(degree_skew)
    base = _heuristic(n, m, skew)

    if pinned_strategy is not None:
        return dataclasses.replace(
            base, sampling_strategy=pinned_strategy,
            variant=pinned_variant or base.variant, origin="pinned",
            # a pinned strategy implies the adaptive schedule is wanted
            sampling=max(2, base.sampling), compact_every=2)

    if m > 0 and n > 1:
        examples = load_fitted(bench_path)
        if examples:
            winner, name = _predict_1nn(examples, n, m, skew)
            return dataclasses.replace(
                base, sampling_strategy=winner,
                variant=pinned_variant or base.variant,
                origin="fitted", neighbor=name)

    if pinned_variant is not None:
        return dataclasses.replace(base, variant=pinned_variant)
    return base
