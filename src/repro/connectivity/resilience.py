"""Fault tolerance for the connectivity stack (DESIGN.md §12).

The production service the ROADMAP targets must survive the failures
scale brings: a crashed host mid-stream, a lost shard mid-solve, a
straggling device dragging every collective.  This module wires the
previously train-loop-only runtime machinery (``repro.runtime.*``,
``repro.checkpoint``) into ``repro.connectivity``:

* :func:`stream_with_recovery` — a crash-restart driver for
  :class:`~repro.connectivity.streaming.StreamingConnectivity`:
  periodic atomic checkpoints of the full engine state (ring-buffered
  edge store, labels, counters) through ``CheckpointManager``'s
  write-to-tmp-then-rename protocol, restore-on-failure with a bounded
  retry budget and exponential backoff, and replay of only the batches
  ingested after the last committed checkpoint.  Recovery is **bit
  exact**: ingest is deterministic and atomic (a fault anywhere before
  the commit leaves the engine at its pre-batch state), so replaying
  the uncommitted suffix from a snapshot lands on exactly the labels a
  fault-free run produces.  A :class:`StragglerMonitor` can drive the
  checkpoint cadence: persistent slow batches force a snapshot *now* so
  a replace-and-restart loses no work.

* :func:`oocore_with_recovery` — round-boundary checkpoint recovery for
  the out-of-core multi-round solver (DESIGN.md §15): a mid-round crash
  restores labels + the surviving-chunk manifest from the last committed
  round and replays one round, not the stream (exact because chunk
  sources are pure functions of the chunk index).

* :func:`resilient_distributed_contour` — elastic shrink-and-resume for
  distributed solves.  The fixpoint runs in bounded blocks of global
  rounds; between blocks the driver consults a fault injector (and, in
  a real deployment, the collective's health).  On a
  :class:`ShardLossFault` it re-derives a smaller mesh over the
  surviving devices via :func:`repro.runtime.elastic.elastic_mesh`,
  re-shards the edge arrays, and warm-starts from the last good labels.

  **Soundness of the warm restart** (the load-bearing argument): every
  intermediate label array of a min-mapping solver satisfies the
  invariant "``L[v]`` is a vertex in ``v``'s component" and labels are
  monotone non-increasing toward the *unique* fixed point (the
  per-component minimum id).  Any stale snapshot therefore remains a
  valid ``init_labels`` — exactly the contract
  ``minmap.resolve_init_labels`` validates — and the resumed solve
  converges to labels bit-identical to a fault-free run, regardless of
  which rounds were lost, on how many shards, or how stale the
  snapshot is.

Both drivers record what they survived: restart/shrink/checkpoint
counts in a stats dict, and degradation events (elastic shrinks,
straggler evictions, kernel fallbacks) in
:attr:`ComponentResult.provenance`.
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Sequence, Tuple, Type

import jax
import numpy as np

from repro.connectivity import distributed as dist
from repro.connectivity import solvers as _solvers
from repro.connectivity.options import SolveOptions
from repro.connectivity.result import ComponentResult
from repro.connectivity.solve import make_result, resolve_warm_start
from repro.connectivity.streaming import StreamingConnectivity
from repro.graphs.structs import Graph
from repro.runtime.elastic import elastic_mesh
from repro.runtime.recovery import (FaultInjector, ShardLossFault,
                                    SimulatedFault, backoff_delay)
from repro.runtime.straggler import StragglerMonitor


def stream_with_recovery(
    batches: Sequence[tuple],
    n_vertices: int,
    manager,
    options: Optional[SolveOptions] = None,
    *,
    checkpoint_every: int = 8,
    max_restarts: int = 5,
    fault_injector: Optional[FaultInjector] = None,
    straggler: Optional[StragglerMonitor] = None,
    recoverable: Tuple[Type[BaseException], ...] = (SimulatedFault,),
    backoff_base: float = 0.0,
    backoff_factor: float = 2.0,
    backoff_cap: float = 30.0,
    sleep_fn: Callable[[float], None] = time.sleep,
    on_event: Optional[Callable[[str, int], None]] = None,
    **overrides,
) -> tuple[StreamingConnectivity, dict]:
    """Stream ``batches`` through a checkpointed engine with recovery.

    Args:
      batches: seekable sequence of ``(src, dst)`` or
        ``(src, dst, n_vertices)`` micro-batches — batch ``k`` must be a
        pure function of ``k`` (the replay half of exact recovery; the
        atomic checkpoints are the other half).
      n_vertices: initial vertex count for a cold start.
      manager: a :class:`~repro.checkpoint.manager.CheckpointManager`.
        If it already holds a checkpoint, the stream *resumes* from it
        (crash-restart across processes) and earlier batches are never
        re-ingested.
      options / overrides: engine :class:`SolveOptions`, as for
        :class:`StreamingConnectivity`.
      checkpoint_every: snapshot cadence in committed batches; the final
        batch always checkpoints.
      fault_injector: consulted by ``ingest`` at its ``"pre"`` /
        ``"post_write"`` sites (see streaming) — chaos-testing hook.
      straggler: optional monitor fed per-batch wall time; a
        ``"checkpoint"``/``"evict"`` escalation forces an immediate
        snapshot regardless of cadence (so a degrading host can be
        replaced with no lost work).
      recoverable: exception types that trigger restore-and-retry;
        anything else propagates after rolling the engine back (ingest
        is atomic, so the engine stays queryable).
      max_restarts: total restart budget; exceeding it re-raises.
      backoff_*: exponential backoff between restarts (0 = none);
        ``sleep_fn`` is injectable for tests.

    Returns ``(engine, stats)`` with
    ``stats = {"restarts", "checkpoints", "replayed_batches",
    "straggler_events"}``.
    """
    if checkpoint_every < 1:
        raise ValueError(f"checkpoint_every must be >= 1, got "
                         f"{checkpoint_every}")
    stats = {"restarts": 0, "checkpoints": 0, "replayed_batches": 0,
             "straggler_events": 0}

    def fresh():
        return StreamingConnectivity(n_vertices, options,
                                     fault_injector=fault_injector,
                                     **overrides)

    if manager.latest_step() is not None:
        eng, start = StreamingConnectivity.restore(
            manager, options, fault_injector=fault_injector, **overrides)
    else:
        eng, start = fresh(), 0

    n_batches = len(batches)
    restarts = 0
    b = start
    while b < n_batches:
        try:
            if straggler is not None:
                straggler.start_step()
            eng.ingest(*batches[b])
            action = straggler.end_step() if straggler is not None else "ok"
            committed = b + 1
            forced = action in ("checkpoint", "evict")
            if forced:
                stats["straggler_events"] += 1
                if on_event:
                    on_event(f"straggler_{action}", b)
            if committed % checkpoint_every == 0 or committed == n_batches \
                    or forced:
                eng.save(manager, committed)
                manager.wait()
                stats["checkpoints"] += 1
            b += 1
        except recoverable:
            restarts += 1
            stats["restarts"] += 1
            if on_event:
                on_event("restart", b)
            if restarts > max_restarts:
                raise
            delay = backoff_delay(restarts, base=backoff_base,
                                  factor=backoff_factor, cap=backoff_cap)
            if delay > 0:
                sleep_fn(delay)
            if manager.latest_step() is None:
                eng, resume = fresh(), 0
            else:
                eng, resume = StreamingConnectivity.restore(
                    manager, options, fault_injector=fault_injector,
                    **overrides)
            stats["replayed_batches"] += b - resume
            b = resume
    return eng, stats


def oocore_with_recovery(
    chunks,
    manager,
    options: Optional[SolveOptions] = None,
    *,
    max_restarts: int = 5,
    fault_injector: Optional[FaultInjector] = None,
    recoverable: Tuple[Type[BaseException], ...] = (SimulatedFault,),
    backoff_base: float = 0.0,
    backoff_factor: float = 2.0,
    backoff_cap: float = 30.0,
    sleep_fn: Callable[[float], None] = time.sleep,
    on_event: Optional[Callable[[str, int], None]] = None,
    **overrides,
) -> tuple[ComponentResult, dict]:
    """Out-of-core solve with round-boundary checkpoint recovery.

    Drives :class:`~repro.connectivity.oocore.OutOfCoreContraction` one
    round at a time, checkpointing at every round boundary (labels + the
    surviving-chunk manifest — the engine's ``state_dict``) through
    ``manager``'s atomic write-to-tmp-then-rename protocol.  A
    ``recoverable`` fault mid-round restores the last committed round
    boundary and replays *that round only*, never the whole stream; a
    fault inside round 0 replays round 0 from the source, which is exact
    because chunk sources are pure functions of the chunk index
    (``EdgeChunks.chunk(k)``).  Replay is bit-exact for the same reason
    the streaming driver's is: rounds are deterministic, and a fault
    anywhere before the boundary commit leaves the checkpoint at the
    previous round's state.

    If ``manager`` already holds a checkpoint the solve *resumes* from it
    (crash-restart across processes).  Returns ``(result, stats)`` with
    ``stats`` a :class:`RecoveryStats` holding ``restarts``,
    ``checkpoints``, ``replayed_rounds`` and ``rounds``.
    """
    from repro.connectivity import oocore as _oocore
    eng = _oocore.OutOfCoreContraction(chunks, options,
                                       fault_injector=fault_injector,
                                       **overrides)
    if manager.latest_step() is not None:
        eng.restore(manager)
    stats = RecoveryStats(restarts=0, checkpoints=0, replayed_rounds=0,
                          rounds=0)
    restarts = 0
    while not eng.finished_streaming:
        at_round = eng.round_index
        try:
            eng.run_round()
            eng.save(manager)
            manager.wait()
            stats["checkpoints"] += 1
            stats["rounds"] += 1
        except recoverable:
            restarts += 1
            stats["restarts"] += 1
            if on_event:
                on_event("restart", at_round)
            if restarts > max_restarts:
                raise
            delay = backoff_delay(restarts, base=backoff_base,
                                  factor=backoff_factor, cap=backoff_cap)
            if delay > 0:
                sleep_fn(delay)
            if manager.latest_step() is not None:
                eng.restore(manager)
            else:
                eng.reset()   # round-0 fault: replay the source
            stats["replayed_rounds"] += 1
    labels, iterations, converged, visited = eng.finish()
    result = make_result(labels, iterations, converged, visited,
                         provenance=eng.provenance())
    return result, stats


class RecoveryStats(dict):
    """Stats of a resilient distributed solve (dict with attr access)."""

    def __getattr__(self, name):
        try:
            return self[name]
        except KeyError as exc:
            raise AttributeError(name) from exc


def _elastic_edge_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Edge-sharding axes of an ``elastic_mesh``: everything but model."""
    return tuple(a for a in mesh.axis_names if a != "model")


def resilient_distributed_contour(
    graph: Graph,
    devices: Optional[Sequence] = None,
    options: Optional[SolveOptions] = None,
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    block_rounds: int = 8,
    max_restarts: int = 5,
    fault_injector: Optional[FaultInjector] = None,
    manager=None,
    straggler: Optional[StragglerMonitor] = None,
    model_parallel: int = 1,
    prefer_pods: int = 1,
    backoff_base: float = 0.0,
    sleep_fn: Callable[[float], None] = time.sleep,
    on_event: Optional[Callable[[str, int], None]] = None,
    **overrides,
) -> tuple[ComponentResult, RecoveryStats]:
    """Distributed Contour that survives shard loss via elastic shrink.

    Runs :func:`~repro.connectivity.distributed.distributed_contour` in
    blocks of at most ``block_rounds`` global rounds.  Between blocks the
    ``fault_injector`` is consulted at site ``"round"`` (in production:
    the collective's failure detector):

    * :class:`ShardLossFault` — drop the lost device(s), re-derive a
      smaller mesh (``elastic_mesh``; the edge arrays are re-sharded by
      the next block's ``device_put``), and resume warm from the last
      good labels.  Sound because min-mapping labels are monotone
      non-increasing with ``L[v]`` always inside ``v``'s component, so
      any stale snapshot is a valid ``init_labels`` (module docstring).
    * any other :class:`SimulatedFault` — plain warm restart on the same
      mesh (from ``manager``'s last checkpoint when given, else the
      in-memory labels), with exponential backoff.

    A ``straggler`` monitor escalates per the ladder in
    ``repro.runtime.straggler``: ``"checkpoint"`` forces a label
    snapshot (when ``manager`` is given), ``"evict"`` drops one device
    and shrinks — both recorded in the result's provenance.

    Returns ``(result, stats)``; ``result.converged`` is True iff the
    fixed point was reached within ``options.max_iters`` total rounds
    across every block and restart.
    """
    opts = options if options is not None else SolveOptions()
    if overrides:
        opts = opts.replace(**overrides)
    opts.validate()
    if devices is None:
        devices = (list(mesh.devices.flat) if mesh is not None
                   else list(jax.devices()))
    devices = list(devices)
    if mesh is None:
        mesh = elastic_mesh(model_parallel, devices, prefer_pods)
        edge_axes = _elastic_edge_axes(mesh)
    else:
        edge_axes = tuple(opts.edge_axes)
    max_total = opts.max_iters if opts.max_iters is not None else 10_000

    stats = RecoveryStats(restarts=0, shrinks=0, checkpoints=0, blocks=0,
                          mesh_history=[tuple(mesh.devices.shape)],
                          events=[])
    # resolve the execution plan once for the whole elastic solve (shrinks
    # change the mesh, not the graph size, so the plan is stable) and lead
    # the provenance trail with it
    backend, plan = _solvers.resolve_backend_plan(
        graph.n_vertices, graph.n_edges, opts)
    provenance: list = [plan.provenance_entry()]
    L = resolve_warm_start(opts.warm_start, graph.n_vertices)
    if manager is not None and manager.latest_step() is not None:
        state, _ = manager.restore({"labels": np.int64(0)})
        L = jax.numpy.asarray(state["labels"], jax.numpy.int32)
    iterations = 0
    visited = 0.0
    done = False
    restarts = 0
    block = 0

    def record(event: str):
        stats["events"].append((event, block))
        if on_event:
            on_event(event, block)

    def shrink(n_lost: int, reason: str):
        nonlocal devices, mesh, edge_axes
        survivors = devices[:-n_lost] if n_lost else devices
        new_mesh = elastic_mesh(model_parallel, survivors, prefer_pods)
        provenance.append(f"{reason}:{len(devices)}->{len(survivors)}")
        devices = survivors
        mesh = new_mesh
        edge_axes = _elastic_edge_axes(mesh)
        stats["shrinks"] += 1
        stats["mesh_history"].append(tuple(mesh.devices.shape))
        record(reason)

    while not done and iterations < max_total:
        try:
            if fault_injector is not None:
                fault_injector.maybe_fail(block, "round")
            if straggler is not None:
                straggler.start_step()
            labels, it, ok, v = dist.distributed_contour(
                graph, mesh,
                edge_axes=edge_axes,
                local_rounds=opts.local_rounds,
                max_iters=min(block_rounds, max_total - iterations),
                async_compress=opts.async_compress,
                backend=backend,
                plan=plan,
                init_labels=L,
                sampling=opts.sampling,
                compact_every=opts.compact_every)
            action = (straggler.end_step() if straggler is not None
                      else "ok")
        except ShardLossFault as exc:
            restarts += 1
            stats["restarts"] += 1
            if restarts > max_restarts:
                raise
            shrink(exc.n_lost, "elastic_shrink")
            continue
        except SimulatedFault:
            restarts += 1
            stats["restarts"] += 1
            if restarts > max_restarts:
                raise
            delay = backoff_delay(restarts, base=backoff_base)
            if delay > 0:
                sleep_fn(delay)
            if manager is not None and manager.latest_step() is not None:
                state, _ = manager.restore({"labels": np.int64(0)})
                L = jax.numpy.asarray(state["labels"], jax.numpy.int32)
            record("restart")
            continue
        # commit the block: monotone labels make every block's output a
        # valid warm start for the next
        L = labels
        iterations += int(it)
        visited += float(v)
        done = bool(ok)
        stats["blocks"] += 1
        if manager is not None and (action in ("checkpoint", "evict")
                                    or done):
            manager.save(block, {"labels": L})
            manager.wait()
            stats["checkpoints"] += 1
            if action == "checkpoint":
                record("straggler_checkpoint")
        if action == "evict" and len(devices) - 1 >= model_parallel:
            shrink(1, "straggler_evict")
        block += 1

    result = make_result(L, iterations, done, visited,
                         provenance=provenance)
    return result, stats
