"""Typed result of a connectivity solve: labels + lazy component views.

:class:`ComponentResult` is a frozen dataclass registered as a pytree so
it can flow through ``jax.jit`` / ``jax.vmap`` unchanged (the lazy host
views are *not* part of the pytree — they are derived caches, recomputed
after any transformation).

Labels follow the Contour fixed-point convention: the label of a vertex is
the minimum vertex id of its component.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import jax
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class ComponentResult:
    """Component labels plus solve metadata.

    Attributes:
      labels: int32[n] min-vertex-id component labels (``[B, n]`` for a
        batched solve — see :meth:`unstack`).
      iterations: int32 scalar (``[B]`` batched) iteration count.
      converged: bool scalar (``[B]`` batched) — True iff the solver hit
        the connectivity fixed point before ``max_iters``.
      batch_sizes: static per-graph vertex counts of a batched solve
        (None for a single solve); used by :meth:`unstack` to trim padded
        vertices.
      edges_visited: float32 scalar (``[B]`` batched) cumulative count of
        edges swept by the solver, or None for solvers that do not count
        (``iterations × m`` for dense edge-sweep schedules; strictly less
        under the ``sampling``/``compact_every`` frontier contraction —
        see ``repro.connectivity.frontier``).
      provenance: static tuple of degradation/recovery events the solve
        survived (e.g. ``"kernel_fallback:pallas_blocked->xla (...)"`` when
        a Pallas launch failed and the XLA reference path answered, or
        ``"elastic_shrink:8->7"`` from the resilient distributed driver).
        None/empty means a clean solve — see DESIGN.md §12.
    """

    labels: jax.Array
    iterations: jax.Array
    converged: jax.Array
    batch_sizes: Optional[Tuple[int, ...]] = None
    edges_visited: Optional[jax.Array] = None
    provenance: Optional[Tuple[str, ...]] = None

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        children = (self.labels, self.iterations, self.converged,
                    self.edges_visited)
        return children, (self.batch_sizes, self.provenance)

    @classmethod
    def tree_unflatten(cls, aux, children):
        labels, iterations, converged, edges_visited = children
        batch_sizes, provenance = aux
        return cls(labels=labels, iterations=iterations, converged=converged,
                   batch_sizes=batch_sizes, edges_visited=edges_visited,
                   provenance=provenance)

    # -- lazy host-side views --------------------------------------------
    @property
    def is_batched(self) -> bool:
        return getattr(self.labels, "ndim", 1) > 1

    def _require_single(self, what: str):
        if self.is_batched:
            raise ValueError(
                f"{what} is per-graph; this is a batched result — call "
                ".unstack() first")

    @functools.cached_property
    def _np_labels(self) -> np.ndarray:
        return np.asarray(self.labels)

    @functools.cached_property
    def _uniq(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(unique labels, dense inverse, counts) — computed once."""
        self._require_single("component decomposition")
        return np.unique(self._np_labels, return_inverse=True,
                         return_counts=True)

    @property
    def n_components(self) -> int:
        """Number of connected components."""
        return int(self._uniq[0].size)

    def compact_labels(self) -> np.ndarray:
        """Dense ``0..k-1`` relabeling (component order = ascending min id)."""
        return self._uniq[1].astype(np.int32)

    def component_sizes(self) -> np.ndarray:
        """Vertex count per component, indexed like :meth:`compact_labels`."""
        return self._uniq[2]

    def _check_ids(self, *ids):
        # NumPy would silently wrap negative ids to the array tail, and
        # any jax-array indexing path *clamps* out-of-range ids to a
        # valid index and answers for the wrong vertex — the same
        # silently-wrong-component failure mode the negative warm-start
        # validation exists for.  Both bounds are checked eagerly so
        # every query surface fails the same loud way.
        n = self._np_labels.shape[-1]
        for v in ids:
            a = np.asarray(v)
            if np.any(a < 0):
                raise IndexError("vertex ids must be >= 0")
            if a.size and np.any(a >= n):
                raise IndexError(
                    f"vertex id {int(a.max())} out of range for "
                    f"n_vertices={n}")

    def same_component(self, u, v):
        """True iff ``u`` and ``v`` are connected (vectorises over arrays)."""
        self._require_single("same_component")
        self._check_ids(u, v)
        L = self._np_labels
        out = L[np.asarray(u)] == L[np.asarray(v)]
        return bool(out) if np.ndim(out) == 0 else out

    def component_of(self, v):
        """Component id (the component's min vertex id) of ``v``.

        Vectorises over arrays; the id is directly comparable across
        queries of the same result (and across snapshots of a
        ``StreamingConnectivity`` stream *until* a later batch merges the
        component into one with a smaller minimum).
        """
        self._require_single("component_of")
        self._check_ids(v)
        out = self._np_labels[np.asarray(v)]
        return int(out) if np.ndim(out) == 0 else out

    # -- batched results -------------------------------------------------
    def unstack(self) -> List["ComponentResult"]:
        """Split a batched result into per-graph results.

        Padded vertices (ids >= the graph's original ``n_vertices``) are
        isolated self-labelled singletons; ``batch_sizes`` trims them away
        so each returned result matches its source graph exactly.
        """
        if not self.is_batched:
            return [self]
        n_graphs = int(self.labels.shape[0])
        sizes = self.batch_sizes or (self.labels.shape[1],) * n_graphs
        return [
            ComponentResult(
                labels=self.labels[i, :sizes[i]],
                iterations=self.iterations[i],
                converged=self.converged[i],
                edges_visited=(None if self.edges_visited is None
                               else self.edges_visited[i]),
                provenance=self.provenance,
            )
            for i in range(n_graphs)
        ]
