"""Solver registry: every connectivity algorithm family behind one signature.

A registered solver is a callable

    fn(graph: Graph, opts: SolveOptions, init_labels)
        -> (labels, iterations, converged[, edges_visited])

where ``init_labels`` is the resolved warm-start array (or None for a
cold start) and ``converged`` is the solver's own fixed-point flag
(False iff the iteration budget ran out).  Edge-sweep solvers may append
a float32 ``edges_visited`` work counter (the Contour families do — see
``connectivity.frontier``); ``solve()``/``solve_batch`` normalise both
arities.  The ``solve()`` facade looks solvers up here, so adding an
algorithm family is one ``@register_solver`` away — no facade changes.

The registry also records capability flags (warm start, batched ``vmap``
solving, mesh execution, host vs device) that ``solve()``/``solve_batch``
use to fail fast with a clear message instead of deep in a trace, plus the
paper section each family reproduces (surfaced in DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

_REGISTRY: Dict[str, "SolverSpec"] = {}
_ALIASES: Dict[str, str] = {}


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """One registered algorithm family."""

    name: str
    fn: Callable                         # (graph, opts, init) -> (L, it, done)
    aliases: Tuple[str, ...] = ()
    variants: Tuple[str, ...] = ()       # () = takes no variant
    default_variant: Optional[str] = None
    default_max_iters: int = 100_000
    supports_warm_start: bool = True
    supports_batch: bool = True          # solvable under jax.vmap
    supports_mesh: bool = False          # runs on a Mesh via shard_map
    # delta-resweep safe: starting from a star-forest fixed point, sweeping
    # only newly ingested edges (rewritten to their endpoints' current
    # roots) reaches the full graph's fixed point.  A min-mapping property
    # — see connectivity.streaming / DESIGN.md §11 — so only the Contour
    # families set it.
    supports_streaming: bool = False
    runs_on: str = "device"              # "device" | "host"
    paper_ref: str = ""                  # paper section this reproduces

    def validate_variant(self, variant: Optional[str]) -> Optional[str]:
        """Resolve/validate a requested variant for this solver."""
        if variant is None:
            return self.default_variant
        if not self.variants:
            raise ValueError(
                f"solver {self.name!r} takes no variant, got {variant!r}")
        if variant in self.variants:
            return variant
        # Contour accepts literal h-order variants "C-<h>" beyond the
        # named set (used to validate the pointer-jump equivalence).
        if ("C-<h>" in self.variants and variant.startswith("C-")
                and variant[2:].isdigit()):
            return variant
        raise ValueError(
            f"unknown variant {variant!r} for solver {self.name!r}; "
            f"one of {self.variants}")


def register_solver(spec: SolverSpec) -> SolverSpec:
    """Register (or replace) a solver family; returns the spec."""
    _REGISTRY[spec.name] = spec
    for alias in spec.aliases:
        _ALIASES[alias] = spec.name
    return spec


def resolve_name(name: str) -> str:
    """Canonical solver name for ``name`` (which may be an alias)."""
    if name in _REGISTRY:
        return name
    if name in _ALIASES:
        return _ALIASES[name]
    known = sorted(_REGISTRY) + sorted(_ALIASES)
    raise ValueError(f"unknown algorithm {name!r}; known: {known}")


def get_solver(name: str) -> SolverSpec:
    return _REGISTRY[resolve_name(name)]


def list_solvers() -> Tuple[str, ...]:
    """Canonical names of every registered solver family."""
    return tuple(sorted(_REGISTRY))


def solver_specs() -> Tuple[SolverSpec, ...]:
    return tuple(_REGISTRY[k] for k in list_solvers())
