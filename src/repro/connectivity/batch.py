"""Batched multi-graph solving: ``solve_batch`` over padded graphs.

Many production scenarios solve *fleets* of small graphs (per-shard dedup
clusters, per-request subgraphs) rather than one giant graph.  Padding
every graph to a common shape makes the whole fleet one ``vmap``-ed solve:
edge lists pad with self-loops (no-ops for every min-based solver) and
vertex counts pad with isolated vertices (self-labelled singletons), so
padding never changes any real vertex's label.

Under ``vmap`` the solvers' ``lax.while_loop`` runs until the *slowest*
graph converges, with already-converged graphs' updates masked — per-graph
iteration counts stay exact.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.connectivity import minmap
from repro.connectivity.options import SolveOptions
from repro.connectivity.result import ComponentResult
from repro.connectivity.solve import _PLANNED_SOLVERS, _resolve, \
    make_result, resolve_warm_start, solver_output
from repro.graphs.structs import Graph


def stack_graphs(graphs: Sequence[Graph], with_sizes: bool = False):
    """Pad ``graphs`` to a common shape and stack into one batched Graph.

    The result has ``src``/``dst`` of shape ``[B, max_m]`` and
    ``n_vertices = max_n``; edge padding is self-loops at vertex 0.

    ``with_sizes=True`` additionally returns the original per-graph vertex
    counts (a tuple) — the padded Graph cannot record them itself, and
    without them ``ComponentResult.unstack()`` on a pre-batched solve has
    no way to trim the padding vertices back off; thread them into
    ``solve_batch(..., batch_sizes=sizes)``.

    An empty sequence stacks to a ``B=0`` graph (one padding vertex, one
    padding edge slot) — ``solve_batch`` on it returns an empty batched
    result whose ``unstack()`` is ``[]``, so fleet pipelines need no
    special case for an empty shard.
    """
    graphs = list(graphs)
    if not graphs:
        empty = jnp.zeros((0, 1), jnp.int32)
        stacked = Graph(src=empty, dst=empty, n_vertices=1)
        return (stacked, ()) if with_sizes else stacked
    n = max(g.n_vertices for g in graphs)
    m = max(max(g.n_edges for g in graphs), 1)
    padded = [g.pad_edges(m) for g in graphs]
    stacked = Graph(
        src=jnp.stack([g.src for g in padded]),
        dst=jnp.stack([g.dst for g in padded]),
        n_vertices=n,
    )
    if with_sizes:
        return stacked, tuple(g.n_vertices for g in graphs)
    return stacked


def _resolve_batch_sizes(batch_sizes, default, n: int):
    """Validate caller-provided per-graph vertex counts (or use default)."""
    if batch_sizes is None:
        return default
    sizes = tuple(int(s) for s in batch_sizes)
    if len(sizes) != len(default):
        raise ValueError(
            f"batch_sizes has {len(sizes)} entries for {len(default)} "
            "graphs")
    for i, s in enumerate(sizes):
        if not 1 <= s <= n:
            raise ValueError(
                f"batch_sizes[{i}] = {s} outside [1, {n}] (the padded "
                "vertex count)")
    return sizes


def _stack_warm_starts(warm_start, graphs: List[Graph], n: int):
    """Per-graph warm starts -> one [B, n] array (or None)."""
    if warm_start is None:
        return None
    if not isinstance(warm_start, (list, tuple)):
        ws = jnp.asarray(
            warm_start.labels if isinstance(warm_start, ComponentResult)
            else warm_start)
        if ws.ndim != 2 or ws.shape[0] != len(graphs):
            raise ValueError(
                f"batched warm_start must be a [B, n] array or a per-graph "
                f"sequence; got shape {ws.shape} for B={len(graphs)}")
        # stacked rows are padded to the batch-wide max n; trim each back
        # to its graph (the padding region is identity labels anyway)
        warm_start = [ws[i, :min(ws.shape[1], g.n_vertices)]
                      for i, g in enumerate(graphs)]
    if len(warm_start) != len(graphs):
        raise ValueError(
            f"warm_start has {len(warm_start)} entries for "
            f"{len(graphs)} graphs")
    rows = []
    for w, g in zip(warm_start, graphs):
        row = resolve_warm_start(w, g.n_vertices)
        row = minmap.resolve_init_labels(row, n, jnp.int32)
        rows.append(row)
    return jnp.stack(rows) if rows else None


def solve_batch(
    graphs: Union[Sequence[Graph], Graph],
    options: Optional[SolveOptions] = None,
    *,
    warm_start=None,
    batch_sizes: Optional[Sequence[int]] = None,
    **overrides,
) -> ComponentResult:
    """Solve connectivity on a batch of graphs in one vmapped program.

    Args:
      graphs: a sequence of :class:`Graph` (padded/stacked automatically)
        or an already-batched Graph with ``[B, m]`` edge arrays.
      options / overrides: as for :func:`repro.connectivity.solve`.
      warm_start: per-graph previous labels — a sequence (arrays or
        :class:`ComponentResult`) or a stacked ``[B, n]`` array.
      batch_sizes: true per-graph vertex counts, for trimming padding in
        ``unstack()``.  Required to get trimmed results from an
        already-batched Graph (whose padded ``n_vertices`` says nothing
        about the originals — ``stack_graphs(..., with_sizes=True)``
        returns the right tuple); optional override for a sequence, whose
        own sizes are recorded by default.

    Returns:
      a batched :class:`ComponentResult` (``labels [B, n]``,
      ``iterations [B]``, ``converged [B]``); ``unstack()`` splits it into
      per-graph results trimmed to each graph's original vertex count.
    """
    opts, spec = _resolve(options, overrides)
    if opts.mesh is not None:
        raise ValueError("solve_batch is single-device (vmap); it does not "
                         "compose with SolveOptions.mesh")
    if warm_start is None:
        warm_start = opts.warm_start  # same fallback as solve()

    if isinstance(graphs, Graph):
        batched = graphs
        n_graphs = int(batched.src.shape[0])
        sizes = _resolve_batch_sizes(
            batch_sizes, (batched.n_vertices,) * n_graphs,
            batched.n_vertices)
        # per-graph views are trimmed to the true sizes so warm-start
        # length normalisation sees the same graphs the caller stacked
        per_graph = [
            Graph(src=batched.src[i], dst=batched.dst[i],
                  n_vertices=sizes[i])
            for i in range(n_graphs)
        ]
    else:
        per_graph = list(graphs)
        sizes = _resolve_batch_sizes(
            batch_sizes, tuple(g.n_vertices for g in per_graph),
            max((g.n_vertices for g in per_graph), default=1))
        batched = stack_graphs(per_graph)
    n = batched.n_vertices

    if not per_graph:
        # empty fleet: nothing to trace (vmap over B=0 and the host loop
        # both degenerate); unstack() of the result is [].  A mismatched
        # warm_start still surfaces the caller's slicing bug instead of
        # being silently ignored.
        _stack_warm_starts(warm_start, per_graph, n)
        return make_result(labels=jnp.zeros((0, n), jnp.int32),
                           iterations=jnp.zeros((0,), jnp.int32),
                           converged=jnp.zeros((0,), bool),
                           batch_sizes=())

    init_b = _stack_warm_starts(warm_start, per_graph, n)
    if init_b is not None and not spec.supports_warm_start:
        raise ValueError(f"solver {spec.name!r} does not support warm "
                         "starts")

    provenance = None
    if spec.name in _PLANNED_SOLVERS:
        # one plan for the whole fleet (resolution is per padded shape);
        # pinning it keeps the vmapped solver, and the provenance record,
        # on the same plan.  Under vmap the solver always takes the masked
        # compaction schedule — a staged plan still runs, just masked.
        from repro.connectivity.solvers import resolve_backend_plan
        _, plan = resolve_backend_plan(n, int(batched.src.shape[-1]), opts)
        opts = opts.replace(plan=plan)
        provenance = (plan.provenance_entry(),)

    if spec.supports_batch:
        def one(s, d, L0):
            return solver_output(
                spec.fn(Graph(src=s, dst=d, n_vertices=n), opts, L0))

        if init_b is None:
            labels, iterations, converged, edges_visited = jax.vmap(
                lambda s, d: one(s, d, None))(batched.src, batched.dst)
        else:
            labels, iterations, converged, edges_visited = jax.vmap(one)(
                batched.src, batched.dst, init_b)
    elif spec.runs_on == "host":
        # sequential host solver (union-find): plain per-graph loop over
        # the *original* edge lists (padding buys nothing without vmap)
        outs = []
        for i, g in enumerate(per_graph):
            init_i = None if init_b is None else init_b[i]
            outs.append(solver_output(
                spec.fn(Graph(src=g.src, dst=g.dst, n_vertices=n),
                        opts, init_i)))
        labels = jnp.stack([L for L, _, _, _ in outs])
        iterations = jnp.stack([jnp.asarray(it, jnp.int32)
                                for _, it, _, _ in outs])
        converged = jnp.stack([jnp.asarray(c, bool) for _, _, c, _ in outs])
        evs = [ev for _, _, _, ev in outs]
        edges_visited = (None if any(ev is None for ev in evs)
                         else jnp.stack([jnp.asarray(ev, jnp.float32)
                                         for ev in evs]))
    else:
        raise ValueError(
            f"solver {spec.name!r} does not support batched solving")

    return make_result(labels, iterations, converged, edges_visited,
                       batch_sizes=sizes, provenance=provenance)
