"""Label-propagation baseline (paper §I, §V).

Classic min-label propagation: every vertex repeatedly takes the minimum
label among itself and its neighbours.  The paper observes this is the
special case of Contour with a one-order synchronous operator; we keep a
separate implementation (edge-scatter formulation) as the traversal-family
baseline.  Converges in O(d_max) iterations — the method Contour's
log-convergence is measured against.

``init_labels`` warm-starts from a previous solve's labels (propagation is
min-only, so labels decrease monotonically from any valid start).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.connectivity import minmap as lab
from repro.graphs.structs import Graph


class _State(NamedTuple):
    L: jax.Array
    it: jax.Array
    done: jax.Array


@functools.partial(jax.jit, static_argnames=("n_vertices", "max_iters"))
def label_propagation_labels(src, dst, n_vertices: int,
                             init_labels: Optional[jax.Array] = None,
                             max_iters: int = 100_000):
    def cond(s):
        return (~s.done) & (s.it < max_iters)

    def body(s):
        L = s.L
        Lu = L.at[src].min(L[dst])
        Lu = Lu.at[dst].min(L[src])
        done = jnp.all(Lu == L)
        return _State(L=Lu, it=s.it + 1, done=done)

    init = _State(
        L=lab.resolve_init_labels(init_labels, n_vertices, src.dtype),
        it=jnp.int32(0), done=jnp.array(False)
    )
    out = jax.lax.while_loop(cond, body, init)
    return out.L, out.it, out.done


def label_propagation(graph: Graph, max_iters: int = 100_000):
    return label_propagation_labels(graph.src, graph.dst, graph.n_vertices,
                                    max_iters=max_iters)
