"""Streaming incremental connectivity over edge micro-batches.

:class:`StreamingConnectivity` turns the one-shot warm-start path
(``solve(bigger, warm_start=prev)``) into a first-class engine for the
online workloads ConnectIt targets (PAPERS.md): a stream of edge batches
arrives, component labels must stay queryable after every batch, and
re-solving from scratch per batch is unaffordable.  Three pieces:

* **Delta re-convergence on the supervertex graph.**  Between batches
  the label array is a star-forest fixed point of everything ingested so
  far.  A new batch is re-converged by sweeping *only the new edges*,
  warm-started, under the §10 frontier schedule of
  ``connectivity.frontier`` (which contracts batch edges as their
  endpoints merge) — per-batch work tracks the delta, not the
  accumulated ``m``.

  Soundness is load-bearing and subtle.  Sweeping the new edges with
  their *original* endpoints is **wrong**, even at MM order 2: two batch
  edges can target a shared non-root vertex ``w`` and its root ``r`` in
  the same synchronous sweep with different values ``z_w > z_r``, after
  which ``w`` has been redirected off ``r``'s chain and nothing — the
  old edges are never reswept — reconnects them (the engine's test suite
  pins this counterexample).  The engine therefore first **rewrites each
  batch edge to its endpoints' current roots** ``(L[u], L[v])``.  Every
  rewritten endpoint is then a *root* of the warm star forest, so the
  delta solve is literally ordinary Contour on the supervertex graph
  (vertices = current roots, edges = rewritten batch) started from the
  identity labelling of its vertex set — correct by the paper's own
  convergence theorem, for every variant.  Vertices not in the batch are
  untouched during the solve (all sweep targets and label values stay
  inside the root set) and still point at their old root, which the
  final pointer-jump compression resolves through the root's new chain.
  This mirrors how §10 contraction stays sound (rewrite-to-
  representatives) where plain edge dropping is not — DESIGN.md §11.

* **Ring-buffered edge store.**  Ingested edges land in a growable
  device-resident ring (capacity a power of two, amortised doubling,
  free space filled with self-loop no-op edges).  Batches are padded to
  power-of-two shapes, so both the append (one
  ``lax.dynamic_update_slice``) and the delta solve compile once per
  bucket size — jit-stable ingestion.  The store exists for
  ``graph()``/``resolve()`` (audit / repair); queries never touch it.

* **O(1) snapshots.**  Labels are always converged between batches, so
  ``snapshot()`` just wraps them in a :class:`ComponentResult` and
  ``same_component``/``component_of`` answer from the resident array —
  no re-solve, no per-query device work beyond one gather.

``SolveOptions.mesh`` shards each batch through
``distributed.distributed_contour`` — per-shard frontier contraction, the
per-round ``pmin`` staying the only collective — so the same engine
drives a pod-scale stream.

Counters (``iterations``, ``edges_visited``, ``converged``) accumulate as
device scalars: steady-state ingestion performs **zero** host syncs (the
eager endpoint-bounds check is host-side but runs on the caller's NumPy
input; pass ``validate=False`` to skip it for pre-validated device
streams).
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.connectivity import distributed as dist
from repro.connectivity import frontier as fr
from repro.connectivity import minmap as lab
from repro.connectivity import planner as _planner
from repro.connectivity.contour import _make_step
from repro.connectivity.options import SolveOptions
from repro.connectivity.result import ComponentResult
from repro.connectivity.solve import _resolve, make_result, \
    resolve_warm_start, solve
from repro.connectivity.solvers import resolve_backend_plan
from repro.graphs.structs import Graph
from repro.runtime.recovery import FaultInjector, is_transient_error

# Smallest edge-store capacity / batch padding bucket.  Power of two so
# amortised doubling keeps the number of distinct compiled shapes
# logarithmic in the stream length.
MIN_CAPACITY = 64


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    return 1 << max(0, x - 1).bit_length()


@functools.partial(
    jax.jit,
    static_argnames=("variant", "backend", "plan", "warmup",
                     "async_compress", "sampling", "compact_every",
                     "max_iters"),
)
def delta_converge(
    src: jax.Array,
    dst: jax.Array,
    labels: jax.Array,
    n_active: jax.Array,
    *,
    variant: str = "C-2",
    backend: str = "xla",
    plan=None,
    warmup: int = 2,
    async_compress: int = 1,
    sampling: int = 0,
    compact_every: int = 1,
    max_iters: int = 100_000,
):
    """Re-converge ``labels`` after a new edge micro-batch.

    The pure jit-compiled core of :class:`StreamingConnectivity`: rewrite
    the batch ``(src, dst)`` to its endpoints' current roots (see the
    module docstring for why that rewrite carries the soundness of the
    whole engine), sweep its first ``n_active`` edges warm-started from
    ``labels`` — which must be a star-forest fixed point of everything
    before the batch — under the work-adaptive frontier schedule, and
    return ``(labels', iterations, converged, edges_visited)`` with
    ``labels'`` compressed back to a star forest.

    Composes with ``jax.vmap`` for fleets of parallel streams (each lane
    carries its own labels and batch; ``n_active`` may differ per lane).
    """
    # supervertex rewrite: labels is a star forest, so L[u] is u's root
    src = labels[src]
    dst = labels[dst]
    step = _make_step(variant, warmup, async_compress, backend, plan)
    L, it, done, _, visited = fr.adaptive_fixpoint(
        src, dst, labels, step,
        n_vertices=labels.shape[0],
        sampling=sampling,
        compact_every=compact_every,
        max_iters=max_iters,
        active_m0=n_active)
    return L, it, done, visited


@functools.partial(jax.jit, static_argnames=("pad_k",))
def _pad_batch(src: jax.Array, dst: jax.Array, pad_k: int):
    """Pad a batch to its bucket size with self-loop no-op edges."""
    k = src.shape[0]
    fill = jnp.zeros((pad_k - k,), jnp.int32)
    return (jnp.concatenate([src.astype(jnp.int32), fill]),
            jnp.concatenate([dst.astype(jnp.int32), fill]))


@functools.partial(jax.jit, donate_argnums=(0,))
def _ring_write(buf: jax.Array, chunk: jax.Array, offset: jax.Array):
    """Write ``chunk`` into ``buf`` at ``offset`` (one compiled program
    per (capacity, bucket) shape pair).

    ``buf`` is donated: the caller immediately rebinds the store to the
    result, so the append updates in place instead of copying the whole
    capacity every batch.
    """
    return jax.lax.dynamic_update_slice(buf, chunk, (offset,))


class StreamingConnectivity:
    """Incremental connectivity engine over a stream of edge batches.

    Example::

        eng = StreamingConnectivity(n_vertices=1_000_000)
        for src, dst in edge_batches:
            eng.ingest(src, dst)
            eng.same_component(0, 42)       # O(1), no re-solve
        final = eng.snapshot()              # ComponentResult

    Args:
      n_vertices: initial vertex count (``ingest(..., n_vertices=...)``
        grows it later).
      options: a :class:`SolveOptions`; must name a streaming-capable
        solver (Contour, any async variant — the supervertex rewrite
        makes every MM order sound; only the Alg.-1-verbatim ``C-Syn``
        is rejected).  ``mesh`` routes every batch through the
        ``shard_map`` distributed path.  If neither ``sampling`` nor
        ``compact_every`` is set, the engine defaults to
        ``compact_every=1`` so merged batch edges retire immediately.
      warm_start: labels (or a :class:`ComponentResult`) to seed from —
        e.g. a previous engine's :meth:`snapshot`.  Compressed to a star
        forest on entry.
      min_capacity: initial edge-store capacity (rounded up to a power
        of two).
      store_edges: keep every ingested edge in the device-resident store
        (enables :meth:`graph` and :meth:`resolve`).  ``False`` bounds
        the engine's memory at O(n) for indefinite streams — the labels
        are a lossless summary of the partition, so queries and delta
        solves never need the history.
      fault_injector: optional
        :class:`~repro.runtime.recovery.FaultInjector` consulted inside
        :meth:`ingest` at sites ``"pre"`` (before the delta solve) and
        ``"post_write"`` (after the ring-buffer write, before the
        commit) — the chaos-test hook proving ingest atomicity and
        bit-exact crash recovery (DESIGN.md §12).
      **overrides: per-field :class:`SolveOptions` overrides, as for
        ``solve()``.
    """

    # the checkpointable state (see state_dict); a stable key set is the
    # restore contract, so bump thoughtfully
    _STATE_KEYS = ("labels", "src", "dst", "m", "n", "n_cap", "n_batches",
                   "iterations", "converged", "edges_visited",
                   "store_edges")

    def __init__(
        self,
        n_vertices: int,
        options: Optional[SolveOptions] = None,
        *,
        warm_start: Union[None, ComponentResult, jax.Array] = None,
        min_capacity: int = MIN_CAPACITY,
        store_edges: bool = True,
        fault_injector: Optional[FaultInjector] = None,
        **overrides,
    ):
        opts, spec = _resolve(options, overrides)
        if not spec.supports_streaming:
            raise ValueError(
                f"solver {spec.name!r} does not support streaming; use "
                "algorithm='contour' (delta resweeps are a minimum-mapping "
                "property)")
        if opts.variant == "C-Syn":
            raise ValueError(
                "C-Syn is the Alg.-1-verbatim reference and rejects the "
                "frontier schedule the streaming engine is built on; use "
                "C-2/C-m (any async variant — the supervertex rewrite "
                "makes every order sound, see DESIGN.md §11)")
        if opts.sampling == 0 and opts.compact_every == 0:
            # the delta IS the frontier: contract merged batch edges away
            # every iteration by default
            opts = opts.replace(compact_every=1)
        self._opts = opts
        self._spec = spec
        self._n = int(n_vertices)

        # the label array is held at pow2 *capacity*, like the edge store:
        # vertices in [logical n, capacity) are identity-labelled isolated
        # singletons no real edge can touch (bounds-checked against the
        # logical n), so growth within capacity changes no array shape and
        # triggers no recompile — per-doc growers (StreamingDedup) pay one
        # compile per capacity doubling, not per batch
        self._n_cap = next_pow2(max(self._n, 1))
        # same fallback as solve(): the kwarg wins, else the options field
        init = resolve_warm_start(
            warm_start if warm_start is not None else opts.warm_start,
            self._n)
        L0 = lab.resolve_init_labels(init, self._n_cap, jnp.int32)
        # engine invariant: labels between batches are a star-forest fixed
        # point (identity already is one; arbitrary warm starts are only
        # guaranteed L[v]-in-component, so compress)
        self._labels = fr.compress_full(L0) if init is not None else L0

        self._store_edges = bool(store_edges)
        cap = next_pow2(max(int(min_capacity), 1)) if store_edges else 0
        self._src = jnp.zeros((cap,), jnp.int32)
        self._dst = jnp.zeros((cap,), jnp.int32)
        self._m = 0                      # real (unpadded) edges ingested
        self._n_batches = 0
        # device-resident cumulative counters: no host syncs per batch
        self._iterations = jnp.int32(0)
        self._converged = jnp.array(True)
        self._edges_visited = jnp.float32(0)
        self._snap: Optional[ComponentResult] = None
        self.fault_injector = fault_injector
        # degradation events survived by this stream (kernel fallbacks)
        # plus the resolved execution plan of each distinct per-batch
        # resolution; surfaced through snapshot().provenance
        self._provenance: list = []
        self._last_plan_entry: Optional[str] = None

    # -- introspection ---------------------------------------------------
    @property
    def n_vertices(self) -> int:
        return self._n

    @property
    def n_edges(self) -> int:
        """Real (unpadded) edges ingested so far."""
        return self._m

    @property
    def n_batches(self) -> int:
        return self._n_batches

    @property
    def capacity(self) -> int:
        """Current edge-store capacity (power of two)."""
        return int(self._src.shape[0])

    @property
    def vertex_capacity(self) -> int:
        """Label-array capacity (power of two; growth within it is free)."""
        return self._n_cap

    @property
    def options(self) -> SolveOptions:
        return self._opts

    @property
    def labels(self) -> jax.Array:
        """Device-resident converged labels (min vertex id per component),
        trimmed to the logical vertex count."""
        return self._labels[:self._n]

    def graph(self) -> Graph:
        """The accumulated edge list as a :class:`Graph` (store view)."""
        if not self._store_edges:
            raise ValueError(
                "this engine was built with store_edges=False; the edge "
                "history was not kept")
        return Graph(src=self._src[:self._m], dst=self._dst[:self._m],
                     n_vertices=self._n)

    # -- ingestion -------------------------------------------------------
    def _grow_vertices(self, n: int) -> None:
        if n < self._n:
            raise ValueError(
                f"n_vertices={n} shrinks the stream (was {self._n})")
        if n > self._n:
            # new vertices start as their own singleton components —
            # within capacity they already sit identity-labelled past the
            # logical n, so growth is just a bound bump (no recompile);
            # past capacity the label array doubles (one recompile per
            # doubling, amortised like the edge store)
            if n > self._n_cap:
                new_cap = next_pow2(n)
                self._labels = jnp.concatenate(
                    [self._labels,
                     jnp.arange(self._n_cap, new_cap, dtype=jnp.int32)])
                self._n_cap = new_cap
            self._n = n
            # growth alone changes query results (new singletons), so the
            # cached snapshot is stale even if the batch has no edges
            self._snap = None

    def _ensure_capacity(self, need: int) -> None:
        cap = self.capacity
        if need <= cap:
            return
        new_cap = next_pow2(need)
        grown = jnp.zeros((new_cap,), jnp.int32)
        self._src = grown.at[:cap].set(self._src)
        self._dst = grown.at[:cap].set(self._dst)

    def _validate_batch(self, src: np.ndarray, dst: np.ndarray) -> None:
        # same eager guard as Graph.add_edges: out-of-range ids would be
        # silently clamped by XLA gather/scatter and merge vertex 0's
        # component with the wrong vertices.  Runs on the host-side view
        # *before* device conversion so NumPy input costs no device sync.
        hi = int(max(src.max(), dst.max()))
        lo = int(min(src.min(), dst.min()))
        if hi >= self._n:
            raise ValueError(
                f"edge endpoint {hi} >= n_vertices={self._n}; pass "
                "n_vertices= to grow the stream")
        if lo < 0:
            raise ValueError("edge endpoints must be >= 0")

    def ingest(self, src, dst, n_vertices: Optional[int] = None,
               validate: bool = True) -> "StreamingConnectivity":
        """Ingest one edge micro-batch and re-converge the labels.

        Args:
          src, dst: 1-D arrays of equal length (each undirected edge
            once; duplicates and self-loops are harmless no-ops).
          n_vertices: optionally grow the vertex set first (ids in the
            batch may then use the new range).
          validate: eagerly bounds-check the endpoints (one host sync on
            device input; free for NumPy input).  Disable only for
            pre-validated streams.

        Returns ``self`` (chainable).
        """
        # keep device input on device (no pull unless validating); lift
        # everything else to NumPy so validation is a pure host check
        if not isinstance(src, jax.Array):
            src = np.asarray(src)
        if not isinstance(dst, jax.Array):
            dst = np.asarray(dst)
        if np.shape(src) != np.shape(dst) or len(np.shape(src)) != 1:
            raise ValueError(
                f"src/dst must be equal-length 1-D, got {np.shape(src)} "
                f"vs {np.shape(dst)}")
        old_n = self._n
        if n_vertices is not None:
            self._grow_vertices(int(n_vertices))
        k = int(np.shape(src)[0])
        if k == 0:
            return self
        if validate:
            self._validate_batch(np.asarray(src), np.asarray(dst))

        pad_k = next_pow2(k)
        src_p, dst_p = _pad_batch(jnp.asarray(src, jnp.int32),
                                  jnp.asarray(dst, jnp.int32), pad_k)

        # delta re-convergence: sweep only the new batch, warm-started.
        # Everything up to the scalar commit below runs inside the
        # rollback guard — vertex growth rolls back on failure (surplus
        # label capacity is invisible identity padding) and ring writes
        # only ever touch slots >= _m, which no reader observes — so a
        # failure anywhere (backend compile error, OOM at a new bucket
        # size, an injected crash after the ring write) leaves the engine
        # exactly as it was: ingest is atomic.
        try:
            if self.fault_injector is not None:
                self.fault_injector.maybe_fail(self._n_batches, "pre")
            L, it, done, visited = self._delta_solve(src_p, dst_p, pad_k, k)
            if self._store_edges:
                self._ensure_capacity(self._m + pad_k)
                offset = jnp.int32(self._m)
                self._src = _ring_write(self._src, src_p, offset)
                self._dst = _ring_write(self._dst, dst_p, offset)
            if self.fault_injector is not None:
                self.fault_injector.maybe_fail(self._n_batches, "post_write")
        except Exception:
            self._n = old_n
            self._snap = None
            raise
        # commit: the ring store already holds the batch (padding slots
        # hold self-loops; the next batch's write cursor starts at the
        # real size and overwrites them) — publish the size, labels and
        # counters in one uninterruptible run of scalar rebinds
        self._m += k
        self._labels = L
        self._iterations = self._iterations + jnp.asarray(it, jnp.int32)
        self._converged = self._converged & jnp.asarray(done, bool)
        self._edges_visited = (self._edges_visited
                               + jnp.asarray(visited, jnp.float32))
        self._n_batches += 1
        self._snap = None
        return self

    def _delta_solve(self, src_p, dst_p, pad_k: int, k: int):
        """Run the per-batch delta solve, falling back to XLA on a failed
        non-XLA kernel launch (recorded in the stream's provenance)."""
        try:
            return self._delta_solve_backend(src_p, dst_p, pad_k, k,
                                             self._opts)
        except Exception as exc:
            if (not self._opts.kernel_fallback
                    or self._opts.backend == "xla"
                    or not is_transient_error(exc)):
                raise
            try:
                # TTL'd demotion: later batches (and later streams) in
                # this size bucket resolve straight to XLA until it lapses
                _planner.record_kernel_failure(
                    self._n_cap, pad_k,
                    failed_backend=self._opts.backend)
            except Exception:
                pass  # cache writes must never break the fallback
            self._provenance.append(
                f"kernel_fallback:{self._opts.backend}->xla "
                f"(batch {self._n_batches}, {type(exc).__name__}: "
                f"{str(exc)[:120]})")
            out = self._delta_solve_backend(
                src_p, dst_p, pad_k, k,
                self._opts.replace(backend="xla", plan=None))
            self._snap = None
            return out

    def _record_plan(self, plan) -> None:
        """Append the resolved plan to provenance when it changes."""
        entry = plan.provenance_entry()
        if entry != self._last_plan_entry:
            self._provenance.append(entry)
            self._last_plan_entry = entry
            self._snap = None

    def _delta_solve_backend(self, src_p, dst_p, pad_k: int, k: int, opts):
        if opts.mesh is not None:
            # supervertex rewrite (the single-device path does this
            # inside delta_converge); self-loop padding maps to
            # self-loops.  The replica spans the label *capacity* so
            # its shape matches the resident labels.
            backend, plan = resolve_backend_plan(self._n_cap, pad_k, opts)
            self._record_plan(plan)
            return dist.distributed_contour(
                Graph(src=self._labels[src_p], dst=self._labels[dst_p],
                      n_vertices=self._n_cap),
                opts.mesh,
                edge_axes=tuple(opts.edge_axes),
                local_rounds=opts.local_rounds,
                max_iters=opts.max_iters,
                async_compress=opts.async_compress,
                backend=backend,
                plan=plan,
                init_labels=self._labels,
                sampling=opts.sampling,
                compact_every=opts.compact_every,
                n_active=k)
        backend, plan = resolve_backend_plan(self._n_cap, pad_k, opts)
        self._record_plan(plan)
        return delta_converge(
            src_p, dst_p, self._labels, jnp.int32(k),
            variant=opts.variant,
            backend=backend,
            plan=plan,
            warmup=opts.warmup,
            async_compress=opts.async_compress,
            sampling=opts.sampling,
            compact_every=opts.compact_every,
            max_iters=opts.max_iters)

    def ingest_graph(self, graph: Graph,
                     validate: bool = True) -> "StreamingConnectivity":
        """Ingest a whole :class:`Graph` as one batch (growing vertices)."""
        return self.ingest(graph.src, graph.dst,
                           n_vertices=max(self._n, graph.n_vertices),
                           validate=validate)

    # -- queries (no re-solve) -------------------------------------------
    def snapshot(self) -> ComponentResult:
        """Current components as a :class:`ComponentResult` — O(1).

        Labels are already converged (every ``ingest`` re-converges), so
        this wraps the resident arrays; ``iterations``/``edges_visited``
        are cumulative over the stream and ``converged`` is the AND of
        every batch's fixed-point flag (False means some batch exhausted
        ``max_iters`` — call :meth:`resolve` to repair).
        """
        if self._snap is None:
            self._snap = make_result(self._labels[:self._n],
                                     self._iterations, self._converged,
                                     self._edges_visited,
                                     provenance=self._provenance)
        return self._snap

    def _check_query_ids(self, *ids) -> None:
        # eager host-side bounds check: a jax-array gather against the
        # resident labels would *clamp* an out-of-range id to a valid
        # index and silently answer for the wrong vertex (the PR-3
        # negative-warm-start failure class); the serving coalescer
        # performs the same check before its batched device gather
        for x in ids:
            a = np.asarray(x)
            if np.any(a < 0):
                raise IndexError("vertex ids must be >= 0")
            if a.size and np.any(a >= self._n):
                raise IndexError(
                    f"query vertex id out of range for "
                    f"n_vertices={self._n}; grow the stream with "
                    "ingest(..., n_vertices=...) first")

    def same_component(self, u, v):
        """True iff ``u`` and ``v`` are currently connected."""
        self._check_query_ids(u, v)
        return self.snapshot().same_component(u, v)

    def component_of(self, v):
        """Current component id (min vertex id) of ``v``."""
        self._check_query_ids(v)
        return self.snapshot().component_of(v)

    @property
    def n_components(self) -> int:
        return self.snapshot().n_components

    # -- repair ----------------------------------------------------------
    def resolve(self, max_iters: Optional[int] = None) -> ComponentResult:
        """Full warm-started solve over every stored edge.

        Normally a (cheap) no-op — the delta path keeps labels at the
        fixed point, and the warm start means the resweep converges in
        O(1) iterations.  It is the repair path when ``snapshot().
        converged`` is False (a batch ran out of ``max_iters`` mid-merge,
        leaving store edges that were never fully swept).  The repair
        deliberately does *not* inherit the stream's ``max_iters`` — that
        budget's exhaustion is what it exists to fix; ``None`` takes the
        solver's registry default (pass a value to cap it).
        """
        if self._m == 0:
            return self.snapshot()
        res = solve(self.graph(),
                    self._opts.replace(warm_start=None,
                                       max_iters=max_iters),
                    warm_start=self._labels[:self._n])
        # restore the capacity invariant: identity labels past logical n
        self._labels = jnp.concatenate(
            [jnp.asarray(res.labels, jnp.int32),
             jnp.arange(self._n, self._n_cap, dtype=jnp.int32)])
        self._iterations = self._iterations + res.iterations
        self._converged = jnp.asarray(res.converged, bool)
        if res.edges_visited is not None:
            self._edges_visited = self._edges_visited + res.edges_visited
        self._snap = None
        return self.snapshot()

    # -- checkpointing (DESIGN.md §12) -----------------------------------
    def state_dict(self) -> dict:
        """The engine's complete checkpointable state, as a flat pytree.

        Everything a restore needs to resume the stream bit-exactly: the
        ring-buffered edge store, the converged label array (at capacity,
        so the pow2 growth schedule replays identically), the logical
        sizes, and the cumulative counters.  Every leaf is an array (or
        NumPy scalar), so the dict round-trips through
        ``CheckpointManager``'s atomic-rename ``.npy`` protocol
        unchanged.

        The edge-store leaves are *copies*: the live buffers are donated
        to ``_ring_write`` on the next ingest, which would invalidate any
        held reference — a snapshot must stay readable after the stream
        moves on.
        """
        return {
            "labels": self._labels,
            "src": jnp.array(self._src),
            "dst": jnp.array(self._dst),
            "m": np.int64(self._m),
            "n": np.int64(self._n),
            "n_cap": np.int64(self._n_cap),
            "n_batches": np.int64(self._n_batches),
            "iterations": self._iterations,
            "converged": self._converged,
            "edges_visited": self._edges_visited,
            "store_edges": np.bool_(self._store_edges),
        }

    @classmethod
    def _state_like(cls) -> dict:
        """Structure template for ``CheckpointManager.restore`` (only the
        treedef is used; shapes/dtypes come from the manifest)."""
        return {k: np.int64(0) for k in cls._STATE_KEYS}

    def load_state_dict(self, state: dict) -> "StreamingConnectivity":
        """Restore the engine to a :meth:`state_dict` snapshot in place.

        Validates the structural invariants (capacity/size consistency)
        so a corrupt or truncated checkpoint fails loudly instead of
        answering queries from inconsistent state.
        """
        missing = set(self._STATE_KEYS) - set(state)
        if missing:
            raise ValueError(f"checkpoint state is missing {sorted(missing)}")
        n = int(state["n"])
        n_cap = int(state["n_cap"])
        m = int(state["m"])
        labels = jnp.asarray(state["labels"], jnp.int32)
        # copy the edge store (jnp.array, not asarray): the engine will
        # donate these buffers to _ring_write, which must not invalidate
        # the caller's state dict
        src = jnp.array(state["src"]).astype(jnp.int32)
        dst = jnp.array(state["dst"]).astype(jnp.int32)
        if labels.shape != (n_cap,) or not 0 <= n <= n_cap:
            raise ValueError(
                f"corrupt checkpoint: labels shape {labels.shape} vs "
                f"n={n}, n_cap={n_cap}")
        if src.shape != dst.shape or (bool(state["store_edges"])
                                      and m > src.shape[0]):
            raise ValueError(
                f"corrupt checkpoint: edge store {src.shape}/{dst.shape} "
                f"cannot hold m={m}")
        self._n, self._n_cap, self._m = n, n_cap, m
        self._labels = labels
        self._src, self._dst = src, dst
        self._store_edges = bool(state["store_edges"])
        self._n_batches = int(state["n_batches"])
        self._iterations = jnp.asarray(state["iterations"], jnp.int32)
        self._converged = jnp.asarray(state["converged"], bool)
        self._edges_visited = jnp.asarray(state["edges_visited"],
                                          jnp.float32)
        self._snap = None
        return self

    def save(self, manager, step: Optional[int] = None) -> int:
        """Checkpoint the stream through ``manager`` (atomic rename).

        ``step`` defaults to :attr:`n_batches` — the number of committed
        batches — so the crash-restart driver's convention "checkpoint
        step k == resume at batch k" holds without bookkeeping.  Returns
        the step written.
        """
        if step is None:
            step = self._n_batches
        manager.save(int(step), self.state_dict())
        return int(step)

    @classmethod
    def restore(
        cls,
        manager,
        options: Optional[SolveOptions] = None,
        *,
        step: Optional[int] = None,
        fault_injector: Optional[FaultInjector] = None,
        **overrides,
    ) -> tuple["StreamingConnectivity", int]:
        """Rebuild an engine from a checkpoint written by :meth:`save`.

        ``options`` (plus ``**overrides``) are *not* checkpointed —
        solver configuration may legitimately change across a restart
        (e.g. an elastic mesh over fewer devices) — so pass the same
        options to resume identically.  Returns ``(engine, step)``.
        """
        state, step = manager.restore(cls._state_like(), step)
        eng = cls(int(state["n"]), options,
                  store_edges=bool(state["store_edges"]),
                  fault_injector=fault_injector, **overrides)
        eng.load_state_dict(state)
        return eng, int(step)
