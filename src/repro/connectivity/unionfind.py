"""ConnectIt stand-in: Rem's union-find with splicing (paper §III-C).

Host-side by design: Rem's algorithm is sequential pointer-chasing with no
efficient TPU analogue (the paper itself positions it as the winner only
in parallelism-starved regimes — DESIGN.md §8.5).  Registered in the
``repro.connectivity`` solver registry so all three families run through
one ``solve()`` signature.

Warm start seeds the parent array with a previous solve's labels: Rem's
loop only ever rewrites parents to smaller values, so a star forest at the
old component minima is a valid (and already-compressed) starting forest.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.oracle import rem_union_find
from repro.graphs.structs import Graph


def rem_labels(
    src, dst, n_vertices: int,
    init_labels: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Run host-side Rem union-find; returns (labels, n_iterations,
    converged).

    ``n_iterations`` is 1 by the paper's §IV-C convention (a union-find
    pass has no iteration structure to count); ``converged`` is always
    True — the pass is exact by construction.
    """
    parent0 = None if init_labels is None else np.asarray(init_labels)
    dtype = getattr(src, "dtype", jnp.int32)
    labels = rem_union_find(np.asarray(src), np.asarray(dst), n_vertices,
                            parent0=parent0)
    return (jnp.asarray(labels, dtype=dtype), jnp.int32(1),
            jnp.array(True))


def rem(graph: Graph, init_labels=None):
    return rem_labels(graph.src, graph.dst, graph.n_vertices,
                      init_labels=init_labels)


__all__ = ["rem_union_find", "rem_labels", "rem"]
