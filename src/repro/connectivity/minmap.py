"""Minimum-mapping operators (paper §II-B) as pure-JAX primitives.

Moved here from ``repro.core.labels`` (which remains as an alias) so the
``repro.connectivity`` package — the single public connectivity surface —
owns the math while ``repro.core`` holds only deprecation shims.

The paper's h-order minimum-mapping operator ``MM^h(L_u, L, w, v)``:

    z^h = min(L^h[w], L^h[v])           (L^h = h-fold composition of L)
    conditionally assign z^h into L_u at positions
    {w, v, L[w], L[v], ..., L^{h-1}[w], L^{h-1}[v]}

The paper implements the conditional assignment with an atomic CAS loop
(Eq. 4).  On TPU the equivalent race-free primitive is a *scatter-min*
(`L.at[idx].min(z)`): ``min`` is associative and commutative, so XLA's
scatter combiner reaches the identical fixed point deterministically
(DESIGN.md §3).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def gather_chain(L: jax.Array, idx: jax.Array, order: int) -> Tuple[jax.Array, ...]:
    """Return (L^1[idx], ..., L^order[idx])."""
    out = []
    cur = L[idx]
    out.append(cur)
    for _ in range(order - 1):
        cur = L[cur]
        out.append(cur)
    return tuple(out)


def mm_update_stream(
    L: jax.Array, src: jax.Array, dst: jax.Array, order: int
) -> Tuple[jax.Array, jax.Array]:
    """Gather phase of ``MM^order``: the ``(targets, values)`` update stream.

    ``values`` is ``z = min(L^order[src], L^order[dst])`` per edge;
    ``targets`` are the conditional-assignment positions — the endpoints
    plus their 1..order-1 mapped vertices (Definition 3).  This is the
    single source of truth for the sweep's math: :func:`mm_relax` scatters
    the stream with XLA, the label-blocked Pallas kernel
    (`kernels.contour_mm.blocked`) scatters the identical stream through
    binned per-tile segment mins — which is what makes the two backends
    bit-exact per sweep.
    """
    if order < 1:
        raise ValueError(f"order must be >= 1, got {order}")
    chain_s = gather_chain(L, src, order)  # L[src], L^2[src], ...
    chain_d = gather_chain(L, dst, order)
    z = jnp.minimum(chain_s[-1], chain_d[-1])
    targets = [src, dst]
    for k in range(order - 1):
        targets.append(chain_s[k])
        targets.append(chain_d[k])
    return jnp.concatenate(targets), jnp.tile(z, len(targets))


def mm_relax(L: jax.Array, src: jax.Array, dst: jax.Array, order: int) -> jax.Array:
    """One parallel sweep of ``MM^order`` over every edge; returns new labels.

    This is the synchronous formulation: all reads see the input ``L`` and
    all conditional assignments combine by minimum, exactly Alg. 1 lines
    6-9 (``L_u`` initialised to ``L``, then ``L = L_u``).
    """
    idx, vals = mm_update_stream(L, src, dst, order)
    return L.at[idx].min(vals)


def pointer_jump(L: jax.Array, rounds: int = 1) -> jax.Array:
    """``L <- L[L]`` repeated; halves pointer-tree height per round.

    Used (a) as the in-iteration recompaction that adapts the paper's
    asynchronous updates to a functional runtime and (b) to realise the
    high-order ``C-m`` operator without length-m serial gather chains
    (DESIGN.md §3).
    """
    for _ in range(rounds):
        L = jnp.minimum(L, L[L])
    return L


def converged_early(L: jax.Array, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Paper §III-B2 early-convergence predicate.

    Converged iff for every edge (w, v):
        L[w] == L[v]  and  L[w] == L^2[w]  and  L[v] == L^2[v].
    """
    lw, lv = L[src], L[dst]
    bad = (lw != lv) | (lw != L[lw]) | (lv != L[lv])
    return ~jnp.any(bad)


def is_star_forest(L: jax.Array) -> jax.Array:
    """True iff the pointer graph is a forest of stars (L[L] == L)."""
    return jnp.all(L[L] == L)


def check_labels_nonnegative(labels: jax.Array) -> None:
    """Eagerly reject negative labels (mirrors ``Graph.add_edges``).

    The ``min(init, iota)`` warm-start clamp lets negatives through, and
    XLA gather then silently clamps the out-of-range index to 0 — merging
    every poisoned vertex into component 0.  The check needs concrete
    values, so it is a no-op on tracers; eager callers (the ``solve``
    facade, ``solve_batch``, the distributed path) all funnel through it.
    """
    if not isinstance(labels, jax.core.Tracer) and labels.size:
        lo = int(labels.min())
        if lo < 0:
            raise ValueError(
                f"warm-start labels must be >= 0, got minimum {lo}; "
                "negative ids would be clamped to vertex 0 by XLA gather "
                "and silently merge the wrong components")


def resolve_init_labels(
    init: Optional[jax.Array], n_vertices: int, dtype
) -> jax.Array:
    """Initial label array for a (possibly warm-started) solve.

    ``None`` gives the identity labelling of Alg. 1 line 2.  A warm start
    passes the converged labels of a previous solve: any labelling with
    ``L[v]`` in the same component as ``v`` has the same fixed point, and
    min-mapping labels only ever decrease, so starting at the old fixed
    point is both correct and strictly ahead of the identity start.

    Two normalisations keep arbitrary caller input safe:

    * a shorter array (the graph grew vertices since the previous solve)
      is extended with identity labels for the new vertices;
    * the result is clamped to ``min(init, iota)`` so the identity
      invariant ``L[v] <= v`` (which every solver here preserves and the
      monotonicity guarantee is stated against) holds from iteration 0.

    Negative labels are rejected eagerly via
    :func:`check_labels_nonnegative` (see there for why); under a trace
    (e.g. ``solve`` called inside a user ``jax.jit``) the eager check
    cannot fire, so negatives are instead *neutralised* to the identity
    label — an always-valid cold start for that vertex — rather than left
    for XLA gather to clamp to vertex 0 and merge wrong components.
    """
    iota = jnp.arange(n_vertices, dtype=dtype)
    if init is None:
        return iota
    init = jnp.asarray(init).astype(dtype)
    check_labels_nonnegative(init)
    if init.shape[0] > n_vertices:
        raise ValueError(
            f"warm-start labels cover {init.shape[0]} vertices but the "
            f"graph has only {n_vertices}")
    if init.shape[0] < n_vertices:
        init = jnp.concatenate([init, iota[init.shape[0]:]])
    init = jnp.where(init < 0, iota, init)
    return jnp.minimum(init, iota)
