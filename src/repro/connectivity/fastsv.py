"""FastSV baseline (Zhang, Azad & Hu, SIAM PP 2020) — paper §III-C.

FastSV iterates three scatter-min phases over a parent array ``f`` with a
grandparent shortcut ``gf = f[f]``:

  1. *stochastic hooking*:  f_next[f[u]] <- min(f_next[f[u]], gf[v])
  2. *aggressive hooking*:  f_next[u]    <- min(f_next[u],    gf[v])
  3. *shortcutting*:        f_next[u]    <- min(f_next[u],    gf[u])

(applied over both edge directions), converging when the grandparent array
stops changing.  This is the paper's principal large-scale-parallel
comparison target; we implement it with the same scatter-min primitive as
Contour so runtime comparisons isolate the algorithmic difference.

``init_labels`` warm-starts the parent array from a previous solve's
labels — hooking is min-only, so parents decrease monotonically from any
valid start (see ``minmap.resolve_init_labels``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.connectivity import minmap as lab
from repro.graphs.structs import Graph


class _State(NamedTuple):
    f: jax.Array
    gf: jax.Array
    it: jax.Array
    done: jax.Array


@functools.partial(jax.jit, static_argnames=("n_vertices", "max_iters"))
def fastsv_labels(src, dst, n_vertices: int,
                  init_labels: Optional[jax.Array] = None,
                  max_iters: int = 256):
    """Run FastSV; returns (labels[n], n_iterations, converged)."""
    u = jnp.concatenate([src, dst])
    v = jnp.concatenate([dst, src])
    f0 = lab.resolve_init_labels(init_labels, n_vertices, src.dtype)
    gf0 = f0[f0]

    def cond(s: _State):
        return (~s.done) & (s.it < max_iters)

    def body(s: _State):
        f, gf = s.f, s.gf
        fn = f
        # (1) stochastic hooking: hook the root/parent of u under gf[v]
        fn = fn.at[f[u]].min(gf[v])
        # (2) aggressive hooking: hook u itself under gf[v]
        fn = fn.at[u].min(gf[v])
        # (3) shortcutting
        fn = jnp.minimum(fn, gf)
        gf_new = fn[fn]
        done = jnp.all(gf_new == gf)
        return _State(f=fn, gf=gf_new, it=s.it + 1, done=done)

    init = _State(f=f0, gf=gf0, it=jnp.int32(0), done=jnp.array(False))
    out = jax.lax.while_loop(cond, body, init)
    # converged gf is a star forest rooted at component minima
    return out.gf, out.it, out.done


def fastsv(graph: Graph, max_iters: int = 256):
    return fastsv_labels(graph.src, graph.dst, graph.n_vertices,
                         max_iters=max_iters)
