"""Registered solver families wrapping every existing implementation.

Five families, one signature (DESIGN.md §9 maps them onto the paper):

* ``contour``           — paper §III-B, all variants (Alg. 1 + §III-B4),
  any ``kernels.contour_mm`` backend, single device.
* ``distributed``       — paper §III-B over a device mesh (§IV Arkouda
  mapping): ``shard_map`` edge-sharded Contour C-2.  ``solve()`` routes
  ``contour`` here automatically when ``SolveOptions.mesh`` is set.
* ``fastsv``            — paper §III-C, the Shiloach-Vishkin family
  (Zhang, Azad & Hu).
* ``label_propagation`` — paper §I/§V traversal-family strawman.
* ``union_find``        — paper §III-C ConnectIt stand-in (host-side
  Rem's algorithm with splicing).
* ``oocore``            — out-of-core multi-round contraction
  (DESIGN.md §15): edges stream from host memory chunk by chunk, so
  problem size is decoupled from device memory.
* ``auto``              — ConnectIt-style measured dispatch (DESIGN.md
  §16): the planner cost model picks the (solver family, sampling
  strategy) per graph and delegates; the choice lands in provenance.
"""
from __future__ import annotations

import jax

from repro.connectivity import contour as _contour
from repro.connectivity import distributed as _distributed
from repro.connectivity import fastsv as _fastsv
from repro.connectivity import lp as _lp
from repro.connectivity import oocore as _oocore
from repro.connectivity import planner as _planner
from repro.connectivity import unionfind as _unionfind
from repro.connectivity.planner import staged as _staged
from repro.connectivity.registry import (SolverSpec, get_solver,
                                         register_solver)
from repro.graphs import stats as _stats
from repro.graphs.generators import ArrayChunks

# Registry names that resolve to the out-of-core solver (and therefore
# need ExecutionPlan.chunk_bucket stamped at plan resolution).
_OOCORE_NAMES = ("oocore", "out_of_core")


def resolve_backend_plan(n_vertices: int, n_edges: int, opts):
    """Concrete (backend, plan) for a solve.

    Resolution goes through the execution-plan layer
    (:func:`repro.connectivity.planner.resolve_plan`): a plan pinned in
    ``opts.plan`` wins; otherwise ``backend="auto"`` consults the tuning
    cache and falls back to the heuristic tables, while an explicit
    backend takes the tables with that backend substituted.  Always
    returns a concrete backend and an :class:`planner.ExecutionPlan`
    (legacy ``KernelPlan`` pins are lifted).  For the out-of-core solver
    the plan additionally carries the VMEM-derived streaming chunk
    bucket (``chunk_bucket``), unless the pinned plan already set one.
    """
    plan = _planner.resolve_plan(n_vertices, n_edges, backend=opts.backend,
                                 plan=opts.plan)
    backend = plan.backend if opts.backend == "auto" else opts.backend
    if (getattr(opts, "algorithm", None) in _OOCORE_NAMES
            and plan.chunk_bucket == 0):
        plan = plan.replace(chunk_bucket=_planner.oocore_chunk_bucket(
            n_edges,
            vmem_limit_bytes=opts.vmem_limit_bytes,
            requested=opts.oocore_chunk_edges))
    return backend, plan


def _sampling_provenance(opts):
    """Static provenance entry naming the sampling strategy in effect."""
    if opts.sampling <= 0:
        return ()
    return (f"sampling_strategy:{opts.sampling_strategy or 'prefix'}",)


def _contour_solver(graph, opts, init_labels):
    backend, plan = resolve_backend_plan(graph.n_vertices, graph.n_edges,
                                         opts)
    variant = opts.variant or "C-2"
    strategy = opts.sampling_strategy or "prefix"
    adaptive = opts.sampling > 0 or opts.compact_every > 0
    if (adaptive and variant != "C-Syn"
            and plan.compact_schedule == "staged"
            and not isinstance(graph.src, jax.core.Tracer)):
        # physically staged frontier: host-driven stage loop, edge arrays
        # really shrink.  Unavailable under an enclosing trace (vmap'd
        # solve_batch, user jit) — those keep the masked in-loop schedule,
        # which is bit-identical at the fixed point.
        out = _staged.staged_adaptive_labels(
            graph.src, graph.dst, graph.n_vertices, init_labels,
            variant=variant,
            max_iters=opts.max_iters,
            warmup=opts.warmup,
            async_compress=opts.async_compress,
            backend=backend,
            plan=plan,
            sampling=opts.sampling,
            compact_every=opts.compact_every,
            sampling_strategy=strategy,
            sampling_k=opts.sampling_k,
            vmem_limit_bytes=opts.vmem_limit_bytes,
        )
        return (*out, _sampling_provenance(opts))
    out = _contour.contour_labels(
        graph.src, graph.dst, graph.n_vertices, init_labels,
        variant=variant,
        max_iters=opts.max_iters,
        warmup=opts.warmup,
        async_compress=opts.async_compress,
        backend=backend,
        plan=plan,
        sampling=opts.sampling,
        compact_every=opts.compact_every,
        sampling_strategy=strategy,
        sampling_k=opts.sampling_k,
        vmem_limit_bytes=opts.vmem_limit_bytes,
    )
    return (*out, _sampling_provenance(opts))


def _distributed_solver(graph, opts, init_labels):
    if opts.mesh is None:
        raise ValueError(
            "the 'distributed' solver needs SolveOptions.mesh (a "
            "jax.sharding.Mesh); for single-device solves use "
            "algorithm='contour'")
    if (opts.sampling_strategy or "prefix") != "prefix":
        raise ValueError(
            "the 'distributed' solver samples a deterministic per-shard "
            "edge prefix; sampling_strategy "
            f"{opts.sampling_strategy!r} is single-device only (it "
            "permutes the global edge list, which would break the static "
            "shard layout) — use algorithm='contour'")
    backend, plan = resolve_backend_plan(graph.n_vertices, graph.n_edges,
                                         opts)
    return _distributed.distributed_contour(
        graph, opts.mesh,
        edge_axes=tuple(opts.edge_axes),
        local_rounds=opts.local_rounds,
        max_iters=opts.max_iters,
        async_compress=opts.async_compress,
        backend=backend,
        plan=plan,
        init_labels=init_labels,
        sampling=opts.sampling,
        compact_every=opts.compact_every,
    )


def _fastsv_solver(graph, opts, init_labels):
    return _fastsv.fastsv_labels(graph.src, graph.dst, graph.n_vertices,
                                 init_labels,
                                 max_iters=opts.max_iters)


def _lp_solver(graph, opts, init_labels):
    return _lp.label_propagation_labels(graph.src, graph.dst,
                                        graph.n_vertices, init_labels,
                                        max_iters=opts.max_iters)


def _union_find_solver(graph, opts, init_labels):
    return _unionfind.rem_labels(graph.src, graph.dst, graph.n_vertices,
                                 init_labels=init_labels)


def _oocore_solver(graph, opts, init_labels):
    if isinstance(graph.src, jax.core.Tracer):
        raise ValueError(
            "the 'oocore' solver is host-driven (it streams edge chunks "
            "between rounds) and cannot run under an enclosing trace; "
            "call solve() eagerly or use algorithm='contour'")
    backend, plan = resolve_backend_plan(graph.n_vertices, graph.n_edges,
                                         opts)
    bucket = plan.chunk_bucket or _planner.oocore_chunk_bucket(
        graph.n_edges, vmem_limit_bytes=opts.vmem_limit_bytes,
        requested=opts.oocore_chunk_edges)
    src, dst, n = graph.to_numpy()
    chunks = ArrayChunks(src, dst, n, bucket)
    return _oocore.oocore_labels(chunks, opts, init_labels=init_labels)


def _auto_solver(graph, opts, init_labels):
    """ConnectIt-style measured dispatch (DESIGN.md §16).

    Resolves a (solver family, sampling strategy) via the planner cost
    model — pinned ``SolveOptions`` fields > fitted bench-artifact model
    > heuristic table — then delegates to the chosen registered solver.
    The choice (and the delegate's plan) is returned in the static
    provenance element so every auto solve records what ran and why.
    """
    skew = None
    if not isinstance(graph.src, jax.core.Tracer):
        # degree skew needs values, not shapes; under an enclosing trace
        # the model falls back to its size-only features
        np_src, np_dst, n = graph.to_numpy()
        skew = _stats.degree_skew(np_src, np_dst, n)
    choice = _planner.resolve_strategy(
        graph.n_vertices, graph.n_edges,
        degree_skew=skew,
        pinned_strategy=opts.sampling_strategy,
        pinned_variant=opts.variant)
    delegate = get_solver(choice.solver)
    d_opts = opts.replace(
        algorithm=choice.solver,
        variant=choice.variant,
        sampling_strategy=choice.sampling_strategy,
        # explicit schedule knobs on the options win over the model's
        sampling=opts.sampling or choice.sampling,
        compact_every=opts.compact_every or choice.compact_every,
    )
    out = tuple(delegate.fn(graph, d_opts, init_labels))
    provenance = [choice.provenance_entry()]
    from repro.connectivity.solve import _PLANNED_SOLVERS  # lazy: cycle
    if choice.solver in _PLANNED_SOLVERS:
        _, plan = resolve_backend_plan(graph.n_vertices, graph.n_edges,
                                       d_opts)
        provenance.append(plan.provenance_entry())
    if len(out) > 4 and out[4]:
        provenance.extend(out[4])
    base = out[:4] if len(out) >= 4 else (*out[:3], None)
    return (*base, tuple(provenance))


CONTOUR = register_solver(SolverSpec(
    name="contour",
    fn=_contour_solver,
    variants=_contour.VARIANTS + ("C-<h>",),
    default_variant="C-2",
    default_max_iters=100_000,
    supports_mesh=True,          # via automatic routing to 'distributed'
    supports_streaming=True,     # any async variant (C-Syn rejected)
    paper_ref="§III-B (Alg. 1, variants §III-B4)",
))

DISTRIBUTED = register_solver(SolverSpec(
    name="distributed",
    fn=_distributed_solver,
    aliases=("contour_distributed",),
    variants=("C-2",),
    default_variant="C-2",
    default_max_iters=10_000,
    supports_batch=False,        # shard_map placement, not vmappable
    supports_mesh=True,
    supports_streaming=True,     # per-shard delta contraction, C-2 only
    paper_ref="§III-B over §IV's distributed mapping",
))

FASTSV = register_solver(SolverSpec(
    name="fastsv",
    fn=_fastsv_solver,
    default_max_iters=256,
    paper_ref="§III-C (FastSV / Shiloach-Vishkin family)",
))

LABEL_PROPAGATION = register_solver(SolverSpec(
    name="label_propagation",
    fn=_lp_solver,
    aliases=("lp",),
    default_max_iters=100_000,
    paper_ref="§I/§V (traversal-family baseline)",
))

UNION_FIND = register_solver(SolverSpec(
    name="union_find",
    fn=_union_find_solver,
    aliases=("connectit", "rem"),
    default_max_iters=1,
    supports_batch=False,        # host-side sequential loop
    runs_on="host",
    paper_ref="§III-C (ConnectIt stand-in: Rem's union-find)",
))

AUTO = register_solver(SolverSpec(
    name="auto",
    fn=_auto_solver,
    variants=_contour.VARIANTS + ("C-<h>",),
    default_variant=None,        # the cost model picks unless pinned
    default_max_iters=100_000,
    supports_streaming=True,
    paper_ref="ConnectIt strategy-matrix dispatch (DESIGN.md §16)",
))

OOCORE = register_solver(SolverSpec(
    name="oocore",
    fn=_oocore_solver,
    aliases=("out_of_core",),
    variants=_contour.VARIANTS + ("C-<h>",),
    default_variant="C-2",
    default_max_iters=100_000,
    supports_batch=False,        # host-driven round loop, not vmappable
    paper_ref="§III-B streamed per Behnezhad et al. / ConnectIt "
              "multi-round contraction (DESIGN.md §15)",
))
