"""Work-adaptive edge-frontier contraction for the min-mapping fixpoint.

The paper's per-iteration cost is O(m): every sweep touches every edge
(Alg. 1 line 5).  Its own optimizations — early convergence (§III-B2) and
asynchronous updates (§III-B1) — exist precisely because most edges become
intra-component after a few iterations and then contribute nothing.  This
module makes that observation structural, adapting ConnectIt's sampling
phase and Afforest's skip-the-largest-component trick (PAPERS.md) to a
jit-compiled functional runtime:

1. **Sampling phase** — the first ``sampling`` iterations sweep only a
   *sample* of the edge list.  Which sample is a pluggable
   :class:`SamplingStrategy` (ConnectIt's central axis): the default
   ``"prefix"`` strategy sweeps a deterministic prefix
   (``m // SAMPLE_PREFIX_DENOM`` edges); ``"kout"`` is the
   Afforest/k-out neighbour-subgraph sampler (each vertex's first ``k``
   incident edges); ``"bfs"`` grows low-diameter balls around
   high-degree seed vertices.  Every strategy reduces to *a permutation
   of the edge list plus a prefix width* (``prepare_sampling``), which
   is what makes the whole matrix sound: scatter-min sweeps are
   order-free and sweeping any edge subset is a valid min-mapping
   relaxation, so a sampled sweep is just a cheaper sound sweep and the
   fixed point is untouched.  On power-law / suite graphs a few cheap
   sampled sweeps are enough for one giant intermediate component to
   emerge.

2. **Skip-the-largest-component filter** — after the sampling phase, the
   most frequent current label (the largest intermediate component) is
   found on device (``largest_component_label``) and every edge both of
   whose endpoints contract into it is retired, à la ConnectIt/Afforest.

3. **Periodic active-edge contraction** — every ``compact_every``
   iterations the still-active edges are *contracted*: endpoints are
   rewritten to their depth-2 representatives ``L²[v]`` and self-loops of
   the contraction are retired by a stable partition into an
   ``[active | retired]`` edge layout with a device-resident ``active_m``
   count.  Subsequent sweeps and the early-convergence check touch only
   the active prefix (masked tiles under XLA; skipped grid steps in the
   label-blocked Pallas kernel via a scalar-prefetched live-chunk count).

Everything runs inside one ``lax.while_loop`` — edge arrays and
``active_m`` are loop state, compaction happens under ``lax.switch`` —
so there are **zero** host syncs, and the schedule composes with ``vmap``
(``solve_batch``) and per-shard with ``shard_map`` (``distributed``).

Why *contraction* and not mere dropping (DESIGN.md §10): retiring an edge
``(u, v)`` solely because its endpoint labels currently agree is unsound
here — the agreement is witnessed only by label *pointers*, and a later
scatter-min can redirect those pointers through a different part of the
component, stranding one side on a stale root (the seed's union-find
baseline never hits this because its unions are permanent).  Rewriting the
*surviving* edges to their current representatives keeps every
inter-supervertex adjacency in the edge list itself, so retired vertices
only ever hang off monotone pointer chains; a final pointer-jump
compression to the star-forest fixed point then yields labels bit-identical
to the uncompacted path (property-tested against the oracle in
``tests/test_frontier.py``).

The retired suffix keeps the edge arrays' static shape, so labels at the
fixed point are bit-identical to the uncompacted path while the counted
work (``edges_visited``) collapses from ``iterations × m`` to the sum of
per-sweep active counts.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.connectivity import minmap as lab

# The deterministic sampling prefix is m // SAMPLE_PREFIX_DENOM edges
# (at least 1 — a zero-width prefix on a graph with m < DENOM edges
# would turn every sampling iteration into a no-op that burns the
# budget).  ConnectIt samples neighbours per vertex; an edge-list
# prefix is the order-free analogue and keeps the phase a pure static
# slice of the same arrays.
SAMPLE_PREFIX_DENOM = 4

# k-out/Afforest sampling: how many incident edges each vertex
# contributes to the sample by default (SolveOptions.sampling_k).
DEFAULT_SAMPLING_K = 2

# BFS/low-diameter-decomposition sampling: balls of this radius are
# grown around this many top-degree seed vertices; the sample is every
# edge with an endpoint inside a ball.
BFS_SAMPLE_SEEDS = 16
BFS_SAMPLE_ROUNDS = 4


def sample_prefix_m(n_edges: int) -> int:
    """Static size of the deterministic edge-prefix sample."""
    return max(1, n_edges // SAMPLE_PREFIX_DENOM)


def stable_partition(src: jax.Array, dst: jax.Array, keep: jax.Array
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Stable two-way partition of an edge list into ``[keep | rest]``.

    O(m) via two prefix sums (the ``contract_edges`` trick): keepers land
    at their keep-rank, the rest after the last keeper at their
    rest-rank; both ranks are monotone in position, so relative order
    within each class is preserved.  Returns ``(src', dst', n_keep)``
    with ``n_keep`` an int32 scalar (traced-safe).
    """
    n_keep = jnp.sum(keep).astype(jnp.int32)
    kidx = jnp.cumsum(keep.astype(jnp.int32)) - 1
    ridx = n_keep + jnp.cumsum((~keep).astype(jnp.int32)) - 1
    dest = jnp.where(keep, kidx, ridx).astype(jnp.int32)
    out_s = jnp.zeros_like(src).at[dest].set(src)
    out_d = jnp.zeros_like(dst).at[dest].set(dst)
    return out_s, out_d, n_keep


def _occurrence_rank(x: jax.Array) -> jax.Array:
    """``rank[i]`` = how many earlier positions hold the same value as
    ``x[i]`` — i.e. the edge-list-order index of this occurrence among
    its value's occurrences.  Vectorised: stable argsort groups equal
    values (ties keep list order), a cummax over group starts recovers
    each group's base offset."""
    m = x.shape[0]
    if m == 0:
        return jnp.zeros((0,), jnp.int32)
    order = jnp.argsort(x)                       # stable in jax.numpy
    xs = x[order]
    idx = jnp.arange(m, dtype=jnp.int32)
    starts = jnp.concatenate(
        [jnp.ones((1,), bool), xs[1:] != xs[:-1]])
    group_start = jax.lax.cummax(jnp.where(starts, idx, 0))
    rank_sorted = idx - group_start
    return jnp.zeros((m,), jnp.int32).at[order].set(rank_sorted)


def _prepare_prefix(src, dst, n_vertices, k):
    """The deterministic edge prefix: identity permutation."""
    del n_vertices, k
    return src, dst, jnp.int32(sample_prefix_m(src.shape[0]))


def _prepare_kout(src, dst, n_vertices, k):
    """Afforest/k-out neighbour subgraph: each vertex's first ``k``
    incident edges (in edge-list order, either endpoint) are sampled.

    Sampled edges are stably partitioned to the front; the sample is the
    resulting prefix.  Low-degree vertices contribute everything they
    have, so on bounded-degree graphs (paths, grids with degree <= k)
    the sample is the whole edge list — exactly Afforest's behaviour.
    """
    del n_vertices
    m = src.shape[0]
    if m == 0:
        return src, dst, jnp.int32(0)
    sampled = (_occurrence_rank(src) < k) | (_occurrence_rank(dst) < k)
    out_s, out_d, sample_m = stable_partition(src, dst, sampled)
    # >= 1 whenever edges exist: rank 0 of any endpoint is always sampled
    return out_s, out_d, jnp.maximum(sample_m, jnp.int32(min(1, m)))


def _prepare_bfs(src, dst, n_vertices, k):
    """BFS/low-diameter-decomposition sample: grow balls of radius
    ``BFS_SAMPLE_ROUNDS`` around the ``BFS_SAMPLE_SEEDS`` highest-degree
    vertices; sample every edge with an endpoint in a ball.

    High-degree seeds are where the giant component condenses first, so
    the sampled subgraph gives the post-sampling largest-component
    filter the best target per swept edge.
    """
    del k
    m = src.shape[0]
    if m == 0:
        return src, dst, jnp.int32(0)
    deg = (jnp.zeros((n_vertices,), jnp.int32).at[src].add(1)
           .at[dst].add(1))
    _, seeds = jax.lax.top_k(deg, min(BFS_SAMPLE_SEEDS, n_vertices))
    reached = jnp.zeros((n_vertices,), jnp.int32).at[seeds].set(1)

    def grow(_, r):
        hit = jnp.maximum(r[src], r[dst])
        return r.at[src].max(hit).at[dst].max(hit)

    reached = jax.lax.fori_loop(0, BFS_SAMPLE_ROUNDS, grow, reached)
    sampled = (reached[src] | reached[dst]) > 0
    out_s, out_d, sample_m = stable_partition(src, dst, sampled)
    # the top-degree seed has an incident edge whenever m > 0
    return out_s, out_d, jnp.maximum(sample_m, jnp.int32(min(1, m)))


@dataclasses.dataclass(frozen=True)
class SamplingStrategy:
    """One pluggable sampling phase (ConnectIt's sampling axis).

    ``prepare(src, dst, n_vertices, k) -> (src', dst', sample_m)``
    returns the edge list *permuted* so the sampled edges form the
    leading ``sample_m`` positions (``sample_m`` is an int32 scalar, may
    be traced).  Reducing every sampler to permutation + prefix is the
    soundness argument of DESIGN.md §16: the main loop then treats any
    strategy exactly like the original prefix sampler, and a sampled
    sweep is just a sound min-mapping sweep over fewer edges.
    """

    name: str
    prepare: Callable[[jax.Array, jax.Array, int, int],
                      Tuple[jax.Array, jax.Array, jax.Array]]


_SAMPLING_REGISTRY: Dict[str, SamplingStrategy] = {}


def register_sampling_strategy(strategy: SamplingStrategy
                               ) -> SamplingStrategy:
    _SAMPLING_REGISTRY[strategy.name] = strategy
    return strategy


register_sampling_strategy(SamplingStrategy("prefix", _prepare_prefix))
register_sampling_strategy(SamplingStrategy("kout", _prepare_kout))
register_sampling_strategy(SamplingStrategy("bfs", _prepare_bfs))

# canonical order, used by SolveOptions validation and the bench matrix
SAMPLING_STRATEGIES = ("prefix", "kout", "bfs")


def get_sampling_strategy(name: str) -> SamplingStrategy:
    if name not in _SAMPLING_REGISTRY:
        raise ValueError(
            f"unknown sampling_strategy {name!r}; one of "
            f"{tuple(sorted(_SAMPLING_REGISTRY))}")
    return _SAMPLING_REGISTRY[name]


def prepare_sampling(name: str, src: jax.Array, dst: jax.Array,
                     n_vertices: int, k: int = DEFAULT_SAMPLING_K
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Permute ``(src, dst)`` so the strategy's sample is the leading
    prefix; returns ``(src', dst', sample_m)``."""
    if k < 1:
        raise ValueError(f"sampling k must be >= 1, got {k}")
    return get_sampling_strategy(name).prepare(src, dst, n_vertices, k)


def largest_component_label(L: jax.Array, n_vertices: int) -> jax.Array:
    """Label of the largest *current* intermediate component (device mode).

    The most frequent value of ``L`` — ConnectIt's "skip the largest
    component" target.  O(n) bincount + argmax, run once after the
    sampling phase.
    """
    return jnp.argmax(jnp.bincount(L, length=n_vertices)).astype(L.dtype)


def contract_edges(
    L: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    active_m: jax.Array,
    *,
    only_label: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One contraction step: relabel active edges, retire self-loops.

    Active edges (positions ``< active_m``) are rewritten to their depth-2
    representatives ``(L²[u], L²[v])`` — sound on its own, since the
    representative is in the same component as the vertex — and then
    partitioned (stably, so the sweep order of survivors is preserved)
    into ``[active | retired]``.  ``only_label`` restricts retirement to
    self-loops of that label (the post-sampling largest-component filter);
    ``None`` retires every self-loop of the contraction.

    Returns ``(src', dst', active_m')`` with ``active_m' <= active_m`` —
    retired edges are never re-examined, so the count is monotonically
    non-increasing across compactions.
    """
    m = src.shape[0]
    pos = jnp.arange(m, dtype=active_m.dtype)
    act = pos < active_m
    rs = jnp.where(act, L[L[src]], src)
    rd = jnp.where(act, L[L[dst]], dst)
    if only_label is None:
        retire = rs == rd
    else:
        retire = (rs == only_label) & (rd == only_label)
    retire = retire | ~act
    # Stable two-way partition in O(m) via two prefix sums — replaces the
    # previous stable argsort (O(m log m) and the dominant term of every
    # compaction, ROADMAP open item 1).  Shared with the sampling
    # strategies' sampled-edges-first reorder (stable_partition).
    out_s, out_d, n_keep = stable_partition(rs, rd, ~retire)
    return out_s, out_d, n_keep.astype(active_m.dtype)


def masked_converged_early(
    L: jax.Array, src: jax.Array, dst: jax.Array, active_m: jax.Array
) -> jax.Array:
    """Paper §III-B2 early-convergence predicate over the active prefix.

    Retired edges are inside their components by construction, so only
    the ``active_m``-edge prefix can still violate the predicate; with an
    empty frontier the solve is converged by definition.
    """
    pos = jnp.arange(src.shape[0], dtype=active_m.dtype)
    lw, lv = L[src], L[dst]
    bad = (lw != lv) | (lw != L[lw]) | (lv != L[lv])
    return ~jnp.any(bad & (pos < active_m))


def frontier_limit(it: jax.Array, active_m: jax.Array, sample_m: jax.Array,
                   sampling: int) -> jax.Array:
    """Per-iteration sweep bound: sample prefix first, live frontier after.

    Shared by the single-device engine and the per-shard ``shard_map``
    step (``connectivity.distributed``) so the two schedules cannot drift.
    """
    if sampling > 0:
        return jnp.where(it < sampling, jnp.minimum(sample_m, active_m),
                         active_m)
    return active_m


def gate_sampling_done(done: jax.Array, it: jax.Array,
                       sampling: int) -> jax.Array:
    """Pass-through: convergence may fire during the sampling phase.

    The old gate (``done & (it >= sampling)``) held convergence hostage
    to the full sampling budget on the reasoning that "the sample sees
    only part of the graph" — but :func:`masked_converged_early` checks
    the §III-B2 predicate over the *entire* active prefix, not just the
    swept sample, so ``done`` already certifies the full fixed point.
    The gate only burned ``sampling - it`` no-op iterations on graphs
    that converge during sampling (an already-connected warm start, or
    an edgeless graph whose every sampled sweep is empty).  Kept as a
    named seam so the masked, staged, and distributed engines document
    the shared rationale at their one convergence site.
    """
    del it, sampling
    return done


def apply_compaction(
    L: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    active_m: jax.Array,
    it1: jax.Array,
    *,
    sampling: int,
    compact_every: int,
    n_vertices: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The compaction schedule for iteration ``it1`` (post-increment).

    The largest-component filter fires once, right after the sampling
    phase; general contraction fires every ``compact_every`` iterations
    thereafter.  One ``lax.switch`` keeps the O(m log m) partition out of
    non-compacting iterations — on the *unbatched* path; under ``vmap``
    (``solve_batch``) the batched branch index lowers the switch to
    compute-all-and-select, so batched adaptive lanes pay the partition
    every iteration (fleets of small graphs, so the sort is small too —
    but batched adaptive is a counter/TPU win, not a CPU wall-time one).
    Shared by the single-device engine and the per-shard distributed
    step.
    """
    do_lc = (it1 == sampling) if sampling > 0 else jnp.array(False)
    if compact_every > 0:
        do_gen = (it1 > sampling) & ((it1 - sampling) % compact_every == 0)
    else:
        do_gen = jnp.array(False)

    def no_compact(args):
        _, e_src, e_dst, am = args
        return e_src, e_dst, am

    def compact_largest(args):
        lbl, e_src, e_dst, am = args
        c_hat = largest_component_label(lbl, n_vertices)
        return contract_edges(lbl, e_src, e_dst, am, only_label=c_hat)

    def compact_general(args):
        lbl, e_src, e_dst, am = args
        return contract_edges(lbl, e_src, e_dst, am)

    idx = jnp.where(do_lc, 1, jnp.where(do_gen, 2, 0))
    return jax.lax.switch(idx, [no_compact, compact_largest, compact_general],
                          (L, src, dst, active_m))


def compress_full(L: jax.Array) -> jax.Array:
    """Pointer-jump to the star-forest fixed point.

    The classic (uncompacted) loop ends one jump from a star forest, but
    vertices retired by contraction hang off pointer *chains* whose depth
    is unbounded by the convergence predicate (only active edges are
    checked), so the adaptive path compresses to the fixed point — the
    O(log depth) rounds run once, after the main loop.
    """
    return jax.lax.while_loop(
        lambda lbl: ~lab.is_star_forest(lbl),
        lambda lbl: lab.pointer_jump(lbl, rounds=1),
        L,
    )


class FrontierState(NamedTuple):
    """Loop state of the work-adaptive fixpoint."""

    L: jax.Array
    it: jax.Array          # int32 iteration counter
    done: jax.Array        # bool, on device
    src: jax.Array         # [m] edge sources, [active | retired] layout
    dst: jax.Array         # [m] edge destinations, same layout
    active_m: jax.Array    # int32 count of live prefix edges
    visited: jax.Array     # float32 cumulative edges swept (perf counter)


def adaptive_fixpoint(
    src: jax.Array,
    dst: jax.Array,
    L0: jax.Array,
    step: Callable[[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array],
                   jax.Array],
    *,
    n_vertices: int,
    sampling: int,
    compact_every: int,
    max_iters: int,
    active_m0: Optional[jax.Array] = None,
    sample_m0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Run ``step`` to the connectivity fixed point, work-adaptively.

    Args:
      src, dst: int32[m] edge list (each undirected edge once).
      L0: int32[n] initial labels (identity or a warm start).
      step: ``step(L, it, src, dst, limit) -> L_new`` — one sweep over the
        first ``limit`` edges of ``(src, dst)``; backends realise the
        limit as masked tiles (XLA) or skipped grid steps (Pallas).
      n_vertices: static vertex count.
      sampling: number of prefix-sample iterations (static, >= 0).
      compact_every: contraction cadence in iterations (static; 0 = only
        the post-sampling largest-component filter, if any).
      max_iters: iteration budget (static).
      active_m0: initial live-prefix count (traced int32 scalar; default
        the full ``m``).  Callers passing fewer assert the suffix is
        already intra-component under ``L0`` — e.g. self-loop padding, or
        the streaming engine's pre-retired padded tail
        (``connectivity.streaming``) — so it is never swept *and never
        counted* in ``edges_visited``.
      sample_m0: sample-prefix width (traced int32 scalar; default the
        deterministic ``sample_prefix_m``).  A non-default
        :class:`SamplingStrategy` passes the width of its sampled-first
        permutation here (``prepare_sampling``).

    Returns:
      ``(labels, iterations, converged, active_m, edges_visited)``.
      ``edges_visited`` is a float32 counter (documented approximate above
      2**24 per-increment precision; exact for every suite graph here).
    """
    m = src.shape[0]
    sample_m = (jnp.int32(sample_prefix_m(m)) if sample_m0 is None
                else jnp.asarray(sample_m0, jnp.int32))

    def cond(s: FrontierState):
        return (~s.done) & (s.it < max_iters)

    def body(s: FrontierState):
        limit = frontier_limit(s.it, s.active_m, sample_m, sampling)
        L = step(s.L, s.it, s.src, s.dst, limit)
        visited = s.visited + limit.astype(jnp.float32)
        done = gate_sampling_done(
            masked_converged_early(L, s.src, s.dst, s.active_m),
            s.it, sampling)
        it1 = s.it + 1
        src2, dst2, active2 = apply_compaction(
            L, s.src, s.dst, s.active_m, it1, sampling=sampling,
            compact_every=compact_every, n_vertices=n_vertices)
        return FrontierState(L=L, it=it1, done=done, src=src2, dst=dst2,
                             active_m=active2, visited=visited)

    init = FrontierState(
        L=L0,
        it=jnp.int32(0),
        done=jnp.array(False),
        src=src,
        dst=dst,
        active_m=(jnp.int32(m) if active_m0 is None
                  else jnp.asarray(active_m0, jnp.int32)),
        visited=jnp.float32(0),
    )
    out = jax.lax.while_loop(cond, body, init)
    return compress_full(out.L), out.it, out.done, out.active_m, out.visited
