"""``repro.connectivity`` — the unified public connectivity API.

One facade over every algorithm family the reproduction implements::

    from repro.connectivity import solve, SolveOptions

    result = solve(graph)                                # Contour C-2
    result = solve(graph, SolveOptions(algorithm="fastsv"))
    result.n_components, result.component_sizes()
    result.same_component(u, v)

Warm-start / incremental::

    bigger = graph.add_edges(new_src, new_dst)
    result2 = solve(bigger, warm_start=result)

Batched multi-graph::

    batch = solve_batch([g1, g2, g3])
    for r in batch.unstack(): ...

Streaming (edge micro-batches, per-batch work tracks the delta)::

    eng = StreamingConnectivity(n_vertices=n)
    eng.ingest(src_batch, dst_batch)
    eng.same_component(u, v)            # O(1), no re-solve
    final = eng.snapshot()

Out-of-core (edges stream from host memory; device holds O(n) labels
plus one chunk — problem size decoupled from device memory)::

    chunks = rmat_chunks(scale=26, edge_factor=16, chunk_edges=1 << 20)
    result = solve_chunks(chunks)       # never materialises all edges

The old per-algorithm entry points in ``repro.core`` remain as deprecation
shims; new code should import from here (or ``from repro import solve``).
"""
from repro.connectivity.options import SolveOptions
from repro.connectivity.result import ComponentResult
from repro.connectivity.registry import (
    SolverSpec,
    get_solver,
    list_solvers,
    register_solver,
    solver_specs,
)
from repro.connectivity import solvers as _solvers  # registers the families
from repro.connectivity.solve import solve
from repro.connectivity.batch import solve_batch, stack_graphs
from repro.connectivity.contour import VARIANTS
from repro.connectivity.streaming import StreamingConnectivity
from repro.connectivity.oocore import OutOfCoreContraction, solve_chunks
from repro.connectivity.resilience import (
    RecoveryStats,
    oocore_with_recovery,
    resilient_distributed_contour,
    stream_with_recovery,
)
from repro.graphs.structs import Graph
from repro.runtime.recovery import FaultInjector, ShardLossFault, \
    SimulatedFault

__all__ = [
    "ComponentResult",
    "FaultInjector",
    "Graph",
    "OutOfCoreContraction",
    "RecoveryStats",
    "ShardLossFault",
    "SimulatedFault",
    "SolveOptions",
    "SolverSpec",
    "StreamingConnectivity",
    "VARIANTS",
    "get_solver",
    "list_solvers",
    "register_solver",
    "oocore_with_recovery",
    "resilient_distributed_contour",
    "solve",
    "solve_batch",
    "solve_chunks",
    "solver_specs",
    "stack_graphs",
    "stream_with_recovery",
]
