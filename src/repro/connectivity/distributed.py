"""Distributed (multi-pod) Contour connectivity via ``shard_map``.

Mapping of the paper's Arkouda/Chapel distribution onto a TPU mesh
(DESIGN.md §3/§4):

* the edge list is block-sharded across the data-parallel mesh axes
  (``pod`` × ``data``); padding uses self-loop edges which are no-ops for
  every min-mapping operator;
* the label array ``L`` is replicated per device (n × 4 B — even a
  2³⁰-vertex graph is a 4 GB replica, fine for 16 GB HBM chips; an
  all-to-all label-sharded variant is the documented scale-out path);
* each global round: every device relaxes its local edge shard (through
  the ``kernels.contour_mm`` backend dispatch — XLA scatter-min on CPU
  hosts, the label-blocked Pallas kernel on TPU) and compresses, then one
  ``lax.pmin`` all-reduce merges label arrays — the collective is the
  *only* cross-device traffic;
* convergence: the paper's early-convergence predicate evaluated on local
  edges, AND-reduced across devices.

Beyond-paper optimisation (§Perf, hillclimb #3): ``local_rounds > 1`` runs
k relax+compress rounds on the local shard between all-reduces.  Labels
decrease monotonically toward the same fixed point regardless of staleness,
so correctness is unaffected, while collective bytes per convergence drop
by ~k× on diameter-bound graphs.

``init_labels`` warm-starts the replicated label array from a previous
solve — the only change to the round structure is the initial replica.

``sampling`` / ``compact_every`` enable the work-adaptive frontier
contraction (``repro.connectivity.frontier``) *per shard*: each device
samples a prefix of its local edge shard, retires its local edges into
the largest component after the sampling phase, and periodically contracts
its own active prefix — the shard-local edge arrays and ``active_m``
counts are loop state, so the schedule adds no collective traffic (the
per-round ``pmin`` stays the only cross-device exchange; the counted
``edges_visited`` is ``psum``-reduced inside the existing round).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import jax_compat
from repro.connectivity import frontier as fr
from repro.connectivity import minmap as lab
from repro.graphs.structs import Graph
from repro.kernels.contour_mm import ops as mm_ops


class _State(NamedTuple):
    L: jax.Array
    it: jax.Array
    done: jax.Array


class _FrontierShardState(NamedTuple):
    L: jax.Array
    it: jax.Array
    done: jax.Array
    src: jax.Array         # local shard, [active | retired] layout
    dst: jax.Array
    active_m: jax.Array    # live count of this shard's prefix
    visited: jax.Array     # float32, psum-reduced (identical on all shards)


def _round_up(x: int, k: int) -> int:
    return (x + k - 1) // k * k


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "edge_axes", "local_rounds", "max_iters",
                     "async_compress", "backend", "plan", "sampling",
                     "compact_every"),
)
def _distributed_fixpoint(
    src: jax.Array,
    dst: jax.Array,
    L0: jax.Array,
    n_active: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    edge_axes: tuple,
    local_rounds: int,
    max_iters: int,
    async_compress: int,
    backend: str,
    plan=None,
    sampling: int,
    compact_every: int,
):
    """Module-level jitted core of :func:`distributed_contour`.

    Module-level so the jit cache survives across calls: a streaming
    engine re-invoking the mesh path per micro-batch (same shapes, same
    statics) compiles once, not once per batch.  ``n_active`` is the real
    (pre-padding) edge count; padding is never counted in
    ``edges_visited`` on either schedule — the dense branch scales its
    ``iterations x m`` count by it, the adaptive branch clamps each
    shard's initial live prefix to its slice of it (matching the
    single-device ``active_m0`` path).
    """
    axis = tuple(edge_axes)
    n_shards = 1
    for a in axis:
        n_shards *= mesh.shape[a]
    m = src.shape[0]
    n = L0.shape[0]
    m_loc = m // n_shards
    adaptive = sampling > 0 or compact_every > 0

    edge_spec = P(axis if len(axis) > 1 else axis[0])
    lbl_spec = P()  # replicated

    # per-shard tile parameters come from the resolved execution plan when
    # the facade threads one down (None = the heuristic tables, as before)
    tile_kw = {}
    if plan is not None:
        tile_kw = dict(block_edges=plan.block_edges,
                       label_block=plan.label_block,
                       chunk_updates=plan.chunk_updates,
                       interpret=plan.interpret,
                       fuse=getattr(plan, "fuse_relabel", False))

    def body(src_in, dst_in, L0, n_act):
        def relax_rounds(L, src_loc, dst_loc, limit):
            for _ in range(local_rounds):
                L = mm_ops.mm_relax_backend(L, src_loc, dst_loc, order=2,
                                            backend=backend,
                                            edge_limit=limit, **tile_kw)
                L = lab.pointer_jump(L, rounds=async_compress)
            # the one collective of the round: elementwise min across shards
            return jax.lax.pmin(L, axis)

        def all_shards_ok(ok_local):
            return jnp.bool_(jax.lax.pmin(ok_local.astype(jnp.int32), axis))

        if not adaptive:
            def cond(s: _State):
                return (~s.done) & (s.it < max_iters)

            def step(s: _State):
                L = relax_rounds(s.L, src_in, dst_in, None)
                ok = all_shards_ok(lab.converged_early(L, src_in, dst_in))
                return _State(L=L, it=s.it + 1, done=ok)

            out = jax.lax.while_loop(
                cond, step,
                _State(L=L0, it=jnp.int32(0), done=jnp.array(False)))
            # dense sweeps physically touch the whole padded array (the
            # self-loops are no-ops), but the counter reports real edges
            # only — same contract as the adaptive branch
            visited = (out.it.astype(jnp.float32) * local_rounds
                       * n_act.astype(jnp.float32))
            return out.L, out.it, out.done, visited

        sample_m = jnp.int32(fr.sample_prefix_m(m_loc))

        # this shard's slice of the real-edge prefix: the global layout is
        # [real | padding] and P(axis) block-shards contiguously with the
        # first axis major, so shard i holds [i*m_loc, (i+1)*m_loc)
        shard_idx = jnp.int32(0)
        for a in axis:
            shard_idx = shard_idx * mesh.shape[a] + jax.lax.axis_index(a)
        active0 = jnp.clip(n_act - shard_idx * m_loc, 0,
                           m_loc).astype(jnp.int32)

        def cond(s: _FrontierShardState):
            return (~s.done) & (s.it < max_iters)

        def step(s: _FrontierShardState):
            limit = fr.frontier_limit(s.it, s.active_m, sample_m, sampling)
            L = relax_rounds(s.L, s.src, s.dst, limit)
            visited = s.visited + local_rounds * jax.lax.psum(
                limit.astype(jnp.float32), axis)
            ok = fr.gate_sampling_done(
                all_shards_ok(
                    fr.masked_converged_early(L, s.src, s.dst, s.active_m)),
                s.it, sampling)
            it1 = s.it + 1
            # L is replicated post-pmin, so every shard agrees on the
            # largest component and contracts its own edge shard against
            # the same schedule (shared with the single-device engine)
            src2, dst2, active2 = fr.apply_compaction(
                L, s.src, s.dst, s.active_m, it1, sampling=sampling,
                compact_every=compact_every, n_vertices=n)
            return _FrontierShardState(L=L, it=it1, done=ok, src=src2,
                                       dst=dst2, active_m=active2,
                                       visited=visited)

        out = jax.lax.while_loop(
            cond, step,
            _FrontierShardState(L=L0, it=jnp.int32(0), done=jnp.array(False),
                                src=src_in, dst=dst_in,
                                active_m=active0,
                                visited=jnp.float32(0)))
        return fr.compress_full(out.L), out.it, out.done, out.visited

    return jax_compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(edge_spec, edge_spec, lbl_spec, lbl_spec),
        out_specs=(lbl_spec, lbl_spec, lbl_spec, lbl_spec),
    )(src, dst, L0, n_active)


def distributed_contour(
    graph: Graph,
    mesh: jax.sharding.Mesh,
    *,
    edge_axes: Sequence[str] = ("data",),
    local_rounds: int = 1,
    max_iters: int = 10_000,
    async_compress: int = 1,
    backend: str = "xla",
    plan=None,
    init_labels: Optional[jax.Array] = None,
    sampling: int = 0,
    compact_every: int = 0,
    n_active: Optional[int] = None,
):
    """Run Contour C-2 with edges sharded over ``edge_axes`` of ``mesh``.

    Returns ``(labels, n_global_rounds, converged, edges_visited)``.
    Works on any mesh whose
    ``edge_axes`` product divides the (padded) edge count — the production
    meshes in ``repro.launch.mesh`` and the multi-device CPU test mesh
    alike.  ``backend`` selects the per-shard sweep realisation through
    the shared ``kernels.contour_mm`` dispatch layer ("xla" scatter-min by
    default; "pallas_blocked"/"auto" for the label-blocked TPU kernel).

    ``n_active`` overrides the real-edge count when the caller's graph is
    itself already padded with trailing self-loops (the streaming engine's
    pow2 buckets): edges past it are born retired in the adaptive
    schedule and never counted in ``edges_visited``.
    """
    if sampling < 0 or compact_every < 0:
        raise ValueError("sampling and compact_every must be >= 0, got "
                         f"{sampling} / {compact_every}")
    if n_active is None:
        n_active = graph.n_edges
    elif not 0 <= n_active <= graph.n_edges:
        raise ValueError(f"n_active={n_active} outside [0, "
                         f"{graph.n_edges}]")
    n_shards = 1
    for a in edge_axes:
        n_shards *= mesh.shape[a]
    g = graph.pad_edges(_round_up(max(graph.n_edges, n_shards), n_shards))
    axis = tuple(edge_axes)
    edge_spec = P(axis if len(axis) > 1 else axis[0])
    lbl_spec = P()

    src = jax.device_put(g.src, NamedSharding(mesh, edge_spec))
    dst = jax.device_put(g.dst, NamedSharding(mesh, edge_spec))
    L0 = jax.device_put(
        lab.resolve_init_labels(init_labels, g.n_vertices, g.src.dtype),
        NamedSharding(mesh, lbl_spec))
    return _distributed_fixpoint(
        src, dst, L0, jnp.int32(n_active),
        mesh=mesh, edge_axes=axis, local_rounds=local_rounds,
        max_iters=max_iters, async_compress=async_compress, backend=backend,
        plan=plan, sampling=sampling, compact_every=compact_every)


@functools.partial(
    jax.jit,
    static_argnames=("n_vertices", "mesh", "edge_axes", "local_rounds",
                     "max_iters", "check_every", "backend"),
)
def distributed_contour_step_fn(
    src,
    dst,
    n_vertices: int,
    mesh: jax.sharding.Mesh,
    edge_axes: tuple = ("data",),
    local_rounds: int = 1,
    max_iters: int = 10_000,
    check_every: int = 1,
    backend: str = "xla",
):
    """jit-compilable entry used by the dry-run/roofline harness.

    Identical math to :func:`distributed_contour`, but takes pre-sharded
    arrays so it can be ``.lower().compile()``-ed against
    ``ShapeDtypeStruct`` inputs on the production mesh.

    ``check_every`` is the beyond-paper convergence-check cadence: the
    paper's early check (§III-B2) gathers L at every edge endpoint each
    iteration (an O(m) gather + a scalar all-reduce); checking every k-th
    round removes that traffic from the other k-1 rounds at the cost of
    up to k-1 extra (cheap) relaxation rounds after the fixed point.
    """
    axis = tuple(edge_axes)
    edge_spec = P(axis if len(axis) > 1 else axis[0])

    def body(src_loc, dst_loc):
        L0 = jnp.arange(n_vertices, dtype=src_loc.dtype)

        def cond(s: _State):
            return (~s.done) & (s.it < max_iters)

        def step(s: _State):
            L = s.L
            for _ in range(local_rounds):
                L = mm_ops.mm_relax_backend(L, src_loc, dst_loc, order=2,
                                            backend=backend)
                L = lab.pointer_jump(L, rounds=1)
            L = jax.lax.pmin(L, axis)
            if check_every <= 1:
                ok_local = lab.converged_early(L, src_loc, dst_loc)
                ok = jnp.bool_(jax.lax.pmin(ok_local.astype(jnp.int32), axis))
            else:
                def do_check(_):
                    ok_local = lab.converged_early(L, src_loc, dst_loc)
                    return jnp.bool_(
                        jax.lax.pmin(ok_local.astype(jnp.int32), axis))
                ok = jax.lax.cond(
                    (s.it + 1) % check_every == 0, do_check,
                    lambda _: jnp.array(False), operand=None)
            return _State(L=L, it=s.it + 1, done=ok)

        out = jax.lax.while_loop(
            cond, step, _State(L=L0, it=jnp.int32(0), done=jnp.array(False))
        )
        return out.L, out.it

    return jax_compat.shard_map(
        body, mesh=mesh, in_specs=(edge_spec, edge_spec), out_specs=(P(), P())
    )(src, dst)
