"""Graph generators mirroring the paper's benchmark families (Table I).

The paper benchmarks real-world power-law graphs (SNAP/SuiteSparse) and
synthetic Delaunay triangulations.  Offline we generate statistically
matching families:

* ``path`` / ``cycle`` / ``star`` / ``caterpillar`` — extreme-diameter and
  extreme-degree stress shapes used by the convergence proofs (Lemma 1-3).
* ``grid2d`` — planar, bounded-degree, large-diameter: the stand-in for the
  paper's ``delaunay_n*`` family (Delaunay triangulations are planar with
  average degree < 6; an 8-neighbour grid matches that regime).
* ``rmat`` — power-law degree graphs standing in for the SNAP social
  networks (com-orkut, soc-LiveJournal1, ...).
* ``erdos_renyi`` — low-diameter uniformly random graphs.
* ``components_mix`` — disjoint unions, exercising multi-component
  convergence (Theorem 1 is in terms of the *max component* diameter).

Everything returns a canonicalised :class:`repro.graphs.Graph`.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.structs import Graph, canonicalize_edges


def _finish(src, dst, n, drop_self_loops=True) -> Graph:
    src, dst = canonicalize_edges(src, dst, n, drop_self_loops=drop_self_loops)
    return Graph.from_numpy(src, dst, n)


def path(n: int, seed: int = 0, shuffle_ids: bool = True) -> Graph:
    """Path graph; with shuffled vertex ids (worst case for label spread)."""
    rng = np.random.default_rng(seed)
    ids = rng.permutation(n) if shuffle_ids else np.arange(n)
    return _finish(ids[:-1], ids[1:], n)


def cycle(n: int, seed: int = 0, shuffle_ids: bool = True) -> Graph:
    rng = np.random.default_rng(seed)
    ids = rng.permutation(n) if shuffle_ids else np.arange(n)
    src = ids
    dst = np.roll(ids, -1)
    return _finish(src, dst, n)


def star(n: int, seed: int = 0) -> Graph:
    """Star: hub 0 connected to all others (diameter 2, max degree n-1)."""
    rng = np.random.default_rng(seed)
    hub = int(rng.integers(n))
    spokes = np.setdiff1d(np.arange(n), [hub])
    return _finish(np.full(n - 1, hub), spokes, n)


def caterpillar(spine: int, legs_per_node: int, seed: int = 0) -> Graph:
    """Long spine with pendant legs: long diameter + high local fanout."""
    n = spine * (1 + legs_per_node)
    spine_ids = np.arange(spine)
    src = [spine_ids[:-1]]
    dst = [spine_ids[1:]]
    leg = spine
    for s in range(spine):
        for _ in range(legs_per_node):
            src.append(np.array([s]))
            dst.append(np.array([leg]))
            leg += 1
    return _finish(np.concatenate(src), np.concatenate(dst), n)


def grid2d(rows: int, cols: int, diagonals: bool = True, seed: int = 0) -> Graph:
    """2-D grid, optionally with one diagonal per cell (Delaunay-like)."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    src = [idx[:, :-1].ravel(), idx[:-1, :].ravel()]
    dst = [idx[:, 1:].ravel(), idx[1:, :].ravel()]
    if diagonals:
        src.append(idx[:-1, :-1].ravel())
        dst.append(idx[1:, 1:].ravel())
    return _finish(np.concatenate(src), np.concatenate(dst), rows * cols)


def delaunay_like(scale: int, seed: int = 0) -> Graph:
    """Stand-in for the paper's delaunay_n{scale}: 2^scale vertices on a grid."""
    n = 1 << scale
    rows = 1 << (scale // 2)
    cols = n // rows
    return grid2d(rows, cols, diagonals=True, seed=seed)


def rmat(scale: int, edge_factor: int = 8, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> Graph:
    """RMAT power-law generator (Graph500 parameters by default)."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab = a + b
    a_norm = a / ab if ab > 0 else 0.5
    c_norm = c / (1.0 - ab) if ab < 1 else 0.5
    for bit in range(scale):
        go_right_rows = rng.random(m) > ab  # choose bottom half of matrix
        p_col = np.where(go_right_rows, c_norm, a_norm)
        go_right_cols = rng.random(m) > p_col
        src |= go_right_rows.astype(np.int64) << bit
        dst |= go_right_cols.astype(np.int64) << bit
    # permute ids so degree isn't correlated with vertex id
    perm = rng.permutation(n)
    return _finish(perm[src], perm[dst], n)


class EdgeChunks:
    """Seekable host-side edge stream: pow2 chunks, never the full list.

    The out-of-core contract (``repro.connectivity.oocore``): ``chunk(k)``
    is a **pure function of k** — chunk ``k`` can be (re)generated at any
    time without touching any other chunk, which is what makes the
    stream (a) double-bufferable without a full materialisation and
    (b) replayable after a crash (round-boundary checkpoints store only
    labels + a survivor manifest; round 0 re-reads the source).

    Concrete sources subclass and implement :meth:`chunk`; every chunk
    except possibly the last has exactly ``chunk_edges`` (a power of two)
    edges.  Duplicate edges and self-loops are harmless to every
    min-mapping solver, so chunk sources need no global canonicalisation
    — which would require materialising the full list.
    """

    def __init__(self, n_vertices: int, n_edges: int, chunk_edges: int):
        if chunk_edges < 1 or chunk_edges & (chunk_edges - 1):
            raise ValueError(
                f"chunk_edges must be a positive power of two, got "
                f"{chunk_edges}")
        self.n_vertices = int(n_vertices)
        self.n_edges = int(n_edges)
        self.chunk_edges = int(chunk_edges)

    @property
    def n_chunks(self) -> int:
        return -(-self.n_edges // self.chunk_edges)

    def chunk_size(self, k: int) -> int:
        """Real (unpadded) edge count of chunk ``k``."""
        lo = k * self.chunk_edges
        return min(self.chunk_edges, self.n_edges - lo)

    def chunk(self, k: int):
        """Return ``(src, dst)`` int64 NumPy arrays for chunk ``k``."""
        raise NotImplementedError

    def __iter__(self):
        return (self.chunk(k) for k in range(self.n_chunks))

    def materialize(self) -> Graph:
        """Concatenate every chunk into an in-core :class:`Graph`.

        The *in-core oracle* side of the out-of-core equivalence gate —
        only call it on graphs that actually fit in memory.
        """
        srcs, dsts = zip(*self) if self.n_chunks else ((), ())
        return Graph.from_numpy(
            np.concatenate(srcs) if srcs else np.zeros(0, np.int64),
            np.concatenate(dsts) if dsts else np.zeros(0, np.int64),
            self.n_vertices)


class ArrayChunks(EdgeChunks):
    """View host-resident edge arrays as an :class:`EdgeChunks` stream."""

    def __init__(self, src, dst, n_vertices: int, chunk_edges: int):
        src = np.asarray(src)
        dst = np.asarray(dst)
        if src.shape != dst.shape or src.ndim != 1:
            raise ValueError(
                f"src/dst must be equal-length 1-D, got {src.shape} vs "
                f"{dst.shape}")
        super().__init__(n_vertices, src.shape[0], chunk_edges)
        self._src, self._dst = src, dst

    def chunk(self, k: int):
        sl = slice(k * self.chunk_edges, (k + 1) * self.chunk_edges)
        return self._src[sl], self._dst[sl]


class RmatChunks(EdgeChunks):
    """RMAT power-law edges generated chunk-by-chunk, never all at once.

    Same recursive-matrix recursion as :func:`rmat`, but each pow2 block
    of edges is generated by its own ``default_rng([seed, k])`` stream,
    so ``chunk(k)`` is a pure function of ``k`` (seekable — the
    out-of-core replay/checkpoint contract) and the peak host memory of
    generation is O(chunk), independent of the total edge count.  In
    place of the full generator's O(n) id-permutation (which would
    materialise an n-sized array per chunk call), ids are decorrelated
    from degree by a fixed odd-multiplier affine bijection on [0, 2^scale)
    — bijective because the multiplier is odd and n is a power of two.
    """

    # odd multiplier of the id-scrambling bijection (a Weyl/Knuth-style
    # multiplicative constant, truncated per scale)
    _SCRAMBLE_MULT = 0x9E3779B1

    def __init__(self, scale: int, edge_factor: int = 8, seed: int = 0,
                 chunk_edges: int = 1 << 14,
                 a: float = 0.57, b: float = 0.19, c: float = 0.19):
        n = 1 << scale
        super().__init__(n, n * edge_factor, chunk_edges)
        self.scale = int(scale)
        self.seed = int(seed)
        self._abc = (float(a), float(b), float(c))

    def _scramble(self, ids: np.ndarray) -> np.ndarray:
        mask = self.n_vertices - 1
        mult = (self._SCRAMBLE_MULT | 1) & mask if self.scale < 32 else \
            (self._SCRAMBLE_MULT | 1)
        return ((ids * mult) + self.seed) & mask

    def chunk(self, k: int):
        if not 0 <= k < self.n_chunks:
            raise IndexError(f"chunk {k} out of range "
                             f"[0, {self.n_chunks})")
        m = self.chunk_size(k)
        rng = np.random.default_rng([self.seed, k])
        a, b, c = self._abc
        ab = a + b
        a_norm = a / ab if ab > 0 else 0.5
        c_norm = c / (1.0 - ab) if ab < 1 else 0.5
        src = np.zeros(m, dtype=np.int64)
        dst = np.zeros(m, dtype=np.int64)
        for bit in range(self.scale):
            go_right_rows = rng.random(m) > ab
            p_col = np.where(go_right_rows, c_norm, a_norm)
            go_right_cols = rng.random(m) > p_col
            src |= go_right_rows.astype(np.int64) << bit
            dst |= go_right_cols.astype(np.int64) << bit
        return self._scramble(src), self._scramble(dst)


def rmat_chunks(scale: int, edge_factor: int = 8, seed: int = 0,
                chunk_edges: int = 1 << 14, **kwargs) -> RmatChunks:
    """Chunk-iterator form of :func:`rmat` (see :class:`RmatChunks`)."""
    return RmatChunks(scale, edge_factor, seed, chunk_edges, **kwargs)


def star_forest_chunks(k: int = 16, b: int = 1024) -> ArrayChunks:
    """Disjoint star forest that genuinely needs >= 2 out-of-core rounds.

    ``k`` stars of ``b`` edges; star ``i`` owns the contiguous id block
    ``[i*(b+1), (i+1)*(b+1))`` with the hub at the block's *top* id, so
    every edge of a chunk scatter-mins into the same hub cell — one
    surviving write per sweep.  With ``chunk_edges=b`` and
    ``oocore_local_iters=1`` round 0 retires only ~1 edge per star,
    forcing a genuine second round (most natural graphs collapse in one
    round because the sequential chunk fold accumulates global label
    state, like a union-find pass).  The adversarial source behind the
    ``multiround`` gate row in ``BENCH_connectivity.json``.
    """
    n = k * (b + 1)
    src = np.empty(k * b, np.int64)
    dst = np.empty(k * b, np.int64)
    for i in range(k):
        base = i * (b + 1)
        src[i * b:(i + 1) * b] = base + b            # the hub
        dst[i * b:(i + 1) * b] = np.arange(base, base + b)
    return ArrayChunks(src, dst, n, b)


def erdos_renyi(n: int, avg_degree: float = 8.0, seed: int = 0) -> Graph:
    m = int(n * avg_degree / 2)
    rng = np.random.default_rng(seed)
    return _finish(rng.integers(0, n, m), rng.integers(0, n, m), n)


def random_tree(n: int, seed: int = 0) -> Graph:
    """Uniform attachment tree: each vertex i>0 attaches to a random j<i."""
    rng = np.random.default_rng(seed)
    parents = (rng.random(n - 1) * np.arange(1, n)).astype(np.int64)
    perm = rng.permutation(n)
    return _finish(perm[np.arange(1, n)], perm[parents], n)


def components_mix(parts, seed: int = 0) -> Graph:
    """Disjoint union of graphs (vertex ids offset), plus isolated vertices.

    Args:
      parts: list of Graph
    """
    rng = np.random.default_rng(seed)
    offset = 0
    srcs, dsts = [], []
    for g in parts:
        s, d, n = g.to_numpy()
        srcs.append(s.astype(np.int64) + offset)
        dsts.append(d.astype(np.int64) + offset)
        offset += n
    n_total = offset + int(rng.integers(0, 4))  # a few isolated vertices
    return _finish(np.concatenate(srcs), np.concatenate(dsts), n_total)


def paper_suite(small: bool = True):
    """The benchmark suite used by ``benchmarks/``: name -> Graph.

    ``small=True`` keeps the suite CPU-friendly; ``small=False`` scales up
    toward the paper's sizes (still bounded for a single host).
    """
    k = 1 if small else 4
    suite = {
        "path_64k": path(65_536 * k, seed=1),
        "cycle_64k": cycle(65_536 * k, seed=2),
        "star_64k": star(65_536 * k, seed=3),
        "caterpillar_16k": caterpillar(16_384 * k, 3, seed=4),
        "grid_256x256": grid2d(256 * k, 256, diagonals=True),
        "delaunay_n16": delaunay_like(16 if small else 18),
        "delaunay_n18": delaunay_like(18 if small else 20),
        "rmat_16": rmat(16 if small else 18, edge_factor=8, seed=5),
        "rmat_18": rmat(18 if small else 20, edge_factor=8, seed=6),
        "er_100k": erdos_renyi(100_000 * k, avg_degree=8.0, seed=7),
        "tree_100k": random_tree(100_000 * k, seed=8),
        "mix_3comp": components_mix(
            [path(20_000, seed=9), rmat(14, seed=10), grid2d(128, 128)], seed=11
        ),
    }
    return suite
