"""Edge-list graph structure used by every connectivity algorithm.

The Contour paper operates on an undirected edge list ``E`` plus a label
array ``L``.  We keep the same representation: two int32 arrays ``src`` and
``dst`` of equal length ``m`` (each undirected edge stored once) plus the
static vertex count ``n``.  The struct is a registered pytree so it can be
passed straight through ``jax.jit`` / ``shard_map``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected graph as an edge list.

    Attributes:
      src: int32[m] edge sources.
      dst: int32[m] edge destinations.
      n_vertices: static python int, number of vertices (ids are 0..n-1).
    """

    src: jax.Array
    dst: jax.Array
    n_vertices: int

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.src, self.dst), self.n_vertices

    @classmethod
    def tree_unflatten(cls, aux, children):
        src, dst = children
        return cls(src=src, dst=dst, n_vertices=aux)

    # -- convenience -----------------------------------------------------
    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    def symmetrized(self) -> "Graph":
        """Return a graph with both edge directions materialised."""
        return Graph(
            src=jnp.concatenate([self.src, self.dst]),
            dst=jnp.concatenate([self.dst, self.src]),
            n_vertices=self.n_vertices,
        )

    @classmethod
    def from_numpy(cls, src: np.ndarray, dst: np.ndarray, n_vertices: int) -> "Graph":
        return cls(
            src=jnp.asarray(src, dtype=jnp.int32),
            dst=jnp.asarray(dst, dtype=jnp.int32),
            n_vertices=int(n_vertices),
        )

    def to_numpy(self) -> Tuple[np.ndarray, np.ndarray, int]:
        return np.asarray(self.src), np.asarray(self.dst), self.n_vertices

    def add_edges(self, src, dst, n_vertices: int = None) -> "Graph":
        """Return a graph with the given edges appended (incremental use).

        ``n_vertices`` may grow the vertex set at the same time; combined
        with ``repro.connectivity.solve(..., warm_start=prev_result)``
        this is the batch-incremental update path — labels from the
        previous solve stay a valid (monotonically decreasing) start.
        """
        n = self.n_vertices if n_vertices is None else int(n_vertices)
        if n < self.n_vertices:
            raise ValueError(
                f"n_vertices={n} shrinks the graph (was {self.n_vertices})")
        src = jnp.asarray(src, dtype=jnp.int32)
        dst = jnp.asarray(dst, dtype=jnp.int32)
        if src.shape != dst.shape:
            raise ValueError(f"src/dst shape mismatch: {src.shape} vs "
                             f"{dst.shape}")
        # eager bounds check: out-of-range ids would otherwise be silently
        # clamped by XLA gather/scatter and merge the wrong components
        if src.size and int(jnp.maximum(src.max(), dst.max())) >= n:
            raise ValueError(
                f"edge endpoint {int(jnp.maximum(src.max(), dst.max()))} "
                f">= n_vertices={n}; pass n_vertices= to grow the graph")
        if src.size and int(jnp.minimum(src.min(), dst.min())) < 0:
            raise ValueError("edge endpoints must be >= 0")
        return Graph(
            src=jnp.concatenate([self.src, src]),
            dst=jnp.concatenate([self.dst, dst]),
            n_vertices=n,
        )

    def pad_edges(self, target_m: int, fill_vertex: int = 0) -> "Graph":
        """Pad the edge list to ``target_m`` with self-loop edges.

        Self-loops ``(fill_vertex, fill_vertex)`` are no-ops for every
        connectivity algorithm here (min(L[v], L[v]) == L[v]) which makes
        them the natural padding for even sharding across devices.
        """
        m = self.n_edges
        if target_m < m:
            raise ValueError(f"target_m={target_m} < m={m}")
        pad = target_m - m
        if pad == 0:
            return self
        fill = jnp.full((pad,), fill_vertex, dtype=jnp.int32)
        return Graph(
            src=jnp.concatenate([self.src, fill]),
            dst=jnp.concatenate([self.dst, fill]),
            n_vertices=self.n_vertices,
        )


def canonicalize_edges(
    src: np.ndarray, dst: np.ndarray, n_vertices: int, drop_self_loops: bool = True
) -> Tuple[np.ndarray, np.ndarray]:
    """Sort edges as (min,max) pairs, dedupe, optionally drop self loops."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    if drop_self_loops:
        keep = lo != hi
        lo, hi = lo[keep], hi[keep]
    key = lo * n_vertices + hi
    key = np.unique(key)
    return (key // n_vertices).astype(np.int32), (key % n_vertices).astype(np.int32)


def build_csr(src: np.ndarray, dst: np.ndarray, n_vertices: int):
    """Build a CSR adjacency (row_ptr, col_idx) from an undirected edge list."""
    s = np.concatenate([src, dst]).astype(np.int64)
    d = np.concatenate([dst, src]).astype(np.int64)
    order = np.argsort(s, kind="stable")
    s, d = s[order], d[order]
    counts = np.bincount(s, minlength=n_vertices)
    row_ptr = np.zeros(n_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    return row_ptr, d.astype(np.int32)
