"""Graph substrate: edge-list structures, generators, oracles, statistics."""
from repro.graphs.structs import Graph, canonicalize_edges, build_csr
from repro.graphs import generators
from repro.graphs.oracle import connected_components_oracle, rem_union_find
from repro.graphs.stats import component_sizes, degree_stats, approx_max_diameter

__all__ = [
    "Graph",
    "canonicalize_edges",
    "build_csr",
    "generators",
    "connected_components_oracle",
    "rem_union_find",
    "component_sizes",
    "degree_stats",
    "approx_max_diameter",
]
