"""Host-side connectivity oracles.

Two oracles:

* :func:`connected_components_oracle` — vectorised NumPy union-find used as
  ground truth in tests and to canonicalise labels (min vertex id per
  component, matching the Contour fixed point).
* :func:`rem_union_find` — a faithful Rem-style union-find with splicing,
  the algorithm ConnectIt found fastest on shared memory (paper §III-C).
  It is inherently sequential pointer-chasing, which is exactly why the
  paper positions it as the parallel-resource-starved baseline; we keep it
  host-side (see DESIGN.md §8.5) and use it both as oracle cross-check and
  as the ``ConnectIt`` stand-in for benchmark figures.
"""
from __future__ import annotations

import numpy as np


def _find_roots_vectorized(parent: np.ndarray) -> np.ndarray:
    """Resolve every vertex to its root by repeated pointer jumping."""
    roots = parent.copy()
    while True:
        nxt = roots[roots]
        if np.array_equal(nxt, roots):
            return roots
        roots = nxt


def connected_components_oracle(src, dst, n_vertices: int) -> np.ndarray:
    """Return min-vertex-id labels per component (NumPy, vectorised).

    Implementation: iterated hook-to-minimum + full pointer jumping — a
    dense variant of Shiloach-Vishkin that is simple enough to trust as an
    oracle (it is *not* the algorithm under test).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    parent = np.arange(n_vertices, dtype=np.int64)
    while True:
        ps, pd = parent[src], parent[dst]
        lo = np.minimum(ps, pd)
        hi = np.maximum(ps, pd)
        changed_edges = ps != pd
        if not changed_edges.any():
            break
        np.minimum.at(parent, hi, lo)
        parent = _find_roots_vectorized(parent)
    # roots are already component minima because we always hook max->min
    return parent


def rem_union_find(src, dst, n_vertices: int, parent0=None) -> np.ndarray:
    """Rem's union-find with splicing (ConnectIt's winner), sequential.

    Returns min-vertex-id labels per component.  The union loop follows
    Patwary et al.'s presentation: walk both vertices' parent chains,
    splicing the larger root under the smaller as we go.

    ``parent0`` warm-starts the parent forest from a previous solve's
    labels (may be shorter than ``n_vertices`` if the graph grew; clamped
    to the ``p[v] <= v`` invariant every union here preserves).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    p = np.arange(n_vertices, dtype=np.int64)
    if parent0 is not None:
        parent0 = np.asarray(parent0, dtype=np.int64)
        if parent0.shape[0] > n_vertices:
            raise ValueError(
                f"parent0 covers {parent0.shape[0]} vertices but the graph "
                f"has only {n_vertices}")
        k = parent0.shape[0]
        p[:k] = np.minimum(parent0, p[:k])
    for u, v in zip(src.tolist(), dst.tolist()):
        r_u, r_v = u, v
        while p[r_u] != p[r_v]:
            if p[r_u] > p[r_v]:
                if r_u == p[r_u]:  # root: hook under the smaller chain
                    p[r_u] = p[r_v]
                    break
                # splice: shortcut r_u to p[r_v] and climb
                z = p[r_u]
                p[r_u] = p[r_v]
                r_u = z
            else:
                if r_v == p[r_v]:
                    p[r_v] = p[r_u]
                    break
                z = p[r_v]
                p[r_v] = p[r_u]
                r_v = z
    roots = _find_roots_vectorized(p)
    # Rem roots are minima along parent chains (we always hook larger under
    # smaller), so roots are already the component minimum.
    return roots


def labels_equivalent(a: np.ndarray, b: np.ndarray) -> bool:
    """True iff two labelings induce the same partition."""
    a = np.asarray(a)
    b = np.asarray(b)
    if a.shape != b.shape:
        return False
    # map each a-label to the first b-label seen; must be a bijection
    order = np.argsort(a, kind="stable")
    a_s, b_s = a[order], b[order]
    # within runs of equal a, all b must be equal
    boundaries = np.flatnonzero(np.diff(a_s)) + 1
    groups_b = np.split(b_s, boundaries)
    reps = []
    for g in groups_b:
        if (g != g[0]).any():
            return False
        reps.append(g[0])
    reps = np.asarray(reps)
    return len(np.unique(reps)) == len(reps)
