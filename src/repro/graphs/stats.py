"""Graph statistics used by benchmarks and the convergence-bound tests."""
from __future__ import annotations

import numpy as np

from repro.graphs.oracle import connected_components_oracle
from repro.graphs.structs import build_csr


def component_sizes(src, dst, n_vertices: int) -> np.ndarray:
    labels = connected_components_oracle(src, dst, n_vertices)
    _, counts = np.unique(labels, return_counts=True)
    return np.sort(counts)[::-1]


def degree_stats(src, dst, n_vertices: int):
    deg = np.bincount(np.concatenate([src, dst]), minlength=n_vertices)
    return {
        "max_degree": int(deg.max()),
        "avg_degree": float(deg.mean()),
        "isolated": int((deg == 0).sum()),
    }


def degree_skew(src, dst, n_vertices: int) -> float:
    """Max-degree / mean-degree ratio — the cost model's skew feature.

    ~1 for regular graphs (paths, grids), large for hub-dominated
    families (stars, R-MAT).  0.0 for edgeless graphs (no degrees to
    compare), so degenerate inputs stay finite.
    """
    if n_vertices <= 0 or len(src) == 0:
        return 0.0
    deg = np.bincount(np.concatenate([np.asarray(src), np.asarray(dst)]),
                      minlength=n_vertices)
    mean = float(deg.mean())
    return float(deg.max()) / mean if mean > 0 else 0.0


def _bfs_ecc(row_ptr, col_idx, start: int, n: int) -> tuple[int, int]:
    """Eccentricity of ``start`` via NumPy frontier BFS; returns (ecc, far)."""
    dist = np.full(n, -1, dtype=np.int64)
    dist[start] = 0
    frontier = np.array([start], dtype=np.int64)
    d = 0
    far = start
    while frontier.size:
        # gather all neighbours of the frontier
        starts = row_ptr[frontier]
        ends = row_ptr[frontier + 1]
        total = int((ends - starts).sum())
        if total == 0:
            break
        out = np.concatenate([col_idx[s:e] for s, e in zip(starts, ends)])
        out = np.unique(out)
        nxt = out[dist[out] < 0]
        if nxt.size == 0:
            break
        d += 1
        dist[nxt] = d
        far = int(nxt[0])
        frontier = nxt
    return d, far


def approx_max_diameter(src, dst, n_vertices: int, sweeps: int = 2) -> int:
    """Double-sweep BFS lower bound on the max component diameter.

    Exact on trees/paths; a tight lower bound elsewhere — sufficient for
    validating the Theorem-1 iteration bound (which needs an upper bound on
    iterations given a diameter, so a lower-bound diameter makes the test
    conservative in the right direction when used as log argument check).
    """
    labels = connected_components_oracle(src, dst, n_vertices)
    row_ptr, col_idx = build_csr(np.asarray(src), np.asarray(dst), n_vertices)
    best = 0
    for comp in np.unique(labels):
        start = int(comp)  # min-id vertex of the component
        ecc, far = _bfs_ecc(row_ptr, col_idx, start, n_vertices)
        for _ in range(sweeps - 1):
            ecc, far = _bfs_ecc(row_ptr, col_idx, far, n_vertices)
        best = max(best, ecc)
    return best
