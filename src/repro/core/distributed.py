"""Deprecation shims for the old distributed-Contour entry points.

The implementation moved to ``repro.connectivity.distributed``; the
public surface is ``repro.connectivity.solve(graph,
SolveOptions(mesh=mesh))`` — a mesh in the options routes the solve
through the ``shard_map`` path automatically.
"""
from __future__ import annotations

from repro.connectivity.distributed import distributed_contour as _distributed_contour
from repro.connectivity.distributed import (
    distributed_contour_step_fn as _distributed_contour_step_fn,
)
from repro.core._deprecated import warn_once

__all__ = ["distributed_contour", "distributed_contour_step_fn"]


def distributed_contour(graph, mesh, **kw):
    """Deprecated: use ``solve(graph, SolveOptions(mesh=mesh))``.

    Returns ``(labels, n_global_rounds)`` as the seed did.
    """
    warn_once("repro.core.distributed.distributed_contour",
              "repro.connectivity.solve(graph, SolveOptions(mesh=mesh))")
    labels, rounds, _, _ = _distributed_contour(graph, mesh, **kw)
    return labels, rounds


def distributed_contour_step_fn(src, dst, n_vertices, mesh, **kw):
    """Deprecated: use ``repro.connectivity.distributed``."""
    warn_once(
        "repro.core.distributed.distributed_contour_step_fn",
        "repro.connectivity.distributed.distributed_contour_step_fn")
    return _distributed_contour_step_fn(src, dst, n_vertices, mesh, **kw)
