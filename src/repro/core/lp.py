"""Deprecation shims for the old label-propagation entry points.

The implementation moved to ``repro.connectivity.lp``; the public surface
is ``repro.connectivity.solve(graph, algorithm="label_propagation")``.
"""
from __future__ import annotations

from repro.connectivity.lp import label_propagation as _label_propagation
from repro.connectivity.lp import label_propagation_labels as _label_propagation_labels
from repro.core._deprecated import warn_once

__all__ = ["label_propagation", "label_propagation_labels"]


def label_propagation_labels(src, dst, n_vertices, max_iters: int = 100_000):
    """Deprecated: use ``solve(graph, algorithm='label_propagation')``.

    Keeps the seed signature exactly (``max_iters`` stays reachable
    positionally); returns ``(labels, n_iterations)``.
    """
    warn_once("repro.core.lp.label_propagation_labels",
              "repro.connectivity.solve(graph, "
              "algorithm='label_propagation')")
    labels, iters, _ = _label_propagation_labels(src, dst, n_vertices,
                                                 max_iters=max_iters)
    return labels, iters


def label_propagation(graph, max_iters: int = 100_000):
    """Deprecated: use ``solve(graph, algorithm='label_propagation')``."""
    warn_once("repro.core.lp.label_propagation",
              "repro.connectivity.solve(graph, "
              "algorithm='label_propagation')")
    labels, iters, _ = _label_propagation(graph, max_iters=max_iters)
    return labels, iters
