"""Deprecation shims for the old Contour entry points.

The implementation moved to ``repro.connectivity.contour``; the public
surface is now ``repro.connectivity.solve`` (one facade over every solver
family, typed options, warm starts, batching).  These wrappers stay
call-compatible and emit one ``DeprecationWarning`` per entry point.
"""
from __future__ import annotations

from repro.connectivity.contour import VARIANTS
from repro.connectivity.contour import connected_components as _connected_components
from repro.connectivity.contour import contour as _contour
from repro.connectivity.contour import contour_labels as _contour_labels
from repro.core._deprecated import warn_once

__all__ = ["VARIANTS", "connected_components", "contour", "contour_labels"]


def contour_labels(src, dst, n_vertices, **kw):
    """Deprecated: use ``repro.connectivity.solve`` (algorithm='contour').

    Keeps the seed signature (all options were keyword-only after
    ``n_vertices``); returns ``(labels, n_iterations)``.
    """
    warn_once("repro.core.contour.contour_labels",
              "repro.connectivity.solve(graph, algorithm='contour')")
    labels, iters, _, _ = _contour_labels(src, dst, n_vertices, **kw)
    return labels, iters


def contour(graph, **kw):
    """Deprecated: use ``repro.connectivity.solve``."""
    warn_once("repro.core.contour.contour",
              "repro.connectivity.solve(graph)")
    labels, iters, _, _ = _contour(graph, **kw)
    return labels, iters


def connected_components(graph, variant: str = "C-2"):
    """Deprecated: use ``repro.connectivity.solve(graph).labels``."""
    warn_once("repro.core.contour.connected_components",
              "repro.connectivity.solve(graph).labels")
    return _connected_components(graph, variant=variant)
