"""The Contour connectivity algorithm (paper Alg. 1) and its six variants.

Variants (paper §III-B4):

* ``C-Syn``  — Alg. 1 verbatim: synchronous 2-order sweeps, double
  buffered, plain no-change convergence test.
* ``C-1``    — 1-order operator + async recompaction + early check.
* ``C-2``    — 2-order operator + async recompaction + early check
  (the paper's default).
* ``C-m``    — high-order operator: realised as a 2-order edge sweep
  followed by ``log2(m)`` pointer-jump rounds (same fixed point as the
  literal L^m chain; DESIGN.md §3).
* ``C-11mm`` — ``warmup`` iterations of C-1 then C-m until convergence.
* ``C-1m1m`` — alternate C-1 and C-m per iteration.

Every variant is a pure function of the edge list, runs under ``jax.jit``
with a ``lax.while_loop``, and returns ``(labels, n_iterations)``.

The MM sweep itself is routed through the ``kernels.contour_mm`` dispatch
layer: ``backend="xla"`` (default) is the scatter-min realisation,
``backend="pallas_blocked"`` the label-blocked vectorized TPU kernel and
``backend="auto"`` picks per platform/graph size
(`ops.plan_contour_kernel`) — so every variant can run on every backend.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import labels as lab
from repro.graphs.structs import Graph
from repro.kernels.contour_mm import ops as mm_ops

VARIANTS = ("C-Syn", "C-1", "C-2", "C-m", "C-11mm", "C-1m1m")

# C-m's effective order: the paper uses m = 1024; log2(1024) = 10 jump
# rounds after the 2-order edge sweep covers the same mapping depth.
_CM_JUMP_ROUNDS = 10


class ContourState(NamedTuple):
    L: jax.Array
    it: jax.Array          # int32 iteration counter
    done: jax.Array        # bool


def _sweep_sync(L, src, dst, order, backend):
    """Alg. 1 body: one synchronous MM^order sweep."""
    return mm_ops.mm_relax_backend(L, src, dst, order=order, backend=backend)


def _sweep_async(L, src, dst, order, jump_rounds, compress, backend):
    """Optimised sweep: MM^order + pointer-jump recompaction.

    ``jump_rounds`` realises high-order variants; ``compress`` is the
    async-update adaptation (spreads freshly lowered labels inside the
    same iteration, mirroring the paper's in-place updates).
    """
    L = mm_ops.mm_relax_backend(L, src, dst, order=order, backend=backend)
    L = lab.pointer_jump(L, rounds=jump_rounds + compress)
    return L


def _make_step(variant: str, warmup: int, async_compress: int,
               backend: str = "xla"):
    """Return step(L, it, src, dst) -> L_new for the chosen variant."""
    if variant == "C-Syn":
        def step(L, it, src, dst):
            del it
            return _sweep_sync(L, src, dst, 2, backend)
    elif variant == "C-1":
        def step(L, it, src, dst):
            del it
            return _sweep_async(L, src, dst, 1, 0, async_compress, backend)
    elif variant == "C-2":
        def step(L, it, src, dst):
            del it
            return _sweep_async(L, src, dst, 2, 0, async_compress, backend)
    elif variant == "C-m":
        def step(L, it, src, dst):
            del it
            return _sweep_async(L, src, dst, 2, _CM_JUMP_ROUNDS,
                                async_compress, backend)
    elif variant == "C-11mm":
        def step(L, it, src, dst):
            return jax.lax.cond(
                it < warmup,
                lambda L: _sweep_async(L, src, dst, 1, 0,
                                       async_compress, backend),
                lambda L: _sweep_async(L, src, dst, 2, _CM_JUMP_ROUNDS,
                                       async_compress, backend),
                L,
            )
    elif variant == "C-1m1m":
        def step(L, it, src, dst):
            return jax.lax.cond(
                it % 2 == 0,
                lambda L: _sweep_async(L, src, dst, 1, 0,
                                       async_compress, backend),
                lambda L: _sweep_async(L, src, dst, 2, _CM_JUMP_ROUNDS,
                                       async_compress, backend),
                L,
            )
    elif variant.startswith("C-") and variant[2:].isdigit():
        # literal h-order minimum-mapping operator (Definition 3): the
        # length-h gather chain per edge, exactly as written in the paper.
        # The named C-m variant realises high orders via pointer jumping
        # instead (same fixed point, TPU-vectorisable — DESIGN.md §3);
        # this literal form exists to validate that equivalence.
        order = int(variant[2:])

        def step(L, it, src, dst):
            del it
            return _sweep_async(L, src, dst, order, 0, async_compress,
                                backend)
    else:
        raise ValueError(f"unknown variant {variant!r}; one of {VARIANTS} "
                         "or literal 'C-<h>'")
    return step


@functools.partial(
    jax.jit,
    static_argnames=("n_vertices", "variant", "max_iters", "warmup",
                     "async_compress", "backend"),
)
def contour_labels(
    src: jax.Array,
    dst: jax.Array,
    n_vertices: int,
    *,
    variant: str = "C-2",
    max_iters: int = 100_000,
    warmup: int = 2,
    async_compress: int = 1,
    backend: str = "xla",
):
    """Run the Contour algorithm; returns (labels[n], n_iterations).

    Labels converge to the minimum vertex id of each component.
    """
    step = _make_step(variant, warmup, async_compress, backend)
    sync = variant == "C-Syn"
    L0 = jnp.arange(n_vertices, dtype=src.dtype)

    def cond(s: ContourState):
        return (~s.done) & (s.it < max_iters)

    def body(s: ContourState):
        L_new = step(s.L, s.it, src, dst)
        if sync:
            done = jnp.all(L_new == s.L)  # Alg. 1 line 10: no label change
        else:
            done = lab.converged_early(L_new, src, dst)  # paper §III-B2
        return ContourState(L=L_new, it=s.it + 1, done=done)

    init = ContourState(L=L0, it=jnp.int32(0), done=jnp.array(False))
    out = jax.lax.while_loop(cond, body, init)
    # Final compression: at the early-convergence point the pointer graph
    # restricted to edge endpoints is a star forest; interior tree vertices
    # of padded/isolated chains may still be one hop away.
    L = lab.pointer_jump(out.L, rounds=1)
    return L, out.it


def contour(graph: Graph, **kw):
    """Convenience wrapper over :func:`contour_labels`."""
    return contour_labels(graph.src, graph.dst, graph.n_vertices, **kw)


def connected_components(graph: Graph, variant: str = "C-2") -> jax.Array:
    """Public API: min-vertex-id component labels."""
    L, _ = contour(graph, variant=variant)
    return L
