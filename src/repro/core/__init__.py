"""Core: the paper's Contour connectivity algorithm + baselines."""
from repro.core.contour import (
    VARIANTS,
    connected_components,
    contour,
    contour_labels,
)
from repro.core.fastsv import fastsv, fastsv_labels
from repro.core.lp import label_propagation, label_propagation_labels
from repro.core import labels

__all__ = [
    "VARIANTS",
    "connected_components",
    "contour",
    "contour_labels",
    "fastsv",
    "fastsv_labels",
    "label_propagation",
    "label_propagation_labels",
    "labels",
]
