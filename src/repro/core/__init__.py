"""Core: deprecation shims over ``repro.connectivity``.

The algorithms moved to the unified ``repro.connectivity`` package
(``solve()`` facade, typed options/results, solver registry, warm starts,
batching).  Everything here stays importable and call-compatible but
emits one ``DeprecationWarning`` per entry point on first use.
"""
from repro.core.contour import (
    VARIANTS,
    connected_components,
    contour,
    contour_labels,
)
from repro.core.fastsv import fastsv, fastsv_labels
from repro.core.lp import label_propagation, label_propagation_labels
from repro.core import labels

__all__ = [
    "VARIANTS",
    "connected_components",
    "contour",
    "contour_labels",
    "fastsv",
    "fastsv_labels",
    "label_propagation",
    "label_propagation_labels",
    "labels",
]
