"""Alias of :mod:`repro.connectivity.minmap` (the implementation moved).

Quiet (non-warning) re-export: these are shared math primitives, not a
deprecated entry point — kernels, tests and the solver implementations all
use the same functions through either name.
"""
from repro.connectivity.minmap import (
    converged_early,
    gather_chain,
    is_star_forest,
    mm_relax,
    mm_update_stream,
    pointer_jump,
    resolve_init_labels,
)

__all__ = [
    "converged_early",
    "gather_chain",
    "is_star_forest",
    "mm_relax",
    "mm_update_stream",
    "pointer_jump",
    "resolve_init_labels",
]
