"""ConnectIt stand-in: Rem's union-find with splicing (paper §III-C).

Host-side by design: Rem's algorithm is sequential pointer-chasing with no
efficient TPU analogue (the paper itself positions it as the winner only
in parallelism-starved regimes — DESIGN.md §8.5).  Exposed from
``repro.core`` so benchmarks compare all three families through one API.
"""
from repro.graphs.oracle import rem_union_find

__all__ = ["rem_union_find"]
