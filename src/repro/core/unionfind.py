"""Deprecation shim for the ConnectIt stand-in entry point.

The registered solver lives in ``repro.connectivity.unionfind``; the
public surface is ``repro.connectivity.solve(graph,
algorithm="union_find")``.  The raw oracle stays importable from
``repro.graphs.oracle`` (it doubles as test ground truth).
"""
from __future__ import annotations

from repro.graphs.oracle import rem_union_find as _rem_union_find
from repro.core._deprecated import warn_once

__all__ = ["rem_union_find"]


def rem_union_find(src, dst, n_vertices, *args, **kw):
    """Deprecated: use ``solve(graph, algorithm='union_find')``."""
    warn_once("repro.core.unionfind.rem_union_find",
              "repro.connectivity.solve(graph, algorithm='union_find')")
    return _rem_union_find(src, dst, n_vertices, *args, **kw)
