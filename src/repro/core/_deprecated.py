"""Warn-once helper for the ``repro.core`` deprecation shims.

Each old entry point fires exactly one ``DeprecationWarning`` per process
(the first call), so migrating callers see the pointer to the new API
without log spam from hot loops.  ``reset()`` clears the memo — used by
the deprecation tests to assert the warning deterministically.
"""
from __future__ import annotations

import warnings

_seen: set = set()


def warn_once(old: str, replacement: str) -> None:
    if old in _seen:
        return
    _seen.add(old)
    warnings.warn(
        f"{old} is deprecated; use {replacement} "
        "(see repro.connectivity).",
        DeprecationWarning,
        stacklevel=3,
    )


def reset() -> None:
    """Forget which warnings fired (test hook)."""
    _seen.clear()
