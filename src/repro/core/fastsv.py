"""Deprecation shims for the old FastSV entry points.

The implementation moved to ``repro.connectivity.fastsv``; the public
surface is ``repro.connectivity.solve(graph, algorithm="fastsv")``.
"""
from __future__ import annotations

from repro.connectivity.fastsv import fastsv as _fastsv
from repro.connectivity.fastsv import fastsv_labels as _fastsv_labels
from repro.core._deprecated import warn_once

__all__ = ["fastsv", "fastsv_labels"]


def fastsv_labels(src, dst, n_vertices, max_iters: int = 256):
    """Deprecated: use ``repro.connectivity.solve`` (algorithm='fastsv').

    Keeps the seed signature exactly (``max_iters`` stays reachable
    positionally); returns ``(labels, n_iterations)``.
    """
    warn_once("repro.core.fastsv.fastsv_labels",
              "repro.connectivity.solve(graph, algorithm='fastsv')")
    labels, iters, _ = _fastsv_labels(src, dst, n_vertices,
                                      max_iters=max_iters)
    return labels, iters


def fastsv(graph, max_iters: int = 256):
    """Deprecated: use ``repro.connectivity.solve`` (algorithm='fastsv')."""
    warn_once("repro.core.fastsv.fastsv",
              "repro.connectivity.solve(graph, algorithm='fastsv')")
    labels, iters, _ = _fastsv(graph, max_iters=max_iters)
    return labels, iters
