"""Trip-count-aware cost model over optimized HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` counts a ``while`` body
ONCE, no matter the trip count (validated in tests/test_roofline.py) — so
every scanned program (scan-over-layers, gradient accumulation, chunked
attention) under-reports flops, bytes and collectives by the layer/step
count.  This module re-derives the three roofline inputs from
``compiled.as_text()`` with loops multiplied out:

  * flops            — from ``dot`` ops (2 x |out| x contracted), including
                       dots inside fusion computations, x loop trip counts;
  * hbm bytes        — per instruction: operands + outputs (the TPU fusion
                       model: every fusion streams HBM->VMEM->HBM);
  * collective bytes — ring-model link traffic per participant, by op kind,
                       x loop trip counts.

Loop trip counts are recovered from the loop condition computation (the
largest s32 scalar constant — matches the counter pattern XLA emits for
``lax.scan`` / ``fori_loop``; for dynamic ``while_loop`` convergence loops
the caller should lower with a representative ``max_iters``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z0-9]*)\[([0-9,\s]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([a-z][\w\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_CONST_S32_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_COLLECTIVES = {
    "all-reduce", "all-reduce-start", "all-gather", "all-gather-start",
    "reduce-scatter", "all-to-all", "collective-permute",
    "collective-permute-start",
}
# opcodes that move no data themselves
_FREE_OPS = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "all-reduce-done",
    "all-gather-done", "collective-permute-done", "iota",
}


def _shape_dims(type_text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_text):
        if dtype not in _DTYPE_BYTES:
            continue
        dd = [int(d) for d in dims.replace(" ", "").split(",") if d]
        out.append((dtype, dd))
    return out


def _shape_bytes(type_text: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    type_text: str
    out_bytes: int
    operands: List[str]
    tail: str                   # text after the operand list (attributes)
    raw: str = ""               # full text after `opcode(`


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_link_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        for k, v in other.coll_link_bytes.items():
            self.coll_link_bytes[k] = self.coll_link_bytes.get(k, 0) + v * times
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * times

    @property
    def total_coll_link_bytes(self) -> float:
        return sum(self.coll_link_bytes.values())


def _split_operands(args_text: str) -> Tuple[List[str], str]:
    """Names referenced in the operand list + the attribute tail."""
    depth = 0
    end = len(args_text)
    for i, ch in enumerate(args_text):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                end = i
                break
            depth -= 1
    ops_text, tail = args_text[:end], args_text[end + 1:]
    return _NAME_RE.findall(ops_text), tail


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.shapes: Dict[str, str] = {}       # instr name -> type text
        self.entry: Optional[str] = None
        self._parse(text)

    def _parse(self, text: str):
        current: Optional[str] = None
        for line in text.splitlines():
            mc = _COMP_RE.match(line.strip())
            if mc and line.rstrip().endswith("{"):
                current = mc.group(1)
                self.computations[current] = []
                if line.lstrip().startswith("ENTRY"):
                    self.entry = current
                continue
            if current is None:
                continue
            if line.strip() == "}":
                current = None
                continue
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            name, type_text, opcode, rest = mi.groups()
            operands, tail = _split_operands(rest)
            instr = Instr(
                name=name, opcode=opcode, type_text=type_text,
                out_bytes=_shape_bytes(type_text), operands=operands,
                tail=tail, raw=rest,
            )
            self.computations[current].append(instr)
            self.shapes[name] = type_text

    # -- helpers ----------------------------------------------------------

    def _called(self, instr: Instr, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w.\-]+)", instr.tail)
        return m.group(1) if m else None

    def _trip_count(self, cond_comp: str, _depth: int = 0) -> int:
        """Largest s32 scalar constant in the loop condition computation.

        Matches XLA's counter pattern for lax.scan / fori_loop (`i < N`).
        Descends into fusions called from the condition (CPU XLA fuses the
        whole predicate, burying the bound constant one level down).
        Dynamic-convergence while_loops must be lowered by the caller with
        a representative max_iters (documented at the call sites).
        """
        best = 1
        if _depth > 2:
            return best
        for instr in self.computations.get(cond_comp, ()):
            if instr.opcode == "constant" and "s32[]" in instr.type_text:
                m = re.match(r"\s*(\d+)", instr.raw)
                if m:
                    best = max(best, int(m.group(1)))
            elif instr.opcode == "fusion":
                callee = self._called(instr, "calls")
                if callee:
                    best = max(best, self._trip_count(callee, _depth + 1))
        return best

    def _group_size(self, instr: Instr) -> int:
        m = _GROUPS_RE.search(instr.tail)
        if m:
            return max(int(m.group(2)), 1)
        m = _GROUPS_LIST_RE.search(instr.tail)
        if m:
            return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
        return 2

    def _dot_flops(self, instr: Instr) -> float:
        out_elems = 0
        for _, dims in _shape_dims(instr.type_text):
            n = 1
            for d in dims:
                n *= d
            out_elems += n
        m = re.search(r"lhs_contracting_dims=\{([0-9,\s]*)\}", instr.tail)
        contract = 1
        if m and instr.operands:
            lhs_type = self.shapes.get(instr.operands[0], "")
            dims_list = _shape_dims(lhs_type)
            if dims_list:
                lhs_dims = dims_list[0][1]
                for idx in m.group(1).replace(" ", "").split(","):
                    if idx and int(idx) < len(lhs_dims):
                        contract *= lhs_dims[int(idx)]
        return 2.0 * out_elems * contract

    def _fusion_param_adjust(self, callee: str):
        """Slice-proportional byte accounting for fused scan access patterns.

        Returns (param_pos -> adjusted_bytes, root_adjust | None): params
        consumed ONLY by dynamic-slice / gather / dynamic-update-slice (as
        the sliced operand) are charged ~2x the addressed region instead of
        their full size; a dynamic-update-slice root (the ys-accumulate
        pattern) charges the update, not the whole buffer.
        """
        instrs = self.computations.get(callee, ())
        if not instrs:
            return {}, None
        param_pos = {}
        for ins in instrs:
            if ins.opcode == "parameter":
                m = re.match(r"\s*(\d+)", ins.raw)
                if m:
                    param_pos[ins.name] = int(m.group(1))
        sliced: Dict[int, int] = {}
        poisoned = set()
        for ins in instrs:
            if ins.opcode == "parameter":
                continue
            for pos_i, o in enumerate(ins.operands):
                if o not in param_pos:
                    continue
                p = param_pos[o]
                if ins.opcode in ("dynamic-slice", "gather") and pos_i == 0:
                    sliced[p] = max(sliced.get(p, 0), 2 * ins.out_bytes)
                elif ins.opcode == "dynamic-update-slice" and pos_i == 0:
                    upd = (_shape_bytes(self.shapes.get(ins.operands[1], ""))
                           if len(ins.operands) > 1 else ins.out_bytes)
                    sliced[p] = max(sliced.get(p, 0), 2 * upd)
                else:
                    poisoned.add(p)
        adj = {p: b for p, b in sliced.items() if p not in poisoned}
        root = instrs[-1]
        root_adj = None
        if root.opcode == "dynamic-update-slice":
            upd = (_shape_bytes(self.shapes.get(root.operands[1], ""))
                   if len(root.operands) > 1 else root.out_bytes)
            root_adj = 2 * upd
        return adj, root_adj

    def _collective_traffic(self, instr: Instr) -> float:
        op = instr.opcode.replace("-start", "")
        n = self._group_size(instr)
        frac = (n - 1) / n
        nbytes = instr.out_bytes
        if op == "all-reduce":
            return 2.0 * nbytes * frac
        if op == "all-gather":
            return nbytes * frac
        if op == "reduce-scatter":
            return nbytes * (n - 1)
        if op == "all-to-all":
            return nbytes * frac
        return float(nbytes)        # collective-permute

    # -- recursive cost ----------------------------------------------------

    def cost(self, comp: Optional[str] = None,
              _memo: Optional[Dict[str, Cost]] = None) -> Cost:
        comp = comp or self.entry
        _memo = _memo if _memo is not None else {}
        if comp in _memo:
            return _memo[comp]
        total = Cost()
        _memo[comp] = total          # cycle guard (shouldn't happen in HLO)
        for instr in self.computations.get(comp, ()):
            op = instr.opcode
            if op in _FREE_OPS:
                continue
            if op == "while":
                body = self._called(instr, "body")
                cond = self._called(instr, "condition")
                trips = self._trip_count(cond) if cond else 1
                if body:
                    total.add(self.cost(body, _memo), trips)
                if cond:
                    total.add(self.cost(cond, _memo), trips)
                continue
            if op == "conditional":
                # max over branches (branch computations referenced in tail)
                branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"(?:true|false)_computation=%?([\w.\-]+))",
                                      instr.tail)
                names = []
                for a, b in branches:
                    if a:
                        names.extend(_NAME_RE.findall(a) or
                                     [x.strip().lstrip("%") for x in a.split(",")])
                    if b:
                        names.append(b)
                if names:
                    costs = [self.cost(n, _memo) for n in names if
                             n in self.computations]
                    if costs:
                        best = max(costs, key=lambda c: c.flops + c.bytes)
                        total.add(best)
                continue
            if op in ("call", "async-start"):
                callee = self._called(instr, "to_apply") or \
                    self._called(instr, "calls")
                if callee:
                    total.add(self.cost(callee, _memo))
                continue

            # data movement: operands + output (fusion streaming model).
            # Indexed ops only touch the addressed region, not the whole
            # operand — a dynamic-slice inside a 32k-step scan would
            # otherwise be charged 32k full-array reads (measured to
            # inflate recurrent models' memory term by >100x):
            #   dynamic-slice           ~ 2 x slice bytes
            #   dynamic-update-slice    ~ 2 x update bytes (aliased r/m/w)
            #   gather                  ~ 2 x output + indices
            #   scatter                 ~ 2 x updates + indices (aliased)
            if op == "dynamic-slice":
                nbytes = 2 * instr.out_bytes
            elif op == "dynamic-update-slice":
                upd = (_shape_bytes(self.shapes.get(instr.operands[1], ""))
                       if len(instr.operands) > 1 else instr.out_bytes)
                nbytes = 2 * upd
            elif op == "gather":
                idx = (_shape_bytes(self.shapes.get(instr.operands[1], ""))
                       if len(instr.operands) > 1 else 0)
                nbytes = 2 * instr.out_bytes + idx
            elif op == "scatter":
                upd = sum(_shape_bytes(self.shapes.get(o, ""))
                          for o in instr.operands[2:]) \
                    if len(instr.operands) > 2 else instr.out_bytes
                idx = (_shape_bytes(self.shapes.get(instr.operands[1], ""))
                       if len(instr.operands) > 1 else 0)
                nbytes = 2 * upd + idx
            else:
                nbytes = instr.out_bytes
                for o in instr.operands:
                    nbytes += _shape_bytes(self.shapes.get(o, ""))
            total.bytes += nbytes

            if op == "dot":
                total.flops += self._dot_flops(instr)
            elif op == "fusion":
                callee = self._called(instr, "calls")
                if callee:
                    inner = self.cost(callee, _memo)
                    total.flops += inner.flops
                    # inner bytes intentionally NOT added: fusion internals
                    # stay in VMEM/registers; only callsite operands+output
                    # touch HBM.  Inner collectives shouldn't exist.
                    #
                    # BUT: XLA fuses the dynamic-slice / dynamic-update-slice
                    # that lax.scan uses to read xs / accumulate ys — the
                    # naive "charge full operands" model then bills the whole
                    # stacked array every loop trip (measured 1000x memory
                    # inflation on a 32k-step recurrence).  Re-charge params
                    # that are only sliced/accumulated inside the fusion at
                    # slice-proportional bytes, and a DUS root at update size.
                    adj, root_adj = self._fusion_param_adjust(callee)
                    if adj or root_adj is not None:
                        nbytes = (root_adj if root_adj is not None
                                  else instr.out_bytes)
                        for pos, o in enumerate(instr.operands):
                            full = _shape_bytes(self.shapes.get(o, ""))
                            nbytes += min(full, adj.get(pos, full))
                        total.bytes += nbytes - (
                            instr.out_bytes + sum(
                                _shape_bytes(self.shapes.get(o, ""))
                                for o in instr.operands))
            elif op in _COLLECTIVES:
                kind = op.replace("-start", "")
                total.coll_counts[kind] = total.coll_counts.get(kind, 0) + 1
                total.coll_link_bytes[kind] = (
                    total.coll_link_bytes.get(kind, 0)
                    + self._collective_traffic(instr))
        return total


def analyze_text(hlo_text: str) -> Cost:
    """Entry-computation cost of an optimized HLO module, loops unrolled."""
    return HloModule(hlo_text).cost()
