"""Three-term roofline analysis from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

Empirics (validated in tests): XLA's ``compiled.cost_analysis()`` on an
SPMD-partitioned module reports **per-device** flops/bytes, so the formulas
reduce to per-device quantities over per-chip peaks.  ``cost_analysis`` has
no collective entry at all — collective bytes are parsed from
``compiled.as_text()`` (the *post*-partitioning optimized HLO, where the
real collective schedule lives; ``lowered.as_text()`` is pre-SPMD and holds
none of it).

Per-collective link traffic uses the standard ring-algorithm byte counts
(per participant, group size n):

    all-reduce       2 x bytes x (n-1)/n
    all-gather       out_bytes x (n-1)/n
    reduce-scatter   in_bytes  x (n-1)/n      (= out x (n-1))
    all-to-all       bytes x (n-1)/n
    collective-permute  bytes

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(assignment constants).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

from repro import jax_compat

HW_V5E = {
    "peak_flops": 197e12,    # bf16 FLOP/s per chip
    "hbm_bw": 819e9,         # bytes/s per chip
    "link_bw": 50e9,         # bytes/s per ICI link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%all-gather.7 = bf16[2,1024]{1,0} all-gather(...)`; tuple-shaped outputs
# look like `(f32[8]{0}, f32[8]{0}) all-reduce(...)`.
_INSTR_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,\s]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    """Sum bytes over every `dtype[dims]` occurrence in `text`."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            d = d.strip()
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> Optional[int]:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return None


@dataclasses.dataclass
class CollectiveStats:
    """Per-device collective byte counts parsed from optimized HLO."""

    op_counts: Dict[str, int]
    out_bytes: Dict[str, int]      # raw output bytes by op kind
    link_bytes: Dict[str, int]     # ring-model per-device link traffic

    @property
    def total_link_bytes(self) -> int:
        return sum(self.link_bytes.values())

    @property
    def total_out_bytes(self) -> int:
        return sum(self.out_bytes.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    out_b: Dict[str, int] = {}
    link_b: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        nbytes = _shape_bytes(shape_txt)
        n = _group_size(line) or 2
        frac = (n - 1) / n
        if op == "all-reduce":
            traffic = 2 * nbytes * frac
        elif op == "all-gather":
            traffic = nbytes * frac              # nbytes is gathered output
        elif op == "reduce-scatter":
            traffic = nbytes * (n - 1)           # input = out x n
        elif op == "all-to-all":
            traffic = nbytes * frac
        else:                                    # collective-permute
            traffic = nbytes
        counts[op] = counts.get(op, 0) + 1
        out_b[op] = out_b.get(op, 0) + nbytes
        link_b[op] = link_b.get(op, 0) + int(traffic)
    return CollectiveStats(op_counts=counts, out_bytes=out_b, link_bytes=link_b)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    kind: str                      # train | prefill | decode | contour
    n_devices: int
    # per-device quantities
    hlo_flops: float
    hlo_bytes: float
    collective_link_bytes: float
    peak_hbm_bytes: float          # temp+argument+output per device
    # three terms, seconds
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    dominant: str = ""
    # usefulness
    model_flops_global: float = 0.0
    flops_ratio: float = 0.0       # model_flops / (hlo_flops x devices)
    collective_detail: Optional[Dict[str, Any]] = None
    note: str = ""

    def finalize(self, hw=HW_V5E) -> "RooflineReport":
        self.t_compute = self.hlo_flops / hw["peak_flops"]
        self.t_memory = self.hlo_bytes / hw["hbm_bw"]
        self.t_collective = self.collective_link_bytes / hw["link_bw"]
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.dominant = max(terms, key=terms.get)
        total_hlo = self.hlo_flops * self.n_devices
        self.flops_ratio = (self.model_flops_global / total_hlo
                            if total_hlo else 0.0)
        return self

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     kind: str, n_devices: int,
                     model_flops_global: float = 0.0,
                     note: str = "") -> RooflineReport:
    from repro.roofline.hlo_cost import analyze_text

    ca = jax_compat.cost_analysis(compiled)
    ma = compiled.memory_analysis()
    # Trip-count-aware HLO cost: XLA's own cost_analysis counts while-loop
    # bodies once (the layer scan would be 1/n_layers undercounted) — see
    # repro.roofline.hlo_cost.  The raw XLA numbers ride along as
    # `xla_*_loop_once` reference fields.
    cost = analyze_text(compiled.as_text())
    peak = (ma.temp_size_in_bytes + ma.argument_size_in_bytes
            + ma.output_size_in_bytes)
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, kind=kind,
        n_devices=n_devices,
        hlo_flops=float(cost.flops),
        hlo_bytes=float(cost.bytes),
        collective_link_bytes=float(cost.total_coll_link_bytes),
        peak_hbm_bytes=float(peak),
        model_flops_global=model_flops_global,
        collective_detail={
            "counts": cost.coll_counts,
            "link_bytes": cost.coll_link_bytes,
            "xla_flops_loop_once": float(ca.get("flops", 0.0)),
            "xla_bytes_loop_once": float(ca.get("bytes accessed", 0.0)),
        },
        note=note,
    )
    return rep.finalize()


# ---------------------------------------------------------------------------
# MODEL_FLOPS: 6·N·D (train) / 2·N·D (forward), N_active for MoE
# ---------------------------------------------------------------------------

def count_params(model, active_only: bool = False) -> float:
    """Non-embedding parameter count from the model's ParamSpec tree.

    ``active_only`` scales expert tensors by top_k/n_experts (MoE active
    parameters — the N in the assignment's 6·N_active·D).
    """
    import numpy as np
    from repro.models.common import ParamSpec

    cfg = model.config
    specs = model.param_specs()
    total = 0.0

    def visit(tree, path):
        nonlocal total
        if isinstance(tree, ParamSpec):
            name = path[-1] if path else ""
            if name in ("tok_embed", "lm_head"):
                return
            n = float(np.prod(tree.shape))
            if active_only and name.endswith("_e"):  # stacked expert tensors
                n *= cfg.top_k / max(cfg.n_experts, 1)
            total += n
            return
        if isinstance(tree, dict):
            for k, v in tree.items():
                visit(v, path + [k])
        elif isinstance(tree, (list, tuple)):
            for i, v in enumerate(tree):
                visit(v, path + [str(i)])

    visit(specs, [])
    return total


def model_flops(model, kind: str, seq_len: int, global_batch: int) -> float:
    """Assignment MODEL_FLOPS for one step of a grid cell."""
    n_active = count_params(model, active_only=True)
    if kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * global_batch
