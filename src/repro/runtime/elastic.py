"""Elastic scaling: re-derive a mesh from whatever devices survive.

Policy: preserve the model (TP/EP) axis if possible — model-parallel state
is the expensive thing to reshard — and absorb device loss on the
data-parallel axes.  Combined with global-array checkpoints
(``repro.checkpoint``) and a seekable data pipeline, a job can restart on
any device count that still fits the model axis.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def derive_mesh_shape(
    n_devices: int, model_parallel: int, prefer_pods: int = 1
) -> Tuple[int, ...]:
    """Largest (pod, data, model) grid using <= n_devices devices.

    ``model_parallel`` is fixed (weights are sharded that way); data/pod
    axes shrink to fit.  Raises if even one model replica doesn't fit.
    """
    if n_devices < model_parallel:
        raise ValueError(
            f"{n_devices} devices cannot hold model_parallel={model_parallel}"
        )
    replicas = n_devices // model_parallel
    pods = prefer_pods
    while pods > 1 and replicas % pods:
        pods -= 1
    data = replicas // pods
    if pods > 1:
        return (pods, data, model_parallel)
    return (data, model_parallel)


def elastic_mesh(
    model_parallel: int,
    devices: Optional[Sequence] = None,
    prefer_pods: int = 1,
) -> jax.sharding.Mesh:
    devices = list(devices if devices is not None else jax.devices())
    shape = derive_mesh_shape(len(devices), model_parallel, prefer_pods)
    n_used = int(np.prod(shape))
    names = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    dev_array = np.asarray(devices[:n_used]).reshape(shape)
    return jax.sharding.Mesh(dev_array, names)
