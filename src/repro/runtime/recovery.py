"""Crash-restart driver: checkpoint/restore around injected or real faults.

``run_with_recovery`` wraps a step function with the full fault-tolerance
loop: periodic checkpoints, restore-on-failure, bounded retries with
exponential backoff.  The ``FaultInjector`` lets tests (and the chaos
example/benchmark) kill arbitrary steps — or arbitrary *sites* within a
step — and assert bit-exact recovery, possible because state is
checkpointed atomically and the replayed inputs are seekable (batch k is
a pure function of k).

The recoverable-exception set is configurable: by default only the
injected :class:`SimulatedFault` triggers a restore (conservative — a
bug should crash loudly), but a production driver passes e.g.
``recoverable=(RuntimeError,)`` so real faults (jaxlib XLA runtime
errors, transient I/O) restore from the last checkpoint instead of
propagating with all work lost.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple, Type, Union

from repro.checkpoint.manager import CheckpointManager


class SimulatedFault(RuntimeError):
    """An injected fault (process crash stand-in).

    Deliberately *not* in the transient-kernel-error class
    (:func:`is_transient_error`): a simulated machine fault must be
    handled by checkpoint/restore, never silently absorbed by the
    kernel-fallback path.
    """


class ShardLossFault(SimulatedFault):
    """Simulated loss of ``n_lost`` device shard(s) mid-solve.

    Raised by a :class:`FaultInjector` (via ``exc_factory``) between
    rounds of a distributed solve; the elastic driver
    (``repro.connectivity.resilience``) reacts by re-deriving a smaller
    mesh over the surviving devices and warm-restarting from the last
    good labels.
    """

    def __init__(self, n_lost: int = 1, message: str = ""):
        super().__init__(message or f"simulated loss of {n_lost} shard(s)")
        self.n_lost = int(n_lost)


# Exception classes that signal a caller bug (bad arguments, shape/type
# mismatch) rather than a transient fault; retrying or falling back on
# these would mask the bug.
NON_TRANSIENT_ERRORS: Tuple[Type[BaseException], ...] = (
    ValueError, TypeError, KeyError, IndexError, NotImplementedError)


def is_transient_error(exc: BaseException) -> bool:
    """True iff ``exc`` plausibly came from the machine, not the caller.

    Used by the kernel-fallback path (``solve()`` / streaming ingest) to
    decide whether a failed Pallas launch is worth retrying on the XLA
    reference backend: runtime/compile errors are; argument-validation
    errors and injected :class:`SimulatedFault`\\ s are not.
    """
    if isinstance(exc, SimulatedFault):
        return False
    if isinstance(exc, NON_TRANSIENT_ERRORS):
        return False
    return isinstance(exc, Exception)


def backoff_delay(attempt: int, *, base: float, factor: float = 2.0,
                  cap: float = 30.0) -> float:
    """Exponential backoff delay for retry ``attempt`` (1-based)."""
    if base <= 0:
        return 0.0
    return min(cap, base * factor ** max(0, attempt - 1))


@dataclasses.dataclass
class FaultInjector:
    """Raise a fault at the given step numbers / sites (once each).

    ``fail_at`` entries are either a bare step number — fires at the
    first ``maybe_fail`` call for that step, whatever the site — or a
    ``(step, site)`` pair for a precise injection point, e.g.
    ``(3, "post_write")`` to kill ingest batch 3 after its ring-buffer
    write but before the commit.  ``exc_factory`` customises the raised
    exception (default :class:`SimulatedFault`); pass e.g.
    ``lambda step, site: ShardLossFault(1)`` to simulate shard loss.
    """
    fail_at: tuple = ()
    exc_factory: Optional[Callable[[int, Optional[str]], Exception]] = None
    _fired: set = dataclasses.field(default_factory=set)

    def _make(self, step: int, site: Optional[str]) -> Exception:
        if self.exc_factory is not None:
            return self.exc_factory(step, site)
        where = f"step {step}" + (f" at site {site!r}" if site else "")
        return SimulatedFault(f"injected fault at {where}")

    def maybe_fail(self, step: int, site: Optional[str] = None):
        for entry in self.fail_at:
            if entry in self._fired:
                continue
            if isinstance(entry, tuple):
                if entry == (step, site):
                    self._fired.add(entry)
                    raise self._make(step, site)
            elif entry == step:
                self._fired.add(entry)
                raise self._make(step, site)


def run_with_recovery(
    step_fn: Callable[[Any, int], Any],
    init_state: Any,
    n_steps: int,
    manager: CheckpointManager,
    *,
    checkpoint_every: int = 10,
    max_restarts: int = 5,
    fault_injector: Optional[FaultInjector] = None,
    on_event: Optional[Callable[[str, int], None]] = None,
    recoverable: Tuple[Type[BaseException], ...] = (SimulatedFault,),
    backoff_base: float = 0.0,
    backoff_factor: float = 2.0,
    backoff_cap: float = 30.0,
    sleep_fn: Callable[[float], None] = time.sleep,
) -> tuple[Any, Dict[str, int]]:
    """Run ``state = step_fn(state, k)`` for k in [0, n_steps) with recovery.

    Any exception in ``recoverable`` restores from the latest checkpoint
    and retries (up to ``max_restarts``, with exponential backoff when
    ``backoff_base > 0``); everything else propagates immediately.
    ``sleep_fn`` is injectable so tests assert the backoff schedule
    without actually sleeping.
    """
    stats = {"restarts": 0, "checkpoints": 0}
    state = init_state
    start = 0
    latest = manager.latest_step()
    if latest is not None:
        state, start = manager.restore(init_state)
        start += 1

    restarts = 0
    k = start
    while k < n_steps:
        try:
            if fault_injector is not None:
                fault_injector.maybe_fail(k)
            state = step_fn(state, k)
            if (k + 1) % checkpoint_every == 0 or k == n_steps - 1:
                manager.save(k, state)
                manager.wait()
                stats["checkpoints"] += 1
            k += 1
        except recoverable:
            restarts += 1
            stats["restarts"] += 1
            if on_event:
                on_event("restart", k)
            if restarts > max_restarts:
                raise
            delay = backoff_delay(restarts, base=backoff_base,
                                  factor=backoff_factor, cap=backoff_cap)
            if delay > 0:
                sleep_fn(delay)
            latest = manager.latest_step()
            if latest is None:
                state, k = init_state, 0
            else:
                state, kk = manager.restore(init_state)
                k = kk + 1
    return state, stats
