"""Crash-restart driver: checkpoint/restore around injected or real faults.

``run_with_recovery`` wraps a step function with the full fault-tolerance
loop: periodic checkpoints, restore-on-failure, bounded retries.  The
``FaultInjector`` lets tests (and the chaos-style example) kill arbitrary
steps and assert bit-exact recovery — possible because the optimizer state
is checkpointed and the data pipeline is seekable (batch k is a pure
function of k).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

from repro.checkpoint.manager import CheckpointManager


class SimulatedFault(RuntimeError):
    pass


@dataclasses.dataclass
class FaultInjector:
    """Raise a SimulatedFault at the given step numbers (once each)."""
    fail_at: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFault(f"injected fault at step {step}")


def run_with_recovery(
    step_fn: Callable[[Any, int], Any],
    init_state: Any,
    n_steps: int,
    manager: CheckpointManager,
    *,
    checkpoint_every: int = 10,
    max_restarts: int = 5,
    fault_injector: Optional[FaultInjector] = None,
    on_event: Optional[Callable[[str, int], None]] = None,
) -> tuple[Any, Dict[str, int]]:
    """Run ``state = step_fn(state, k)`` for k in [0, n_steps) with recovery."""
    stats = {"restarts": 0, "checkpoints": 0}
    state = init_state
    start = 0
    latest = manager.latest_step()
    if latest is not None:
        state, start = manager.restore(init_state)
        start += 1

    restarts = 0
    k = start
    while k < n_steps:
        try:
            if fault_injector is not None:
                fault_injector.maybe_fail(k)
            state = step_fn(state, k)
            if (k + 1) % checkpoint_every == 0 or k == n_steps - 1:
                manager.save(k, state)
                manager.wait()
                stats["checkpoints"] += 1
            k += 1
        except SimulatedFault:
            restarts += 1
            stats["restarts"] += 1
            if on_event:
                on_event("restart", k)
            if restarts > max_restarts:
                raise
            latest = manager.latest_step()
            if latest is None:
                state, k = init_state, 0
            else:
                state, kk = manager.restore(init_state)
                k = kk + 1
    return state, stats
