"""Straggler mitigation: per-step timing EWMA with outlier detection.

At multi-pod scale a single slow host drags every synchronous collective.
The monitor tracks per-step wall time (per host in a real deployment —
here, per process), flags steps slower than ``threshold ×`` the EWMA, and
recommends an action the driver acts on:

  * ``"warn"``      — sporadic outlier (logging only)
  * ``"checkpoint"``— persistent degradation: snapshot now so a replace-
                      and-restart loses no work
  * ``"evict"``     — repeated offender past ``evict_after``: the driver
                      should drop the host and re-derive an elastic mesh
                      (``repro.runtime.elastic``)

This is the same escalation ladder MaxText/Pathways-style deployments use;
the decision logic is fully testable on one host.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0          # step slower than threshold*ewma = outlier
    alpha: float = 0.1              # EWMA coefficient
    evict_after: int = 3            # consecutive outliers before eviction
    ewma: Optional[float] = None
    consecutive_slow: int = 0
    history: List[float] = dataclasses.field(default_factory=list)
    _t0: Optional[float] = None

    def start_step(self):
        self._t0 = time.perf_counter()

    def end_step(self) -> str:
        assert self._t0 is not None, "start_step() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(dt)

    def observe(self, dt: float) -> str:
        """Feed one step duration; returns the recommended action."""
        self.history.append(dt)
        if self.ewma is None:
            self.ewma = dt
            return "ok"
        slow = dt > self.threshold * self.ewma
        if slow:
            self.consecutive_slow += 1
        else:
            self.consecutive_slow = 0
            # only fold non-outlier steps into the EWMA (robustness)
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        if self.consecutive_slow >= self.evict_after:
            return "evict"
        if self.consecutive_slow >= 2:
            return "checkpoint"
        if slow:
            return "warn"
        return "ok"
