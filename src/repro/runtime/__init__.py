from repro.runtime.straggler import StragglerMonitor
from repro.runtime.elastic import derive_mesh_shape, elastic_mesh
from repro.runtime.recovery import (
    FaultInjector,
    ShardLossFault,
    SimulatedFault,
    backoff_delay,
    is_transient_error,
    run_with_recovery,
)

__all__ = [
    "StragglerMonitor", "derive_mesh_shape", "elastic_mesh",
    "run_with_recovery", "FaultInjector", "ShardLossFault",
    "SimulatedFault", "backoff_delay", "is_transient_error",
]
