from repro.runtime.straggler import StragglerMonitor
from repro.runtime.elastic import derive_mesh_shape, elastic_mesh
from repro.runtime.recovery import run_with_recovery, FaultInjector

__all__ = [
    "StragglerMonitor", "derive_mesh_shape", "elastic_mesh",
    "run_with_recovery", "FaultInjector",
]
