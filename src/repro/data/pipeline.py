"""Deterministic, seekable token data pipeline.

Restart-exactness is the data-side half of fault tolerance: batch ``k`` is
a pure function of ``(seed, k)`` (counter-based RNG), so a job restored
from a step-``k`` checkpoint consumes exactly the batches it would have —
no pipeline state to checkpoint, any host can produce any shard.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticTokenPipeline:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    step: int = 0

    def seek(self, step: int) -> "SyntheticTokenPipeline":
        self.step = step
        return self

    def _rng(self, step: int) -> np.random.Generator:
        # counter-based: independent stream per (seed, step)
        return np.random.default_rng(np.random.SeedSequence([self.seed, step]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        # zipf-ish marginal over the vocab: realistic logit scale for CE
        raw = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        tokens = (raw - 1) % self.vocab_size
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            b = self.batch_at(self.step)
            self.step += 1
            yield b


def make_corpus(
    n_docs: int,
    doc_len: int,
    vocab_size: int,
    *,
    dup_fraction: float = 0.3,
    near_dup_noise: float = 0.05,
    seed: int = 0,
) -> List[np.ndarray]:
    """Synthetic corpus with planted (near-)duplicate clusters.

    ``dup_fraction`` of documents are noisy copies of earlier documents —
    the ground truth the MinHash+Contour dedup stage must recover.
    """
    rng = np.random.default_rng(seed)
    docs: List[np.ndarray] = []
    for i in range(n_docs):
        if docs and rng.random() < dup_fraction:
            base = docs[int(rng.integers(len(docs)))].copy()
            flip = rng.random(base.shape[0]) < near_dup_noise
            base[flip] = rng.integers(0, vocab_size, flip.sum())
            docs.append(base)
        else:
            docs.append(rng.integers(0, vocab_size, doc_len).astype(np.int64))
    return docs
