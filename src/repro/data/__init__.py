from repro.data.pipeline import SyntheticTokenPipeline, make_corpus
from repro.data.dedup import minhash_dedup, DedupReport

__all__ = ["SyntheticTokenPipeline", "make_corpus", "minhash_dedup", "DedupReport"]
