"""MinHash-LSH near-duplicate detection with Contour connected components.

This is the production integration of the paper's algorithm (DESIGN.md §5):
RefinedWeb/SlimPajama-style dedup builds a similarity graph from MinHash
LSH collisions and needs connected components to turn pairwise collisions
into duplicate *clusters* — at corpus scale the CC step is the scalability
bottleneck, which is exactly the regime Contour targets (massive edge
parallelism, tiny iteration count).

Pipeline: shingle -> MinHash signatures -> LSH banding -> candidate pairs
-> Contour CC -> keep the minimum doc id per cluster (Contour's min-label
fixed point *is* the canonical representative).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.connectivity import SolveOptions, solve
from repro.graphs.structs import Graph, canonicalize_edges

_MERSENNE = (1 << 61) - 1


@dataclasses.dataclass
class DedupReport:
    labels: np.ndarray          # cluster label (min doc id) per doc
    keep: np.ndarray            # bool per doc: cluster representative?
    n_clusters: int
    n_candidate_pairs: int
    cc_iterations: int


def _shingles(doc: np.ndarray, k: int) -> np.ndarray:
    if doc.shape[0] < k:
        return doc[None, :].copy() if doc.shape[0] else np.zeros((1, 1), np.int64)
    return np.lib.stride_tricks.sliding_window_view(doc, k)


def minhash_signatures(
    docs: Sequence[np.ndarray], n_hashes: int = 64, shingle: int = 5, seed: int = 0
) -> np.ndarray:
    """(n_docs, n_hashes) int64 MinHash signatures."""
    rng = np.random.default_rng(seed)
    a = rng.integers(1, _MERSENNE, n_hashes, dtype=np.int64)
    b = rng.integers(0, _MERSENNE, n_hashes, dtype=np.int64)
    sigs = np.empty((len(docs), n_hashes), np.int64)
    for i, doc in enumerate(docs):
        sh = _shingles(np.asarray(doc, np.int64), shingle)
        # polynomial-hash each shingle to one 61-bit value
        h = np.zeros(sh.shape[0], np.int64)
        for c in range(sh.shape[1]):
            h = (h * np.int64(1_000_003) + sh[:, c]) % _MERSENNE
        hv = (h[:, None] * a[None, :] + b[None, :]) % _MERSENNE
        sigs[i] = hv.min(axis=0)
    return sigs


def lsh_candidate_pairs(
    sigs: np.ndarray, bands: int = 16
) -> tuple[np.ndarray, np.ndarray]:
    """Band the signatures; docs sharing any band bucket become an edge."""
    n_docs, n_hashes = sigs.shape
    assert n_hashes % bands == 0
    rows = n_hashes // bands
    srcs, dsts = [], []
    for b in range(bands):
        band = sigs[:, b * rows : (b + 1) * rows]
        key = np.zeros(n_docs, np.int64)
        for c in range(rows):
            key = (key * np.int64(1_000_003) + band[:, c]) % _MERSENNE
        order = np.argsort(key, kind="stable")
        ks = key[order]
        # group boundaries; chain consecutive members of each bucket
        same = ks[1:] == ks[:-1]
        srcs.append(order[:-1][same])
        dsts.append(order[1:][same])
    if not srcs:
        return np.zeros(0, np.int32), np.zeros(0, np.int32)
    return np.concatenate(srcs).astype(np.int64), np.concatenate(dsts).astype(np.int64)


def minhash_dedup(
    docs: Sequence[np.ndarray],
    *,
    n_hashes: int = 64,
    bands: int = 16,
    shingle: int = 5,
    seed: int = 0,
    variant: str = "C-2",
) -> DedupReport:
    """Full dedup pass; the CC step runs the paper's Contour algorithm."""
    n = len(docs)
    sigs = minhash_signatures(docs, n_hashes=n_hashes, shingle=shingle, seed=seed)
    src, dst = lsh_candidate_pairs(sigs, bands=bands)
    src, dst = canonicalize_edges(src, dst, n)
    if src.shape[0] == 0:
        labels = np.arange(n)
        return DedupReport(labels, np.ones(n, bool), n, 0, 0)
    g = Graph.from_numpy(src, dst, n)
    result = solve(g, SolveOptions(algorithm="contour", variant=variant))
    labels = np.asarray(result.labels)
    keep = labels == np.arange(n)
    return DedupReport(
        labels=labels,
        keep=keep,
        n_clusters=int(keep.sum()),
        n_candidate_pairs=int(src.shape[0]),
        cc_iterations=int(result.iterations),
    )
