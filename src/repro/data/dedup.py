"""MinHash-LSH near-duplicate detection with Contour connected components.

This is the production integration of the paper's algorithm (DESIGN.md §5):
RefinedWeb/SlimPajama-style dedup builds a similarity graph from MinHash
LSH collisions and needs connected components to turn pairwise collisions
into duplicate *clusters* — at corpus scale the CC step is the scalability
bottleneck, which is exactly the regime Contour targets (massive edge
parallelism, tiny iteration count).

Pipeline: shingle -> MinHash signatures -> LSH banding -> candidate pairs
-> Contour CC -> keep the minimum doc id per cluster (Contour's min-label
fixed point *is* the canonical representative).

Two entry points:

* :func:`minhash_dedup` — one batch pass over a finite corpus;
* :class:`StreamingDedup` — the *online* form: documents arrive in
  micro-batches, each batch's LSH collisions are ingested into a
  :class:`~repro.connectivity.streaming.StreamingConnectivity` engine,
  and cluster membership is queryable after every batch without
  re-solving (serve-path dedup: "is this an already-seen page?").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.connectivity import SolveOptions, StreamingConnectivity, solve
from repro.graphs.structs import Graph, canonicalize_edges

_MERSENNE = (1 << 61) - 1


@dataclasses.dataclass
class DedupReport:
    labels: np.ndarray          # cluster label (min doc id) per doc
    keep: np.ndarray            # bool per doc: cluster representative?
    n_clusters: int
    n_candidate_pairs: int
    cc_iterations: int


def _shingles(doc: np.ndarray, k: int) -> np.ndarray:
    if doc.shape[0] < k:
        return doc[None, :].copy() if doc.shape[0] else np.zeros((1, 1), np.int64)
    return np.lib.stride_tricks.sliding_window_view(doc, k)


def minhash_signatures(
    docs: Sequence[np.ndarray], n_hashes: int = 64, shingle: int = 5, seed: int = 0
) -> np.ndarray:
    """(n_docs, n_hashes) int64 MinHash signatures."""
    rng = np.random.default_rng(seed)
    a = rng.integers(1, _MERSENNE, n_hashes, dtype=np.int64)
    b = rng.integers(0, _MERSENNE, n_hashes, dtype=np.int64)
    sigs = np.empty((len(docs), n_hashes), np.int64)
    for i, doc in enumerate(docs):
        sh = _shingles(np.asarray(doc, np.int64), shingle)
        # polynomial-hash each shingle to one 61-bit value
        h = np.zeros(sh.shape[0], np.int64)
        for c in range(sh.shape[1]):
            h = (h * np.int64(1_000_003) + sh[:, c]) % _MERSENNE
        hv = (h[:, None] * a[None, :] + b[None, :]) % _MERSENNE
        sigs[i] = hv.min(axis=0)
    return sigs


def _band_keys(sigs: np.ndarray, bands: int) -> np.ndarray:
    """(n_docs, bands) int64 bucket key per band.

    The single definition of the band hash: both the batch pass
    (:func:`lsh_candidate_pairs`) and the streaming pass
    (:class:`StreamingDedup`) bucket through it, which is what makes
    their cluster partitions bit-identical.
    """
    n_docs, n_hashes = sigs.shape
    assert n_hashes % bands == 0
    rows = n_hashes // bands
    keys = np.empty((n_docs, bands), np.int64)
    for b in range(bands):
        band = sigs[:, b * rows:(b + 1) * rows]
        key = np.zeros(n_docs, np.int64)
        for c in range(rows):
            key = (key * np.int64(1_000_003) + band[:, c]) % _MERSENNE
        keys[:, b] = key
    return keys


def lsh_candidate_pairs(
    sigs: np.ndarray, bands: int = 16
) -> tuple[np.ndarray, np.ndarray]:
    """Band the signatures; docs sharing any band bucket become an edge."""
    keys = _band_keys(sigs, bands)
    srcs, dsts = [], []
    for b in range(bands):
        key = keys[:, b]
        order = np.argsort(key, kind="stable")
        ks = key[order]
        # group boundaries; chain consecutive members of each bucket
        same = ks[1:] == ks[:-1]
        srcs.append(order[:-1][same])
        dsts.append(order[1:][same])
    if not srcs:
        return np.zeros(0, np.int32), np.zeros(0, np.int32)
    return np.concatenate(srcs).astype(np.int64), np.concatenate(dsts).astype(np.int64)


def minhash_dedup(
    docs: Sequence[np.ndarray],
    *,
    n_hashes: int = 64,
    bands: int = 16,
    shingle: int = 5,
    seed: int = 0,
    variant: str = "C-2",
) -> DedupReport:
    """Full dedup pass; the CC step runs the paper's Contour algorithm."""
    n = len(docs)
    sigs = minhash_signatures(docs, n_hashes=n_hashes, shingle=shingle, seed=seed)
    src, dst = lsh_candidate_pairs(sigs, bands=bands)
    src, dst = canonicalize_edges(src, dst, n)
    if src.shape[0] == 0:
        labels = np.arange(n)
        return DedupReport(labels, np.ones(n, bool), n, 0, 0)
    g = Graph.from_numpy(src, dst, n)
    result = solve(g, SolveOptions(algorithm="contour", variant=variant))
    labels = np.asarray(result.labels)
    keep = labels == np.arange(n)
    return DedupReport(
        labels=labels,
        keep=keep,
        n_clusters=int(keep.sum()),
        n_candidate_pairs=int(src.shape[0]),
        cc_iterations=int(result.iterations),
    )


class StreamingDedup:
    """Online MinHash-LSH dedup over document micro-batches.

    Maintains, per LSH band, a host dict ``bucket key -> first doc id``;
    each new document that lands in an occupied bucket contributes one
    candidate edge to its bucket's representative — within a bucket that
    chains every member into one component, the same partition the batch
    path's consecutive-pair chaining produces.  The edges stream into a
    :class:`StreamingConnectivity` engine (vertex set grown per batch),
    so ``labels()``/``is_duplicate()`` answer after every batch from the
    resident converged labels — no per-query re-solve.

    The MinHash parameters are seeded identically to
    :func:`minhash_signatures`, so a streamed corpus clusters exactly
    like the one-shot :func:`minhash_dedup` pass over the same docs
    (property-tested in ``tests/test_data_dedup.py``).
    """

    def __init__(self, *, n_hashes: int = 64, bands: int = 16,
                 shingle: int = 5, seed: int = 0,
                 options: Optional[SolveOptions] = None):
        self._kw = dict(n_hashes=n_hashes, shingle=shingle, seed=seed)
        self._bands = bands
        self._buckets: List[Dict[int, int]] = [dict() for _ in range(bands)]
        self._n_docs = 0
        self._n_pairs = 0
        self._engine = StreamingConnectivity(
            0, options if options is not None
            else SolveOptions(algorithm="contour"))

    @property
    def engine(self) -> StreamingConnectivity:
        """The underlying connectivity engine (for snapshots/counters)."""
        return self._engine

    @property
    def n_docs(self) -> int:
        return self._n_docs

    @property
    def n_candidate_pairs(self) -> int:
        return self._n_pairs

    def add_docs(self, docs: Sequence[np.ndarray]) -> np.ndarray:
        """Ingest a document micro-batch; returns the new docs' ids."""
        ids = np.arange(self._n_docs, self._n_docs + len(docs))
        if not len(docs):
            return ids
        sigs = minhash_signatures(docs, n_hashes=self._kw["n_hashes"],
                                  shingle=self._kw["shingle"],
                                  seed=self._kw["seed"])
        keys = _band_keys(sigs, self._bands)
        srcs, dsts = [], []
        for i, doc_id in enumerate(ids):
            for b in range(self._bands):
                rep = self._buckets[b].setdefault(int(keys[i, b]),
                                                  int(doc_id))
                if rep != doc_id:
                    srcs.append(rep)
                    dsts.append(int(doc_id))
        self._n_docs += len(docs)
        self._n_pairs += len(srcs)
        self._engine.ingest(np.asarray(srcs, np.int64),
                            np.asarray(dsts, np.int64),
                            n_vertices=self._n_docs)
        return ids

    def labels(self) -> np.ndarray:
        """Cluster label (min doc id) per ingested doc — O(1) snapshot."""
        return np.asarray(self._engine.labels)

    def is_duplicate(self, doc_id) -> bool:
        """True iff ``doc_id`` is not its cluster's representative."""
        return int(self._engine.component_of(doc_id)) != int(doc_id)

    # -- checkpointing (DESIGN.md §12) -----------------------------------
    def state_dict(self) -> dict:
        """Full checkpointable state: LSH buckets + the engine's state.

        The per-band bucket dicts are packed into one ``[P, 3]``
        ``(band, key, representative)`` array so the whole thing is a
        flat array pytree for ``CheckpointManager``; the nested
        ``"engine"`` entry is the connectivity engine's own
        :meth:`~repro.connectivity.StreamingConnectivity.state_dict`.
        """
        triples = [(b, k, rep)
                   for b, bucket in enumerate(self._buckets)
                   for k, rep in bucket.items()]
        return {
            "buckets": np.asarray(triples, np.int64).reshape(-1, 3),
            "n_docs": np.int64(self._n_docs),
            "n_pairs": np.int64(self._n_pairs),
            "engine": self._engine.state_dict(),
        }

    def load_state_dict(self, state: dict) -> "StreamingDedup":
        """Restore to a :meth:`state_dict` snapshot in place (the MinHash
        parameters are construction-time config, not state — build the
        instance with the same ``n_hashes``/``bands``/``shingle``/
        ``seed`` to resume identically)."""
        buckets: List[Dict[int, int]] = [dict() for _ in range(self._bands)]
        for band, key, rep in np.asarray(state["buckets"],
                                         np.int64).reshape(-1, 3):
            if not 0 <= band < self._bands:
                raise ValueError(
                    f"corrupt checkpoint: band {band} outside "
                    f"[0, {self._bands})")
            buckets[int(band)][int(key)] = int(rep)
        self._buckets = buckets
        self._n_docs = int(state["n_docs"])
        self._n_pairs = int(state["n_pairs"])
        self._engine.load_state_dict(state["engine"])
        return self

    def report(self) -> DedupReport:
        """Cumulative :class:`DedupReport` over everything streamed."""
        labels = self.labels()
        keep = labels == np.arange(self._n_docs)
        return DedupReport(
            labels=labels,
            keep=keep,
            n_clusters=int(keep.sum()),
            n_candidate_pairs=self._n_pairs,
            cc_iterations=int(self._engine.snapshot().iterations),
        )
