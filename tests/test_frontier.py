"""Work-adaptive edge-frontier contraction (DESIGN.md §10).

The load-bearing property: the sampled/compacted schedule must reach a
fixed point *bit-identical* to the dense every-edge schedule (which is
itself oracle-exact) — contraction rewrites edges to representatives, so
this is a real theorem to defend, not a tautology.  Plus the work
accounting: ``edges_visited`` strictly below dense ``iterations × m``,
``active_m`` monotonically non-increasing across compactions.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.connectivity import SolveOptions, solve, solve_batch
from repro.connectivity import frontier as fr
from repro.connectivity import minmap as lab
from repro.connectivity.contour import contour_labels
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle
from repro.graphs.structs import Graph, canonicalize_edges
from repro.kernels.contour_mm.ops import KernelPlan, contour_cc_fixpoint

# A fixed tile plan keeps the blocked-kernel tests off the autotuner and
# in interpret (CPU validation) mode.
_BLOCKED_PLAN = KernelPlan(backend="pallas_blocked", label_block=256,
                           chunk_updates=64, interpret=True)


def _graph(n, m, seed):
    rng = np.random.default_rng(seed)
    s, d = canonicalize_edges(rng.integers(0, n, m), rng.integers(0, n, m), n)
    if s.shape[0] == 0:
        s, d = np.array([0]), np.array([0])
    return Graph.from_numpy(s, d, n)


# ---------------------------------------------------------------------------
# bit-identical fixed point: adaptive vs dense (the uncompacted oracle)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sampling,compact_every", [(0, 1), (0, 2), (2, 0),
                                                    (2, 2), (3, 1)])
@pytest.mark.parametrize("variant", ["C-1", "C-2", "C-m"])
def test_adaptive_bit_identical_to_dense(variant, sampling, compact_every):
    g = gen.components_mix(
        [gen.path(240, seed=1), gen.star(150, seed=2), gen.rmat(8, seed=3)],
        seed=4)
    oracle = connected_components_oracle(*g.to_numpy())
    dense = solve(g, variant=variant, backend="xla")
    adaptive = solve(g, variant=variant, backend="xla",
                     sampling=sampling, compact_every=compact_every)
    assert np.array_equal(np.asarray(adaptive.labels),
                          np.asarray(dense.labels))
    assert np.array_equal(np.asarray(adaptive.labels), oracle)
    assert bool(adaptive.converged)


def test_adaptive_property_random_graphs():
    """Hypothesis sweep: compacted == dense == oracle on random graphs."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    # one static shape -> one jit trace per (sampling, compact_every);
    # hypothesis varies the edge structure inside it
    n, m = 64, 96

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(0, 3), st.integers(0, 3))
    def prop(seed, sampling, compact_every):
        g = _graph(n, m, seed)
        oracle = connected_components_oracle(*g.to_numpy())
        adaptive = solve(g, variant="C-2", backend="xla",
                         sampling=sampling, compact_every=compact_every)
        assert np.array_equal(np.asarray(adaptive.labels), oracle), (
            seed, sampling, compact_every)

    prop()


def test_adaptive_warm_start_matches_dense():
    """Warm-started adaptive solve: same fixed point as dense cold/warm."""
    base = gen.path(300, seed=5)
    prev = solve(base, variant="C-2")
    rng = np.random.default_rng(6)
    grown = base.add_edges(rng.integers(0, 380, 40),
                           rng.integers(0, 380, 40), n_vertices=380)
    oracle = connected_components_oracle(*grown.to_numpy())
    dense = solve(grown, variant="C-2", warm_start=prev)
    adaptive = solve(grown, variant="C-2", warm_start=prev,
                     sampling=2, compact_every=1)
    assert np.array_equal(np.asarray(adaptive.labels),
                          np.asarray(dense.labels))
    assert np.array_equal(np.asarray(adaptive.labels), oracle)


def test_adaptive_solve_batch_matches_dense():
    graphs = [gen.path(40, seed=0), gen.rmat(6, seed=1),
              gen.star(30, seed=2)]
    dense = solve_batch(graphs, variant="C-2")
    adaptive = solve_batch(graphs, variant="C-2", sampling=2,
                           compact_every=1)
    assert np.array_equal(np.asarray(adaptive.labels),
                          np.asarray(dense.labels))
    for r, g in zip(adaptive.unstack(), graphs):
        oracle = connected_components_oracle(*g.to_numpy())
        assert np.array_equal(np.asarray(r.labels), oracle)


def test_adaptive_blocked_interpret_backend():
    """The frontier limit threads into the blocked kernel's dead-bin path
    (interpret mode here; on TPU the same path skips whole grid steps)."""
    g = gen.components_mix([gen.path(200, seed=7), gen.rmat(8, seed=8)],
                           seed=9)
    oracle = connected_components_oracle(*g.to_numpy())
    r = solve(g, variant="C-2", backend="pallas_blocked",
              plan=_BLOCKED_PLAN, sampling=2, compact_every=2)
    assert np.array_equal(np.asarray(r.labels), oracle)
    assert float(r.edges_visited) < int(r.iterations) * g.n_edges


def test_adaptive_kernel_fixpoint_matches_classic():
    """`contour_cc_fixpoint` under the adaptive schedule (the C-2-blk
    bench path) reaches the classic path's exact labels."""
    g = gen.components_mix([gen.path(300, seed=1), gen.star(200, seed=2)],
                           seed=3)
    classic, it_c, ok_c, visited_c = contour_cc_fixpoint(g, backend="xla")
    adaptive, it_a, ok_a, visited_a = contour_cc_fixpoint(
        g, backend="xla", sampling=2, compact_every=2)
    assert bool(ok_c) and bool(ok_a)
    assert np.array_equal(np.asarray(adaptive), np.asarray(classic))
    assert float(visited_c) == float(it_c) * g.n_edges
    assert float(visited_a) < float(it_a) * g.n_edges


# ---------------------------------------------------------------------------
# work accounting
# ---------------------------------------------------------------------------


def test_edges_visited_dense_vs_compacted():
    g = gen.path(4096, seed=11)
    dense = solve(g, variant="C-2", backend="xla")
    assert float(dense.edges_visited) == int(dense.iterations) * g.n_edges
    adaptive = solve(g, variant="C-2", backend="xla", sampling=2,
                     compact_every=1)
    assert float(adaptive.edges_visited) < int(adaptive.iterations) * g.n_edges
    assert float(adaptive.edges_visited) > 0


def test_active_m_monotone_across_compactions():
    """`contract_edges` can only retire edges: active_m never grows,
    whatever the interleaving of sweeps and label movement."""
    g = gen.components_mix([gen.path(120, seed=1), gen.rmat(7, seed=2)],
                           seed=3)
    L = jnp.arange(g.n_vertices, dtype=jnp.int32)
    src, dst = g.src, g.dst
    active_m = jnp.int32(g.n_edges)
    counts = [int(active_m)]
    for it in range(8):
        L = lab.mm_relax(L, src, dst, order=2)
        L = lab.pointer_jump(L, rounds=1)
        if it == 1:  # the one largest-component filter pass
            c_hat = fr.largest_component_label(L, g.n_vertices)
            src, dst, active_m = fr.contract_edges(L, src, dst, active_m,
                                                   only_label=c_hat)
        else:
            src, dst, active_m = fr.contract_edges(L, src, dst, active_m)
        counts.append(int(active_m))
    assert counts == sorted(counts, reverse=True)
    assert counts[-1] < counts[0]          # work actually shrank
    # retired suffix means the prefix layout is preserved for the live set
    assert int(active_m) >= 0


def test_largest_component_label_is_mode():
    L = jnp.asarray([0, 0, 0, 3, 3, 5], jnp.int32)
    assert int(fr.largest_component_label(L, 6)) == 0


def test_sample_prefix_m_floor():
    assert fr.sample_prefix_m(1) == 1
    assert fr.sample_prefix_m(3) == 1
    assert fr.sample_prefix_m(4096) == 1024


# ---------------------------------------------------------------------------
# schedule plumbing / guards
# ---------------------------------------------------------------------------


def test_c_syn_rejects_adaptive_schedule():
    g = gen.path(50, seed=0)
    with pytest.raises(ValueError, match="C-Syn"):
        solve(g, variant="C-Syn", sampling=2)
    with pytest.raises(ValueError, match="C-Syn"):
        contour_labels(g.src, g.dst, g.n_vertices, variant="C-Syn",
                       compact_every=1)


def test_adaptive_loop_stays_on_device():
    """The adaptive schedule must lower to on-device while loops — edge
    arrays, active_m, and the convergence flag are all loop state; any
    host-side compaction would fail to trace under this jit."""
    g = gen.rmat(8, seed=13)
    txt = contour_labels.lower(
        g.src, g.dst, g.n_vertices, variant="C-2", sampling=2,
        compact_every=2).as_text()
    assert "while" in txt


def test_solve_options_validate_rejects_negative_counts():
    g = gen.path(20, seed=0)
    for field in ("warmup", "async_compress", "sampling", "compact_every"):
        with pytest.raises(ValueError, match=field):
            SolveOptions(**{field: -1}).validate()
        with pytest.raises(ValueError, match=field):
            solve(g, **{field: -1})
    # zero stays legal for all four
    SolveOptions(warmup=0, async_compress=0, sampling=0,
                 compact_every=0).validate()


def test_distributed_adaptive_single_device_mesh():
    """Per-shard contraction on the degenerate 1-device mesh (the
    multi-device case runs in test_distributed's subprocess tier)."""
    from repro import jax_compat
    from repro.connectivity.distributed import distributed_contour

    mesh = jax_compat.device_mesh(np.array(jax.devices()[:1]), ("data",))
    g = gen.components_mix([gen.path(300, seed=1), gen.rmat(8, seed=2)],
                           seed=3)
    oracle = connected_components_oracle(*g.to_numpy())
    dense_L, _, _, dense_v = distributed_contour(g, mesh,
                                                 edge_axes=("data",))
    L, rounds, ok, visited = distributed_contour(
        g, mesh, edge_axes=("data",), sampling=2, compact_every=2)
    assert bool(ok)
    assert np.array_equal(np.asarray(L), np.asarray(dense_L))
    assert np.array_equal(np.asarray(L), oracle)
    assert float(visited) < float(dense_v) or int(rounds) < 3


# ---------------------------------------------------------------------------
# contract_edges degenerate boundaries (the O(m) cumsum partition)
# ---------------------------------------------------------------------------


def test_contract_edges_empty_frontier():
    """active_m == 0: every edge is already retired — nothing relabels,
    nothing moves, the count stays zero (the partition's base case)."""
    L = jnp.arange(6, dtype=jnp.int32)
    src = jnp.array([0, 2, 4], jnp.int32)
    dst = jnp.array([1, 3, 5], jnp.int32)
    s, d, am = fr.contract_edges(L, src, dst, jnp.int32(0))
    assert int(am) == 0
    assert np.array_equal(np.asarray(s), np.asarray(src))
    assert np.array_equal(np.asarray(d), np.asarray(dst))


def test_contract_edges_zero_length_arrays():
    """m == 0: the cumsum ranks are empty slices, not an error."""
    L = jnp.arange(4, dtype=jnp.int32)
    e = jnp.zeros(0, jnp.int32)
    s, d, am = fr.contract_edges(L, e, e, jnp.int32(0))
    assert int(am) == 0 and s.shape == (0,) and d.shape == (0,)


def test_contract_edges_all_active_all_retire():
    """Every active edge is an intra-component self-loop after the
    depth-2 relabel: n_keep hits 0 and the retirees keep stream order,
    rewritten to their representatives."""
    L = jnp.array([0, 0, 0, 3, 3], jnp.int32)
    src = jnp.array([1, 2, 4], jnp.int32)
    dst = jnp.array([2, 0, 3], jnp.int32)
    s, d, am = fr.contract_edges(L, src, dst, jnp.int32(3))
    assert int(am) == 0
    assert np.array_equal(np.asarray(s), [0, 0, 3])
    assert np.array_equal(np.asarray(d), [0, 0, 3])


def test_contract_edges_single_survivor():
    """Exactly one inter-component edge survives: it must land at slot 0
    (the keep-rank) with both retirees stably behind it."""
    L = jnp.array([0, 0, 2, 2], jnp.int32)
    src = jnp.array([0, 1, 2], jnp.int32)
    dst = jnp.array([1, 2, 3], jnp.int32)
    s, d, am = fr.contract_edges(L, src, dst, jnp.int32(3))
    assert int(am) == 1
    assert (int(s[0]), int(d[0])) == (0, 2)
    assert np.array_equal(np.asarray(s)[1:], [0, 2])
    assert np.array_equal(np.asarray(d)[1:], [0, 2])
