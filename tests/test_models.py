"""Model-zoo behaviour: forward/backward, prefill/decode consistency, MoE."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # large-model forward/backward; excluded from the fast tier

from repro.models.common import ModelConfig
from repro.models.model import build_model

TINY = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=256, vocab_pad_multiple=32, remat="none")


def _batch(b=2, t=16, vocab=256, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "tokens": jax.random.randint(k1, (b, t), 0, vocab),
        "labels": jax.random.randint(k2, (b, t), 0, vocab),
    }


CONFIGS = {
    "dense": ModelConfig(name="d", family="dense", **TINY),
    "moe": ModelConfig(name="m", family="moe", **TINY, moe_style="deepseek",
                       n_experts=4, top_k=2, n_shared_experts=1, d_expert=32,
                       first_k_dense=1, dense_d_ff=128, moe_groups=2),
    "ssm": ModelConfig(name="x", family="ssm",
                       **{**TINY, "n_layers": 4, "d_ff": 0,
                          "n_kv_heads": 4}, slstm_every=4),
    "hybrid": ModelConfig(name="z", family="hybrid",
                          **{**TINY, "n_layers": 4, "n_kv_heads": 4},
                          ssm_state=16, attn_every=2),
    "audio": ModelConfig(name="a", family="audio",
                         **{**TINY, "n_kv_heads": 4},
                         n_enc_layers=2, n_dec_layers=2,
                         frontend="audio_stub"),
    "vlm": ModelConfig(name="v", family="vlm", **TINY,
                       frontend="patch_stub", n_frontend_tokens=4),
}


def _full_batch(config, b=2, t=16, seed=0):
    batch = _batch(b, t, config.vocab_size, seed)
    if config.frontend == "patch_stub":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(7), (b, config.n_frontend_tokens,
                                    config.d_model), jnp.float32)
    if config.frontend == "audio_stub":
        batch["frame_embeds"] = jax.random.normal(
            jax.random.PRNGKey(8), (b, t // 2, config.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("family", sorted(CONFIGS))
def test_loss_and_grads_finite(family):
    config = CONFIGS[family]
    model = build_model(config)
    params = model.init(jax.random.PRNGKey(0))
    batch = _full_batch(config)
    (loss, metrics), grads = jax.value_and_grad(
        model.loss, has_aux=True)(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    assert any(float(jnp.abs(g).sum()) > 0 for g in flat)


@pytest.mark.parametrize("family", sorted(CONFIGS))
def test_prefill_decode_consistency(family):
    """Greedy decode path == teacher-forced forward at the same positions.

    Prefill tokens[:, :t0], then decode tokens[t0], ... — the logits must
    match the full-sequence forward's logits at those positions."""
    config = CONFIGS[family]
    if family == "moe":
        # capacity drops are sequence-length dependent (8-token prefill
        # routes differently from 12-token forward); consistency is only
        # defined in the drop-free regime
        config = config.replace(capacity_factor=8.0)
    model = build_model(config)
    params = model.init(jax.random.PRNGKey(1))
    b, t, t0 = 2, 12, 8
    batch = _full_batch(config, b, t, seed=3)

    # full forward logits via loss-path internals: use prefill on the full
    # sequence (causal => its last-position logits equal forward's)
    full_logits, _ = model.prefill(params, batch)

    pre = {k: (v[:, :t0] if k in ("tokens", "labels") else v)
           for k, v in batch.items()}
    logits, cache = model.prefill(params, pre, max_len=t)
    outs = [logits[:, -1]]
    for i in range(t0, t):
        logits, cache = model.decode_step(
            params, batch["tokens"][:, i:i + 1], cache)
        outs.append(logits[:, -1])

    # decode at position t-1 consumed token t-1 => its logits must equal
    # the full prefill's last-position logits
    np.testing.assert_allclose(
        np.asarray(outs[-1], np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        atol=2e-2, rtol=2e-2)


def test_moe_capacity_drops_and_aux():
    config = CONFIGS["moe"].replace(capacity_factor=0.5)  # force drops
    model = build_model(config)
    params = model.init(jax.random.PRNGKey(2))
    loss, metrics = model.loss(params, _full_batch(config))
    assert np.isfinite(float(loss))
    assert float(metrics["aux"]) >= 1.0 - 1e-3   # Switch aux >= 1 at balance


def test_moe_groups_equivalence():
    """Grouped dispatch is a pure repartition: G=1 vs G=2 agree when no
    tokens are dropped (generous capacity)."""
    base = CONFIGS["moe"].replace(capacity_factor=8.0)
    m1 = build_model(base.replace(moe_groups=1))
    m2 = build_model(base.replace(moe_groups=2))
    params = m1.init(jax.random.PRNGKey(3))
    batch = _full_batch(base)
    l1, _ = m1.loss(params, batch)
    l2, _ = m2.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-5)


def test_rope_partial_and_biases():
    config = CONFIGS["dense"].replace(rotary_pct=0.25, use_qkv_bias=True,
                                      norm_type="layernorm")
    model = build_model(config)
    params = model.init(jax.random.PRNGKey(4))
    loss, _ = model.loss(params, _full_batch(config))
    assert np.isfinite(float(loss))


def test_nonparametric_norm_has_no_scale_params():
    config = CONFIGS["dense"].replace(norm_type="nonparametric")
    model = build_model(config)
    leaves = jax.tree_util.tree_leaves_with_path(model.param_specs())
    names = ["/".join(str(p) for p in path) for path, _ in leaves]
    assert not any("ln_attn" in n and "scale" in n for n in names)


def test_tied_embeddings_shape():
    config = CONFIGS["dense"].replace(tie_embeddings=True)
    model = build_model(config)
    params = model.init(jax.random.PRNGKey(5))
    assert "lm_head" not in params["embed"]
    loss, _ = model.loss(params, _full_batch(config))
    assert np.isfinite(float(loss))


def test_long_context_decode_state_is_o1():
    """ssm/hybrid decode state must not grow with cache length."""
    config = CONFIGS["ssm"]
    model = build_model(config)
    c_small = jax.eval_shape(lambda: model.init_cache(1, 128))
    c_large = jax.eval_shape(lambda: model.init_cache(1, 1 << 19))
    sz = lambda c: sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(c))
    assert sz(c_small) == sz(c_large)

    config = CONFIGS["hybrid"]   # shared attn block DOES grow (KV), mamba not
    model = build_model(config)
    c_small = jax.eval_shape(lambda: model.init_cache(1, 128))
    leaves = jax.tree_util.tree_leaves(c_small)
    assert len(leaves) > 0
