"""Warm-start / incremental solving: correctness against the from-scratch
oracle and the monotone-labels guarantee (min-mapping labels never
increase across a warm-started run)."""
import numpy as np
import pytest

from repro import SolveOptions, solve, solve_batch
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle

WARM_ALGOS = ("contour", "fastsv", "label_propagation", "union_find")


def _base_and_grown(kind: str, seed: int):
    """A base graph and the same graph with extra cross-component edges."""
    rng = np.random.default_rng(seed)
    if kind == "components_mix":
        base = gen.components_mix(
            [gen.path(800, seed=seed), gen.rmat(10, seed=seed + 1),
             gen.grid2d(20, 20)], seed=seed + 2)
    elif kind == "rmat":
        base = gen.rmat(11, seed=seed)
    else:
        raise ValueError(kind)
    n = base.n_vertices
    grown = base.add_edges(rng.integers(0, n, 12), rng.integers(0, n, 12))
    return base, grown


@pytest.mark.parametrize("kind", ("components_mix", "rmat"))
@pytest.mark.parametrize("algorithm", WARM_ALGOS)
def test_warm_start_matches_from_scratch_oracle(kind, algorithm):
    base, grown = _base_and_grown(kind, seed=11)
    opts = SolveOptions(algorithm=algorithm)
    prev = solve(base, opts)
    assert bool(prev.converged)

    warm = solve(grown, opts, warm_start=prev)
    oracle = connected_components_oracle(*grown.to_numpy())
    assert (np.asarray(warm.labels) == oracle).all(), (kind, algorithm)
    assert bool(warm.converged)
    # monotonicity: a warm-started run only ever lowers labels
    assert (np.asarray(warm.labels) <= np.asarray(prev.labels)).all()


def test_negative_warm_start_labels_raise():
    """Regression (ISSUE 3): a negative warm-start label survives the
    min(init, iota) clamp, and XLA gather then silently clamps the index
    to 0 — merging every poisoned vertex into component 0.  Both the
    canonical validator and the solve() facade must refuse eagerly."""
    from repro.connectivity import minmap

    g = gen.path(40, seed=0)
    bad = np.arange(g.n_vertices, dtype=np.int32)
    bad[7] = -3
    with pytest.raises(ValueError, match=">= 0"):
        minmap.resolve_init_labels(bad, g.n_vertices, np.int32)
    with pytest.raises(ValueError, match=">= 0"):
        solve(g, warm_start=bad)
    with pytest.raises(ValueError, match=">= 0"):
        solve_batch([g, g], warm_start=[bad, bad])
    # the all -1 labelling is the classic "uninitialised" poison
    with pytest.raises(ValueError, match=">= 0"):
        solve(g, warm_start=np.full(g.n_vertices, -1, np.int32))


def test_negative_warm_start_neutralised_under_trace():
    """Inside a user jax.jit the labels are tracers, so the eager check
    cannot fire — negatives must be neutralised to identity labels (a
    valid cold start) instead of being gather-clamped to vertex 0."""
    import jax
    import jax.numpy as jnp

    g = gen.components_mix([gen.path(30, seed=1), gen.star(20, seed=2)],
                           seed=3)
    oracle = connected_components_oracle(*g.to_numpy())
    bad = jnp.arange(g.n_vertices, dtype=jnp.int32).at[7].set(-5)

    @jax.jit
    def solve_traced(ws):
        return solve(g, warm_start=ws).labels

    labels = solve_traced(bad)
    assert (np.asarray(labels) == oracle).all()


@pytest.mark.parametrize("kind", ("components_mix", "rmat"))
def test_warm_start_accepts_raw_label_arrays(kind):
    base, grown = _base_and_grown(kind, seed=23)
    prev = solve(base)
    oracle = connected_components_oracle(*grown.to_numpy())
    # raw array instead of ComponentResult; options-field spelling too
    warm = solve(grown, warm_start=np.asarray(prev.labels))
    assert (np.asarray(warm.labels) == oracle).all()
    warm2 = solve(grown, SolveOptions(warm_start=prev.labels))
    assert (np.asarray(warm2.labels) == oracle).all()


def test_warm_start_after_vertex_growth():
    """add_edges may grow the vertex set; old labels still warm-start."""
    base = gen.rmat(9, seed=3)
    n_old = base.n_vertices
    grown = base.add_edges([0, 5], [n_old + 3, n_old + 7],
                           n_vertices=n_old + 8)
    prev = solve(base)
    warm = solve(grown, warm_start=prev)
    oracle = connected_components_oracle(*grown.to_numpy())
    assert (np.asarray(warm.labels) == oracle).all()


def test_warm_start_no_new_edges_is_a_fixed_point():
    """Re-solving with its own result converges immediately."""
    g = gen.components_mix([gen.path(500, seed=5), gen.rmat(9, seed=6)],
                           seed=7)
    prev = solve(g)
    again = solve(g, warm_start=prev)
    assert (np.asarray(again.labels) == np.asarray(prev.labels)).all()
    assert int(again.iterations) <= 2  # detect-convergence sweep only


def test_warm_start_iteration_savings_on_long_diameter():
    """The point of warm starts: few new edges, few new iterations."""
    base = gen.path(30_000, seed=8)
    rng = np.random.default_rng(9)
    grown = base.add_edges(rng.integers(0, 100, 3),
                           rng.integers(29_900, 30_000, 3))
    prev = solve(base)
    cold = solve(grown)
    warm = solve(grown, warm_start=prev)
    assert (np.asarray(warm.labels) == np.asarray(cold.labels)).all()
    assert int(warm.iterations) < int(cold.iterations)


def test_warm_start_distributed_mesh():
    import jax
    from repro import jax_compat
    mesh = jax_compat.device_mesh(np.array(jax.devices()[:1]), ("data",))
    base, grown = _base_and_grown("components_mix", seed=31)
    opts = SolveOptions(mesh=mesh)
    prev = solve(base, opts)
    warm = solve(grown, opts, warm_start=prev)
    oracle = connected_components_oracle(*grown.to_numpy())
    assert (np.asarray(warm.labels) == oracle).all()
    assert (np.asarray(warm.labels) <= np.asarray(prev.labels)).all()


def test_warm_start_batched():
    """Per-graph warm starts flow through solve_batch."""
    bases, growns = [], []
    for seed in (41, 42, 43):
        b, g = _base_and_grown("rmat", seed=seed)
        bases.append(b)
        growns.append(g)
    prev = solve_batch(bases)
    warm = solve_batch(growns, warm_start=prev.unstack())
    for part, g, p in zip(warm.unstack(), growns, prev.unstack()):
        oracle = connected_components_oracle(*g.to_numpy())
        assert (np.asarray(part.labels) == oracle).all()
        assert (np.asarray(part.labels) <= np.asarray(p.labels)).all()


def test_warm_start_batched_heterogeneous_sizes():
    """A previous batched result warm-starts a fleet of *different-size*
    graphs (padded rows are trimmed back per graph)."""
    rng = np.random.default_rng(51)
    bases = [gen.rmat(6, seed=1), gen.path(50, seed=2), gen.grid2d(5, 8)]
    growns = [b.add_edges(rng.integers(0, b.n_vertices, 2),
                          rng.integers(0, b.n_vertices, 2))
              for b in bases]
    prev = solve_batch(bases)
    for ws in (prev, prev.labels):   # whole result, or stacked [B, n] array
        warm = solve_batch(growns, warm_start=ws)
        for part, g in zip(warm.unstack(), growns):
            oracle = connected_components_oracle(*g.to_numpy())
            assert (np.asarray(part.labels) == oracle).all()


def test_warm_start_batched_via_options_field():
    """SolveOptions.warm_start works for solve_batch like it does for
    solve() — not just the per-call kwarg."""
    bases, growns = [], []
    for seed in (61, 62):
        b, g = _base_and_grown("rmat", seed=seed)
        bases.append(b)
        growns.append(g)
    prev = solve_batch(bases)
    warm = solve_batch(growns, SolveOptions(warm_start=prev.unstack()))
    cold = solve_batch(growns)
    for part, cold_part, g in zip(warm.unstack(), cold.unstack(), growns):
        oracle = connected_components_oracle(*g.to_numpy())
        assert (np.asarray(part.labels) == oracle).all()
        assert int(part.iterations) <= int(cold_part.iterations)


def test_add_edges_validates_endpoints():
    """Out-of-range endpoints must error eagerly, not silently clamp."""
    g = gen.path(10, seed=0)
    with pytest.raises(ValueError, match="n_vertices"):
        g.add_edges([0], [10])          # forgot to grow the vertex set
    with pytest.raises(ValueError, match=">= 0"):
        g.add_edges([-1], [3])
    grown = g.add_edges([0], [10], n_vertices=11)
    assert grown.n_vertices == 11 and grown.n_edges == g.n_edges + 1


def test_warm_start_validation():
    g = gen.path(50, seed=0)
    prev = solve(g)
    with pytest.raises(ValueError, match="1-D"):
        solve(g, warm_start=np.zeros((2, 50), np.int32))
    with pytest.raises(ValueError, match="vertices"):
        solve(g, warm_start=np.zeros(51, np.int32))
