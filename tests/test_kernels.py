"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp ref."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle

# ---------------------------------------------------------------------------
# contour_mm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block_edges", [64, 256, 512])
@pytest.mark.parametrize("gname,make", [
    ("path", lambda: gen.path(800, seed=1)),
    ("rmat", lambda: gen.rmat(10, seed=2)),
    ("grid", lambda: gen.grid2d(24, 24)),
])
def test_contour_mm_kernel_bitexact(gname, make, block_edges):
    from repro.kernels.contour_mm.ops import _pad_edges, contour_mm_step
    from repro.kernels.contour_mm.ref import mm_block_ref

    g = make()
    L0 = jnp.arange(g.n_vertices, dtype=jnp.int32)
    src_p, dst_p = _pad_edges(g.src, g.dst, block_edges)
    out = contour_mm_step(g.src, g.dst, L0, backend="pallas",
                          block_edges=block_edges)
    ref = mm_block_ref(src_p, dst_p, L0)
    assert (np.asarray(out) == np.asarray(ref)).all()


def test_contour_mm_fixpoint_matches_oracle():
    from repro.kernels.contour_mm.ops import contour_cc_fixpoint

    g = gen.components_mix(
        [gen.path(300, seed=1), gen.star(200, seed=2)], seed=3)
    labels, iters = contour_cc_fixpoint(g, backend="pallas")
    oracle = connected_components_oracle(*g.to_numpy())
    assert (np.asarray(labels) == oracle).all()
    assert iters < 30


def test_contour_mm_xla_backend_matches_sync_ref():
    from repro.kernels.contour_mm.ops import contour_mm_step
    from repro.kernels.contour_mm.ref import mm_sync_ref

    g = gen.rmat(9, seed=5)
    L0 = jnp.arange(g.n_vertices, dtype=jnp.int32)
    out = contour_mm_step(g.src, g.dst, L0, backend="xla")
    ref = mm_sync_ref(g.src, g.dst, L0)
    assert (np.asarray(out) == np.asarray(ref)).all()


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, h, hkv, t, hd, causal, dtype, blocks)
    (2, 4, 4, 128, 64, True, jnp.float32, (64, 64)),
    (2, 4, 2, 256, 64, True, jnp.float32, (64, 128)),
    (1, 8, 1, 192, 32, True, jnp.float32, (64, 64)),       # MQA
    (1, 8, 2, 130, 32, True, jnp.bfloat16, (64, 64)),      # ragged pad
    (2, 4, 4, 128, 64, False, jnp.float32, (64, 64)),
    (1, 2, 2, 512, 128, True, jnp.bfloat16, (128, 128)),
]


@pytest.mark.parametrize("b,h,hkv,t,hd,causal,dtype,blocks", FLASH_CASES)
def test_flash_attention_sweep(b, h, hkv, t, hd, causal, dtype, blocks):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import mha_ref

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, t, hd), dtype)
    k = jax.random.normal(ks[1], (b, hkv, t, hd), dtype)
    v = jax.random.normal(ks[2], (b, hkv, t, hd), dtype)
    out = flash_attention(q, k, v, causal=causal,
                          block_q=blocks[0], block_k=blocks[1])
    ref = mha_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


def test_flash_matches_model_attention_path():
    """Kernel vs the model's XLA chunked path (the dry-run lowering)."""
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.models.attention import attend_chunked

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, h, hkv, t, hd = 2, 8, 2, 256, 64
    q = jax.random.normal(ks[0], (b, t, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, hkv, hd), jnp.float32)
    xla = attend_chunked(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    pallas = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, block_q=64, block_k=64
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pallas),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# fused_rmsnorm
# ---------------------------------------------------------------------------

RMS_CASES = [
    (64, 512, jnp.float32),
    (33, 768, jnp.bfloat16),     # non-divisible rows -> padding path
    (7, 128, jnp.float32),
    (256, 2048, jnp.bfloat16),
    (1, 8192, jnp.float32),      # wide row, shrunken block
]


@pytest.mark.parametrize("r,d,dtype", RMS_CASES)
def test_fused_rmsnorm_sweep(r, d, dtype):
    from repro.kernels.fused_rmsnorm.ops import fused_rmsnorm
    from repro.kernels.fused_rmsnorm.ref import rmsnorm_ref

    x = jax.random.normal(jax.random.PRNGKey(2), (r, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(3), (d,), dtype)
    out = fused_rmsnorm(x, w)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=1e-6 if dtype == jnp.float32 else 1e-2,
                               rtol=1e-6 if dtype == jnp.float32 else 1e-2)


def test_fused_rmsnorm_batched_shape():
    from repro.kernels.fused_rmsnorm.ops import fused_rmsnorm

    x = jax.random.normal(jax.random.PRNGKey(4), (2, 5, 256), jnp.float32)
    w = jnp.ones((256,), jnp.float32)
    out = fused_rmsnorm(x, w)
    assert out.shape == x.shape
    # rms of output rows ~= 1
    rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)
