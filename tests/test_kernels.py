"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp ref."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle

# ---------------------------------------------------------------------------
# contour_mm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block_edges", [64, 256, 512])
@pytest.mark.parametrize("gname,make", [
    ("path", lambda: gen.path(800, seed=1)),
    ("rmat", lambda: gen.rmat(10, seed=2)),
    ("grid", lambda: gen.grid2d(24, 24)),
])
def test_contour_mm_kernel_bitexact(gname, make, block_edges):
    from repro.kernels.contour_mm.ops import _pad_edges, contour_mm_step
    from repro.kernels.contour_mm.ref import mm_block_ref

    g = make()
    L0 = jnp.arange(g.n_vertices, dtype=jnp.int32)
    src_p, dst_p = _pad_edges(g.src, g.dst, block_edges)
    out = contour_mm_step(g.src, g.dst, L0, backend="pallas",
                          block_edges=block_edges)
    ref = mm_block_ref(src_p, dst_p, L0)
    assert (np.asarray(out) == np.asarray(ref)).all()


def test_contour_mm_fixpoint_matches_oracle():
    from repro.kernels.contour_mm.ops import contour_cc_fixpoint

    g = gen.components_mix(
        [gen.path(300, seed=1), gen.star(200, seed=2)], seed=3)
    labels, iters, converged, visited = contour_cc_fixpoint(g,
                                                            backend="pallas")
    assert bool(converged)
    assert float(visited) == float(iters) * g.n_edges
    oracle = connected_components_oracle(*g.to_numpy())
    assert (np.asarray(labels) == oracle).all()
    assert iters < 30


def test_contour_mm_xla_backend_matches_sync_ref():
    from repro.kernels.contour_mm.ops import contour_mm_step
    from repro.kernels.contour_mm.ref import mm_sync_ref

    g = gen.rmat(9, seed=5)
    L0 = jnp.arange(g.n_vertices, dtype=jnp.int32)
    out = contour_mm_step(g.src, g.dst, L0, backend="xla")
    ref = mm_sync_ref(g.src, g.dst, L0)
    assert (np.asarray(out) == np.asarray(ref)).all()


# ---------------------------------------------------------------------------
# contour_mm: label-blocked vectorized backend (DESIGN.md §3.4)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("label_block,chunk", [
    (512, 128),    # 8 label blocks at n=4096
    (1024, 256),   # 4 label blocks
    (300, 64),     # 14 blocks, tile not a divisor of n -> L padding path
])
def test_blocked_sweep_bitexact_vs_mm_relax(label_block, chunk):
    """Per-sweep the blocked kernel must equal the scatter-min oracle
    bit-for-bit on graphs whose n spans >= 4 label blocks — including on
    mid-run (non-trivial) label states."""
    from repro.core import labels as lab
    from repro.kernels.contour_mm.ops import contour_mm_step

    g = gen.rmat(12, seed=7)   # n = 4096
    assert g.n_vertices >= 4 * label_block
    L = jnp.arange(g.n_vertices, dtype=jnp.int32)
    for _ in range(3):         # sweep 0 from identity, then mid-run states
        out = contour_mm_step(g.src, g.dst, L, backend="pallas_blocked",
                              label_block=label_block, chunk_updates=chunk)
        ref = lab.mm_relax(L, g.src, g.dst, order=2)
        assert (np.asarray(out) == np.asarray(ref)).all()
        L = ref


@pytest.mark.parametrize("order", [1, 2, 3])
def test_blocked_backend_is_order_generic(order):
    from repro.core import labels as lab
    from repro.kernels.contour_mm.ops import contour_mm_step

    g = gen.grid2d(40, 40)
    L0 = jnp.arange(g.n_vertices, dtype=jnp.int32)
    out = contour_mm_step(g.src, g.dst, L0, backend="pallas_blocked",
                          order=order, label_block=256, chunk_updates=64)
    ref = lab.mm_relax(L0, g.src, g.dst, order=order)
    assert (np.asarray(out) == np.asarray(ref)).all()


def test_blocked_fixpoint_matches_oracle_multiblock():
    """On-device fixpoint on the blocked kernel, n spanning >= 4 blocks."""
    from repro.kernels.contour_mm.ops import contour_cc_fixpoint

    g = gen.components_mix(
        [gen.path(900, seed=1), gen.star(700, seed=2), gen.rmat(10, seed=3)],
        seed=4)
    assert g.n_vertices >= 4 * 512
    labels, iters, converged, _ = contour_cc_fixpoint(
        g, backend="pallas_blocked", label_block=512, chunk_updates=128)
    assert bool(converged)
    oracle = connected_components_oracle(*g.to_numpy())
    assert (np.asarray(labels) == oracle).all()
    assert 1 <= int(iters) < 30


def test_fixpoint_runs_on_device_without_host_sync():
    """`contour_cc_fixpoint` must be a single on-device `lax.while_loop`:
    it is jitted end-to-end, so any seed-style per-iteration
    `bool(converged_early(...))` readback would fail to trace; the lowered
    HLO must contain the while op carrying the convergence flag."""
    from repro.kernels.contour_mm.ops import contour_cc_fixpoint

    g = gen.rmat(9, seed=11)
    txt = contour_cc_fixpoint.lower(g, backend="xla").as_text()
    assert "while" in txt
    labels, iters, _, _ = contour_cc_fixpoint(g, backend="xla")
    oracle = connected_components_oracle(*g.to_numpy())
    assert (np.asarray(labels) == oracle).all()


def test_fixpoint_backends_agree():
    """Every backend reaches the identical min-vertex-id fixed point."""
    from repro.kernels.contour_mm.ops import contour_cc_fixpoint

    g = gen.components_mix([gen.path(300, seed=1), gen.star(200, seed=2)],
                           seed=3)
    oracle = connected_components_oracle(*g.to_numpy())
    for backend in ("xla", "auto", "pallas", "pallas_blocked"):
        labels, iters, _, _ = contour_cc_fixpoint(
            g, backend=backend, label_block=256, chunk_updates=64)
        assert (np.asarray(labels) == oracle).all(), backend
        assert int(iters) < 30, backend


def test_dispatch_plan():
    """The heuristic tables: XLA off-TPU; blocked with sane tiles on TPU."""
    from repro.connectivity.planner import heuristic_plan

    cpu = heuristic_plan(100_000, 1_000_000, platform="cpu")
    assert cpu.backend == "xla"
    assert cpu.interpret            # forced pallas runs in validation mode

    small = heuristic_plan(2_000, 20_000, platform="tpu")
    assert small.backend == "pallas_blocked"
    assert small.label_block >= 2_000       # single tile, no binning waste
    assert not small.interpret
    assert small.fuse_relabel               # single-tile fused pass applies

    big = heuristic_plan(50_000_000, 800_000_000, platform="tpu")
    assert big.backend == "pallas_blocked"  # no vertex ceiling
    # one-hot combine buffer stays within a VMEM-friendly budget
    assert big.label_block * big.chunk_updates * 4 <= 4 * 1024 * 1024
    assert not big.fuse_relabel             # multi-tile: binned pipeline

    auto = heuristic_plan(10_000, 80_000)        # this host: not a TPU
    assert auto.backend in ("xla", "pallas_blocked")


def test_scalar_pallas_vmem_ceiling_enforced():
    """Above the whole-L VMEM ceiling the scalar kernel must refuse with a
    clear error (not an opaque Mosaic allocation failure)."""
    from repro.kernels.contour_mm.ops import (WHOLE_L_VMEM_CEILING,
                                              mm_relax_backend)

    n = WHOLE_L_VMEM_CEILING + 1
    L = jnp.zeros((n,), jnp.int32)
    src = jnp.zeros((4,), jnp.int32)
    dst = jnp.ones((4,), jnp.int32)
    with pytest.raises(ValueError, match="ceiling"):
        mm_relax_backend(L, src, dst, backend="pallas")


def test_auto_backend_step_matches_mm_relax():
    from repro.core import labels as lab
    from repro.kernels.contour_mm.ops import contour_mm_step

    g = gen.erdos_renyi(2_000, 5.0, seed=9)
    L0 = jnp.arange(g.n_vertices, dtype=jnp.int32)
    out = contour_mm_step(g.src, g.dst, L0, backend="auto")
    ref = lab.mm_relax(L0, g.src, g.dst, order=2)
    assert (np.asarray(out) == np.asarray(ref)).all()


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, h, hkv, t, hd, causal, dtype, blocks)
    (2, 4, 4, 128, 64, True, jnp.float32, (64, 64)),
    (2, 4, 2, 256, 64, True, jnp.float32, (64, 128)),
    (1, 8, 1, 192, 32, True, jnp.float32, (64, 64)),       # MQA
    (1, 8, 2, 130, 32, True, jnp.bfloat16, (64, 64)),      # ragged pad
    (2, 4, 4, 128, 64, False, jnp.float32, (64, 64)),
    (1, 2, 2, 512, 128, True, jnp.bfloat16, (128, 128)),
]


@pytest.mark.slow  # interpret-mode Pallas, 3-6s per case
@pytest.mark.parametrize("b,h,hkv,t,hd,causal,dtype,blocks", FLASH_CASES)
def test_flash_attention_sweep(b, h, hkv, t, hd, causal, dtype, blocks):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import mha_ref

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, t, hd), dtype)
    k = jax.random.normal(ks[1], (b, hkv, t, hd), dtype)
    v = jax.random.normal(ks[2], (b, hkv, t, hd), dtype)
    out = flash_attention(q, k, v, causal=causal,
                          block_q=blocks[0], block_k=blocks[1])
    ref = mha_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


@pytest.mark.slow  # interpret-mode Pallas
def test_flash_matches_model_attention_path():
    """Kernel vs the model's XLA chunked path (the dry-run lowering)."""
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.models.attention import attend_chunked

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, h, hkv, t, hd = 2, 8, 2, 256, 64
    q = jax.random.normal(ks[0], (b, t, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, hkv, hd), jnp.float32)
    xla = attend_chunked(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    pallas = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, block_q=64, block_k=64
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pallas),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# fused_rmsnorm
# ---------------------------------------------------------------------------

RMS_CASES = [
    (64, 512, jnp.float32),
    (33, 768, jnp.bfloat16),     # non-divisible rows -> padding path
    (7, 128, jnp.float32),
    (256, 2048, jnp.bfloat16),
    (1, 8192, jnp.float32),      # wide row, shrunken block
]


@pytest.mark.slow  # interpret-mode Pallas
@pytest.mark.parametrize("r,d,dtype", RMS_CASES)
def test_fused_rmsnorm_sweep(r, d, dtype):
    from repro.kernels.fused_rmsnorm.ops import fused_rmsnorm
    from repro.kernels.fused_rmsnorm.ref import rmsnorm_ref

    x = jax.random.normal(jax.random.PRNGKey(2), (r, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(3), (d,), dtype)
    out = fused_rmsnorm(x, w)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=1e-6 if dtype == jnp.float32 else 1e-2,
                               rtol=1e-6 if dtype == jnp.float32 else 1e-2)


def test_fused_rmsnorm_batched_shape():
    from repro.kernels.fused_rmsnorm.ops import fused_rmsnorm

    x = jax.random.normal(jax.random.PRNGKey(4), (2, 5, 256), jnp.float32)
    w = jnp.ones((256,), jnp.float32)
    out = fused_rmsnorm(x, w)
    assert out.shape == x.shape
    # rms of output rows ~= 1
    rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)
