"""Training loop, optimizer, checkpointing, fault tolerance, elasticity."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # multi-step training loops; excluded from the fast tier

from repro.checkpoint.manager import (
    CheckpointManager, restore_checkpoint, save_checkpoint)
from repro.configs import get_arch
from repro.launch.train import train_loop
from repro.models.model import build_model
from repro.optim.adamw import OptConfig, apply_updates, init_opt_state
from repro.runtime.elastic import derive_mesh_shape
from repro.runtime.recovery import FaultInjector, run_with_recovery
from repro.runtime.straggler import StragglerMonitor
from repro.train.step import init_train_state, make_train_step


def test_training_reduces_loss(tmp_path):
    config = get_arch("olmo-1b").smoke_config()
    out = train_loop(config, steps=30, batch=4, seq=32, log_every=0,
                     opt=OptConfig(peak_lr=3e-3, warmup_steps=3,
                                   decay_steps=30))
    assert out["last_loss"] < out["first_loss"] - 0.5


def test_grad_accum_matches_full_batch():
    config = get_arch("olmo-1b").smoke_config()
    model = build_model(config)
    opt = OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=10)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(k1, (4, 16), 0, 512),
             "labels": jax.random.randint(k2, (4, 16), 0, 512)}
    s0 = init_train_state(model, jax.random.PRNGKey(1), opt)
    s1, m1 = jax.jit(make_train_step(model, opt, grad_accum=1))(s0, batch)
    s2, m2 = jax.jit(make_train_step(model, opt, grad_accum=2))(s0, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m2["grad_norm"]), rtol=1e-3)
    # post-AdamW params: m/sqrt(v) at step 1 amplifies fp32 reduction-order
    # noise near zero-gradient coordinates — 2e-3 x lr is the right scale
    a = jax.tree_util.tree_leaves(s1.params)
    b = jax.tree_util.tree_leaves(s2.params)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=2e-3, rtol=1e-2)


def test_checkpoint_roundtrip_bitexact(tmp_path):
    config = get_arch("xlstm-125m").smoke_config()
    model = build_model(config)
    opt = OptConfig()
    state = init_train_state(model, jax.random.PRNGKey(2), opt)
    save_checkpoint(str(tmp_path), 7, state)
    restored, step = restore_checkpoint(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_checkpoint_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for k in range(5):
        mgr.save(k, {"x": jnp.full((3,), k)})
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4


def test_crash_recovery_bitexact(tmp_path):
    """Train with injected faults == train uninterrupted (data is seekable,
    checkpoints are atomic, so recovery must be exact)."""
    config = get_arch("olmo-1b").smoke_config()
    opt = OptConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=20)

    ref = train_loop(config, steps=20, batch=2, seq=16, log_every=0, opt=opt)

    model = build_model(config)
    step_jit = jax.jit(make_train_step(model, opt))
    from repro.launch.train import build_batch_fn
    batch_at = build_batch_fn(config, 2, 16)
    init = init_train_state(model, jax.random.PRNGKey(0), opt)

    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    inj = FaultInjector(fail_at=(7, 13))
    events = []

    def one(state, k):
        state, _ = step_jit(state, batch_at(k))
        return state

    final, stats = run_with_recovery(
        one, init, 20, mgr, checkpoint_every=5, fault_injector=inj,
        on_event=lambda ev, k: events.append((ev, k)))
    assert stats["restarts"] == 2
    for a, b in zip(jax.tree_util.tree_leaves(ref["state"].params),
                    jax.tree_util.tree_leaves(final.params)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_train_loop_resume_from_checkpoint(tmp_path):
    config = get_arch("olmo-1b").smoke_config()
    opt = OptConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=20)
    d = str(tmp_path / "ck")
    ref = train_loop(config, steps=12, batch=2, seq=16, log_every=0, opt=opt)
    a = train_loop(config, steps=6, batch=2, seq=16, ckpt_dir=d,
                   checkpoint_every=3, log_every=0, opt=opt)
    b = train_loop(config, steps=12, batch=2, seq=16, ckpt_dir=d,
                   checkpoint_every=3, log_every=0, opt=opt)
    assert b["steps_run"] == 6      # resumed, did not redo work
    for x, y in zip(jax.tree_util.tree_leaves(ref["state"].params),
                    jax.tree_util.tree_leaves(b["state"].params)):
        assert (np.asarray(x) == np.asarray(y)).all()


def test_adamw_moment_dtype_compression():
    params = {"w": jnp.ones((8, 8))}
    grads = {"w": jnp.full((8, 8), 0.1)}
    cfg = OptConfig(moment_dtype=jnp.bfloat16)
    st = init_opt_state(params, cfg)
    assert st["m"]["w"].dtype == jnp.bfloat16
    p2, st2, _ = apply_updates(params, grads, st, cfg)
    assert st2["v"]["w"].dtype == jnp.bfloat16
    assert not np.array_equal(np.asarray(p2["w"]),
                              np.asarray(params["w"]))


def test_lr_schedule_shape():
    from repro.optim.adamw import learning_rate
    cfg = OptConfig(peak_lr=1e-3, min_lr=1e-4, warmup_steps=10,
                    decay_steps=100)
    lrs = [float(learning_rate(jnp.int32(s), cfg)) for s in
           (0, 5, 10, 50, 100, 200)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < 1e-3
    assert lrs[4] == pytest.approx(1e-4, rel=1e-3)
    assert lrs[5] == pytest.approx(1e-4, rel=1e-3)


def test_straggler_monitor_escalation():
    m = StragglerMonitor(threshold=2.0, evict_after=3)
    assert m.observe(1.0) == "ok"
    for _ in range(5):
        assert m.observe(1.0) == "ok"
    assert m.observe(5.0) == "warn"          # 1 slow
    assert m.observe(5.0) == "checkpoint"    # 2 consecutive
    assert m.observe(5.0) == "evict"         # 3 consecutive
    assert m.observe(1.0) == "ok"            # recovers
    # EWMA must not have been polluted by outliers
    assert m.ewma < 1.5


def test_elastic_mesh_derivation():
    assert derive_mesh_shape(512, 16, prefer_pods=2) == (2, 16, 16)
    assert derive_mesh_shape(256, 16) == (16, 16)
    # lose a pod: absorb on data axis
    assert derive_mesh_shape(384, 16, prefer_pods=2) == (2, 12, 16)
    # lose odd devices
    assert derive_mesh_shape(250, 16) == (15, 16)
    with pytest.raises(ValueError):
        derive_mesh_shape(8, 16)
