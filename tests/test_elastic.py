"""Edge cases of ``repro.runtime.elastic`` mesh re-derivation.

The elastic shrink path (``connectivity.resilience``) calls these under
fire — after shard loss — so the degenerate shapes (1-wide data axis,
non-dividing pod preference, too few devices) must be exact, not
approximate.
"""
import jax
import numpy as np
import pytest

from repro.connectivity import SolveOptions, solve
from repro.connectivity.distributed import distributed_contour
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle
from repro.runtime.elastic import derive_mesh_shape, elastic_mesh


def test_one_wide_data_axis():
    """All devices consumed by the model axis: data axis degrades to 1
    (the mesh is still well-formed, just no data parallelism left)."""
    assert derive_mesh_shape(4, 4) == (1, 4)
    assert derive_mesh_shape(16, 16) == (1, 16)
    # one spare replica short of 2-wide: still (1, model)
    assert derive_mesh_shape(31, 16) == (1, 16)
    # prefer_pods cannot split a single replica
    assert derive_mesh_shape(4, 4, prefer_pods=2) == (1, 4)


def test_prefer_pods_not_dividing_replicas():
    """Pod preference decays to the largest feasible divisor, never
    drops devices that a smaller pod count could use."""
    # 32 replicas, prefer 3 pods: 3 does not divide 32 -> falls to 2
    assert derive_mesh_shape(512, 16, prefer_pods=3) == (2, 16, 16)
    # 10 replicas, prefer 4: 4 and 3 fail, 2 divides
    assert derive_mesh_shape(40, 4, prefer_pods=4) == (2, 5, 4)
    # 7 replicas (prime), prefer 4: only 1 pod fits -> 2-axis shape
    assert derive_mesh_shape(7, 1, prefer_pods=4) == (7, 1)
    # prefer_pods equal to replicas: every replica its own pod
    assert derive_mesh_shape(12, 2, prefer_pods=6) == (6, 1, 2)


def test_derive_mesh_shape_raises_when_model_axis_does_not_fit():
    with pytest.raises(ValueError, match="model_parallel"):
        derive_mesh_shape(3, 4)
    with pytest.raises(ValueError, match="model_parallel"):
        derive_mesh_shape(0, 1)


def test_shrink_sequence_monotone():
    """Losing devices one at a time never raises until the model axis no
    longer fits, and the device budget is always respected."""
    for n in range(16, 3, -1):
        shape = derive_mesh_shape(n, 4)
        assert int(np.prod(shape)) <= n
        assert shape[-1] == 4
    with pytest.raises(ValueError):
        derive_mesh_shape(3, 4)


def test_elastic_mesh_single_device_runs_distributed_solve():
    """The smallest elastic mesh (1 CPU device) is a real mesh the
    distributed solver accepts — the shrink path's terminal state."""
    mesh = elastic_mesh(1, jax.devices())
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.shape == (len(jax.devices()), 1)
    g = gen.components_mix([gen.path(200, seed=1), gen.rmat(8, seed=2)],
                           seed=3)
    oracle = connected_components_oracle(*g.to_numpy())
    labels, it, done, visited = distributed_contour(g, mesh,
                                                    edge_axes=("data",))
    assert bool(done)
    assert (np.asarray(labels) == oracle).all()


def test_elastic_mesh_too_few_devices_raises():
    with pytest.raises(ValueError, match="model_parallel"):
        elastic_mesh(len(jax.devices()) + 1, jax.devices())


def test_elastic_mesh_discards_surplus_devices():
    """With prefer_pods=1 and model_parallel=1 every device is used; the
    reshape must match the derived shape exactly."""
    devs = jax.devices()
    mesh = elastic_mesh(1, devs, prefer_pods=1)
    assert mesh.devices.size == len(devs)
    assert tuple(mesh.devices.shape) == derive_mesh_shape(len(devs), 1)
