"""The unified ``repro.connectivity`` API: solve() facade, typed options,
solver registry, ComponentResult utilities, batched solving."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import (
    ComponentResult,
    Graph,
    SolveOptions,
    list_solvers,
    solve,
    solve_batch,
)
from repro.connectivity import (
    VARIANTS,
    get_solver,
    register_solver,
    stack_graphs,
)
from repro.connectivity.registry import SolverSpec
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle

GRAPHS = {
    "path": lambda: gen.path(1_500, seed=1),
    "rmat": lambda: gen.rmat(11, seed=2),
    "multi_component": lambda: gen.components_mix(
        [gen.path(400, seed=3), gen.star(200, seed=4), gen.rmat(9, seed=5)],
        seed=6),
}

# every registered family that runs without a mesh
SINGLE_DEVICE_ALGOS = ("contour", "fastsv", "label_propagation", "union_find")


# ---------------------------------------------------------------- facade

@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("algorithm", SINGLE_DEVICE_ALGOS)
def test_solve_every_family_matches_oracle(gname, algorithm):
    g = GRAPHS[gname]()
    oracle = connected_components_oracle(*g.to_numpy())
    result = solve(g, SolveOptions(algorithm=algorithm))
    assert (np.asarray(result.labels) == oracle).all()
    assert bool(result.converged)
    assert int(result.iterations) >= 1


def test_solve_mesh_routes_contour_through_distributed():
    """A mesh in the options dispatches to the shard_map path."""
    from repro import jax_compat
    mesh = jax_compat.device_mesh(np.array(jax.devices()[:1]), ("data",))
    g = GRAPHS["multi_component"]()
    oracle = connected_components_oracle(*g.to_numpy())
    result = solve(g, SolveOptions(algorithm="contour", mesh=mesh))
    assert (np.asarray(result.labels) == oracle).all()
    assert bool(result.converged)


@pytest.mark.parametrize("variant", VARIANTS + ("C-3",))
def test_solve_contour_variants(variant):
    g = GRAPHS["multi_component"]()
    oracle = connected_components_oracle(*g.to_numpy())
    result = solve(g, variant=variant)
    assert (np.asarray(result.labels) == oracle).all(), variant


def test_solve_overrides_and_aliases():
    g = GRAPHS["path"]()
    oracle = connected_components_oracle(*g.to_numpy())
    # kwargs override the options object; aliases resolve
    r = solve(g, SolveOptions(algorithm="contour"), algorithm="lp")
    assert (np.asarray(r.labels) == oracle).all()
    r2 = solve(g, algorithm="connectit")
    assert (np.asarray(r2.labels) == oracle).all()


def test_solve_validation_errors():
    g = GRAPHS["path"]()
    with pytest.raises(ValueError, match="unknown algorithm"):
        solve(g, algorithm="dijkstra")
    with pytest.raises(ValueError, match="variant"):
        solve(g, algorithm="fastsv", variant="C-2")
    with pytest.raises(ValueError, match="unknown variant"):
        solve(g, variant="C-banana")
    with pytest.raises(ValueError, match="backend"):
        solve(g, backend="cuda")
    with pytest.raises(ValueError, match="mesh"):
        solve(g, algorithm="distributed")  # needs a mesh
    with pytest.raises(ValueError, match="does not run on a mesh"):
        from repro import jax_compat
        mesh = jax_compat.device_mesh(np.array(jax.devices()[:1]), ("data",))
        solve(g, SolveOptions(algorithm="fastsv", mesh=mesh))
    with pytest.raises(TypeError, match="SolveOptions"):
        solve(g, {"algorithm": "contour"})


def test_options_frozen_and_replace():
    opts = SolveOptions()
    with pytest.raises(dataclasses.FrozenInstanceError):
        opts.algorithm = "fastsv"
    opts2 = opts.replace(algorithm="fastsv", max_iters=7)
    assert opts2.algorithm == "fastsv" and opts2.max_iters == 7
    assert opts.algorithm == "contour"  # original untouched


def test_solve_max_iters_cutoff_reports_not_converged():
    g = gen.path(4_000, seed=7)
    result = solve(g, algorithm="label_propagation", max_iters=3)
    assert not bool(result.converged)
    assert int(result.iterations) == 3


# ---------------------------------------------------------------- registry

def test_registry_lists_every_family():
    assert set(SINGLE_DEVICE_ALGOS) | {"distributed"} <= set(list_solvers())
    spec = get_solver("contour")
    assert spec.paper_ref  # DESIGN.md §9 mapping is populated
    assert get_solver("lp").name == "label_propagation"


def test_registry_custom_solver_roundtrip():
    """A new family plugs in without touching the facade."""
    def oracle_solver(graph, opts, init_labels):
        L = connected_components_oracle(*graph.to_numpy())
        return jnp.asarray(L, jnp.int32), jnp.int32(1), jnp.array(True)

    register_solver(SolverSpec(name="_test_oracle", fn=oracle_solver,
                               supports_batch=False, runs_on="host"))
    g = GRAPHS["rmat"]()
    result = solve(g, algorithm="_test_oracle")
    assert (np.asarray(result.labels)
            == connected_components_oracle(*g.to_numpy())).all()
    assert bool(result.converged)


# ---------------------------------------------------------------- result

def test_component_result_utilities():
    g = GRAPHS["multi_component"]()
    oracle = connected_components_oracle(*g.to_numpy())
    result = solve(g)
    k = len(np.unique(oracle))
    assert result.n_components == k
    compact = result.compact_labels()
    assert compact.min() == 0 and compact.max() == k - 1
    assert len(np.unique(compact)) == k
    # compact labeling induces the same partition
    assert len(np.unique(oracle * k + compact)) == k
    sizes = result.component_sizes()
    assert sizes.sum() == g.n_vertices
    # same_component agrees with the oracle on a vertex sample
    rng = np.random.default_rng(0)
    u = rng.integers(0, g.n_vertices, 64)
    v = rng.integers(0, g.n_vertices, 64)
    np.testing.assert_array_equal(result.same_component(u, v),
                                  oracle[u] == oracle[v])
    assert result.same_component(0, 0) is True
    # scalar-vs-array broadcasts instead of collapsing to bool
    np.testing.assert_array_equal(result.same_component(0, v),
                                  oracle[0] == oracle[v])


def test_component_result_is_a_pytree():
    g = GRAPHS["path"]()
    result = solve(g)
    leaves, treedef = jax.tree_util.tree_flatten(result)
    # labels, iterations, converged, edges_visited (the work counter)
    assert len(leaves) == 4
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert (np.asarray(rebuilt.labels) == np.asarray(result.labels)).all()
    # flows through jit
    out = jax.jit(lambda r: r)(result)
    assert isinstance(out, ComponentResult)
    assert (np.asarray(out.labels) == np.asarray(result.labels)).all()


# ---------------------------------------------------------------- batching

@pytest.mark.parametrize("algorithm",
                         ("contour", "fastsv", "label_propagation",
                          "union_find"))
def test_solve_batch_matches_per_graph_oracle(algorithm):
    graphs = [gen.path(300, seed=8), gen.rmat(9, seed=9),
              gen.grid2d(12, 24), gen.star(150, seed=10)]
    batch = solve_batch(graphs, algorithm=algorithm)
    assert batch.is_batched
    parts = batch.unstack()
    assert len(parts) == len(graphs)
    for part, g in zip(parts, graphs):
        oracle = connected_components_oracle(*g.to_numpy())
        assert part.labels.shape[0] == g.n_vertices
        assert (np.asarray(part.labels) == oracle).all(), algorithm
        assert bool(part.converged)


def test_solve_batch_single_results_vs_solo_solves():
    """Batched labels are bit-exact vs solo solves (padding is a no-op)."""
    graphs = [gen.rmat(8, seed=s) for s in range(3)]
    batch = solve_batch(graphs)
    for part, g in zip(batch.unstack(), graphs):
        solo = solve(g)
        assert (np.asarray(part.labels) == np.asarray(solo.labels)).all()
        assert int(part.iterations) == int(solo.iterations)


def test_stack_graphs_pads_with_self_loops():
    g1, g2 = gen.path(10, seed=0), gen.path(50, seed=1)
    batched = stack_graphs([g1, g2])
    assert batched.src.shape == (2, g2.n_edges)
    assert batched.n_vertices == 50
    # padded tail of the smaller graph is self-loops
    pad_s = np.asarray(batched.src[0, g1.n_edges:])
    pad_d = np.asarray(batched.dst[0, g1.n_edges:])
    assert (pad_s == pad_d).all()


def test_prebatched_solve_trims_padding_with_batch_sizes():
    """Regression (ISSUE 3): a pre-batched Graph solve used to record the
    padded n_vertices for every graph, so unstack() could not trim the
    padding vertices — batch_sizes= carries the true per-graph counts."""
    graphs = [gen.path(10, seed=0), gen.path(50, seed=1),
              gen.rmat(5, seed=2)]
    batched, sizes = stack_graphs(graphs, with_sizes=True)
    assert sizes == tuple(g.n_vertices for g in graphs)

    batch = solve_batch(batched, batch_sizes=sizes)
    parts = batch.unstack()
    for part, g in zip(parts, graphs):
        oracle = connected_components_oracle(*g.to_numpy())
        assert part.labels.shape[0] == g.n_vertices     # padding trimmed
        assert (np.asarray(part.labels) == oracle).all()
        assert part.n_components == len(np.unique(oracle))

    # parity with the sequence form (which records sizes itself)
    from_seq = solve_batch(graphs)
    for a, b in zip(parts, from_seq.unstack()):
        assert (np.asarray(a.labels) == np.asarray(b.labels)).all()

    # without batch_sizes the padded singletons leak into the counts —
    # the documented (pre-fix) behaviour stays available but explicit
    untrimmed = solve_batch(batched).unstack()
    assert untrimmed[0].labels.shape[0] == batched.n_vertices
    assert untrimmed[0].n_components > parts[0].n_components


def test_solve_batch_batch_sizes_validation():
    graphs = [gen.path(10, seed=0), gen.path(20, seed=1)]
    batched, sizes = stack_graphs(graphs, with_sizes=True)
    with pytest.raises(ValueError, match="entries"):
        solve_batch(batched, batch_sizes=(10,))
    with pytest.raises(ValueError, match="outside"):
        solve_batch(batched, batch_sizes=(10, 999))
    with pytest.raises(ValueError, match="outside"):
        solve_batch(batched, batch_sizes=(0, 20))


def test_solve_batch_rejects_mesh_and_distributed():
    graphs = [gen.path(20, seed=0), gen.path(30, seed=1)]
    from repro import jax_compat
    mesh = jax_compat.device_mesh(np.array(jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="mesh"):
        solve_batch(graphs, SolveOptions(mesh=mesh))
    with pytest.raises(ValueError, match="batched"):
        solve_batch(graphs, algorithm="distributed")


def test_batched_component_result_guards_scalar_views():
    batch = solve_batch([gen.path(20, seed=0), gen.path(30, seed=1)])
    with pytest.raises(ValueError, match="unstack"):
        batch.n_components
    with pytest.raises(ValueError, match="unstack"):
        batch.same_component(0, 1)
