"""Cross-solver metamorphic conformance suite.

Every registered solver family, on every backend it can run here, must
satisfy the metamorphic invariances of the connectivity *problem* — not
of any particular algorithm:

* **vertex relabelling** — permuting vertex ids permutes the partition
  (permutation equivariance);
* **edge orientation** — flipping (or symmetrising) edge direction
  changes nothing: the edge list is an undirected graph;
* **edge duplication** — repeating edges changes nothing;
* **self-loops** — adding self-loops changes nothing;
* **disjoint union** — stacking two graphs block-diagonally solves each
  block independently (labels are the per-block labels, offset).

Each transformed solve is compared *component-partition-equal* to the
NumPy oracle (``graphs/oracle.py``); transforms that preserve the vertex
set are additionally compared bit-exact to the untransformed solve, since
every solver here converges to the canonical min-vertex-id labelling.

Deterministic seeded instances always run; when ``hypothesis`` is
installed (the CI fast tier installs it) a property-based layer fuzzes
the same invariances over random graphs and permutations.
"""
import numpy as np
import pytest

import jax

from repro import jax_compat
from repro.connectivity import SolveOptions, list_solvers, solve
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle, labels_equivalent
from repro.graphs.structs import Graph

try:
    import hypothesis  # noqa: F401
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


def _mesh1():
    return jax_compat.device_mesh(np.array(jax.devices()[:1]), ("data",))


# every (solver, backend) pair that can execute on this host; the Pallas
# backends only run in interpret mode off-TPU, which the slow tier covers
# elsewhere (tests/test_kernels.py) — conformance runs the compiled paths.
SOLVER_CONFIGS = [
    ("contour", dict(algorithm="contour", backend="xla")),
    ("contour-auto", dict(algorithm="contour", backend="auto")),
    ("contour-Cm", dict(algorithm="contour", variant="C-m", backend="xla")),
    ("contour-frontier", dict(algorithm="contour", backend="xla",
                              sampling=2, compact_every=2)),
    # the strategy matrix (DESIGN.md §16): every registered sampling
    # strategy through the work-adaptive schedule, across finish
    # variants, plus the cost-model dispatcher itself
    ("contour-kout", dict(algorithm="contour", backend="xla",
                          sampling=2, compact_every=2,
                          sampling_strategy="kout")),
    ("contour-bfs", dict(algorithm="contour", backend="xla",
                         sampling=2, compact_every=2,
                         sampling_strategy="bfs")),
    ("contour-Cm-kout", dict(algorithm="contour", variant="C-m",
                             backend="xla", sampling=2, compact_every=2,
                             sampling_strategy="kout")),
    ("contour-Cm-bfs", dict(algorithm="contour", variant="C-m",
                            backend="xla", sampling=2, compact_every=2,
                            sampling_strategy="bfs")),
    ("auto", dict(algorithm="auto")),
    ("fastsv", dict(algorithm="fastsv")),
    ("label_propagation", dict(algorithm="label_propagation")),
    ("union_find", dict(algorithm="union_find")),
    ("distributed", dict(algorithm="distributed", mesh="MESH1")),
]
CONFIG_IDS = [name for name, _ in SOLVER_CONFIGS]


def test_every_registry_solver_is_covered():
    """The matrix above must not silently rot as families are added.

    Compared against the built-in families (other tests may register
    throwaway solvers into the process-global registry).
    """
    from repro.connectivity import solvers as builtin
    built_in = {spec.name for spec in (builtin.CONTOUR, builtin.DISTRIBUTED,
                                       builtin.FASTSV,
                                       builtin.LABEL_PROPAGATION,
                                       builtin.UNION_FIND, builtin.AUTO)}
    covered = {cfg.get("algorithm") for _, cfg in SOLVER_CONFIGS}
    assert built_in <= covered
    assert built_in <= set(list_solvers())


def _solve_np(graph: Graph, cfg: dict) -> np.ndarray:
    cfg = dict(cfg)
    if cfg.get("mesh") == "MESH1":
        cfg["mesh"] = _mesh1()
    return np.asarray(solve(graph, SolveOptions(**cfg)).labels)


def _graphs(small_only: bool = False):
    gs = [
        ("path", gen.path(120, seed=3)),
        ("mix", gen.components_mix([gen.path(40, seed=1),
                                    gen.star(30, seed=2),
                                    gen.grid2d(6, 6)], seed=4)),
    ]
    if not small_only:
        gs.append(("rmat", gen.rmat(8, seed=5)))
    return gs


def _assert_oracle_partition(labels: np.ndarray, graph: Graph, ctx):
    oracle = connected_components_oracle(*graph.to_numpy())
    assert labels_equivalent(labels, oracle), ctx


@pytest.mark.parametrize("name,cfg", SOLVER_CONFIGS, ids=CONFIG_IDS)
def test_vertex_relabelling_equivariance(name, cfg):
    rng = np.random.default_rng(7)
    for gname, g in _graphs():
        src, dst, n = g.to_numpy()
        pi = rng.permutation(n)
        gp = Graph.from_numpy(pi[src], pi[dst], n)
        base = _solve_np(g, cfg)
        permuted = _solve_np(gp, cfg)
        # vertex v of g is vertex pi[v] of gp: the pulled-back labelling
        # must induce the same partition
        assert labels_equivalent(permuted[pi], base), (name, gname)
        _assert_oracle_partition(permuted, gp, (name, gname))


@pytest.mark.parametrize("name,cfg", SOLVER_CONFIGS, ids=CONFIG_IDS)
def test_orientation_and_symmetrisation_invariance(name, cfg):
    for gname, g in _graphs():
        src, dst, n = g.to_numpy()
        base = _solve_np(g, cfg)
        flipped = _solve_np(Graph.from_numpy(dst, src, n), cfg)
        both = _solve_np(g.symmetrized(), cfg)
        # same vertex set + canonical min-id labels => bit-exact
        assert (flipped == base).all(), (name, gname)
        assert (both == base).all(), (name, gname)
        _assert_oracle_partition(base, g, (name, gname))


@pytest.mark.parametrize("name,cfg", SOLVER_CONFIGS, ids=CONFIG_IDS)
def test_duplication_and_self_loop_invariance(name, cfg):
    rng = np.random.default_rng(11)
    for gname, g in _graphs():
        src, dst, n = g.to_numpy()
        base = _solve_np(g, cfg)
        dup = Graph.from_numpy(np.concatenate([src, src]),
                               np.concatenate([dst, dst]), n)
        loops = rng.integers(0, n, 13)
        looped = Graph.from_numpy(np.concatenate([src, loops]),
                                  np.concatenate([dst, loops]), n)
        assert (_solve_np(dup, cfg) == base).all(), (name, gname)
        assert (_solve_np(looped, cfg) == base).all(), (name, gname)


@pytest.mark.parametrize("name,cfg", SOLVER_CONFIGS, ids=CONFIG_IDS)
def test_disjoint_union_block_diagonality(name, cfg):
    (n1_name, g1), (n2_name, g2) = _graphs(small_only=True)
    s1, d1, n1 = g1.to_numpy()
    s2, d2, n2 = g2.to_numpy()
    union = Graph.from_numpy(np.concatenate([s1, s2 + n1]),
                             np.concatenate([d1, d2 + n1]), n1 + n2)
    labels = _solve_np(union, cfg)
    base1 = _solve_np(g1, cfg)
    base2 = _solve_np(g2, cfg)
    # blocks are independent; min-id labels of the offset block shift by n1
    assert (labels[:n1] == base1).all(), (name, n1_name)
    assert (labels[n1:] == base2 + n1).all(), (name, n2_name)
    _assert_oracle_partition(labels, union, name)


@pytest.mark.parametrize("name,cfg", SOLVER_CONFIGS, ids=CONFIG_IDS)
def test_warm_start_invariance(name, cfg):
    """Warm starts are metamorphic too: restarting from any sound upper
    bound of the fixed point (a cold solve's own labels, or a prefix
    solve of half the edges) must land on the same canonical labels."""
    for gname, g in _graphs(small_only=True):
        cfg2 = dict(cfg)
        if cfg2.get("mesh") == "MESH1":
            cfg2["mesh"] = _mesh1()
        base = _solve_np(g, cfg)
        opts = SolveOptions(**cfg2)
        again = solve(g, opts, warm_start=jnp_array(base))
        assert (np.asarray(again.labels) == base).all(), (name, gname)
        src, dst, n = g.to_numpy()
        half = Graph.from_numpy(src[: len(src) // 2], dst[: len(dst) // 2], n)
        partial = solve(half, opts)
        resumed = solve(g, opts, warm_start=partial)
        assert (np.asarray(resumed.labels) == base).all(), (name, gname)


def jnp_array(x):
    import jax.numpy as jnp
    return jnp.asarray(x)


# ---------------------------------------------------------------------------
# hypothesis layer: the same invariances over random graphs/permutations


if HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    # keep the fuzz layer off the two expensive configs (mesh re-jits per
    # call; the host union-find is a python loop) — the deterministic
    # layer above already covers them
    FUZZ_CONFIGS = [(n, c) for n, c in SOLVER_CONFIGS
                    if n not in ("distributed", "union_find")]

    @st.composite
    def random_graph_and_perm(draw):
        n = draw(st.integers(2, 60))
        m = draw(st.integers(0, 3 * n))
        rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        return Graph.from_numpy(src, dst, n), rng.permutation(n)

    @settings(max_examples=20, deadline=None)
    @given(random_graph_and_perm(),
           st.sampled_from([n for n, _ in FUZZ_CONFIGS]))
    def test_fuzz_metamorphic_invariances(gp, config_name):
        cfg = dict(FUZZ_CONFIGS)[config_name]
        g, pi = gp
        src, dst, n = g.to_numpy()
        base = _solve_np(g, cfg)
        _assert_oracle_partition(base, g, config_name)
        permuted = _solve_np(Graph.from_numpy(pi[src], pi[dst], n), cfg)
        assert labels_equivalent(permuted[pi], base), config_name
        flipped = _solve_np(Graph.from_numpy(dst, src, n), cfg)
        assert (flipped == base).all(), config_name
else:
    @pytest.mark.skip(reason="hypothesis not installed; the deterministic "
                             "metamorphic layer above still ran")
    def test_fuzz_metamorphic_invariances():
        pass
