"""Strategy matrix + ``solver="auto"`` cost model (DESIGN.md §16).

Covers the pluggable sampling strategies (soundness: every strategy is a
permutation of the edge list plus a prefix width, so the fixed point is
strategy-independent), the cost-model precedence chain
(pinned > fitted-from-artifact > heuristic), the degenerate feature
regimes (m=0, n=1), the provenance strings on every path, and the two
sampling-phase bugfix regressions of ISSUE 10:

* the zero-width sampling prefix on small graphs (``m //
  SAMPLE_PREFIX_DENOM == 0``) must clamp to >= 1 edge;
* ``gate_sampling_done`` must not hold convergence hostage to the full
  sampling budget — a graph already at its fixed point exits in one
  iteration on both the masked and staged schedules.
"""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.connectivity import SolveOptions, solve, solve_batch
from repro.connectivity import frontier as fr
from repro.connectivity.planner import costmodel
from repro.connectivity.planner import ExecutionPlan
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle
from repro.graphs.stats import degree_skew
from repro.graphs.structs import Graph

pytestmark = pytest.mark.strategy

ALL_STRATEGIES = fr.SAMPLING_STRATEGIES


def _rand_graph(n, m, seed):
    rng = np.random.default_rng(seed)
    return Graph.from_numpy(rng.integers(0, n, m), rng.integers(0, n, m), n)


def _oracle(g):
    return connected_components_oracle(*g.to_numpy())


# ---------------------------------------------------------------- samplers


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
@pytest.mark.parametrize("n,m,seed", [(50, 200, 0), (200, 90, 1),
                                      (64, 64, 2)])
def test_prepare_sampling_is_permutation_with_nonzero_prefix(
        strategy, n, m, seed):
    """Every strategy permutes the edge list and claims a 1..m prefix —
    the property the soundness argument (DESIGN.md §16) rests on."""
    g = _rand_graph(n, m, seed)
    src2, dst2, sample_m = fr.prepare_sampling(strategy, g.src, g.dst, n)
    pairs = sorted(zip(np.asarray(g.src).tolist(),
                       np.asarray(g.dst).tolist()))
    pairs2 = sorted(zip(np.asarray(src2).tolist(),
                        np.asarray(dst2).tolist()))
    assert pairs == pairs2, strategy        # a permutation, nothing lost
    assert 1 <= int(sample_m) <= m, strategy


def test_kout_prefix_covers_every_vertex_k_edges():
    """k-out/Afforest: each vertex's first k incident edges land in the
    sampled prefix."""
    g = _rand_graph(80, 400, 3)
    k = 2
    src2, dst2, sample_m = fr.prepare_sampling("kout", g.src, g.dst, 80,
                                               k=k)
    sm = int(sample_m)
    seen = np.zeros(80, dtype=int)
    np.add.at(seen, np.asarray(src2[:sm]), 1)
    np.add.at(seen, np.asarray(dst2[:sm]), 1)
    deg = np.zeros(80, dtype=int)
    np.add.at(deg, np.asarray(g.src), 1)
    np.add.at(deg, np.asarray(g.dst), 1)
    assert (seen >= np.minimum(deg, k)).all()


def test_unknown_strategy_and_bad_k_fail_eagerly():
    g = _rand_graph(10, 20, 4)
    with pytest.raises(ValueError, match="unknown sampling_strategy"):
        fr.prepare_sampling("bogus", g.src, g.dst, 10)
    with pytest.raises(ValueError, match="sampling k must be >= 1"):
        fr.prepare_sampling("kout", g.src, g.dst, 10, k=0)


def test_solve_options_reject_bad_strategy_knobs():
    """Satellite bugfix: typo'd knobs die at validate(), not trace time."""
    with pytest.raises(ValueError, match="unknown sampling_strategy"):
        SolveOptions(sampling_strategy="prefx").validate()
    with pytest.raises(ValueError, match="sampling_k must be >= 1"):
        SolveOptions(sampling_k=0).validate()
    with pytest.raises(ValueError, match="unknown sampling_strategy"):
        solve(_rand_graph(8, 10, 5), sampling_strategy="afforest")


def test_registering_a_custom_strategy_extends_the_matrix():
    """The registry is open: a registered name passes validation and
    runs through the same adaptive schedule."""
    def prepare(src, dst, n_vertices, k):
        # reverse order: still a permutation + prefix, still sound
        return src[::-1], dst[::-1], jnp.int32(max(1, src.shape[0] // 2))

    fr.register_sampling_strategy(
        fr.SamplingStrategy(name="_test_rev", prepare=prepare))
    try:
        g = _rand_graph(60, 150, 6)
        r = solve(g, SolveOptions(algorithm="contour", sampling=2,
                                  compact_every=2, backend="xla",
                                  sampling_strategy="_test_rev"))
        assert np.array_equal(np.asarray(r.labels), _oracle(g))
        assert "sampling_strategy:_test_rev" in (r.provenance or ())
    finally:
        fr._SAMPLING_REGISTRY.pop("_test_rev", None)


# ---------------------------------------------- strategy x engine matrix


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
@pytest.mark.parametrize("schedule", ["masked", "staged"])
def test_strategies_bit_identical_across_schedules(strategy, schedule):
    g = _rand_graph(3000, 5000, 7)
    plan = ExecutionPlan(backend="xla", compact_schedule=schedule,
                         origin="pinned")
    r = solve(g, SolveOptions(algorithm="contour", variant="C-2",
                              backend="xla", plan=plan, sampling=2,
                              compact_every=2, sampling_strategy=strategy))
    assert np.array_equal(np.asarray(r.labels), _oracle(g))
    assert f"sampling_strategy:{strategy}" in r.provenance


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_strategies_under_vmapped_solve_batch(strategy):
    """The traced path: data-dependent sample widths must survive vmap."""
    graphs = [_rand_graph(40, 90, s) for s in (8, 9, 10)]
    res = solve_batch(graphs, SolveOptions(
        algorithm="contour", backend="xla", sampling=2, compact_every=2,
        sampling_strategy=strategy))
    for g, lab in zip(graphs, res.unstack()):
        assert np.array_equal(np.asarray(lab.labels), _oracle(g))


def test_distributed_rejects_nonprefix_strategy():
    import jax as _jax
    from repro import jax_compat
    mesh = jax_compat.device_mesh(np.array(_jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="single-device only"):
        solve(_rand_graph(20, 40, 11),
              SolveOptions(algorithm="distributed", mesh=mesh,
                           sampling=2, sampling_strategy="kout"))


# -------------------------------------------------- bugfix regressions


def test_sampling_prefix_clamped_at_small_m():
    """Regression (pre-PR bug 1): at ``m = SAMPLE_PREFIX_DENOM - 1`` the
    integer-division prefix would be 0 edges — pure no-op rounds.  The
    width must clamp to >= 1 and the solve must do real work during
    sampling."""
    m = fr.SAMPLE_PREFIX_DENOM - 1          # = 3
    assert fr.sample_prefix_m(m) == 1
    g = Graph.from_numpy(np.array([0, 1, 2]), np.array([1, 2, 3]), 4)
    for strategy in ALL_STRATEGIES:
        r = solve(g, SolveOptions(algorithm="contour", backend="xla",
                                  sampling=3, sampling_strategy=strategy))
        assert np.array_equal(np.asarray(r.labels), _oracle(g)), strategy
        # every sampling sweep touched >= 1 edge: with a zero-width
        # prefix the counter would undercount by the whole phase
        assert float(r.edges_visited) >= 3.0, strategy


def test_edgeless_graph_converges_in_one_iteration():
    """Regression (pre-PR bug 2): with zero edges every sweep is empty,
    so the first convergence check fires — but the old
    ``gate_sampling_done`` forced ``sampling + 1`` iterations anyway."""
    g = Graph.from_numpy(np.zeros(0, np.int32), np.zeros(0, np.int32), 6)
    r = solve(g, SolveOptions(algorithm="contour", backend="xla",
                              sampling=3))
    assert bool(r.converged)
    assert int(r.iterations) == 1
    assert np.array_equal(np.asarray(r.labels), np.arange(6))


@pytest.mark.parametrize("schedule", ["masked", "staged"])
def test_warm_start_converged_exits_during_sampling(schedule):
    """Regression (pre-PR bug 2, warm-start form): re-solving from an
    already-converged label fixed point must exit after one iteration —
    the old gate burned the full ``sampling`` budget first."""
    g = _rand_graph(3000, 5000, 12)
    r0 = solve(g, SolveOptions(algorithm="contour", backend="xla"))
    assert bool(r0.converged)
    plan = ExecutionPlan(backend="xla", compact_schedule=schedule,
                         origin="pinned")
    r = solve(g, SolveOptions(algorithm="contour", backend="xla",
                              plan=plan, sampling=4, compact_every=2),
              warm_start=r0)
    assert bool(r.converged)
    assert int(r.iterations) == 1, schedule
    assert np.array_equal(np.asarray(r.labels), np.asarray(r0.labels))


# ------------------------------------------------------------ cost model


def test_costmodel_precedence_pinned_wins(tmp_path):
    choice = costmodel.resolve_strategy(
        1000, 4000, degree_skew=50.0, pinned_strategy="bfs",
        bench_path=tmp_path / "nope.json")
    assert choice.origin == "pinned"
    assert choice.sampling_strategy == "bfs"
    assert choice.sampling >= 1
    assert "origin=pinned" in choice.provenance_entry()


def _write_artifact(path, rows):
    path.write_text(json.dumps({"schema": 7, "strategy_gate": rows}))


def test_costmodel_fitted_copies_nearest_measured_graph(tmp_path):
    art = tmp_path / "bench.json"
    _write_artifact(art, {
        "hubby": {"n": 1000, "m": 50_000, "degree_skew": 100.0,
                  "sides": {"prefix": {"seconds": [2.0]},
                            "kout": {"seconds": [1.0]},
                            "auto": {"seconds": [1.0]}}},
        "pathy": {"n": 100_000, "m": 100_000, "degree_skew": 2.0,
                  "sides": {"prefix": {"seconds": [1.0]},
                            "kout": {"seconds": [3.0]}}},
    })
    near_hub = costmodel.resolve_strategy(2000, 80_000, degree_skew=80.0,
                                          bench_path=art)
    assert near_hub.origin == "fitted"
    assert near_hub.sampling_strategy == "kout"
    assert near_hub.neighbor == "hubby"
    assert "nn=hubby" in near_hub.provenance_entry()
    near_path = costmodel.resolve_strategy(90_000, 95_000, degree_skew=2.1,
                                           bench_path=art)
    assert (near_path.origin, near_path.sampling_strategy) == \
        ("fitted", "prefix")
    # pinned still beats a usable fitted model
    pinned = costmodel.resolve_strategy(2000, 80_000, degree_skew=80.0,
                                        pinned_strategy="bfs",
                                        bench_path=art)
    assert (pinned.origin, pinned.sampling_strategy) == ("pinned", "bfs")


def test_costmodel_heuristic_fallbacks(tmp_path):
    # no artifact at all
    missing = costmodel.resolve_strategy(1000, 4000, degree_skew=1.5,
                                         bench_path=tmp_path / "no.json")
    assert missing.origin == "heuristic"
    assert missing.solver == "contour"
    # corrupt artifact must not raise
    bad = tmp_path / "bad.json"
    bad.write_text("{ not json !!")
    corrupt = costmodel.resolve_strategy(1000, 4000, degree_skew=1.5,
                                         bench_path=bad)
    assert corrupt.origin == "heuristic"
    # pre-schema-7 artifacts carry no strategy rows
    old = tmp_path / "old.json"
    old.write_text(json.dumps({"schema": 6, "strategy_gate": {}}))
    assert costmodel.resolve_strategy(
        1000, 4000, degree_skew=1.5, bench_path=old).origin == "heuristic"
    # hub regime heuristic
    hub = costmodel.resolve_strategy(1000, 64_000, degree_skew=100.0,
                                     bench_path=tmp_path / "no.json")
    assert hub.sampling_strategy == "kout"


def test_costmodel_degenerate_features(tmp_path):
    for n, m in ((1, 0), (5, 0), (1, 3)):
        choice = costmodel.resolve_strategy(n, m, degree_skew=0.0,
                                            bench_path=tmp_path / "x.json")
        assert choice.origin == "heuristic"
        assert choice.sampling == 0          # nothing worth sampling
        assert choice.sampling_strategy == "prefix"
    # skew=None (tracer regime) is the regular-graph prior, not an error
    assert costmodel.resolve_strategy(
        100, 200, degree_skew=None,
        bench_path=tmp_path / "x.json").sampling_strategy == "prefix"


def test_degree_skew_feature():
    s, d, n = gen.star(64, seed=0).to_numpy()
    assert degree_skew(s, d, n) > 10.0
    s, d, n = gen.path(64, seed=0).to_numpy()
    assert degree_skew(s, d, n) < 2.0
    assert degree_skew(np.zeros(0, int), np.zeros(0, int), 4) == 0.0


# ------------------------------------------------------- solver="auto"


def test_auto_solver_bit_identical_and_provenanced():
    g = _rand_graph(500, 900, 13)
    r = solve(g, SolveOptions(algorithm="auto"))
    assert np.array_equal(np.asarray(r.labels), _oracle(g))
    auto_entries = [p for p in r.provenance if p.startswith("auto:")]
    assert auto_entries and "origin=heuristic" in auto_entries[0]
    assert any(p.startswith("plan:") for p in r.provenance)


def test_auto_solver_pinned_strategy_in_provenance():
    g = _rand_graph(500, 900, 14)
    r = solve(g, SolveOptions(algorithm="auto", sampling_strategy="bfs"))
    assert np.array_equal(np.asarray(r.labels), _oracle(g))
    assert any(p.startswith("auto:") and "strategy=bfs" in p
               and "origin=pinned" in p for p in r.provenance)
    assert "sampling_strategy:bfs" in r.provenance


def test_auto_solver_fitted_end_to_end(tmp_path, monkeypatch):
    art = tmp_path / "bench.json"
    _write_artifact(art, {
        "only": {"n": 500, "m": 900, "degree_skew": 3.0,
                 "sides": {"prefix": {"seconds": [2.0]},
                           "bfs": {"seconds": [1.0]}}}})
    monkeypatch.setenv(costmodel.ENV_BENCH_ARTIFACT, str(art))
    g = _rand_graph(500, 900, 15)
    r = solve(g, SolveOptions(algorithm="auto"))
    assert np.array_equal(np.asarray(r.labels), _oracle(g))
    assert any("origin=fitted" in p and "strategy=bfs" in p
               and "nn=only" in p for p in r.provenance)


def test_auto_solver_warm_start_and_variant_pin():
    g = _rand_graph(500, 900, 16)
    r0 = solve(g, SolveOptions(algorithm="auto"))
    r = solve(g, SolveOptions(algorithm="auto", variant="C-m"),
              warm_start=r0)
    assert bool(r.converged)
    assert np.array_equal(np.asarray(r.labels), np.asarray(r0.labels))


def test_auto_solver_under_solve_batch():
    """Under vmap the model sees only shape features (skew needs values);
    the labels must still match the oracle."""
    graphs = [_rand_graph(40, 90, s) for s in (17, 18)]
    res = solve_batch(graphs, SolveOptions(algorithm="auto"))
    for g, lab in zip(graphs, res.unstack()):
        assert np.array_equal(np.asarray(lab.labels), _oracle(g))


# ----------------------------------------------------- artifact checker


def test_check_artifact_strategy_gate(tmp_path):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "check_artifact", "benchmarks/check_artifact.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    def payload(auto_secs, bit=True):
        return {"schema": 7, "summary": {"all_correct": True},
                "strategy_gate": {
                    "g": {"n": 10, "m": 20, "degree_skew": 1.0,
                          "sides": {
                              "prefix": {"bit_identical": True,
                                         "seconds": [1.0, 1.1]},
                              "auto": {"bit_identical": bit,
                                       "seconds": auto_secs}}}}}

    assert mod.check_strategy_gate(payload([1.05])) == []
    errs = mod.check_strategy_gate(payload([1.5]))
    assert errs and "geomean" in errs[0]
    errs = mod.check_strategy_gate(payload([1.0], bit=False))
    assert any("differ from the dense oracle" in e for e in errs)
    assert mod.check_strategy_gate({"schema": 7, "strategy_gate": {}})
