"""Property-based tests (hypothesis) for the system's invariants.

``hypothesis`` is an optional dependency (pyproject ``[test]`` extra); when
absent this module must *skip*, not error — a collection error under
``pytest -x`` would zero out the whole tier-1 suite.
"""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import contour, fastsv
from repro.core.contour import contour_labels
from repro.graphs.oracle import connected_components_oracle, labels_equivalent
from repro.graphs.stats import approx_max_diameter
from repro.graphs.structs import Graph, canonicalize_edges


@st.composite
def random_graphs(draw):
    n = draw(st.integers(2, 120))
    m = draw(st.integers(0, 4 * n))
    src = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    dst = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    s, d = canonicalize_edges(np.array(src + [0]), np.array(dst + [0]), n)
    if s.shape[0] == 0:
        s, d = np.array([0]), np.array([0])
    return Graph.from_numpy(s, d, n)


@settings(max_examples=60, deadline=None)
@given(random_graphs(), st.sampled_from(["C-1", "C-2", "C-m", "C-Syn"]))
def test_partition_matches_oracle(g, variant):
    oracle = connected_components_oracle(*g.to_numpy())
    labels, _ = contour(g, variant=variant)
    assert (np.asarray(labels) == oracle).all()


@settings(max_examples=40, deadline=None)
@given(random_graphs())
def test_labels_are_component_minima(g):
    labels = np.asarray(contour(g, variant="C-2")[0])
    # every label is a vertex id that maps to itself (star roots)
    assert (labels[labels] == labels).all()
    # label <= vertex id (minimum-mapping is monotone decreasing)
    assert (labels <= np.arange(g.n_vertices)).all()


@settings(max_examples=30, deadline=None)
@given(random_graphs())
def test_idempotence_after_convergence(g):
    """Feeding converged labels through one more MM sweep changes nothing."""
    from repro.core import labels as lab

    L = contour(g, variant="C-2")[0]
    L2 = lab.mm_relax(L, g.src, g.dst, order=2)
    assert (np.asarray(L2) == np.asarray(L)).all()


@settings(max_examples=30, deadline=None)
@given(random_graphs())
def test_fastsv_agrees_with_contour(g):
    Lc = np.asarray(contour(g, variant="C-2")[0])
    Lf = np.asarray(fastsv(g)[0])
    assert labels_equivalent(Lc, Lf)


@settings(max_examples=25, deadline=None)
@given(random_graphs())
def test_theorem1_bound_holds(g):
    d = max(approx_max_diameter(*g.to_numpy()), 2)
    bound = math.ceil(math.log(d, 1.5)) + 2   # +1 convergence observation
    _, iters = contour(g, variant="C-2")
    assert int(iters) <= bound


@settings(max_examples=30, deadline=None)
@given(random_graphs(), st.integers(0, 3))
def test_edge_order_invariance(g, seed):
    """The fixed point is independent of edge permutation (determinism of
    the scatter-min combiner; the paper's async races can't affect it)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.n_edges)
    g2 = Graph.from_numpy(np.asarray(g.src)[perm], np.asarray(g.dst)[perm],
                          g.n_vertices)
    L1 = np.asarray(contour(g, variant="C-2")[0])
    L2 = np.asarray(contour(g2, variant="C-2")[0])
    assert (L1 == L2).all()


@settings(max_examples=20, deadline=None)
@given(random_graphs())
def test_direction_invariance(g):
    """Undirected semantics: swapping src/dst leaves the labelling fixed."""
    g2 = Graph(src=g.dst, dst=g.src, n_vertices=g.n_vertices)
    L1 = np.asarray(contour(g, variant="C-2")[0])
    L2 = np.asarray(contour(g2, variant="C-2")[0])
    assert (L1 == L2).all()
