"""Degenerate-shape regressions: m=0, n=1, all-self-loop, empty batch.

Tiny shapes are where static-shape JAX code miscompiles quietly: the
``m // 4`` sampling prefix at m=0, the ``compact_every`` stable partition
over zero edges, ``vmap`` over a B=0 fleet, empty scatters.  Every solver
and the frontier schedule must return the identity labelling (every
vertex its own component) for an edgeless graph, and treat self-loops as
no-ops, through ``solve``, ``solve_batch`` and the streaming engine.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.connectivity import (SolveOptions, StreamingConnectivity, solve,
                                solve_batch, stack_graphs)
from repro.connectivity.contour import contour_labels
from repro.graphs.structs import Graph

ALGOS = ("contour", "fastsv", "label_propagation", "union_find")


def _empty(n: int) -> Graph:
    z = np.zeros(0, np.int32)
    return Graph.from_numpy(z, z, n)


@pytest.mark.parametrize("algorithm", ALGOS)
@pytest.mark.parametrize("n", (1, 5))
def test_edgeless_graph_is_identity(algorithm, n):
    res = solve(_empty(n), algorithm=algorithm)
    assert (np.asarray(res.labels) == np.arange(n)).all()
    assert res.n_components == n
    assert bool(res.converged)


@pytest.mark.parametrize("algorithm", ALGOS)
def test_all_self_loop_graph_is_identity(algorithm):
    n = 6
    loops = np.arange(n, dtype=np.int32)
    res = solve(Graph.from_numpy(loops, loops, n), algorithm=algorithm)
    assert (np.asarray(res.labels) == np.arange(n)).all()
    assert res.n_components == n


@pytest.mark.parametrize("n", (1, 4))
def test_frontier_schedule_at_m0(n):
    """The m//4 sampling prefix and the compaction partition at m=0."""
    res = solve(_empty(n),
                SolveOptions(backend="xla", sampling=2, compact_every=2))
    assert (np.asarray(res.labels) == np.arange(n)).all()
    assert float(res.edges_visited) == 0.0
    # and straight through the jitted kernel entry
    z = jnp.zeros((0,), jnp.int32)
    L, it, done, visited = contour_labels(z, z, n, backend="xla",
                                          sampling=3, compact_every=1)
    assert (np.asarray(L) == np.arange(n)).all()
    assert bool(done)
    assert float(visited) == 0.0


def test_single_vertex_with_self_loop():
    res = solve(Graph.from_numpy(np.array([0]), np.array([0]), 1),
                backend="xla", sampling=1, compact_every=1)
    assert np.asarray(res.labels).tolist() == [0]
    assert res.n_components == 1


def test_empty_stack_graphs_and_solve_batch():
    stacked, sizes = stack_graphs([], with_sizes=True)
    assert sizes == ()
    assert stacked.src.shape[0] == 0

    for graphs in ([], stacked):
        res = solve_batch(graphs, backend="xla")
        assert res.is_batched
        assert res.labels.shape[0] == 0
        assert res.unstack() == []

    # empty fleet composes with the frontier schedule and batch_sizes
    res = solve_batch([], SolveOptions(sampling=1, compact_every=1),
                      batch_sizes=())
    assert res.unstack() == []

    # a mismatched warm_start is a caller bug even on an empty fleet
    with pytest.raises(ValueError, match="warm_start"):
        solve_batch([], warm_start=[np.zeros(3, np.int32)])
    assert solve_batch([], warm_start=[]).unstack() == []


@pytest.mark.parametrize("algorithm", ("contour", "fastsv",
                                       "label_propagation"))
def test_solve_batch_of_edgeless_graphs(algorithm):
    """A fleet whose members all have m=0 pads to one self-loop slot."""
    res = solve_batch([_empty(3), _empty(5)], algorithm=algorithm)
    parts = res.unstack()
    assert [p.labels.shape[0] for p in parts] == [3, 5]
    for p in parts:
        labels = np.asarray(p.labels)
        assert (labels == np.arange(labels.shape[0])).all()


def test_streaming_engine_degenerate_stream():
    eng = StreamingConnectivity(1)
    eng.ingest([], [])
    eng.ingest([0], [0])                      # self-loop batch
    assert eng.n_components == 1
    assert np.asarray(eng.labels).tolist() == [0]
    snap = eng.snapshot()
    assert bool(snap.converged)


def test_mixed_degenerate_warm_start_roundtrip():
    """m=0 solve results remain valid warm starts as the graph grows."""
    prev = solve(_empty(4), backend="xla")
    grown = _empty(4).add_edges([0, 2], [1, 3])
    res = solve(grown, backend="xla", warm_start=prev)
    assert np.asarray(res.labels).tolist() == [0, 0, 2, 2]
