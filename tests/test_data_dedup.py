"""Data pipeline determinism + the Contour-powered dedup integration."""
import numpy as np

from repro.data.dedup import lsh_candidate_pairs, minhash_dedup, minhash_signatures
from repro.data.pipeline import SyntheticTokenPipeline, make_corpus


def test_pipeline_seek_determinism():
    p1 = SyntheticTokenPipeline(vocab_size=1000, batch=4, seq_len=32, seed=3)
    b10 = p1.batch_at(10)
    # a fresh pipeline seeked anywhere yields identical batches
    p2 = SyntheticTokenPipeline(vocab_size=1000, batch=4, seq_len=32, seed=3)
    assert (p2.batch_at(10)["tokens"] == b10["tokens"]).all()
    # labels are next-token shifted
    assert (b10["labels"][:, :-1] == b10["tokens"][:, 1:]).all()
    # different steps differ
    assert (p1.batch_at(11)["tokens"] != b10["tokens"]).any()


def test_pipeline_iterator_matches_batch_at():
    p = SyntheticTokenPipeline(vocab_size=100, batch=2, seq_len=8, seed=1)
    it = iter(p)
    first = next(it)
    q = SyntheticTokenPipeline(vocab_size=100, batch=2, seq_len=8, seed=1)
    assert (first["tokens"] == q.batch_at(0)["tokens"]).all()


def test_minhash_similar_docs_collide():
    rng = np.random.default_rng(0)
    base = rng.integers(0, 1000, 200)
    near = base.copy()
    near[::29] = rng.integers(0, 1000, near[::29].shape[0])
    far = rng.integers(0, 1000, 200)
    sigs = minhash_signatures([base, near, far], n_hashes=64)
    sim_near = (sigs[0] == sigs[1]).mean()
    sim_far = (sigs[0] == sigs[2]).mean()
    assert sim_near > 0.5
    assert sim_far < 0.2


def test_dedup_recovers_planted_clusters():
    docs = make_corpus(n_docs=120, doc_len=150, vocab_size=500,
                       dup_fraction=0.4, near_dup_noise=0.03, seed=5)
    report = minhash_dedup(docs, n_hashes=64, bands=16)
    # planted ~40% duplicates: dedup must find a significant reduction
    assert report.n_clusters < 110
    assert report.n_clusters >= 60          # but not collapse everything
    # representatives are the cluster minima (Contour fixed point)
    keep_ids = np.flatnonzero(report.keep)
    assert (report.labels[keep_ids] == keep_ids).all()
    # every doc's label is a kept representative
    assert set(report.labels) <= set(keep_ids)
    assert report.cc_iterations >= 1


def test_dedup_no_duplicates_corpus():
    rng = np.random.default_rng(9)
    docs = [rng.integers(0, 10_000, 64) for _ in range(30)]
    report = minhash_dedup(docs, n_hashes=32, bands=8)
    assert report.n_clusters >= 28      # little to no collapse
