"""Data pipeline determinism + the Contour-powered dedup integration."""
import numpy as np

from repro.data.dedup import (StreamingDedup, lsh_candidate_pairs,
                              minhash_dedup, minhash_signatures)
from repro.data.pipeline import SyntheticTokenPipeline, make_corpus


def test_pipeline_seek_determinism():
    p1 = SyntheticTokenPipeline(vocab_size=1000, batch=4, seq_len=32, seed=3)
    b10 = p1.batch_at(10)
    # a fresh pipeline seeked anywhere yields identical batches
    p2 = SyntheticTokenPipeline(vocab_size=1000, batch=4, seq_len=32, seed=3)
    assert (p2.batch_at(10)["tokens"] == b10["tokens"]).all()
    # labels are next-token shifted
    assert (b10["labels"][:, :-1] == b10["tokens"][:, 1:]).all()
    # different steps differ
    assert (p1.batch_at(11)["tokens"] != b10["tokens"]).any()


def test_pipeline_iterator_matches_batch_at():
    p = SyntheticTokenPipeline(vocab_size=100, batch=2, seq_len=8, seed=1)
    it = iter(p)
    first = next(it)
    q = SyntheticTokenPipeline(vocab_size=100, batch=2, seq_len=8, seed=1)
    assert (first["tokens"] == q.batch_at(0)["tokens"]).all()


def test_minhash_similar_docs_collide():
    rng = np.random.default_rng(0)
    base = rng.integers(0, 1000, 200)
    near = base.copy()
    near[::29] = rng.integers(0, 1000, near[::29].shape[0])
    far = rng.integers(0, 1000, 200)
    sigs = minhash_signatures([base, near, far], n_hashes=64)
    sim_near = (sigs[0] == sigs[1]).mean()
    sim_far = (sigs[0] == sigs[2]).mean()
    assert sim_near > 0.5
    assert sim_far < 0.2


def test_dedup_recovers_planted_clusters():
    docs = make_corpus(n_docs=120, doc_len=150, vocab_size=500,
                       dup_fraction=0.4, near_dup_noise=0.03, seed=5)
    report = minhash_dedup(docs, n_hashes=64, bands=16)
    # planted ~40% duplicates: dedup must find a significant reduction
    assert report.n_clusters < 110
    assert report.n_clusters >= 60          # but not collapse everything
    # representatives are the cluster minima (Contour fixed point)
    keep_ids = np.flatnonzero(report.keep)
    assert (report.labels[keep_ids] == keep_ids).all()
    # every doc's label is a kept representative
    assert set(report.labels) <= set(keep_ids)
    assert report.cc_iterations >= 1


def test_dedup_no_duplicates_corpus():
    rng = np.random.default_rng(9)
    docs = [rng.integers(0, 10_000, 64) for _ in range(30)]
    report = minhash_dedup(docs, n_hashes=32, bands=8)
    assert report.n_clusters >= 28      # little to no collapse


def test_streaming_dedup_matches_batch_dedup():
    """Online LSH ingestion lands on the one-shot pass's exact labels.

    Per band, the batch path chains consecutive bucket members while the
    streaming path links each arrival to the bucket's first member — both
    make every bucket one connected set, and signatures are per-doc
    deterministic, so the cluster partitions (and their canonical min-id
    labels) must coincide no matter how the corpus is micro-batched.
    """
    docs = make_corpus(n_docs=90, doc_len=120, vocab_size=400,
                       dup_fraction=0.4, near_dup_noise=0.03, seed=7)
    batch_report = minhash_dedup(docs, n_hashes=32, bands=8)

    for batch_size in (7, 30, 90):
        sd = StreamingDedup(n_hashes=32, bands=8)
        for pos in range(0, len(docs), batch_size):
            sd.add_docs(docs[pos:pos + batch_size])
        assert sd.n_docs == len(docs)
        assert (sd.labels() == batch_report.labels).all(), batch_size
        report = sd.report()
        assert report.n_clusters == batch_report.n_clusters
        assert (report.keep == batch_report.keep).all()
        # representatives are non-duplicates; later cluster members are
        rep = int(np.flatnonzero(report.keep)[0])
        assert not sd.is_duplicate(rep)
        dups = np.flatnonzero(~report.keep)
        if dups.size:
            assert sd.is_duplicate(int(dups[0]))


def test_streaming_dedup_empty_and_single_batches():
    sd = StreamingDedup(n_hashes=32, bands=8)
    assert sd.add_docs([]).size == 0
    rng = np.random.default_rng(1)
    ids = sd.add_docs([rng.integers(0, 500, 64)])
    assert ids.tolist() == [0]
    assert sd.labels().tolist() == [0]
    assert not sd.is_duplicate(0)
