"""Per-assigned-architecture smoke tests: reduced config, one forward /
train step on CPU, output shapes + no NaNs (assignment requirement)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # full-arch train/serve steps; excluded from the fast tier

from repro.configs import ARCHS, SHAPES, get_arch, input_specs
from repro.models.model import build_model
from repro.optim.adamw import OptConfig
from repro.train.step import init_train_state, make_train_step


def _smoke_batch(config, b=2, t=16, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    batch = {
        "tokens": jax.random.randint(k1, (b, t), 0, config.vocab_size),
        "labels": jax.random.randint(k2, (b, t), 0, config.vocab_size),
    }
    if config.frontend == "patch_stub":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3),
            (b, min(config.n_frontend_tokens, t), config.d_model),
            jnp.float32)
    if config.frontend == "audio_stub":
        batch["frame_embeds"] = jax.random.normal(
            jax.random.PRNGKey(4), (b, t // 2, config.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_name", sorted(ARCHS))
def test_arch_train_step(arch_name):
    arch = get_arch(arch_name)
    config = arch.smoke_config()
    model = build_model(config)
    opt = OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=10)
    step = jax.jit(make_train_step(model, opt))
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    batch = _smoke_batch(config)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), arch_name
    # params actually moved
    assert float(metrics["grad_norm"]) > 0
    # second step: still finite
    state, metrics = step(state, _smoke_batch(config, seed=1))
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch_name", sorted(ARCHS))
def test_arch_serve_step(arch_name):
    arch = get_arch(arch_name)
    config = arch.smoke_config()
    model = build_model(config)
    params = model.init(jax.random.PRNGKey(1))
    batch = _smoke_batch(config, b=2, t=8)
    logits, cache = model.prefill(params, batch)
    assert logits.shape[:2] == (2, 1)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, cache = model.decode_step(
        params, jnp.zeros((2, 1), jnp.int32), cache)
    assert logits2.shape[:2] == (2, 1)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch_name", sorted(ARCHS))
def test_arch_grid_declared(arch_name):
    """Every arch declares its full 4-shape grid with explicit skips."""
    arch = get_arch(arch_name)
    cells = arch.cells()
    assert len(cells) == len(SHAPES) == 4
    for shape_name, skip in cells:
        specs = None
        if skip is None:
            specs = input_specs(arch, shape_name)
            assert "tokens" in specs
            shape = SHAPES[shape_name]
            b = shape.global_batch
            exp_t = 1 if shape.kind == "decode" else shape.seq_len
            assert specs["tokens"].shape == (b, exp_t)
        else:
            assert arch_name not in ("xlstm-125m", "zamba2-2.7b") or \
                shape_name != "long_500k", \
                "sub-quadratic archs must run long_500k"


def test_exact_assignment_configs():
    """Pin the exact assigned hyperparameters (catch accidental edits)."""
    rows = {
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }
    for name, (L, d, h, kv, ff, vocab) in rows.items():
        c = get_arch(name).config
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, h, kv, ff, vocab), name
    # family-specific pins
    assert ARCHS["deepseek-moe-16b"].config.n_experts == 64
    assert ARCHS["deepseek-moe-16b"].config.top_k == 6
    assert ARCHS["arctic-480b"].config.n_experts == 128
    assert ARCHS["arctic-480b"].config.top_k == 2
    assert ARCHS["zamba2-2.7b"].config.ssm_state == 64
    assert ARCHS["seamless-m4t-large-v2"].config.n_enc_layers == 24
