"""The execution-plan layer: resolution, cache, VMEM, staged/fused
bit-exactness, autotuner determinism, provenance (DESIGN.md §14).

The load-bearing properties, in test order:

* **resolution precedence** — pinned plan > tuning cache (``auto`` only)
  > heuristic tables; forced backends never consult the cache;
* **cache robustness** — round-trips are deterministic; corrupt, stale,
  malformed or expired entries resolve to the heuristic prior and can
  never crash a solve;
* **VMEM ceiling** — derived from the queried/declared budget instead of
  the seed's hard-coded 3M, overridable via ``SolveOptions`` and env,
  with the boundary unit-tested;
* **schedule equivalence** — the physically staged frontier driver and
  the fused relabel+scatter-min pass are bit-exact with the masked/
  unfused realisations (and the oracle);
* **autotuner** — deterministic under an injected measure function,
  hysteresis keeps the prior on near-ties, tuned plans are bit-exact
  with heuristic plans on every backend (``tuning`` marker);
* **provenance** — every planned solve path records the resolved plan.
"""
import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.connectivity import SolveOptions, solve, solve_batch
from repro.connectivity import planner
from repro.connectivity.contour import contour_labels
from repro.connectivity.planner import (
    ExecutionPlan,
    cache,
    heuristic_plan,
    plan_key,
    resolve_plan,
)
from repro.connectivity.planner import staged as staged_mod
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle, labels_equivalent
from repro.kernels.contour_mm import ops as mm_ops


@pytest.fixture()
def tmp_cache(tmp_path):
    return str(tmp_path / "tuning.json")


@pytest.fixture()
def graph():
    return gen.components_mix([gen.path(400, seed=1), gen.rmat(9, seed=2)],
                              seed=3)


# ---------------------------------------------------------------- resolution

def test_heuristic_plan_is_platform_and_size_aware():
    cpu = heuristic_plan(1000, 5000, "cpu")
    assert cpu.backend == "xla" and cpu.interpret
    small = heuristic_plan(1000, 5000, "tpu")
    assert small.backend == "pallas_blocked"
    assert small.fuse_relabel and small.label_block >= 1000
    big = heuristic_plan(1 << 20, 1 << 22, "tpu")
    assert not big.fuse_relabel and big.label_block == 2048
    assert heuristic_plan(100, 100, "tpu").compact_schedule == "masked"
    assert heuristic_plan(100, 1 << 16, "tpu").compact_schedule == "staged"


def test_pinned_plan_wins_over_cache(tmp_cache, monkeypatch):
    monkeypatch.setenv(cache.ENV_CACHE_PATH, tmp_cache)
    cached = ExecutionPlan(backend="xla", interpret=True, block_edges=64)
    cache.store(1000, 5000, "cpu", cached)
    pin = ExecutionPlan(backend="xla", interpret=True, block_edges=4096,
                        origin="pinned")
    got = resolve_plan(1000, 5000, backend="auto", plan=pin, platform="cpu")
    assert got.block_edges == 4096 and got.origin == "pinned"


def test_legacy_kernel_plan_is_lifted():
    legacy = mm_ops.KernelPlan(backend="xla", block_edges=128,
                               label_block=512, chunk_updates=32,
                               interpret=True)
    got = resolve_plan(10, 10, plan=legacy, platform="cpu")
    assert isinstance(got, ExecutionPlan)
    assert got.block_edges == 128 and got.label_block == 512
    assert got.origin == "pinned" and got.compact_schedule == "masked"


def test_auto_consults_cache_but_forced_backend_does_not(tmp_cache,
                                                         monkeypatch):
    monkeypatch.setenv(cache.ENV_CACHE_PATH, tmp_cache)
    tuned = ExecutionPlan(backend="xla", interpret=True, block_edges=99)
    cache.store(1000, 5000, "cpu", tuned)
    auto = resolve_plan(1000, 5000, backend="auto", platform="cpu")
    assert auto.block_edges == 99 and auto.origin == "tuned"
    forced = resolve_plan(1000, 5000, backend="xla", platform="cpu")
    assert forced.origin == "heuristic" and forced.block_edges != 99


def test_forced_pallas_off_tpu_gets_interpret_mode():
    p = resolve_plan(1000, 5000, backend="pallas_blocked", platform="cpu")
    assert p.backend == "pallas_blocked" and p.interpret
    t = resolve_plan(1000, 5000, backend="pallas_blocked", platform="tpu")
    assert not t.interpret


# --------------------------------------------------------------------- cache

def test_cache_round_trip_is_deterministic(tmp_cache):
    plan = heuristic_plan(5000, 200_000, "tpu").replace(origin="tuned")
    cache.store(5000, 200_000, "tpu", plan, time_s=0.5,
                timings={"a": 0.5}, path=tmp_cache)
    first = cache.lookup(5000, 200_000, "tpu", path=tmp_cache)
    second = cache.lookup(5000, 200_000, "tpu", path=tmp_cache)
    assert first is not None and first.config_equal(plan)
    assert first == second
    # buckets are pow2: a nearby size hits the same entry, a far one misses
    assert cache.lookup(5000, 200_001, "tpu", path=tmp_cache) is not None
    assert cache.lookup(5000, 500, "tpu", path=tmp_cache) is None
    assert cache.lookup(5000, 200_000, "cpu", path=tmp_cache) is None


@pytest.mark.parametrize("payload", [
    "not json at all {",
    json.dumps([1, 2, 3]),
    json.dumps({"schema": 999, "entries": {}}),
    json.dumps({"schema": 1, "entries": "nope"}),
])
def test_corrupt_cache_file_falls_back_without_crashing(tmp_cache, payload,
                                                        monkeypatch):
    with open(tmp_cache, "w") as f:
        f.write(payload)
    monkeypatch.setenv(cache.ENV_CACHE_PATH, tmp_cache)
    assert cache.lookup(1000, 5000, "cpu") is None
    got = resolve_plan(1000, 5000, backend="auto", platform="cpu")
    assert got.origin == "heuristic"


def test_corrupt_cache_entry_falls_back(tmp_cache):
    key = plan_key("cpu", 1000, 5000)
    for bad_entry in (
        "not a dict",
        {"origin": "tuned"},                       # no config at all
        {"origin": "tuned", "config": {"backend": "warp9"}},
        {"origin": "tuned", "config": {"backend": "xla", "mystery": 1}},
        {"origin": "tuned",
         "config": {"backend": "xla", "interpret": "yes"}},
        {"origin": "evil", "config": {"backend": "xla"}},
        {"origin": "fallback", "config": {"backend": "xla"}},  # no expiry
    ):
        with open(tmp_cache, "w") as f:
            json.dump({"schema": 1, "entries": {key: bad_entry}}, f)
        assert cache.lookup(1000, 5000, "cpu", path=tmp_cache) is None


def test_fallback_demotion_expires(tmp_cache):
    planner.record_kernel_failure(1000, 5000, "cpu",
                                  failed_backend="pallas_blocked",
                                  ttl_s=100.0, cache_path=tmp_cache)
    entry = cache.entries(tmp_cache)[plan_key("cpu", 1000, 5000)]
    live = cache.lookup(1000, 5000, "cpu", path=tmp_cache,
                        now=entry["measured_at"] + 50)
    assert live is not None and live.origin == "fallback"
    assert live.backend == "xla"
    expired = cache.lookup(1000, 5000, "cpu", path=tmp_cache,
                           now=entry["measured_at"] + 101)
    assert expired is None  # lapsed: the bucket retunes, XLA is not pinned


def test_cache_clear(tmp_cache):
    plan = ExecutionPlan(backend="xla", interpret=True)
    cache.store(10, 10, "cpu", plan, path=tmp_cache)
    assert cache.entries(tmp_cache)
    cache.clear(tmp_cache)
    assert not cache.entries(tmp_cache)
    assert cache.lookup(10, 10, "cpu", path=tmp_cache) is None


# ---------------------------------------------------------------------- vmem

def test_vmem_ceiling_boundary():
    # default budget (16 MiB): 3/4 of it for L, 4 bytes per label
    assert planner.whole_l_vmem_ceiling("tpu") == 3_145_728
    assert mm_ops.WHOLE_L_VMEM_CEILING == planner.whole_l_vmem_ceiling()
    # exact boundary arithmetic on a toy budget
    assert planner.whole_l_vmem_ceiling("tpu", vmem_bytes=16) == 3
    assert planner.vmem_budget_bytes("tpu", override=1234) == 1234
    with pytest.raises(ValueError):
        planner.vmem_budget_bytes("tpu", override=0)


def test_vmem_env_override(monkeypatch):
    monkeypatch.setenv(planner.ENV_VMEM_BYTES, "32")
    assert planner.vmem_budget_bytes("cpu") == 32
    assert planner.whole_l_vmem_ceiling("cpu") == 6
    monkeypatch.setenv(planner.ENV_VMEM_BYTES, "banana")
    with pytest.raises(ValueError, match="REPRO_VMEM_BYTES"):
        planner.vmem_budget_bytes("cpu")


def test_scalar_pallas_ceiling_uses_solve_options_override():
    g = gen.path(64, seed=0)
    # a 16-byte budget allows 3 whole-L labels: n=64 must refuse clearly
    with pytest.raises(ValueError, match="ceiling"):
        solve(g, backend="pallas", vmem_limit_bytes=16)
    # raising the budget over 4*n/0.75 bytes admits the same graph
    res = solve(g, backend="pallas", vmem_limit_bytes=1 << 20)
    oracle = connected_components_oracle(*g.to_numpy())
    assert labels_equivalent(np.asarray(res.labels), oracle)


def test_scalar_pallas_ceiling_env(monkeypatch):
    g = gen.path(64, seed=0)
    monkeypatch.setenv(planner.ENV_VMEM_BYTES, "16")
    with pytest.raises(ValueError, match="ceiling"):
        solve(g, backend="pallas")


# --------------------------------------------------------- deprecation shim

def test_plan_contour_kernel_is_a_warning_shim():
    with pytest.warns(DeprecationWarning, match="plan_contour_kernel"):
        legacy = mm_ops.plan_contour_kernel(1000, 5000)
    rich = heuristic_plan(1000, 5000)
    assert isinstance(legacy, mm_ops.KernelPlan)
    assert legacy.backend == rich.backend
    assert legacy.label_block == rich.label_block
    assert legacy.interpret == rich.interpret


# ------------------------------------------------- schedule / fused kernels

@pytest.mark.parametrize("n,m,seed", [(200, 900, 0), (500, 3000, 1),
                                      (257, 1100, 2)])
@pytest.mark.parametrize("sampling,compact_every", [(0, 2), (2, 2), (2, 0)])
def test_staged_masked_dense_oracle_bit_exact(n, m, seed, sampling,
                                              compact_every):
    rng = np.random.default_rng(seed)
    src = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    dense = contour_labels(src, dst, n, variant="C-2")[0]
    masked = contour_labels(src, dst, n, variant="C-2", sampling=sampling,
                            compact_every=compact_every)
    staged = staged_mod.staged_adaptive_labels(
        src, dst, n, variant="C-2", sampling=sampling,
        compact_every=compact_every)
    oracle = connected_components_oracle(np.asarray(src), np.asarray(dst), n)
    assert np.array_equal(np.asarray(masked[0]), np.asarray(dense))
    assert np.array_equal(np.asarray(staged[0]), np.asarray(dense))
    assert int(staged[1]) == int(masked[1])          # iteration counts
    assert float(staged[3]) == float(masked[3])      # visited counters
    assert labels_equivalent(np.asarray(staged[0]), oracle)


def test_staged_rejects_csyn_and_negative_schedule():
    g = gen.path(100, seed=0)
    with pytest.raises(ValueError, match="C-Syn"):
        staged_mod.staged_adaptive_labels(g.src, g.dst, g.n_vertices,
                                          variant="C-Syn", sampling=2)
    with pytest.raises(ValueError, match=">= 0"):
        staged_mod.staged_adaptive_labels(g.src, g.dst, g.n_vertices,
                                          sampling=-1)


@pytest.mark.slow
@pytest.mark.parametrize("n,m,seed", [(100, 300, 0), (300, 1500, 1)])
def test_fused_relax_bit_exact_with_reference(n, m, seed):
    from repro.kernels.contour_mm.blocked import fused_relax_pallas
    from repro.connectivity import minmap as lab
    rng = np.random.default_rng(seed)
    src = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    L = jnp.minimum(jnp.arange(n, dtype=jnp.int32),
                    jnp.asarray(rng.integers(0, n, n), jnp.int32))
    L = L.at[0].set(0)
    ref = lab.mm_relax(L, src, dst, 2)
    fused = fused_relax_pallas(L, src, dst, chunk_edges=64, interpret=True)
    assert np.array_equal(np.asarray(fused), np.asarray(ref))
    # the frontier-limited form: suffix edges must not contribute
    limit = jnp.int32(m // 3)
    ref_lim = lab.mm_relax(L, jnp.where(jnp.arange(m) < limit, src, 0),
                           jnp.where(jnp.arange(m) < limit, dst, 0), 2)
    fused_lim = fused_relax_pallas(L, src, dst, chunk_edges=64,
                                   interpret=True, edge_limit=limit)
    assert np.array_equal(np.asarray(fused_lim), np.asarray(ref_lim))


@pytest.mark.slow
def test_fused_plan_routes_through_dispatch(graph):
    """A single-tile fused plan and the unfused path agree elementwise."""
    plan = heuristic_plan(graph.n_vertices, graph.n_edges, "tpu")
    assert plan.fuse_relabel  # small graph: single-tile fused regime
    fused = solve(graph, backend="pallas_blocked",
                  plan=plan.replace(interpret=True))
    unfused = solve(graph, backend="pallas_blocked",
                    plan=plan.replace(interpret=True, fuse_relabel=False))
    assert np.array_equal(np.asarray(fused.labels),
                          np.asarray(unfused.labels))
    assert "fused=1" in fused.provenance[0]
    assert "fused=0" in unfused.provenance[0]


# ----------------------------------------------------------------- autotune

@pytest.mark.tuning
def test_autotune_deterministic_with_injected_measure(graph, tmp_cache):
    # fake clock: the staged-schedule candidate is 2x faster
    def measure(g, plan, opts):
        return 0.05 if plan.compact_schedule == "staged" else 0.10

    tuned, timings = planner.autotune(graph, platform="cpu", measure=measure,
                                      cache_path=tmp_cache)
    assert tuned.origin == "tuned"
    assert tuned.compact_schedule == "staged"
    assert len(timings) >= 2
    # round-trips through the cache: the next auto resolution deploys it
    again = cache.lookup(graph.n_vertices, graph.n_edges, "cpu",
                         path=tmp_cache)
    assert again is not None and again.config_equal(tuned)


@pytest.mark.tuning
def test_autotune_hysteresis_keeps_prior_on_near_tie(graph, tmp_cache):
    heur = heuristic_plan(graph.n_vertices, graph.n_edges, "cpu")

    def measure(g, plan, opts):  # alternative is only 2% faster
        return 0.098 if not plan.config_equal(heur) else 0.10

    tuned, _ = planner.autotune(graph, platform="cpu", measure=measure,
                                cache_path=tmp_cache, margin=0.05)
    assert tuned.config_equal(heur)


@pytest.mark.tuning
@pytest.mark.slow
def test_autotuned_plans_bit_exact_across_backends(graph, tmp_cache):
    """Tuning changes wall time, never labels — on every backend."""
    oracle = connected_components_oracle(*graph.to_numpy())
    heur_cpu = heuristic_plan(graph.n_vertices, graph.n_edges, "cpu")
    reference = solve(graph, options=SolveOptions(
        sampling=2, compact_every=2, plan=heur_cpu))

    def measure(g, plan, opts):  # force a non-prior winner deterministically
        return 0.01 if plan.compact_schedule != \
            heur_cpu.compact_schedule else 1.0

    tuned, _ = planner.autotune(graph, platform="cpu", measure=measure,
                                cache_path=tmp_cache)
    assert not tuned.config_equal(heur_cpu)
    for plan in (
        tuned,
        heur_cpu,
        heuristic_plan(graph.n_vertices, graph.n_edges, "tpu")
        .replace(backend="pallas_blocked", interpret=True),
    ):
        res = solve(graph, options=SolveOptions(
            backend=plan.backend, sampling=2, compact_every=2, plan=plan))
        assert np.array_equal(np.asarray(res.labels),
                              np.asarray(reference.labels)), plan
        assert labels_equivalent(np.asarray(res.labels), oracle)


# --------------------------------------------------------------- provenance

def test_one_shot_solve_records_plan(graph, monkeypatch, tmp_cache):
    # fresh cache: demotions left by other tests must not shadow the tables
    monkeypatch.setenv(cache.ENV_CACHE_PATH, tmp_cache)
    res = solve(graph)
    assert res.provenance is not None
    assert res.provenance[0].startswith("plan:")
    assert "origin=heuristic" in res.provenance[0]
    forced = solve(graph, backend="xla")
    assert forced.provenance[0].startswith("plan:xla")


def test_pinned_plan_provenance(graph):
    pin = ExecutionPlan(backend="xla", interpret=True, origin="pinned")
    res = solve(graph, options=SolveOptions(backend="xla", plan=pin))
    assert "origin=pinned" in res.provenance[0]


def test_cached_plan_provenance(graph, monkeypatch, tmp_cache):
    monkeypatch.setenv(cache.ENV_CACHE_PATH, tmp_cache)
    tuned = heuristic_plan(graph.n_vertices, graph.n_edges,
                           "cpu").replace(origin="tuned")
    cache.store(graph.n_vertices, graph.n_edges, "cpu", tuned,
                path=tmp_cache)
    res = solve(graph)     # backend="auto" consults the cache
    assert "origin=tuned" in res.provenance[0]


def test_batch_solve_records_plan(graph, monkeypatch, tmp_cache):
    monkeypatch.setenv(cache.ENV_CACHE_PATH, tmp_cache)
    res = solve_batch([graph, graph])
    assert res.provenance is not None
    assert res.provenance[0].startswith("plan:")


def test_unplanned_solvers_record_no_plan(graph):
    assert solve(graph, algorithm="fastsv").provenance is None
    assert solve(graph, algorithm="union_find").provenance is None


# ------------------------------------------------------- bench-layer pieces

def test_validate_backend_rejects_unknown():
    from benchmarks.connectivity import validate_backend
    with pytest.raises(SystemExit, match="unknown backend"):
        validate_backend("warp9")
    validate_backend("auto")   # no probe, no error
    validate_backend("xla")


def test_check_artifact_schema5_rederives_from_raw_timings():
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "check_artifact", os.path.join(os.path.dirname(__file__), "..",
                                       "benchmarks", "check_artifact.py"))
    ca = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(ca)
    good = {
        "schema": 5,
        "frontier_wallclock_gate": {
            "g1": {"dense_s": 1.0, "masked_s": 1.5, "staged_s": 0.5},
        },
        "autotune_gate": {
            "g1": {"plan_differs": False, "ratio": 1.0},
            "g2": {"plan_differs": True, "heuristic_s": 1.2, "tuned_s": 1.0},
        },
    }
    assert ca.check_wallclock_gates(good) == []
    slow = json.loads(json.dumps(good))
    slow["frontier_wallclock_gate"]["g1"]["staged_s"] = 2.0
    assert any("no schedule beats dense" in e
               for e in ca.check_wallclock_gates(slow))
    regress = json.loads(json.dumps(good))
    regress["autotune_gate"]["g2"].update(heuristic_s=1.0, tuned_s=1.3)
    assert any("geomean" in e for e in ca.check_wallclock_gates(regress))
    missing = {"schema": 5}
    errs = ca.check_wallclock_gates(missing)
    assert len(errs) == 2  # both gates reported missing
    # a summary edited to look healthy cannot mask failing raw timings
    slow["summary"] = {"frontier_beats_dense_wallclock": True}
    assert ca.check(dict(slow, summary={
        "all_correct": True, "frontier_beats_dense_wallclock": True}))
