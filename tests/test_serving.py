"""Serving-engine suite: coalescing, backpressure, isolation, recovery.

The concurrency stress tests lean on two structural facts:

* connectivity is **monotone** — components only ever merge, so for a
  fixed vertex pair the true answer over the stream's committed
  prefixes goes ``False... -> True...`` and never back.  An engine with
  snapshot isolation (every answer from some committed prefix, prefixes
  observed in commit order per FIFO observer) must therefore produce a
  monotone answer sequence per observer; a ``True -> False`` flip would
  prove a read of rolled-back or mid-ingest state.
* ingest is **atomic** — a poisoned batch (fault injected after the
  ring write, before the commit) must never be visible to any
  concurrent reader, at any point, ever.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.connectivity import StreamingConnectivity, solve
from repro.graphs.structs import Graph
from repro.runtime.recovery import FaultInjector, SimulatedFault
from repro.serving import (BoundedQueue, ConnectivityClient,
                           ConnectivityEngine, DeadlineExceeded,
                           EngineClosed, QueueFull, SlotPool, pow2_bucket)
from repro.serving.simulate import WorkloadSpec, run_simulation

pytestmark = pytest.mark.serving


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
class TestPrimitives:
    def test_pow2_bucket(self):
        assert [pow2_bucket(k) for k in (1, 2, 3, 5, 64, 65)] == \
            [1, 2, 4, 8, 64, 128]
        assert pow2_bucket(3, lo=64) == 64
        assert pow2_bucket(0) == 1

    def test_bounded_queue_fifo_and_reject(self):
        q = BoundedQueue(maxsize=2, name="test")
        q.put("a")
        q.put("b", retry_after=0.25)
        with pytest.raises(QueueFull) as ei:
            q.put("c", retry_after=0.25)
        assert ei.value.retry_after == 0.25
        assert ei.value.name == "test"
        assert q.drain() == ["a", "b"]
        assert q.get_nowait() is None

    def test_bounded_queue_drain_bound(self):
        q = BoundedQueue()
        for i in range(10):
            q.put(i)
        assert q.drain(3) == [0, 1, 2]
        assert len(q) == 7
        assert q.drain() == list(range(3, 10))

    def test_bounded_queue_get_batch_timeout(self):
        q = BoundedQueue()
        t0 = time.perf_counter()
        assert q.get_batch(8, timeout=0.05) == []
        assert time.perf_counter() - t0 >= 0.04
        q.put(1)
        assert q.get_batch(8, timeout=0.05) == [1]

    def test_slot_pool(self):
        pool = SlotPool(3)
        assert [pool.acquire() for _ in range(3)] == [0, 1, 2]
        assert pool.acquire() is None
        assert pool.n_busy == 3
        pool.release(1)
        assert pool.acquire() == 1
        with pytest.raises(ValueError):
            pool.release(7)
        pool.release(0)
        with pytest.raises(ValueError):
            pool.release(0)   # double release

    def test_slot_pool_lowest_first(self):
        pool = SlotPool(4)
        a, b = pool.acquire(), pool.acquire()
        pool.release(a)
        assert pool.acquire() == a   # lowest free id again
        del b


# ---------------------------------------------------------------------------
# engine basics
# ---------------------------------------------------------------------------
def _chain_batches(lo, hi, step):
    """Edge micro-batches forming the path lo - lo+1 - ... - hi-1."""
    src = np.arange(lo, hi - 1, dtype=np.int32)
    return [(src[i:i + step], src[i:i + step] + 1)
            for i in range(0, src.shape[0], step)]


class TestEngineBasics:
    def test_queries_match_oracle(self, rng):
        n, m = 300, 600
        src = rng.integers(0, n, m).astype(np.int32)
        dst = rng.integers(0, n, m).astype(np.int32)
        oracle = solve(Graph(src=src, dst=dst, n_vertices=n))
        with ConnectivityEngine(n_vertices=n) as eng:
            c = ConnectivityClient(eng)
            for i in range(0, m, 100):
                ack = c.ingest(src[i:i + 100], dst[i:i + 100])
            assert ack.batch_index == 5 and ack.n_edges == m
            assert c.n_components() == oracle.n_components
            pairs = rng.integers(0, n, (50, 2))
            for u, v in pairs:
                assert c.same_component(int(u), int(v)) == \
                    bool(oracle.same_component(int(u), int(v)))
                assert c.component_of(int(u)) == oracle.component_of(int(u))
        np.testing.assert_array_equal(np.asarray(eng.snapshot().labels),
                                      np.asarray(oracle.labels))

    def test_coalescing_batches_queries(self):
        with ConnectivityEngine(n_vertices=256) as eng:
            c = ConnectivityClient(eng)
            c.ingest(np.arange(0, 100, dtype=np.int32),
                     np.arange(1, 101, dtype=np.int32))
            futs = [c.same_component_async(i, i + 1) for i in range(99)]
            assert all(f.result(30) for f in futs)
            eng.flush()
            # 99 queries must have ridden far fewer coalesced gathers
            assert eng.metrics.count("query_batches") < 30
            assert eng.metrics.count("queries_answered") == 99
            assert eng.metrics.batch_sizes.total >= 1

    def test_ingest_visible_after_ack(self):
        # read-your-writes: an acked batch must be visible to the next
        # query — ack means committed
        with ConnectivityEngine(n_vertices=64) as eng:
            c = ConnectivityClient(eng)
            assert not c.same_component(10, 11)
            c.ingest([10], [11])
            assert c.same_component(10, 11)

    def test_vertex_growth_through_engine(self):
        with ConnectivityEngine(n_vertices=8) as eng:
            c = ConnectivityClient(eng)
            ack = c.ingest([7, 12], [12, 13], n_vertices=16)
            assert ack.n_vertices == 16
            assert c.same_component(7, 13)

    def test_out_of_range_query_rejected_not_clamped(self):
        with ConnectivityEngine(n_vertices=32) as eng:
            c = ConnectivityClient(eng)
            c.ingest([0], [31])
            with pytest.raises(IndexError, match="out of range"):
                c.component_of(32)
            with pytest.raises(IndexError, match="out of range"):
                c.same_component(0, 100)
            with pytest.raises(IndexError):
                c.same_component(-1, 0)
            # the engine survives rejected queries
            assert c.same_component(0, 31)

    def test_bad_ingest_fails_request_not_engine(self):
        with ConnectivityEngine(n_vertices=16) as eng:
            c = ConnectivityClient(eng)
            with pytest.raises(ValueError, match="n_vertices"):
                c.ingest([0], [99])        # out-of-range endpoint
            ack = c.ingest([0], [1])       # engine still serving
            assert ack.batch_index == 0
            assert c.same_component(0, 1)

    def test_submit_after_close_raises(self):
        eng = ConnectivityEngine(n_vertices=8).start()
        eng.close()
        with pytest.raises(EngineClosed):
            eng.submit_query("same_component", 0, 1)
        with pytest.raises(EngineClosed):
            eng.submit_ingest([0], [1])

    def test_close_drains_pending(self):
        eng = ConnectivityEngine(n_vertices=8)
        fut = eng.submit_query("n_components")
        eng.start()
        eng.close()                        # default drain=True
        assert fut.result(timeout=1) == 8

    def test_n_components_query_validation(self):
        eng = ConnectivityEngine(n_vertices=8)
        with pytest.raises(ValueError):
            eng.submit_query("n_components", 1)
        with pytest.raises(ValueError):
            eng.submit_query("component_of", 1, 2)
        with pytest.raises(ValueError):
            eng.submit_query("nope", 1, 2)
        eng.close()


# ---------------------------------------------------------------------------
# backpressure / deadlines / cancellation
# ---------------------------------------------------------------------------
class TestFlowControl:
    def test_query_backpressure_rejects_with_retry_after(self):
        eng = ConnectivityEngine(n_vertices=16, max_pending_queries=4)
        # worker not started: the queue can only fill
        for _ in range(4):
            eng.submit_query("n_components")
        with pytest.raises(QueueFull) as ei:
            eng.submit_query("n_components")
        assert ei.value.retry_after >= 0.0
        assert eng.metrics.count("rejected") == 1
        eng.start()
        eng.close()

    def test_ingest_backpressure(self):
        eng = ConnectivityEngine(n_vertices=16, max_pending_ingests=2)
        eng.submit_ingest([0], [1])
        eng.submit_ingest([1], [2])
        with pytest.raises(QueueFull):
            eng.submit_ingest([2], [3])
        eng.start()
        eng.close()
        assert eng.n_batches == 2

    def test_client_retries_through_backpressure(self):
        eng = ConnectivityEngine(n_vertices=16, max_pending_queries=2)
        eng.submit_query("n_components")
        eng.submit_query("n_components")
        sleeps = []

        def sleep_then_start(dt):
            sleeps.append(dt)
            eng.start()               # drain begins; retry will fit
            time.sleep(0.01)

        c = ConnectivityClient(eng, retries=50, retry_sleep=sleep_then_start)
        assert c.n_components() == 16
        assert len(sleeps) >= 1
        eng.close()

    def test_client_retry_budget_exhausted(self):
        eng = ConnectivityEngine(n_vertices=16, max_pending_queries=1)
        eng.submit_query("n_components")
        c = ConnectivityClient(eng, retries=2, retry_sleep=lambda dt: None)
        with pytest.raises(QueueFull):
            c.n_components()
        eng.start()
        eng.close()

    def test_deadline_exceeded(self):
        eng = ConnectivityEngine(n_vertices=16)
        fut = eng.submit_query("same_component", 0, 1, timeout=0.01)
        time.sleep(0.05)              # deadline passes while queued
        eng.start()
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=5)
        eng.flush()
        assert eng.metrics.count("deadline_missed") == 1
        eng.close()

    def test_cancellation_while_queued(self):
        eng = ConnectivityEngine(n_vertices=16)
        fut = eng.submit_query("same_component", 0, 1)
        assert fut.cancel()
        eng.start()
        eng.flush()
        assert fut.cancelled()
        assert eng.metrics.count("cancelled") == 1
        assert eng.metrics.count("queries_answered") == 0
        eng.close()

    def test_queue_depth_and_visibility_metrics(self):
        with ConnectivityEngine(n_vertices=64) as eng:
            c = ConnectivityClient(eng)
            for lo in range(0, 30, 10):
                c.ingest(np.arange(lo, lo + 9, dtype=np.int32),
                         np.arange(lo + 1, lo + 10, dtype=np.int32))
            c.map_component_of(range(30))
            eng.flush()
        s = eng.metrics.summary(wall_s=1.0)
        assert s["counters"]["ingests_committed"] == 3
        assert s["ingest_visibility_ms"]["count"] == 3
        assert s["ingest_visibility_ms"]["p99"] > 0
        assert s["latency_ms"]["count"] == 30
        assert s["throughput_qps"] == 30.0
        assert s["queue_depth_hist"]["query"]   # sampled at least once


# ---------------------------------------------------------------------------
# concurrency stress: snapshot isolation (satellite)
# ---------------------------------------------------------------------------
class TestConcurrencyStress:
    N = 660                     # 3 chains of 200 + untouched tail
    CHAINS = ((0, 200), (200, 400), (400, 600))

    def test_snapshot_isolation_under_concurrent_load(self):
        eng = ConnectivityEngine(n_vertices=self.N, recoverable=())
        eng.start()
        c = ConnectivityClient(eng)
        stop = threading.Event()
        errors: list = []
        # the poisoned batch: injected fault *after* the ring write,
        # before the commit — must roll back invisibly
        poison = FaultInjector(fail_at=[(100, "post_write")])
        eng._fault_injector = poison
        eng._stream.fault_injector = poison

        def ingest_chain(lo, hi):
            try:
                for src, dst in _chain_batches(lo, hi, step=20):
                    ack = c.ingest(src, dst)
                    # read-your-writes: acked edges are visible to the
                    # very next query from this thread
                    if not c.same_component(int(src[0]), int(dst[-1])):
                        errors.append(
                            f"acked batch {ack.batch_index} invisible")
            except Exception as exc:  # noqa: BLE001
                errors.append(f"ingest_chain({lo}): {exc!r}")

        def query_chain_pairs(tid):
            try:
                monotone_pairs = [(lo, hi - 1) for lo, hi in self.CHAINS]
                cross_pairs = [(50, 250), (250, 450), (50, 450),
                               (610, 630), (601, 602)]
                seen = {p: False for p in monotone_pairs}
                while not stop.is_set():
                    for p in monotone_pairs:
                        ans = c.same_component(*p)
                        if seen[p] and not ans:
                            errors.append(f"monotonicity violated {p}")
                        seen[p] = seen[p] or ans
                    for p in cross_pairs:
                        if c.same_component(*p):
                            errors.append(
                                f"impossible connection {p} (tid {tid})")
            except Exception as exc:  # noqa: BLE001
                errors.append(f"query({tid}): {exc!r}")

        qthreads = [threading.Thread(target=query_chain_pairs, args=(t,),
                                     daemon=True) for t in range(3)]
        ithreads = [threading.Thread(target=ingest_chain, args=span,
                                     daemon=True) for span in self.CHAINS]
        for t in qthreads + ithreads:
            t.start()
        for t in ithreads:
            t.join(timeout=120)
        # poisoned batch: unique pair in the untouched tail; the fault
        # fires post-write and the commit must roll back
        poison.fail_at = ((eng.n_batches, "post_write"),)
        with pytest.raises(SimulatedFault):
            c.ingest([610], [630])
        assert not c.same_component(610, 630)   # rollback never visible
        stop.set()
        for t in qthreads:
            t.join(timeout=60)
        eng.close()
        assert not errors, errors[:10]
        # final state == oracle over everything successfully ingested
        final = eng.snapshot()
        graph = eng._stream.graph()
        oracle = solve(graph)
        np.testing.assert_array_equal(np.asarray(final.labels),
                                      np.asarray(oracle.labels))
        assert final.same_component(0, 199)
        assert not final.same_component(610, 630)

    def test_no_torn_reads_during_rollback_storm(self):
        # every 2nd ingest is poisoned post-write; readers hammering the
        # poisoned pair must never see it connected.  The injector keys
        # on the stream's *committed* batch index, which a rolled-back
        # batch does not advance: poisoned submission k sits at step
        # k//2, and the clean one that follows commits that step after
        # the (fire-once) entry has already fired.
        n_batches = 8
        injector = FaultInjector(
            fail_at=[(k, "post_write") for k in range(n_batches)])
        eng = ConnectivityEngine(n_vertices=64, recoverable=(),
                                 fault_injector=injector)
        eng.start()
        c = ConnectivityClient(eng)
        stop = threading.Event()
        violations: list = []

        def reader():
            while not stop.is_set():
                try:
                    if c.same_component(40, 41):
                        violations.append("rolled-back edge visible")
                except Exception as exc:  # noqa: BLE001
                    violations.append(repr(exc))

        threads = [threading.Thread(target=reader, daemon=True)
                   for _ in range(2)]
        for t in threads:
            t.start()
        committed = 0
        for k in range(2 * n_batches):
            try:
                # poisoned batches carry the sentinel pair (40, 41);
                # clean ones the growing chain
                if k % 2 == 0:
                    c.ingest([40], [41])
                else:
                    c.ingest([committed], [committed + 1])
                    committed += 1
            except SimulatedFault:
                pass
        stop.set()
        for t in threads:
            t.join(timeout=30)
        eng.close()
        assert not violations, violations[:5]
        assert eng.n_batches == committed
        assert not eng.snapshot().same_component(40, 41)


# ---------------------------------------------------------------------------
# crash recovery: zero acked-ingest loss (DESIGN.md §13)
# ---------------------------------------------------------------------------
@pytest.mark.chaos
class TestRecovery:
    def _run(self, tmp_path, rng, fail_at=(), checkpoint_every=2):
        n, batches = 128, 10
        src = rng.integers(0, n, (batches, 32)).astype(np.int32)
        dst = rng.integers(0, n, (batches, 32)).astype(np.int32)
        manager = CheckpointManager(str(tmp_path), async_save=False)
        injector = FaultInjector(
            fail_at=[(k, "pre") for k in fail_at]) if fail_at else None
        eng = ConnectivityEngine(
            n_vertices=n, manager=manager,
            checkpoint_every=checkpoint_every,
            recoverable=(SimulatedFault,), fault_injector=injector,
            backoff_base=0.001, sleep_fn=lambda dt: None)
        eng.start()
        c = ConnectivityClient(eng)
        acks = [c.ingest(src[k], dst[k]) for k in range(batches)]
        labels = np.asarray(eng.snapshot().labels)
        counters = (int(eng.snapshot().iterations),
                    float(np.asarray(eng.snapshot().edges_visited)))
        eng.close()
        return eng, acks, labels, counters

    def test_crash_restart_zero_acked_loss(self, tmp_path, rng):
        clean_rng = np.random.default_rng(7)
        fault_rng = np.random.default_rng(7)
        _, _, clean_labels, clean_counters = self._run(
            tmp_path / "clean", clean_rng)
        eng, acks, labels, counters = self._run(
            tmp_path / "faulty", fault_rng, fail_at=(3, 7))
        # every submitted ingest was acked (recovery, not refusal) ...
        assert [a.batch_index for a in acks] == list(range(10))
        # ... and the final state is bit-identical to the clean run,
        # including the work counters (deterministic replay)
        np.testing.assert_array_equal(labels, clean_labels)
        assert counters == clean_counters
        assert eng.restarts == 2
        assert eng.metrics.count("replayed_batches") >= 1
        assert eng.metrics.count("checkpoints") >= 5

    def test_straggler_forces_checkpoint(self, tmp_path):
        from repro.runtime.straggler import StragglerMonitor

        class Scripted(StragglerMonitor):
            def __init__(self, actions):
                super().__init__()
                self.actions = list(actions)

            def start_step(self):
                pass

            def end_step(self):
                return self.actions.pop(0) if self.actions else "ok"

        manager = CheckpointManager(str(tmp_path), async_save=False)
        eng = ConnectivityEngine(
            n_vertices=32, manager=manager, checkpoint_every=1000,
            straggler=Scripted(["ok", "checkpoint", "ok"]))
        eng.start()
        c = ConnectivityClient(eng)
        for k in range(3):
            c.ingest([k], [k + 1])
        eng.close()
        # cadence alone (every 1000) would never checkpoint — the
        # straggler escalation forced one at batch 2
        assert eng.metrics.count("checkpoints") == 1
        assert eng.metrics.count("straggler_events") == 1
        assert manager.latest_step() == 2

    def test_recovery_without_manager_is_plain_retry(self, rng):
        injector = FaultInjector(fail_at=[(1, "pre")])
        eng = ConnectivityEngine(n_vertices=32, fault_injector=injector,
                                 recoverable=(SimulatedFault,),
                                 sleep_fn=lambda dt: None)
        eng.start()
        c = ConnectivityClient(eng)
        c.ingest([0], [1])
        ack = c.ingest([1], [2])     # fault fires, atomic retry succeeds
        assert ack.batch_index == 1
        assert c.same_component(0, 2)
        assert eng.restarts == 1
        eng.close()


# ---------------------------------------------------------------------------
# streaming-level out-of-range rejection (satellite bugfix)
# ---------------------------------------------------------------------------
class TestStreamingQueryValidation:
    def test_streaming_rejects_out_of_range(self):
        eng = StreamingConnectivity(n_vertices=5)
        eng.ingest([0, 1], [1, 2])
        with pytest.raises(IndexError, match="out of range"):
            eng.component_of(7)
        with pytest.raises(IndexError, match="out of range"):
            eng.same_component(7, 0)
        with pytest.raises(IndexError):
            eng.same_component(0, np.array([1, 9]))
        # ids in [n, capacity) are invisible padding, not real vertices
        assert eng.vertex_capacity > eng.n_vertices
        with pytest.raises(IndexError, match="out of range"):
            eng.component_of(eng.n_vertices)
        assert eng.same_component(0, 2)

    def test_component_result_rejects_out_of_range(self):
        res = solve(Graph(src=np.array([0]), dst=np.array([1]),
                          n_vertices=4))
        with pytest.raises(IndexError, match="out of range"):
            res.component_of(4)
        with pytest.raises(IndexError, match="out of range"):
            res.same_component(np.array([0, 5]), np.array([1, 1]))
        with pytest.raises(IndexError, match=">= 0"):
            res.component_of(-1)
        assert res.component_of(3) == 3


# ---------------------------------------------------------------------------
# simulation harness (the bench's engine, miniature)
# ---------------------------------------------------------------------------
class TestSimulate:
    def test_simulation_report_shape(self):
        spec = WorkloadSpec(n_vertices=512, n_queries=2_000,
                            edges_per_batch=64, write_ratio=0.002,
                            n_query_threads=2, window=256, seed=3)
        report, labels = run_simulation(spec)
        assert report["failures"] == 0
        assert report["counters"]["queries_answered"] == 2_000
        assert report["final"]["n_batches"] == spec.n_ingest_batches
        assert report["acked_batches"] == spec.n_ingest_batches
        assert labels.shape == (512,)
        assert report["latency_ms"]["p99"] >= report["latency_ms"]["p50"]
        assert report["throughput_qps"] > 0
        assert report["batch_size_hist"]
        # same spec, fresh engine -> bit-identical committed state
        report2, labels2 = run_simulation(spec)
        np.testing.assert_array_equal(labels, labels2)
        assert report2["final"]["labels_crc32"] == \
            report["final"]["labels_crc32"]

    def test_simulated_crashes_preserve_acks_and_labels(self, tmp_path):
        spec = WorkloadSpec(n_vertices=256, n_queries=800,
                            edges_per_batch=32, write_ratio=0.01,
                            n_query_threads=2, window=128, seed=5)
        clean, clean_labels = run_simulation(spec)
        injector = FaultInjector(fail_at=[(2, "pre"), (5, "pre")])
        manager = CheckpointManager(str(tmp_path), async_save=False)
        faulty, faulty_labels = run_simulation(
            spec, manager=manager, fault_injector=injector,
            checkpoint_every=2, recoverable=(SimulatedFault,),
            sleep_fn=lambda dt: None)
        np.testing.assert_array_equal(faulty_labels, clean_labels)
        assert faulty["acked_batches"] == clean["acked_batches"] == \
            spec.n_ingest_batches
        assert faulty["counters"]["restarts"] == 2
        assert faulty["failures"] == 0


# ---------------------------------------------------------------------------
# the LM server on the shared primitives (satellite refactor)
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestBatchedServerOnPrimitives:
    def test_serve_to_completion(self):
        from repro.configs import get_arch
        from repro.launch.serve import BatchedServer, Request

        config = get_arch("xlstm-125m").smoke_config()
        server = BatchedServer(config, n_slots=2, max_len=24)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, config.vocab_size,
                                            6).astype(np.int32),
                        max_new_tokens=3)
                for i in range(3)]
        out = server.serve(reqs)
        assert sorted(out) == [0, 1, 2]
        assert all(len(v) == 3 for v in out.values())
        assert all(r.done for r in reqs)
