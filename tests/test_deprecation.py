"""The old ``repro.core`` entry points: still correct, now warning shims."""
import warnings

import numpy as np
import pytest

from repro import solve
from repro.core import _deprecated
from repro.graphs import generators as gen


@pytest.fixture()
def graph():
    return gen.components_mix([gen.path(300, seed=1), gen.rmat(9, seed=2)],
                              seed=3)


def _deprecation_messages(records):
    return [str(r.message) for r in records
            if issubclass(r.category, DeprecationWarning)]


def test_connected_components_shim_warns_and_matches(graph):
    from repro.core.contour import connected_components
    _deprecated.reset()
    with pytest.warns(DeprecationWarning, match="connected_components"):
        labels = connected_components(graph)
    assert (np.asarray(labels) == np.asarray(solve(graph).labels)).all()


def test_contour_labels_shim_warns_and_matches(graph):
    from repro.core.contour import contour_labels
    _deprecated.reset()
    with pytest.warns(DeprecationWarning, match="contour_labels"):
        labels, iters = contour_labels(graph.src, graph.dst,
                                       graph.n_vertices, variant="C-2")
    result = solve(graph)
    assert (np.asarray(labels) == np.asarray(result.labels)).all()
    assert int(iters) == int(result.iterations)


def test_fastsv_labels_shim_warns_and_matches(graph):
    from repro.core.fastsv import fastsv_labels
    _deprecated.reset()
    with pytest.warns(DeprecationWarning, match="fastsv_labels"):
        labels, _ = fastsv_labels(graph.src, graph.dst, graph.n_vertices)
    assert (np.asarray(labels)
            == np.asarray(solve(graph, algorithm="fastsv").labels)).all()


def test_shims_warn_exactly_once_per_entry_point(graph):
    from repro.core.contour import connected_components
    _deprecated.reset()
    with warnings.catch_warnings(record=True) as records:
        warnings.simplefilter("always")
        connected_components(graph)
        connected_components(graph)
        connected_components(graph)
    assert len(_deprecation_messages(records)) == 1


def test_shims_accept_seed_positional_max_iters(graph):
    """The seed signatures took max_iters as the 4th positional arg."""
    from repro.core.fastsv import fastsv_labels
    from repro.core.lp import label_propagation_labels
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        L, it = fastsv_labels(graph.src, graph.dst, graph.n_vertices, 100)
        assert int(it) <= 100
        L2, it2 = label_propagation_labels(graph.src, graph.dst,
                                           graph.n_vertices, 10_000)
        assert int(it2) <= 10_000
        assert (np.asarray(L) == np.asarray(L2)).all()


def test_every_old_entry_point_still_runs(graph):
    """The full legacy surface stays importable and call-compatible."""
    from repro.core import (contour, fastsv, label_propagation)
    from repro.core.distributed import distributed_contour
    from repro.core.unionfind import rem_union_find
    import jax
    from repro import jax_compat

    oracle = np.asarray(solve(graph).labels)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        L, _ = contour(graph)
        assert (np.asarray(L) == oracle).all()
        L, _ = fastsv(graph)
        assert (np.asarray(L) == oracle).all()
        L, _ = label_propagation(graph)
        assert (np.asarray(L) == oracle).all()
        L = rem_union_find(*graph.to_numpy())
        assert (np.asarray(L) == oracle).all()
        mesh = jax_compat.device_mesh(np.array(jax.devices()[:1]), ("data",))
        L, _ = distributed_contour(graph, mesh)
        assert (np.asarray(L) == oracle).all()
