"""Distributed Contour: shard_map edge-parallel execution.

Multi-device coverage runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (per the assignment,
the test process itself must keep seeing 1 device).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro import jax_compat
from repro.core.distributed import distributed_contour
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_distributed_single_device_mesh():
    """Degenerate 1-device mesh: the shard_map path must still be exact."""
    mesh = jax_compat.device_mesh(np.array(jax.devices()[:1]), ("data",))
    g = gen.components_mix([gen.path(400, seed=1), gen.rmat(9, seed=2)],
                           seed=3)
    oracle = connected_components_oracle(*g.to_numpy())
    labels, rounds = distributed_contour(g, mesh, edge_axes=("data",))
    assert (np.asarray(labels) == oracle).all()
    assert int(rounds) >= 1


_SUBPROCESS_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro import jax_compat
    from repro.core.distributed import distributed_contour
    from repro.graphs import generators as gen
    from repro.graphs.oracle import connected_components_oracle

    mesh = jax_compat.make_mesh((8,), ("data",))
    graphs = [
        gen.path(3000, seed=1),
        gen.grid2d(40, 40),
        gen.rmat(11, seed=2),
        gen.components_mix([gen.path(500, seed=3), gen.star(400, seed=4)],
                           seed=5),
    ]
    for g in graphs:
        oracle = connected_components_oracle(*g.to_numpy())
        for lr in (1, 3):
            labels, rounds = distributed_contour(
                g, mesh, edge_axes=("data",), local_rounds=lr)
            assert (np.asarray(labels) == oracle).all(), (g.n_vertices, lr)
            assert int(rounds) >= 1
    # beyond-paper local-iteration mode must reduce global rounds on
    # diameter-bound graphs
    g = gen.path(3000, seed=1)
    _, r1 = distributed_contour(g, mesh, edge_axes=("data",), local_rounds=1)
    _, r3 = distributed_contour(g, mesh, edge_axes=("data",), local_rounds=3)
    assert int(r3) < int(r1), (int(r1), int(r3))
    print("SUBPROCESS_OK", int(r1), int(r3))
""")


@pytest.mark.slow  # spawns a fresh 8-device subprocess (jit recompiles)
def test_distributed_8way_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_BODY],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SUBPROCESS_OK" in out.stdout


_FRONTIER_SUBPROCESS_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro import jax_compat
    from repro.connectivity.distributed import distributed_contour
    from repro.graphs import generators as gen
    from repro.graphs.oracle import connected_components_oracle

    mesh = jax_compat.make_mesh((8,), ("data",))
    g = gen.components_mix([gen.path(2000, seed=1), gen.rmat(10, seed=2)],
                           seed=3)
    oracle = connected_components_oracle(*g.to_numpy())
    dense_L, dense_r, dense_ok, dense_v = distributed_contour(
        g, mesh, edge_axes=("data",))
    assert bool(dense_ok)
    assert (np.asarray(dense_L) == oracle).all()
    # the counter reports real edges only — shard padding is never
    # counted on either schedule
    assert float(dense_v) == int(dense_r) * g.n_edges
    for sampling, ce in ((2, 2), (0, 1), (3, 0)):
        L, r, ok, v = distributed_contour(
            g, mesh, edge_axes=("data",), sampling=sampling,
            compact_every=ce)
        assert bool(ok), (sampling, ce)
        # per-shard contraction must not change the fixed point ...
        assert np.array_equal(np.asarray(L), np.asarray(dense_L)), \\
            (sampling, ce)
        # ... while any compacting schedule counts less work per round
        if ce > 0:
            assert float(v) < int(r) * g.n_edges, \\
                (sampling, ce, float(v))
    print("FRONTIER_SUBPROCESS_OK")
""")


@pytest.mark.slow  # spawns a fresh 8-device subprocess (jit recompiles)
def test_distributed_frontier_8way_subprocess():
    """Per-shard work-adaptive contraction (DESIGN.md §10) on a real
    multi-device mesh: bit-identical labels, fewer edges visited."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _FRONTIER_SUBPROCESS_BODY],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "FRONTIER_SUBPROCESS_OK" in out.stdout
