"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — tests must see
the real (single) CPU device; only the dry-run gets 512 placeholders."""
import os
import tempfile

import numpy as np
import pytest

# Hermetic tuning cache: kernel-fallback demotions and tuner runs write
# plan entries (planner.cache); pointing the cache at a throwaway file
# keeps the suite from reading or mutating ~/.cache/repro.  Set before
# any jax/repro import in this process, respected unless a test already
# pinned its own path.
os.environ.setdefault(
    "REPRO_TUNING_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-test-tuning-"),
                 "contour_tuning.json"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
