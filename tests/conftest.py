"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — tests must see
the real (single) CPU device; only the dry-run gets 512 placeholders."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
