"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — tests must see
the real (single) CPU device; only the dry-run gets 512 placeholders."""
import os
import tempfile

import numpy as np
import pytest

# Hermetic tuning cache: kernel-fallback demotions and tuner runs write
# plan entries (planner.cache); pointing the cache at a throwaway file
# keeps the suite from reading or mutating ~/.cache/repro.  Set before
# any jax/repro import in this process, respected unless a test already
# pinned its own path.
os.environ.setdefault(
    "REPRO_TUNING_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-test-tuning-"),
                 "contour_tuning.json"))

# Hermetic strategy cost model: solver="auto" fits its 1-NN from the
# bench artifact (planner.costmodel); pointing the lookup at a
# nonexistent file keeps test outcomes independent of whatever
# BENCH_connectivity.json happens to be committed.  Tests that exercise
# the fitted path write their own artifact and pass bench_path=.
os.environ.setdefault(
    "REPRO_BENCH_ARTIFACT",
    os.path.join(tempfile.mkdtemp(prefix="repro-test-bench-"),
                 "BENCH_connectivity.json"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
