"""Behavioural tests for the Contour algorithm and baselines (paper Alg. 1,
§III-B variants, §III-C baselines)."""
import math

import numpy as np
import pytest

from repro.core import contour, fastsv, label_propagation
from repro.core.contour import VARIANTS, connected_components, contour_labels
from repro.core.unionfind import rem_union_find
from repro.graphs import generators as gen
from repro.graphs.oracle import (
    connected_components_oracle,
    labels_equivalent,
)
from repro.graphs.stats import approx_max_diameter
from repro.graphs.structs import Graph

GRAPHS = {
    "path_shuffled": lambda: gen.path(2_000, seed=1),
    "path_sorted": lambda: gen.path(512, seed=0, shuffle_ids=False),
    "cycle": lambda: gen.cycle(1_024, seed=2),
    "star": lambda: gen.star(4_096, seed=3),
    "caterpillar": lambda: gen.caterpillar(256, 3, seed=4),
    "grid": lambda: gen.grid2d(48, 48),
    "delaunay_like": lambda: gen.delaunay_like(12),
    "rmat": lambda: gen.rmat(12, seed=5),
    "erdos_renyi": lambda: gen.erdos_renyi(4_000, 6.0, seed=6),
    "tree": lambda: gen.random_tree(3_000, seed=7),
    "multi_component": lambda: gen.components_mix(
        [gen.path(700, seed=8), gen.star(300, seed=9), gen.rmat(9, seed=10)],
        seed=11),
}


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("variant", VARIANTS)
def test_variant_matches_oracle(gname, variant):
    g = GRAPHS[gname]()
    oracle = connected_components_oracle(*g.to_numpy())
    labels, iters = contour(g, variant=variant)
    labels = np.asarray(labels)
    # Contour converges to the *minimum vertex id* labelling exactly
    assert (labels == oracle).all(), f"{gname}/{variant}"
    assert int(iters) >= 1


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_baselines_match_oracle(gname):
    g = GRAPHS[gname]()
    src, dst, n = g.to_numpy()
    oracle = connected_components_oracle(src, dst, n)
    for fn in (fastsv, label_propagation):
        labels, _ = fn(g)
        assert labels_equivalent(np.asarray(labels), oracle), fn.__name__
    assert labels_equivalent(rem_union_find(src, dst, n), oracle)


def test_theorem1_iteration_bound():
    """Thm 1: C-2 converges in <= ceil(log_1.5(d_max)) + 1 iterations.

    Our async C-2 (in-iteration compression) can only converge faster than
    Alg. 1; C-Syn is the literal Alg. 1 so it gets the strict bound check."""
    for gname in ("path_shuffled", "cycle", "grid", "caterpillar", "tree",
                  "multi_component"):
        g = GRAPHS[gname]()
        d = max(approx_max_diameter(*g.to_numpy()), 2)
        bound = math.ceil(math.log(d, 1.5)) + 1
        _, it_syn = contour(g, variant="C-Syn")
        # +1 slack: the implementation needs one extra sweep to *observe*
        # convergence (paper counts label-change iterations)
        assert int(it_syn) <= bound + 1, (gname, int(it_syn), bound)
        _, it_c2 = contour(g, variant="C-2")
        assert int(it_c2) <= bound + 1, (gname, int(it_c2), bound)


def test_iteration_ordering_matches_paper():
    """Paper §IV-C: iters(C-m) <= iters(C-2) <= iters(C-1); C-1 largest."""
    for gname in ("path_shuffled", "grid", "delaunay_like"):
        g = GRAPHS[gname]()
        it = {v: int(contour(g, variant=v)[1])
              for v in ("C-1", "C-2", "C-m")}
        assert it["C-m"] <= it["C-2"] <= it["C-1"], (gname, it)


def test_label_propagation_is_slow_on_long_diameter():
    """The motivating gap: LP needs O(d) iterations, Contour O(log d)."""
    g = gen.path(2_000, seed=1)
    _, it_lp = label_propagation(g)
    _, it_c2 = contour(g, variant="C-2")
    assert int(it_lp) > 10 * int(it_c2)


def test_isolated_vertices_and_self_loops():
    src = np.array([0, 1, 3], dtype=np.int32)
    dst = np.array([1, 0, 3], dtype=np.int32)   # dup edge + self loop
    g = Graph.from_numpy(src, dst, 6)           # vertices 2,4,5 isolated
    labels = np.asarray(connected_components(g))
    assert labels[0] == labels[1] == 0
    for v in (2, 3, 4, 5):
        assert labels[v] == v


def test_single_edge_and_empty():
    g = Graph.from_numpy(np.array([0]), np.array([1]), 2)
    labels, it = contour(g, variant="C-2")
    assert list(np.asarray(labels)) == [0, 0]

    g0 = Graph.from_numpy(np.zeros(0, np.int32), np.zeros(0, np.int32), 3)
    # empty edge set: all vertices are their own component (pad with a
    # single self-loop edge so the edge-parallel loop has work)
    g0 = g0.pad_edges(1)
    labels, _ = contour(g0, variant="C-2")
    assert list(np.asarray(labels)) == [0, 1, 2]


def test_early_convergence_saves_iterations():
    """§III-B2: the early check must not be slower than plain no-change."""
    g = gen.grid2d(32, 32)
    _, it_syn = contour(g, variant="C-Syn")   # plain no-change test
    _, it_c2 = contour(g, variant="C-2")      # async + early convergence
    assert int(it_c2) <= int(it_syn)


def test_pad_edges_is_noop_for_labels():
    g = gen.rmat(10, seed=3)
    L1, _ = contour(g, variant="C-2")
    L2, _ = contour(g.pad_edges(g.n_edges + 1000), variant="C-2")
    assert (np.asarray(L1) == np.asarray(L2)).all()


@pytest.mark.parametrize("order", [3, 4, 8])
def test_literal_high_order_operator(order):
    """Definition 3 at h>2, literally (length-h gather chains): must reach
    the same fixed point as C-2/C-m and converge at least as fast as C-2
    (each sweep maps strictly deeper)."""
    for gname in ("path_shuffled", "grid", "multi_component"):
        g = GRAPHS[gname]()
        oracle = connected_components_oracle(*g.to_numpy())
        labels, it_h = contour(g, variant=f"C-{order}")
        assert (np.asarray(labels) == oracle).all(), (gname, order)
        _, it_2 = contour(g, variant="C-2")
        assert int(it_h) <= int(it_2) + 1, (gname, order)


def test_cm_pointer_jump_equals_literal_high_order():
    """The C-m adaptation (2-order sweep + pointer jumps, DESIGN.md §3)
    and the literal high-order chain reach the identical labelling."""
    for gname in ("caterpillar", "tree", "delaunay_like"):
        g = GRAPHS[gname]()
        l_jump, _ = contour(g, variant="C-m")
        l_lit, _ = contour(g, variant="C-8")
        assert (np.asarray(l_jump) == np.asarray(l_lit)).all(), gname


def test_variants_run_on_blocked_kernel_backend():
    """Backend threading (DESIGN.md §3.4): the algorithm layer can route
    every variant's MM sweep through the label-blocked kernel path and
    still land on the oracle labelling."""
    g = GRAPHS["multi_component"]()
    oracle = connected_components_oracle(*g.to_numpy())
    for variant in ("C-Syn", "C-2", "C-m"):
        labels, iters = contour(g, variant=variant, backend="pallas_blocked")
        assert (np.asarray(labels) == oracle).all(), variant
        # the blocked sweep is bit-exact vs scatter-min, so iteration
        # counts must match the default backend too
        _, iters_xla = contour(g, variant=variant, backend="xla")
        assert int(iters) == int(iters_xla), variant


def test_backend_auto_matches_default():
    g = GRAPHS["grid"]()
    L_auto, it_auto = contour(g, variant="C-2", backend="auto")
    L_xla, it_xla = contour(g, variant="C-2")
    assert (np.asarray(L_auto) == np.asarray(L_xla)).all()
    assert int(it_auto) == int(it_xla)


def test_variant_iteration_counts_recorded():
    """Averages follow the paper's ordering (Fig. 1 analogue, small suite)."""
    suite = [GRAPHS[k]() for k in ("path_shuffled", "grid", "rmat",
                                   "erdos_renyi", "tree")]
    means = {}
    for v in ("C-1", "C-2", "C-m"):
        means[v] = np.mean([int(contour(g, variant=v)[1]) for g in suite])
    assert means["C-m"] <= means["C-2"] <= means["C-1"]
