"""Out-of-core multi-round contraction (DESIGN.md §15).

The load-bearing properties:

* **equivalence** — streaming any chunked edge source through
  :class:`OutOfCoreContraction` lands labels bit-identical to the
  one-shot in-core ``solve()`` (both are the canonical min-vertex-id
  fixed point), warm starts included;
* **decay** — the deduped surviving-edge count strictly decreases every
  round (the termination argument, measured), and the adversarial
  star-forest source genuinely needs more than one round;
* **memory** — the device never holds more than the labels plus one
  double-buffered chunk: the resident-set estimate on a stress graph
  stays below the bytes the in-core path would materialise;
* **recovery** — a crash mid-round restores the round-boundary
  checkpoint (labels + survivor manifest) and replays one round, not the
  stream; a round-0 crash replays the pure source, bit-exactly.

Marked ``oocore`` (the CI oocore job runs ``-m oocore``); everything
here also runs in the tier-1 default gate.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.connectivity import (
    FaultInjector,
    OutOfCoreContraction,
    SolveOptions,
    oocore_with_recovery,
    solve,
    solve_chunks,
)
from repro.connectivity import planner as _planner
from repro.connectivity.oocore import EDGE_BYTES, estimate_peak_bytes
from repro.graphs import generators as gen
from repro.graphs.generators import (
    ArrayChunks,
    RmatChunks,
    rmat_chunks,
    star_forest_chunks,
)
from repro.graphs.oracle import connected_components_oracle
from repro.graphs.structs import Graph

pytestmark = pytest.mark.oocore

_XLA = dict(variant="C-2", backend="xla")


def _chunks_of(graph, chunk_edges):
    src, dst, n = graph.to_numpy()
    return ArrayChunks(src, dst, n, chunk_edges)


def _suite():
    return {
        "path": gen.path(3000, seed=1),
        "rmat": gen.rmat(11, seed=2),
        "mix": gen.components_mix(
            [gen.path(500, seed=3), gen.star(400, seed=4),
             gen.rmat(9, seed=5)], seed=6),
    }


# ---------------------------------------------------------------------------
# equivalence: chunked out-of-core vs one-shot in-core
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["path", "rmat", "mix"])
@pytest.mark.parametrize("chunk_edges", [1024, 4096])
def test_bit_identical_to_incore(name, chunk_edges):
    g = _suite()[name]
    oracle = connected_components_oracle(*g.to_numpy())
    one = solve(g, SolveOptions(**_XLA))
    res = solve_chunks(_chunks_of(g, chunk_edges),
                       SolveOptions(algorithm="oocore", **_XLA))
    assert np.array_equal(np.asarray(res.labels), np.asarray(one.labels))
    assert np.array_equal(np.asarray(res.labels), oracle)
    assert bool(res.converged)
    assert float(res.edges_visited) > 0


def test_generator_fed_chunks_bit_identical():
    chunks = rmat_chunks(scale=12, edge_factor=8, seed=3, chunk_edges=2048)
    res = solve_chunks(chunks, SolveOptions(algorithm="oocore", **_XLA))
    one = solve(chunks.materialize(), SolveOptions(**_XLA))
    assert np.array_equal(np.asarray(res.labels), np.asarray(one.labels))


def test_facade_algorithm_oocore():
    g = _suite()["mix"]
    res = solve(g, algorithm="oocore", oocore_chunk_edges=1024, **_XLA)
    one = solve(g, SolveOptions(**_XLA))
    assert np.array_equal(np.asarray(res.labels), np.asarray(one.labels))
    # plan provenance records the streamed bucket + the round decay
    assert any("chunk=1024" in e for e in res.provenance)
    assert any(e.startswith("oocore:rounds=") for e in res.provenance)


def test_warm_start_resumes():
    g = _suite()["rmat"]
    first = solve_chunks(_chunks_of(g, 2048),
                         SolveOptions(algorithm="oocore", **_XLA))
    warm = solve_chunks(_chunks_of(g, 2048),
                        SolveOptions(algorithm="oocore", **_XLA),
                        warm_start=first)
    assert np.array_equal(np.asarray(warm.labels), np.asarray(first.labels))
    # restarting from the fixed point: every edge retires in round 0
    eng = OutOfCoreContraction(_chunks_of(g, 2048),
                               SolveOptions(algorithm="oocore", **_XLA),
                               init_labels=first.labels)
    eng.run()
    assert eng.round_counts[-1] == 0


def test_tracer_guard():
    g = gen.path(64, seed=0)

    @jax.jit
    def bad(src, dst):
        return solve(Graph(src, dst, g.n_vertices), algorithm="oocore")

    with pytest.raises(ValueError, match="host-driven"):
        bad(g.src, g.dst)


# ---------------------------------------------------------------------------
# round structure: strict decay, the adversarial multi-round source
# ---------------------------------------------------------------------------


def test_decay_strictly_decreasing():
    g = _suite()["mix"]
    eng = OutOfCoreContraction(_chunks_of(g, 1024),
                               SolveOptions(algorithm="oocore", **_XLA))
    rounds = []
    while not eng.finished_streaming:
        rounds.append(eng.run_round())
    chain = [g.n_edges] + [r["survivors"] for r in rounds]
    assert all(b < a for a, b in zip(chain, chain[1:]))
    for r, prev in zip(rounds, chain):
        assert r["edges_in"] == prev


def test_star_forest_needs_two_rounds():
    chunks = star_forest_chunks(k=8, b=1024)
    eng = OutOfCoreContraction(chunks,
                               SolveOptions(algorithm="oocore", **_XLA,
                                            oocore_local_iters=1))
    labels, _, converged, _ = eng.run()
    # round 0's single sweep per chunk leaves far more survivors than
    # the bucket -> a genuine second round ran
    assert len(eng.round_counts) >= 2
    assert eng.round_counts[0] > chunks.chunk_edges
    assert eng.round_counts[-1] <= chunks.chunk_edges
    assert not eng.round_cap_exhausted
    one = solve(chunks.materialize(), SolveOptions(**_XLA))
    assert bool(converged)
    assert np.array_equal(np.asarray(labels), np.asarray(one.labels))


def test_round_cap_forces_finish_with_waiver():
    chunks = star_forest_chunks(k=8, b=1024)
    res = solve_chunks(chunks,
                       SolveOptions(algorithm="oocore", **_XLA,
                                    oocore_local_iters=1,
                                    oocore_round_cap=1))
    assert "oocore_round_cap_exhausted" in res.provenance
    one = solve(chunks.materialize(), SolveOptions(**_XLA))
    assert np.array_equal(np.asarray(res.labels), np.asarray(one.labels))


def test_peak_estimate_below_edge_bytes_on_stress_graph():
    chunks = rmat_chunks(scale=13, edge_factor=8, seed=9, chunk_edges=2048)
    assert chunks.n_edges >= 4 * chunks.chunk_edges
    eng = OutOfCoreContraction(chunks,
                               SolveOptions(algorithm="oocore", **_XLA))
    eng.run()
    assert not eng.round_cap_exhausted
    assert eng.peak_bytes_estimate() < EDGE_BYTES * chunks.n_edges
    assert eng.peak_bytes_estimate() == estimate_peak_bytes(
        chunks.n_vertices, chunks.chunk_edges)


# ---------------------------------------------------------------------------
# the chunked generator
# ---------------------------------------------------------------------------


def test_rmat_chunks_pure_and_deterministic():
    a = RmatChunks(scale=10, edge_factor=8, seed=4, chunk_edges=1024)
    b = RmatChunks(scale=10, edge_factor=8, seed=4, chunk_edges=1024)
    for k in (0, a.n_chunks - 1):
        s1, d1 = a.chunk(k)
        s2, d2 = a.chunk(k)          # same instance, re-asked
        s3, d3 = b.chunk(k)          # fresh instance, same seed
        assert np.array_equal(s1, s2) and np.array_equal(d1, d2)
        assert np.array_equal(s1, s3) and np.array_equal(d1, d3)
        assert s1.min() >= 0 and max(s1.max(), d1.max()) < a.n_vertices
    assert np.array_equal(a.chunk(0)[0], b.chunk(0)[0])
    assert not np.array_equal(a.chunk(0)[0], a.chunk(1)[0])
    diff = RmatChunks(scale=10, edge_factor=8, seed=5, chunk_edges=1024)
    assert not np.array_equal(a.chunk(0)[0], diff.chunk(0)[0])


def test_chunk_sizes_cover_the_edge_count():
    c = ArrayChunks(np.zeros(5000, np.int64), np.ones(5000, np.int64),
                    8, 1024)
    assert c.n_chunks == 5
    assert sum(c.chunk_size(k) for k in range(c.n_chunks)) == 5000
    assert c.chunk_size(c.n_chunks - 1) == 5000 - 4 * 1024
    g = rmat_chunks(scale=9, edge_factor=8, seed=0,
                    chunk_edges=1024).materialize()
    assert g.n_edges == (1 << 9) * 8


def test_chunk_edges_must_be_pow2():
    with pytest.raises(ValueError, match="power of two"):
        ArrayChunks(np.zeros(10, np.int64), np.zeros(10, np.int64), 4, 100)
    with pytest.raises(ValueError, match="power of two"):
        RmatChunks(scale=8, chunk_edges=3)


# ---------------------------------------------------------------------------
# options / plan validation
# ---------------------------------------------------------------------------


def test_options_reject_nonsense_eagerly():
    from repro.connectivity.planner.staged import MIN_STAGE_EDGES
    with pytest.raises(ValueError, match="oocore_chunk_edges"):
        SolveOptions(oocore_chunk_edges=MIN_STAGE_EDGES // 2).validate()
    with pytest.raises(ValueError, match="oocore_round_cap"):
        SolveOptions(oocore_round_cap=0).validate()
    with pytest.raises(ValueError, match="oocore_local_iters"):
        SolveOptions(oocore_local_iters=0).validate()
    # the same rejections fire through the facade, before any solve work
    g = gen.path(32, seed=0)
    with pytest.raises(ValueError, match="oocore_round_cap"):
        solve(g, algorithm="oocore", oocore_round_cap=-1)
    SolveOptions(oocore_chunk_edges=MIN_STAGE_EDGES,
                 oocore_round_cap=1, oocore_local_iters=1).validate()


def test_plan_chunk_bucket_validation_and_roundtrip():
    with pytest.raises(ValueError, match="chunk_bucket"):
        _planner.ExecutionPlan(backend="xla", chunk_bucket=3).validate()
    plan = _planner.ExecutionPlan(backend="xla", chunk_bucket=4096)
    plan.validate()
    assert "chunk=4096" in plan.provenance_entry()
    # config round-trip keeps the bucket; legacy configs default to 0
    assert _planner.ExecutionPlan.from_config(
        plan.to_config()).chunk_bucket == 4096
    legacy = {k: v for k, v in plan.to_config().items()
              if k != "chunk_bucket"}
    assert _planner.ExecutionPlan.from_config(legacy).chunk_bucket == 0


def test_planner_bucket_resolution():
    from repro.connectivity.planner.staged import MIN_STAGE_EDGES
    # an explicit request wins, rounded up to pow2
    assert _planner.oocore_chunk_bucket(1 << 20, requested=3000) == 4096
    # unrequested: VMEM-budget-derived, pow2, within the clamp window
    b = _planner.oocore_chunk_bucket(1 << 20)
    assert b & (b - 1) == 0
    assert MIN_STAGE_EDGES <= b <= (1 << 20)
    # tiny graphs never stream below the stage floor
    assert _planner.oocore_chunk_bucket(64) == MIN_STAGE_EDGES


# ---------------------------------------------------------------------------
# recovery: round-boundary checkpoints (chaos tier rides the oocore marker)
# ---------------------------------------------------------------------------


@pytest.mark.chaos
def test_midround_crash_replays_one_round(tmp_path):
    chunks = star_forest_chunks(k=8, b=1024)
    opts = SolveOptions(algorithm="oocore", **_XLA, oocore_local_iters=1)
    clean = solve_chunks(chunks, opts)
    # chunk counter 9 = second chunk of round 1: past the round-0
    # checkpoint, mid-stream in round 1
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    res, stats = oocore_with_recovery(
        chunks, mgr, opts,
        fault_injector=FaultInjector(fail_at=((9, "oocore_chunk"),)))
    assert np.array_equal(np.asarray(res.labels), np.asarray(clean.labels))
    assert stats.restarts == 1
    assert stats.replayed_rounds >= 1
    assert any(e.startswith("oocore:rounds=") for e in res.provenance)


@pytest.mark.chaos
def test_round0_crash_replays_the_source(tmp_path):
    g = _suite()["mix"]
    opts = SolveOptions(algorithm="oocore", **_XLA)
    clean = solve_chunks(_chunks_of(g, 1024), opts)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    res, stats = oocore_with_recovery(
        _chunks_of(g, 1024), mgr, opts,
        fault_injector=FaultInjector(fail_at=((3, "oocore_chunk"),)))
    assert np.array_equal(np.asarray(res.labels), np.asarray(clean.labels))
    assert stats.restarts == 1


@pytest.mark.chaos
def test_fresh_engine_resumes_from_manifest(tmp_path):
    """Cross-process resume: a new engine restores the round-boundary
    state (labels + survivor manifest) and finishes bit-exactly."""
    chunks = star_forest_chunks(k=8, b=1024)
    opts = SolveOptions(algorithm="oocore", **_XLA, oocore_local_iters=1)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    eng = OutOfCoreContraction(chunks, opts)
    eng.run_round()
    eng.save(mgr)
    mgr.wait()
    clean = solve_chunks(chunks, opts)

    eng2 = OutOfCoreContraction(chunks, opts)
    eng2.restore(mgr)
    assert eng2.round_index == 1
    assert eng2.round_counts == eng.round_counts
    while not eng2.finished_streaming:
        eng2.run_round()
    labels, _, converged, _ = eng2.finish()
    assert bool(converged)
    assert np.array_equal(np.asarray(labels), np.asarray(clean.labels))


def test_unrecoverable_fault_propagates(tmp_path):
    chunks = star_forest_chunks(k=4, b=1024)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    with pytest.raises(Exception):
        oocore_with_recovery(
            chunks, mgr,
            SolveOptions(algorithm="oocore", **_XLA, oocore_local_iters=1),
            max_restarts=0,
            fault_injector=FaultInjector(fail_at=((2, "oocore_chunk"),)))
