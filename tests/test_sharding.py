"""Sharding rules: divisibility-aware resolution, profiles, cache axes.

Uses AbstractMesh (no devices needed) so the production 16x16 / 2x16x16
topologies are testable on a 1-CPU host.
"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.jax_compat import abstract_mesh

from repro.configs import ARCHS, get_arch
from repro.models import common as cm
from repro.models import transformer as tfm
from repro.models.common import ModelConfig, PROFILES


def mesh_single():
    return abstract_mesh((16, 16), ("data", "model"))


def mesh_multi():
    return abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=2048,
                n_heads=32, n_kv_heads=8, d_ff=5632, vocab_size=100352)
    base.update(kw)
    return ModelConfig(**base)


def test_resolve_divisible_axis():
    cfg = _cfg(sharding_profile="tp")
    rules = cm.make_rules(cfg, mesh_single())
    spec = cm.resolve_spec((2048, 5632), (None, "ffn"), mesh_single(), rules)
    assert spec == P(None, "model")


def test_resolve_indivisible_falls_back_to_replication():
    cfg = _cfg(sharding_profile="tp")
    rules = cm.make_rules(cfg, mesh_single())
    # 8 kv heads don't divide the 16-way model axis -> replicated
    spec = cm.resolve_spec((2048, 8, 128), (None, "kv_heads", None),
                           mesh_single(), rules)
    assert spec == P()


def test_batch_flat_profile_uses_all_axes():
    cfg = _cfg(sharding_profile="fsdp")
    rules = cm.make_rules(cfg, mesh_multi())
    spec = cm.resolve_spec((512, 4096), ("batch", None), mesh_multi(), rules)
    assert spec == P(("pod", "data", "model"))
    # batch that only fits (pod, data): graceful prefix assignment
    spec = cm.resolve_spec((64, 4096), ("batch", None), mesh_multi(), rules)
    assert spec == P(("pod", "data"))


def test_used_axis_exclusivity_kv_cache():
    """kv_seq and kv_heads can never both claim the model axis."""
    cfg = _cfg(shard_cache_seq=True)
    rules = cm.make_rules(cfg, mesh_single())
    spec = cm.resolve_spec((128, 32768, 8, 128),
                           ("batch", "kv_seq", "kv_heads", None),
                           mesh_single(), rules)
    assert spec == P("data", "model")    # seq took model; heads replicated

    cfg2 = _cfg(shard_cache_seq=False, n_kv_heads=32)
    rules2 = cm.make_rules(cfg2, mesh_single())
    spec2 = cm.resolve_spec((128, 32768, 32, 128),
                            ("batch", "kv_seq", "kv_heads", None),
                            mesh_single(), rules2)
    assert spec2 == P("data", None, "model")


def test_seq_parallel_profile():
    cfg = _cfg(sharding_profile="tp_sp")
    assert cfg.seq_parallel
    rules = cm.make_rules(cfg, mesh_single())
    spec = cm.resolve_spec((256, 4096, 2048), ("batch", "seq", "embed"),
                           mesh_single(), rules)
    assert spec == P("data", "model")


def test_every_profile_has_all_logical_axes():
    names = set(PROFILES["tp"])
    for pname, rules in PROFILES.items():
        assert set(rules) == names, pname


def test_param_shardings_cover_whole_tree():
    from repro.models.model import build_model
    mesh = mesh_single()
    for arch_name in ("yi-6b", "deepseek-moe-16b", "zamba2-2.7b"):
        cfg = get_arch(arch_name).config
        model = build_model(cfg)
        shardings = cm.shardings_for(model.param_specs(), cfg, mesh)
        specs = model.param_specs()
        n1 = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, cm.ParamSpec)))
        n2 = len(jax.tree_util.tree_leaves(shardings))
        assert n1 == n2 > 10


def test_expert_weights_sharded_on_model():
    cfg = get_arch("deepseek-moe-16b").config
    mesh = mesh_single()
    rules = cm.make_rules(cfg, mesh)
    spec = cm.resolve_spec((64, 2048, 1408),
                           ("experts", None, "expert_inner"), mesh, rules)
    assert spec == P("model")      # stationary experts: EP without FSDP-AG

    cfg2 = get_arch("arctic-480b").config
    rules2 = cm.make_rules(cfg2, mesh)
    spec2 = cm.resolve_spec((128, 7168, 4864),
                            ("experts", None, "expert_inner"), mesh, rules2)
    assert spec2 == P("model", None, "data")   # + storage shard (480B)


def test_cache_axes_structure_matches_cache():
    cfg = get_arch("zamba2-2.7b").smoke_config()
    from repro.models.model import build_model
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init_cache(2, 64))
    resolvers = tfm.cache_shardings(cfg, mesh_single(), model.plan)
    out = tfm.resolve_cache_shardings(resolvers, shapes)
    assert (jax.tree_util.tree_structure(out)
            == jax.tree_util.tree_structure(shapes))


def test_abstract_and_concrete_params_agree():
    """eval_shape of init == abstract_tree (same constructor code path)."""
    cfg = get_arch("stablelm-1.6b").smoke_config()
    from repro.models.model import build_model
    model = build_model(cfg)
    abstract = cm.abstract_tree(model.param_specs(), cfg.param_dtype)
    concrete = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    a = jax.tree_util.tree_map(lambda x: (x.shape, str(x.dtype)), abstract)
    c = jax.tree_util.tree_map(lambda x: (x.shape, str(x.dtype)), concrete)
    assert a == c
