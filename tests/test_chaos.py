"""Chaos suite: fault-injected solves must recover bit-exactly.

Every test here kills the system somewhere — an ingest batch (before or
after its ring-buffer write), a distributed shard round, a kernel launch
— and asserts the recovered labels are *bit-identical* to the fault-free
oracle.  Run with ``-m chaos`` (the CI chaos job); the suite is also part
of the plain tier-1 run.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.connectivity import (
    FaultInjector,
    SolveOptions,
    StreamingConnectivity,
    get_solver,
    register_solver,
    resilient_distributed_contour,
    solve,
    stream_with_recovery,
)
from repro.connectivity import streaming as streaming_mod
from repro.connectivity.solvers import _contour_solver
from repro.data.dedup import StreamingDedup
from repro.data.pipeline import make_corpus
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle
from repro.runtime.recovery import (
    ShardLossFault,
    SimulatedFault,
    run_with_recovery,
)

pytestmark = pytest.mark.chaos

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
XLA = SolveOptions(backend="xla")


def _stream_fixture(n_batches=12, seed=0):
    """(graph, oracle, batches): a shuffled micro-batch stream."""
    g = gen.components_mix([gen.path(300, seed=1), gen.rmat(9, seed=2)],
                           seed=3)
    oracle = connected_components_oracle(*g.to_numpy())
    src, dst, n = g.to_numpy()
    m = len(src)
    perm = np.random.default_rng(seed).permutation(m)
    src, dst = src[perm], dst[perm]
    batches = [(src[b * m // n_batches:(b + 1) * m // n_batches],
                dst[b * m // n_batches:(b + 1) * m // n_batches])
               for b in range(n_batches)]
    return g, oracle, batches


# -- checkpointable streaming + crash-restart driver ---------------------

def test_stream_crash_recovery_bitexact(tmp_path):
    """Faults at arbitrary batches/sites == fault-free run, bit for bit."""
    g, oracle, batches = _stream_fixture()

    clean = StreamingConnectivity(g.n_vertices, XLA)
    for b in batches:
        clean.ingest(*b)

    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    inj = FaultInjector(fail_at=(3, (7, "post_write"), (9, "pre")))
    events = []
    eng, stats = stream_with_recovery(
        batches, g.n_vertices, mgr, XLA, checkpoint_every=3,
        fault_injector=inj, on_event=lambda ev, k: events.append((ev, k)))
    assert stats["restarts"] == 3
    assert stats["checkpoints"] >= 4
    assert [ev for ev, _ in events] == ["restart"] * 3
    snap = eng.snapshot()
    assert bool(snap.converged)
    assert (np.asarray(snap.labels) == oracle).all()
    assert (np.asarray(snap.labels) == np.asarray(clean.labels)).all()
    # the replayed store is byte-identical too, not just the labels
    assert eng.n_edges == clean.n_edges
    assert (np.asarray(eng.graph().src) == np.asarray(clean.graph().src)).all()


def test_stream_recovery_resumes_across_processes(tmp_path):
    """A restart budget blow-through == process death; a second driver
    invocation against the same checkpoint dir resumes, not replays."""
    g, oracle, batches = _stream_fixture()
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    inj = FaultInjector(fail_at=(7,))
    with pytest.raises(SimulatedFault):
        stream_with_recovery(batches, g.n_vertices, mgr, XLA,
                             checkpoint_every=3, max_restarts=0,
                             fault_injector=inj)
    assert mgr.latest_step() == 6  # step 6 == resume at batch 6
    eng, stats = stream_with_recovery(batches, g.n_vertices, mgr, XLA,
                                      checkpoint_every=3)
    assert stats["restarts"] == 0
    assert eng.n_batches == len(batches)
    assert (np.asarray(eng.snapshot().labels) == oracle).all()


def test_engine_state_roundtrip_bitexact(tmp_path):
    """save()/restore() round-trips the full engine state mid-stream."""
    g, oracle, batches = _stream_fixture()
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    e1 = StreamingConnectivity(g.n_vertices, XLA)
    for b in batches[:6]:
        e1.ingest(*b)
    step = e1.save(mgr)
    assert step == 6
    e2, step2 = StreamingConnectivity.restore(mgr, XLA)
    assert step2 == 6
    assert e2.n_vertices == e1.n_vertices
    assert e2.n_edges == e1.n_edges
    assert e2.capacity == e1.capacity
    assert (np.asarray(e2.labels) == np.asarray(e1.labels)).all()
    # both continuations land on the oracle, bit-identically to each other
    for b in batches[6:]:
        e1.ingest(*b)
        e2.ingest(*b)
    assert (np.asarray(e1.labels) == np.asarray(e2.labels)).all()
    assert (np.asarray(e2.labels) == oracle).all()
    assert float(e1.snapshot().edges_visited) == \
        float(e2.snapshot().edges_visited)


def test_restore_rejects_corrupt_state(tmp_path):
    g, _, batches = _stream_fixture()
    eng = StreamingConnectivity(g.n_vertices, XLA)
    eng.ingest(*batches[0])
    state = eng.state_dict()
    bad = dict(state, n_cap=np.int64(int(state["n_cap"]) * 2))
    with pytest.raises(ValueError, match="corrupt checkpoint"):
        StreamingConnectivity(g.n_vertices, XLA).load_state_dict(bad)
    with pytest.raises(ValueError, match="missing"):
        StreamingConnectivity(g.n_vertices, XLA).load_state_dict(
            {k: v for k, v in state.items() if k != "labels"})


# -- ingest atomicity under mid-ingest faults ----------------------------

def test_ingest_rollback_post_write():
    """A fault after the ring write but before the commit leaves the
    engine queryable with its pre-ingest snapshots (satellite regression:
    the write lands at offset >= m, invisible until the commit)."""
    g, oracle, batches = _stream_fixture()
    eng = StreamingConnectivity(g.n_vertices, XLA,
                                fault_injector=FaultInjector(
                                    fail_at=((1, "post_write"),)))
    eng.ingest(*batches[0])
    before = np.asarray(eng.snapshot().labels).copy()
    m_before, nb_before = eng.n_edges, eng.n_batches
    visited_before = float(eng.snapshot().edges_visited)
    with pytest.raises(SimulatedFault):
        eng.ingest(*batches[1])
    assert eng.n_edges == m_before
    assert eng.n_batches == nb_before
    assert (np.asarray(eng.snapshot().labels) == before).all()
    assert float(eng.snapshot().edges_visited) == visited_before
    # the injector fired once; the replayed batch commits and the stream
    # finishes on the oracle
    for b in batches[1:]:
        eng.ingest(*b)
    assert (np.asarray(eng.snapshot().labels) == oracle).all()


def test_ingest_rollback_after_vertex_growth():
    """Mid-ingest failure rolls back vertex growth too: the engine answers
    queries as if the failed batch (and its new vertices) never arrived."""
    eng = StreamingConnectivity(4, XLA,
                                fault_injector=FaultInjector(
                                    fail_at=((1, "pre"),
                                             (1, "post_write"))))
    eng.ingest([0, 1], [1, 2])
    # growth + pre-solve fault (before any device work)
    with pytest.raises(SimulatedFault):
        eng.ingest([5], [6], n_vertices=8)
    assert eng.n_vertices == 4
    assert eng.snapshot().n_components == 2  # {0,1,2}, {3}
    # growth + post-write fault (batch in the ring at offset >= m,
    # invisible because the commit never ran)
    with pytest.raises(SimulatedFault):
        eng.ingest([2, 8], [3, 9], n_vertices=10)
    assert eng.n_vertices == 4
    assert eng.n_edges == 2
    assert eng.snapshot().n_components == 2
    # replay: the injector fired once per site, so the grown ingest
    # commits for real
    eng.ingest([2, 8], [3, 9], n_vertices=10)
    assert eng.n_vertices == 10
    assert eng.same_component(0, 3)
    assert eng.same_component(8, 9)
    assert not eng.same_component(0, 8)


# -- run_with_recovery: configurable recoverable set + backoff -----------

def test_run_with_recovery_recoverable_set(tmp_path):
    """Real faults (RuntimeError) restore when configured; the default
    conservative set still lets them propagate (satellite regression)."""
    def make_step(fail_once_at):
        fired = set()

        def step(state, k):
            if k == fail_once_at and k not in fired:
                fired.add(k)
                raise RuntimeError("transient XLA failure")
            out = state.copy()
            out[k] += 1  # counts executions: replay must not double-apply
            return out
        return step

    init = np.zeros(10, np.int64)
    mgr = CheckpointManager(str(tmp_path / "a"), async_save=False)
    with pytest.raises(RuntimeError):
        run_with_recovery(make_step(5), init, 10, mgr, checkpoint_every=3)

    mgr = CheckpointManager(str(tmp_path / "b"), async_save=False)
    state, stats = run_with_recovery(
        make_step(5), init, 10, mgr, checkpoint_every=3,
        recoverable=(RuntimeError,))
    assert stats["restarts"] == 1
    # restored-then-replayed state is exactly one application per step
    assert (np.asarray(state) == 1).all()


def test_run_with_recovery_backoff_schedule(tmp_path):
    delays = []
    inj = FaultInjector(fail_at=(2, 5, 8))
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    _, stats = run_with_recovery(
        lambda s, k: s + 1, 0, 10, mgr, checkpoint_every=4,
        fault_injector=inj, backoff_base=0.5, backoff_factor=2.0,
        backoff_cap=1.5, sleep_fn=delays.append)
    assert stats["restarts"] == 3
    assert delays == [0.5, 1.0, 1.5]  # exponential, capped


def test_run_with_recovery_budget_exhaustion(tmp_path):
    inj = FaultInjector(fail_at=(1, 2, 3))
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    with pytest.raises(SimulatedFault):
        run_with_recovery(lambda s, k: s, 0, 5, mgr, checkpoint_every=2,
                          max_restarts=2, fault_injector=inj)


# -- graceful degradation: kernel launch failure -> XLA fallback ---------

@pytest.fixture
def flaky_solver():
    """A contour clone whose non-XLA backends always fail to launch."""
    base = get_solver("contour")

    def flaky_fn(graph, opts, init):
        if opts.backend != "xla":
            raise RuntimeError("fake kernel launch failure")
        return _contour_solver(graph, opts, init)

    register_solver(dataclasses.replace(base, name="flaky", fn=flaky_fn,
                                        aliases=()))
    yield "flaky"
    from repro.connectivity.registry import _REGISTRY
    _REGISTRY.pop("flaky", None)


def test_solve_kernel_fallback(flaky_solver):
    g = gen.path(200, seed=1)
    oracle = connected_components_oracle(*g.to_numpy())
    res = solve(g, algorithm=flaky_solver, backend="pallas_blocked")
    assert (np.asarray(res.labels) == oracle).all()
    assert res.provenance is not None
    assert res.provenance[0].startswith("kernel_fallback:pallas_blocked")
    # a clean solve records its resolved plan, but no degradation events
    clean = solve(g, backend="xla").provenance
    assert not [p for p in clean if p.startswith("kernel_fallback")]
    assert [p for p in clean if p.startswith("plan:xla")]
    # opting out fails loudly
    with pytest.raises(RuntimeError, match="fake kernel"):
        solve(g, algorithm=flaky_solver, backend="pallas_blocked",
              kernel_fallback=False)


def test_solve_fallback_never_masks_caller_bugs():
    """Non-transient errors and injected machine faults must propagate:
    a ValueError is a caller bug, and a SimulatedFault must reach the
    checkpoint/restore layer, never be absorbed as a kernel retry."""
    base = get_solver("contour")

    def buggy_fn(graph, opts, init):
        if opts.backend != "xla":
            raise ValueError("caller bug, not a launch failure")
        return _contour_solver(graph, opts, init)

    def faulty_fn(graph, opts, init):
        if opts.backend != "xla":
            raise SimulatedFault("injected machine fault")
        return _contour_solver(graph, opts, init)

    from repro.connectivity.registry import _REGISTRY
    g = gen.path(50, seed=1)
    try:
        register_solver(dataclasses.replace(base, name="buggy", fn=buggy_fn,
                                            aliases=()))
        register_solver(dataclasses.replace(base, name="faulty",
                                            fn=faulty_fn, aliases=()))
        # if either were (wrongly) retried on XLA it would *succeed* and
        # return a fallback-provenance result instead of raising
        with pytest.raises(ValueError, match="caller bug"):
            solve(g, algorithm="buggy", backend="pallas_blocked")
        with pytest.raises(SimulatedFault):
            solve(g, algorithm="faulty", backend="pallas_blocked")
    finally:
        _REGISTRY.pop("buggy", None)
        _REGISTRY.pop("faulty", None)


def test_streaming_kernel_fallback(monkeypatch):
    g, oracle, batches = _stream_fixture(n_batches=4)
    real = streaming_mod.delta_converge

    def fake(*args, **kw):
        if kw.get("backend") != "xla":
            raise RuntimeError("fake kernel launch failure")
        return real(*args, **kw)

    monkeypatch.setattr(streaming_mod, "delta_converge", fake)
    eng = StreamingConnectivity(g.n_vertices,
                                SolveOptions(backend="pallas_blocked"))
    for b in batches:
        eng.ingest(*b)
    snap = eng.snapshot()
    assert (np.asarray(snap.labels) == oracle).all()
    fallbacks = [p for p in snap.provenance
                 if p.startswith("kernel_fallback")]
    assert len(fallbacks) == len(batches)
    assert all(p.startswith("kernel_fallback:pallas_blocked")
               for p in fallbacks)
    # the retry's resolved plan is recorded alongside the events
    assert [p for p in snap.provenance if p.startswith("plan:")]

    eng = StreamingConnectivity(g.n_vertices,
                                SolveOptions(backend="pallas_blocked",
                                             kernel_fallback=False))
    with pytest.raises(RuntimeError, match="fake kernel"):
        eng.ingest(*batches[0])
    assert eng.n_edges == 0  # atomic: nothing committed


# -- straggler-driven checkpoint cadence ---------------------------------

class _ScriptedMonitor:
    """StragglerMonitor stand-in returning a scripted action sequence."""

    def __init__(self, actions):
        self.actions = list(actions)

    def start_step(self):
        pass

    def end_step(self):
        return self.actions.pop(0)


def test_straggler_forces_checkpoint(tmp_path):
    g, oracle, batches = _stream_fixture(n_batches=6)
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    # cadence alone would checkpoint only at batch 6; the monitor flags
    # batch 1 as persistently slow -> snapshot now, losing no work
    monitor = _ScriptedMonitor(["ok", "checkpoint", "ok", "ok", "ok", "ok"])
    steps_seen = []
    orig_save = mgr.save

    def spy(step, state):
        steps_seen.append(step)
        return orig_save(step, state)

    mgr.save = spy
    eng, stats = stream_with_recovery(batches, g.n_vertices, mgr, XLA,
                                      checkpoint_every=6, straggler=monitor)
    assert stats["straggler_events"] == 1
    assert steps_seen == [2, 6]  # forced at committed=2, cadence at end
    assert (np.asarray(eng.snapshot().labels) == oracle).all()


# -- elastic shrink-and-resume (distributed) -----------------------------

def test_resilient_distributed_single_device(tmp_path):
    """Plain fault on a 1-device mesh: warm restart from the manager's
    last checkpoint, fixed point bit-identical to the oracle."""
    g, oracle, _ = _stream_fixture()
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    inj = FaultInjector(fail_at=((1, "round"),))
    res, stats = resilient_distributed_contour(
        g, options=XLA, block_rounds=2, fault_injector=inj, manager=mgr)
    assert stats["restarts"] == 1
    assert stats["shrinks"] == 0
    assert bool(res.converged)
    assert (np.asarray(res.labels) == oracle).all()
    assert mgr.latest_step() is not None  # converged block checkpointed


def test_resilient_distributed_straggler_ladder(tmp_path):
    """'checkpoint' then 'evict' escalation on a 1-device mesh: both
    force a label snapshot; eviction cannot shrink below the model-
    parallel floor, so the solve degrades gracefully instead of dying."""
    g, oracle, _ = _stream_fixture()
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    monitor = _ScriptedMonitor(["checkpoint", "evict"] + ["ok"] * 50)
    res, stats = resilient_distributed_contour(
        g, options=XLA, block_rounds=4, straggler=monitor, manager=mgr)
    assert bool(res.converged)
    assert (np.asarray(res.labels) == oracle).all()
    assert stats["shrinks"] == 0  # 1 device: eviction floor holds
    assert stats["checkpoints"] >= 2  # forced blocks (+ converged block)
    assert ("straggler_checkpoint", 0) in stats["events"]
    assert mgr.latest_step() is not None


def test_resilient_budget_exhaustion_not_converged():
    """Running out of the round budget reports converged=False (and the
    partial labels are still a sound warm start)."""
    g, oracle, _ = _stream_fixture()
    res, stats = resilient_distributed_contour(
        g, options=XLA.replace(max_iters=1), block_rounds=1)
    assert not bool(res.converged)
    res2 = solve(g, XLA, warm_start=res)
    assert (np.asarray(res2.labels) == oracle).all()


_SHRINK_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.connectivity import (SolveOptions, FaultInjector,
                                    resilient_distributed_contour)
    from repro.runtime.recovery import ShardLossFault
    from repro.graphs import generators as gen
    from repro.graphs.oracle import connected_components_oracle

    g = gen.components_mix([gen.path(2000, seed=1), gen.rmat(10, seed=2)],
                           seed=3)
    oracle = connected_components_oracle(*g.to_numpy())

    # lose one shard at round-block 1, another at block 2: 8 -> 7 -> 6
    inj = FaultInjector(fail_at=((1, "round"), (2, "round")),
                        exc_factory=lambda step, site: ShardLossFault(1))
    res, stats = resilient_distributed_contour(
        g, devices=jax.devices(), options=SolveOptions(backend="xla"),
        block_rounds=2, fault_injector=inj)
    assert stats["shrinks"] == 2, stats
    assert stats["mesh_history"] == [(8, 1), (7, 1), (6, 1)], stats
    assert bool(res.converged), stats
    assert (np.asarray(res.labels) == oracle).all()
    assert res.provenance[0].startswith("plan:xla")  # resolved plan leads
    assert res.provenance[1:] == ("elastic_shrink:8->7",
                                  "elastic_shrink:7->6")
    print("SHRINK_OK", dict(stats))
""")


def test_elastic_shrink_8way_subprocess():
    """Shard loss mid-solve on a real 8-way mesh: shrink to 7 then 6
    shards, warm-resume, converge to the fault-free fixed point."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHRINK_SUBPROCESS],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHRINK_OK" in out.stdout


# -- dedup state checkpointing -------------------------------------------

def test_streaming_dedup_state_roundtrip():
    """StreamingDedup checkpoints its LSH buckets + engine state; a
    restored instance continues bit-identically."""
    docs = make_corpus(n_docs=120, doc_len=80, vocab_size=500,
                       dup_fraction=0.3, near_dup_noise=0.03, seed=7)
    d1 = StreamingDedup(n_hashes=32, bands=8)
    for pos in range(0, 60, 20):
        d1.add_docs(docs[pos:pos + 20])
    state = d1.state_dict()

    d2 = StreamingDedup(n_hashes=32, bands=8).load_state_dict(state)
    assert d2.n_docs == d1.n_docs
    assert d2.n_candidate_pairs == d1.n_candidate_pairs
    for pos in range(60, 120, 20):
        d1.add_docs(docs[pos:pos + 20])
        d2.add_docs(docs[pos:pos + 20])
    assert (d1.labels() == d2.labels()).all()
    r1, r2 = d1.report(), d2.report()
    assert r1.n_clusters == r2.n_clusters
    assert (r1.keep == r2.keep).all()
