"""Streaming incremental connectivity (``connectivity.streaming``).

The load-bearing equivalence: any batching/ordering of a shuffled edge
stream must land **bit-identical** to the one-shot ``solve()`` on the
final graph — both converge to the canonical min-vertex-id labelling, so
this is an exact array equality, not just partition equality.  Plus the
soundness counterexample that shapes the engine (the supervertex rewrite),
the work counter, snapshots/queries, vertex growth, the vmapped delta
core, and the mesh path.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import jax_compat
from repro.connectivity import (SolveOptions, StreamingConnectivity, solve,
                                solve_batch)
from repro.connectivity import minmap as lab
from repro.connectivity.streaming import (_pad_batch, delta_converge,
                                          next_pow2)
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle
from repro.graphs.structs import Graph


def _shuffled(graph, seed):
    src, dst, n = graph.to_numpy()
    perm = np.random.default_rng(seed).permutation(src.shape[0])
    return src[perm], dst[perm], n


def _stream(eng, src, dst, n_batches, **kw):
    m = len(src)
    for b in range(n_batches):
        sl = slice(b * m // n_batches, (b + 1) * m // n_batches)
        eng.ingest(src[sl], dst[sl], **kw)
    return eng


# ---------------------------------------------------------------------------
# equivalence: any batching == one-shot solve, bit-identical


@pytest.mark.parametrize("n_batches", (1, 7, 32))
@pytest.mark.parametrize(
    "graph", (gen.path(2000, seed=3), gen.rmat(10, seed=5),
              gen.components_mix([gen.path(300, seed=1),
                                  gen.star(200, seed=2),
                                  gen.grid2d(12, 12)], seed=7)),
    ids=("path", "rmat", "mix"))
def test_stream_bit_identical_to_oneshot(graph, n_batches):
    src, dst, n = _shuffled(graph, seed=n_batches)
    eng = _stream(StreamingConnectivity(n), src, dst, n_batches)
    one = solve(graph, backend="xla")
    snap = eng.snapshot()
    assert (np.asarray(snap.labels) == np.asarray(one.labels)).all()
    assert bool(snap.converged)
    # the delta path must do *less* edge work than the dense one-shot
    # sweep whenever the stream is split at all
    if n_batches > 1:
        assert float(snap.edges_visited) < float(one.edges_visited)


def test_random_batchings_and_variants():
    """Randomised soak: arbitrary batch sizes, stream orders, variants.

    Includes order-1 variants (C-1, C-1m1m): the supervertex rewrite makes
    them sound too — see ``test_delta_sweep_needs_supervertex_rewrite``
    for what happens without it.
    """
    rng = np.random.default_rng(0)
    for trial in range(25):
        n = int(rng.integers(2, 100))
        m = int(rng.integers(0, 4 * n))
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        variant = str(rng.choice(["C-2", "C-m", "C-3", "C-1", "C-1m1m",
                                  "C-11mm"]))
        eng = StreamingConnectivity(
            n, variant=variant, backend="xla",
            compact_every=int(rng.integers(0, 3)))
        pos = 0
        while pos < m:
            k = int(rng.integers(1, max(2, m // 3 + 1)))
            eng.ingest(src[pos:pos + k], dst[pos:pos + k])
            pos += k
        oracle = connected_components_oracle(src, dst, n)
        assert (np.asarray(eng.labels) == oracle).all(), (trial, variant)


def test_delta_sweep_needs_supervertex_rewrite():
    """The counterexample behind the engine's endpoint rewrite.

    Warm star forest with components {1856-}, {2873, 3417-ish}, {1937-}:
    batch edges (a, b) and (c, d) can, in ONE synchronous sweep, redirect
    a shared deep vertex and its root with *different* values, stranding
    a previously merged vertex — so sweeping a batch's original endpoints
    is unsound at every MM order.  Minimal form: vertices 0..4, old
    components {0}, {1}, {2, 3} (L[3] = 2), batch {(0, 3), (1, 2)}.
    Edge (0,3) writes z=0 to {0, 3, L[3]=2}; edge (1,2) writes z=1 to
    {1, 2}; the scatter-min leaves L[3]=0 but L[2]=0 too — fine at order
    2 here, so drive the published failing instance instead: the rewrite
    path must match the oracle where the raw path diverges.
    """
    # old graph: path fragments merged into a star forest
    rng = np.random.default_rng(3)
    g = gen.path(600, seed=3)
    src, dst, n = g.to_numpy()
    perm = rng.permutation(src.shape[0])
    src, dst = src[perm], dst[perm]
    cut = len(src) // 2
    warm = solve(Graph.from_numpy(src[:cut], dst[:cut], n),
                 backend="xla").labels
    batch_s, batch_d = src[cut:], dst[cut:]
    oracle = connected_components_oracle(src, dst, n)

    # raw delta sweep over original endpoints: converges, but is allowed
    # to strand vertices (this is the unsound path — assert only that the
    # *engine's* rewrite path is exact; if the raw path happens to be
    # right on some seed the rewrite must still match it)
    k = len(batch_s)
    pad = next_pow2(k)
    sp, dp = _pad_batch(jnp.asarray(batch_s), jnp.asarray(batch_d), pad)
    step_raw = lambda L: lab.pointer_jump(lab.mm_relax(L, sp, dp, 2), 1)
    L_raw = jnp.asarray(warm)
    for _ in range(50):
        L_raw = step_raw(L_raw)
    raw_ok = (np.asarray(L_raw) == oracle).all()

    eng = StreamingConnectivity(n)
    eng.ingest(src[:cut], dst[:cut])
    eng.ingest(batch_s, batch_d)
    assert (np.asarray(eng.labels) == oracle).all()
    # this seed reproduces the stranding: keep it load-bearing
    assert not raw_ok, ("seed no longer exhibits the raw-endpoint "
                        "counterexample; pick a new one")


# ---------------------------------------------------------------------------
# snapshots, queries, warm starts


def test_snapshot_and_queries_without_resolve():
    g = gen.components_mix([gen.path(100, seed=1), gen.star(80, seed=2)],
                           seed=3)
    src, dst, n = _shuffled(g, seed=9)
    eng = _stream(StreamingConnectivity(n), src, dst, 8)
    oracle = connected_components_oracle(src, dst, n)
    snap = eng.snapshot()
    assert snap is eng.snapshot()            # cached until the next ingest
    assert (np.asarray(snap.labels) == oracle).all()
    assert eng.n_components == len(np.unique(oracle))
    u, v = 0, int(np.flatnonzero(oracle == oracle[0])[-1])
    assert eng.same_component(u, v)
    assert eng.component_of(v) == int(oracle[v])
    # negative ids must raise, not wrap to the array tail
    with pytest.raises(IndexError, match=">= 0"):
        eng.component_of(-1)
    with pytest.raises(IndexError, match=">= 0"):
        eng.same_component(-1, 0)
    eng.ingest([0], [n - 1])
    assert eng.same_component(0, n - 1)      # cache invalidated


def test_warm_started_snapshot_seeds_new_engine():
    g = gen.rmat(9, seed=11)
    src, dst, n = _shuffled(g, seed=1)
    cut = len(src) // 2
    eng1 = _stream(StreamingConnectivity(n), src[:cut], dst[:cut], 4)
    # hand the snapshot to a fresh engine; stream the rest
    eng2 = _stream(StreamingConnectivity(n, warm_start=eng1.snapshot()),
                   src[cut:], dst[cut:], 4)
    oracle = connected_components_oracle(src, dst, n)
    assert (np.asarray(eng2.labels) == oracle).all()
    # and as a warm start for a one-shot solve over the full graph
    full = Graph.from_numpy(src, dst, n)
    warm = solve(full, backend="xla", warm_start=eng1.snapshot())
    assert (np.asarray(warm.labels) == oracle).all()


def test_vertex_growth_and_edge_store():
    eng = StreamingConnectivity(4, min_capacity=4)
    eng.ingest([0, 1], [1, 2])
    eng.ingest([3, 5], [4, 5], n_vertices=7)
    assert eng.n_vertices == 7
    assert eng.n_edges == 4
    assert eng.capacity >= 4 and eng.capacity == next_pow2(eng.capacity)
    # label capacity doubles past 4 -> 8; growth *within* capacity is a
    # bound bump only (no array reshape, hence no recompile)
    assert eng.vertex_capacity == 8
    eng.ingest([7], [0], n_vertices=8)
    assert eng.vertex_capacity == 8 and eng.n_vertices == 8
    g = eng.graph()
    assert g.n_edges == 5 and g.n_vertices == 8
    oracle = connected_components_oracle(*g.to_numpy())
    assert np.asarray(eng.labels).shape == (8,)
    assert (np.asarray(eng.labels) == oracle).all()
    # shrinking is refused
    with pytest.raises(ValueError, match="shrinks"):
        eng.ingest([0], [1], n_vertices=3)


def test_ingest_validation_and_empty_batches():
    eng = StreamingConnectivity(5)
    eng.ingest([], [])                        # no-op, no solve
    assert eng.n_batches == 0 and eng.n_edges == 0
    with pytest.raises(ValueError, match="n_vertices"):
        eng.ingest([0], [7])
    with pytest.raises(ValueError, match=">= 0"):
        eng.ingest([-1], [0])
    with pytest.raises(ValueError, match="equal-length"):
        eng.ingest([0, 1], [1])
    # ingest_graph grows the vertex set automatically
    eng.ingest_graph(gen.path(9, seed=0, shuffle_ids=False))
    assert eng.n_vertices == 9
    assert eng.same_component(0, 8)


def test_empty_ingest_with_growth_invalidates_snapshot():
    """Regression: an edgeless batch that grows the vertex set must not
    leave a stale cached snapshot behind live queries."""
    eng = StreamingConnectivity(5)
    eng.ingest([0, 1], [1, 2])
    assert eng.n_components == 3
    eng.ingest([], [], n_vertices=10)
    assert eng.n_components == 8            # 5 new singletons
    assert eng.component_of(9) == 9         # was: IndexError off stale labels


def test_store_edges_false_bounds_memory_but_keeps_answers():
    """store_edges=False: O(n) memory, same labels; audit paths refuse."""
    g = gen.rmat(8, seed=6)
    src, dst, n = _shuffled(g, seed=2)
    eng = _stream(StreamingConnectivity(n, store_edges=False), src, dst, 6)
    assert eng.capacity == 0
    oracle = connected_components_oracle(src, dst, n)
    assert (np.asarray(eng.labels) == oracle).all()
    assert eng.n_edges == len(src)          # count still tracked
    with pytest.raises(ValueError, match="store_edges=False"):
        eng.graph()
    with pytest.raises(ValueError, match="store_edges=False"):
        eng.resolve()


def test_rejects_non_streaming_solvers_and_csyn():
    with pytest.raises(ValueError, match="does not support streaming"):
        StreamingConnectivity(4, algorithm="fastsv")
    with pytest.raises(ValueError, match="does not support streaming"):
        StreamingConnectivity(4, algorithm="union_find")
    with pytest.raises(ValueError, match="C-Syn"):
        StreamingConnectivity(4, variant="C-Syn")


def test_unconverged_batch_flags_and_resolve_repairs():
    src = np.arange(999)
    dst = np.arange(1, 1000)
    perm = np.random.default_rng(4).permutation(999)
    eng = StreamingConnectivity(1000, max_iters=1)
    eng.ingest(src[perm], dst[perm])
    assert not bool(eng.snapshot().converged)
    # the repair must NOT inherit the starved max_iters=1 budget: it
    # takes the registry default (or an explicit cap) and must converge
    res = eng.resolve()
    assert bool(res.converged)
    assert (np.asarray(res.labels) == 0).all()
    assert (np.asarray(eng.labels) == 0).all()
    assert bool(eng.snapshot().converged)


def test_failed_delta_solve_leaves_engine_unchanged(monkeypatch):
    """ingest is atomic: a solve failure must not commit edges/counters."""
    from repro.connectivity import streaming as streaming_mod
    eng = StreamingConnectivity(10)
    eng.ingest([0, 1], [1, 2])
    before = (eng.n_edges, eng.n_batches, np.asarray(eng.labels).copy(),
              float(eng.snapshot().edges_visited))

    def boom(*a, **kw):
        raise RuntimeError("backend failed to compile")

    monkeypatch.setattr(streaming_mod, "delta_converge", boom)
    with pytest.raises(RuntimeError, match="failed to compile"):
        eng.ingest([3, 4], [4, 5])
    assert (eng.n_edges, eng.n_batches) == before[:2]
    assert (np.asarray(eng.labels) == before[2]).all()
    assert float(eng.snapshot().edges_visited) == before[3]
    assert bool(eng.snapshot().converged)
    # the store holds exactly the committed edges
    assert eng.graph().n_edges == before[0]
    # vertex growth in the failed batch rolls back too
    with pytest.raises(RuntimeError, match="failed to compile"):
        eng.ingest([12], [13], n_vertices=20)
    assert eng.n_vertices == 10
    assert np.asarray(eng.labels).shape == (10,)
    assert eng.n_components == len(np.unique(before[2]))


# ---------------------------------------------------------------------------
# the vmapped delta core: fleets of parallel streams


def test_delta_converge_under_vmap_matches_solve_batch():
    n, lanes = 64, 3
    rng = np.random.default_rng(8)
    S = np.stack([rng.integers(0, n, 3 * n) for _ in range(lanes)])
    D = np.stack([rng.integers(0, n, 3 * n) for _ in range(lanes)])
    cut = (3 * n) // 2

    labels = jnp.tile(jnp.arange(n, dtype=jnp.int32), (lanes, 1))
    vdelta = jax.vmap(
        lambda s, d, L: delta_converge(s, d, L, jnp.int32(s.shape[0])))
    # two streamed batches per lane, all lanes in one vmapped program
    L, _, done1, _ = vdelta(jnp.asarray(S[:, :cut], jnp.int32),
                            jnp.asarray(D[:, :cut], jnp.int32), labels)
    L, _, done2, _ = vdelta(jnp.asarray(S[:, cut:], jnp.int32),
                            jnp.asarray(D[:, cut:], jnp.int32), L)
    assert bool(done1.all()) and bool(done2.all())

    batch = solve_batch([Graph.from_numpy(S[i], D[i], n)
                         for i in range(lanes)], backend="xla")
    assert (np.asarray(L) == np.asarray(batch.labels)).all()


# ---------------------------------------------------------------------------
# mesh path


def test_streaming_on_single_device_mesh():
    mesh = jax_compat.device_mesh(np.array(jax.devices()[:1]), ("data",))
    g = gen.components_mix([gen.path(150, seed=1), gen.rmat(7, seed=2)],
                           seed=3)
    src, dst, n = _shuffled(g, seed=5)
    eng = _stream(StreamingConnectivity(n, SolveOptions(mesh=mesh)),
                  src, dst, 4)
    oracle = connected_components_oracle(src, dst, n)
    assert (np.asarray(eng.labels) == oracle).all()
    assert bool(eng.snapshot().converged)
    assert float(eng.snapshot().edges_visited) > 0


def test_mesh_streaming_excludes_padding_from_visited():
    """The pow2 bucket padding must be born retired on the mesh path too.

    A 1-device mesh runs the identical global schedule as the
    single-device engine, so for the same stream the work counter must
    agree *exactly* — any padding leak (a 3-edge batch pads to 4)
    inflates the mesh side first."""
    mesh = jax_compat.device_mesh(np.array(jax.devices()[:1]), ("data",))
    eng_mesh = StreamingConnectivity(4, SolveOptions(mesh=mesh))
    eng_one = StreamingConnectivity(4)
    for eng in (eng_mesh, eng_one):
        eng.ingest([0, 1, 2], [1, 2, 3])
        assert (np.asarray(eng.labels) == 0).all()
    assert (float(eng_mesh.snapshot().edges_visited)
            == float(eng_one.snapshot().edges_visited))
