"""Roofline machinery: HLO cost parser (incl. the XLA loop-once pitfall),
collective byte model, report math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import HW_V5E, RooflineReport
from repro.roofline.hlo_cost import HloModule, analyze_text

S = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.float32)


def _cost(fn, *shapes):
    comp = jax.jit(fn).lower(*shapes).compile()
    return analyze_text(comp.as_text()), comp


def test_matmul_flops_exact():
    cost, _ = _cost(lambda a, b: a @ b, S(512, 512), S(512, 512))
    assert cost.flops == pytest.approx(2 * 512**3, rel=1e-6)


def test_scan_trip_count_multiplied():
    """THE pitfall this module exists for: XLA cost_analysis counts a while
    body once; the parser must multiply by the trip count."""
    def body(c, w):
        return jnp.tanh(c @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    cost, comp = _cost(scanned, S(64, 256), S(8, 256, 256))
    per_layer = 2 * 64 * 256 * 256
    assert cost.flops == pytest.approx(8 * per_layer, rel=0.05)
    # and XLA's own number is ~1/8 of that (the bug we work around)
    from repro.jax_compat import cost_analysis
    xla = cost_analysis(comp)["flops"]
    assert xla < cost.flops / 4


def test_nested_scan_trips():
    def inner(c, w):
        return jnp.tanh(c @ w), None

    def outer(c, ws):
        return jax.lax.scan(inner, c, ws)[0], None

    def fn(x, wss):
        return jax.lax.scan(outer, x, wss)[0]

    cost, _ = _cost(fn, S(32, 64), S(3, 5, 64, 64))
    per = 2 * 32 * 64 * 64
    assert cost.flops == pytest.approx(15 * per, rel=0.05)


def test_dot_inside_fusion_counted():
    def fn(a, b):
        return jnp.tanh(a @ b) * 2.0 + 1.0
    cost, _ = _cost(fn, S(128, 128), S(128, 128))
    assert cost.flops >= 2 * 128**3 * 0.99


def test_bytes_reasonable_for_elementwise():
    cost, _ = _cost(lambda a: a * 2.0 + 1.0, S(1024, 1024))
    # read + write of a 4MB array, modest overhead allowed
    assert 8e6 <= cost.bytes <= 4e7


def test_scan_xs_slicing_charged_slice_proportional():
    """lax.scan reads xs via a (fused) dynamic-slice: each trip must be
    charged the slice, not the whole stacked array (the naive model
    inflates a 32k-step recurrence's memory term ~1000x)."""
    def body(c, x):
        return jnp.tanh(c + x), c

    def f(c, xs):
        return jax.lax.scan(body, c, xs)

    cost, _ = _cost(f, S(256), S(1000, 256))
    # slice model: ~1000 trips x few KB; naive model: ~1000 x 1MB
    assert cost.bytes < 1e8, cost.bytes


def test_collective_parse_shapes_and_groups():
    txt = """
HloModule m

ENTRY %main (a: f32[1024]) -> f32[1024] {
  %a = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%a), replica_groups=[4,8]<=[32], to_apply=%add
  %ag = bf16[64,128]{1,0} all-gather(%ar), replica_groups=[2,16]<=[32], dimensions={0}
  ROOT %cp = f32[1024]{0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    cost = analyze_text(txt)
    assert cost.coll_counts == {"all-reduce": 1, "all-gather": 1,
                                "collective-permute": 1}
    ar = 2 * 1024 * 4 * (7 / 8)
    ag = 64 * 128 * 2 * (15 / 16)
    cp = 1024 * 4
    assert cost.coll_link_bytes["all-reduce"] == pytest.approx(ar)
    assert cost.coll_link_bytes["all-gather"] == pytest.approx(ag)
    assert cost.coll_link_bytes["collective-permute"] == pytest.approx(cp)


def test_collective_inside_while_multiplied():
    txt = """
HloModule m

%cond (s: (s32[], f32[8])) -> pred[] {
  %s = (s32[], f32[8]{0}) parameter(0)
  %i = s32[] get-tuple-element(%s), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (s: (s32[], f32[8])) -> (s32[], f32[8]) {
  %s = (s32[], f32[8]{0}) parameter(0)
  %x = f32[8]{0} get-tuple-element(%s), index=1
  %ar = f32[8]{0} all-reduce(%x), replica_groups=[1,4]<=[4], to_apply=%add
  %i = s32[] get-tuple-element(%s), index=0
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8]{0}) tuple(%ip, %ar)
}

ENTRY %main (x: f32[8]) -> f32[8] {
  %x = f32[8]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8]{0}) tuple(%z, %x)
  %w = (s32[], f32[8]{0}) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[8]{0} get-tuple-element(%w), index=1
}
"""
    cost = analyze_text(txt)
    assert cost.coll_counts["all-reduce"] == 12
    assert cost.coll_link_bytes["all-reduce"] == pytest.approx(
        12 * 2 * 32 * (3 / 4))


def test_report_three_terms_and_dominant():
    rep = RooflineReport(
        arch="a", shape="s", mesh="m", kind="train", n_devices=256,
        hlo_flops=1.97e12, hlo_bytes=8.19e10, collective_link_bytes=5e9,
        peak_hbm_bytes=8e9, model_flops_global=1.97e12 * 256 * 0.5,
    ).finalize()
    assert rep.t_compute == pytest.approx(0.01)        # 1.97e12/197e12
    assert rep.t_memory == pytest.approx(0.1)          # 8.19e10/819e9
    assert rep.t_collective == pytest.approx(0.1)      # 5e9/50e9
    assert rep.dominant in ("memory", "collective")
    assert rep.flops_ratio == pytest.approx(0.5)


def test_model_flops_active_params():
    from repro.configs import get_arch
    from repro.models.model import build_model
    from repro.roofline.analysis import count_params, model_flops

    arch = get_arch("deepseek-moe-16b")
    model = build_model(arch.config)
    n_total = count_params(model)
    n_active = count_params(model, active_only=True)
    assert n_total > 15e9                  # ~16B total sans embeddings
    assert 2e9 < n_active < 4e9            # ~2.8B active
    mf_train = model_flops(model, "train", 4096, 256)
    assert mf_train == pytest.approx(6 * n_active * 4096 * 256)
    mf_dec = model_flops(model, "decode", 32768, 128)
    assert mf_dec == pytest.approx(2 * n_active * 128)
