"""Online (streaming) near-duplicate detection.

    PYTHONPATH=src python examples/streaming_dedup.py

The serve-path version of ``examples/dedup_pipeline.py``: documents
arrive in micro-batches (a crawl frontier, an ingestion queue), each
batch's MinHash-LSH collisions stream into the incremental connectivity
engine (``repro.connectivity.StreamingConnectivity``), and duplicate
membership is queryable after every batch — no per-batch re-solve, work
tracks the newly arrived pairs rather than the accumulated graph.

Ends by cross-checking the streamed clusters against the one-shot batch
pass over the same corpus: bit-identical labels.
"""
import time

import numpy as np

from repro.data.dedup import StreamingDedup, minhash_dedup
from repro.data.pipeline import make_corpus


def main():
    n_docs, batch_size = 600, 50
    docs = make_corpus(n_docs=n_docs, doc_len=200, vocab_size=1500,
                       dup_fraction=0.35, near_dup_noise=0.04, seed=13)
    print(f"corpus: {n_docs} docs arriving in batches of {batch_size}, "
          f"~35% planted near-duplicates\n")

    sd = StreamingDedup(n_hashes=64, bands=16)
    t0 = time.perf_counter()
    for pos in range(0, n_docs, batch_size):
        batch = docs[pos:pos + batch_size]
        ids = sd.add_docs(batch)
        dupes = sum(sd.is_duplicate(int(i)) for i in ids)
        report = sd.report()
        print(f"batch {pos // batch_size:2d}: +{len(batch)} docs "
              f"({dupes:2d} immediate duplicates)  "
              f"running: {report.n_clusters:3d} clusters / "
              f"{sd.n_docs:3d} docs, {sd.n_candidate_pairs} LSH pairs")
    dt = time.perf_counter() - t0

    snap = sd.report()
    engine_work = float(np.asarray(sd.engine.snapshot().edges_visited))
    print(f"\nstreamed {n_docs} docs in {dt:.2f}s: "
          f"{snap.n_clusters} clusters, "
          f"{int((~snap.keep).sum())} duplicates dropped, "
          f"{engine_work:.0f} edges swept total")

    batch_report = minhash_dedup(docs, n_hashes=64, bands=16)
    identical = bool((snap.labels == batch_report.labels).all())
    print(f"one-shot batch pass agrees bit-identically: {identical}")
    assert identical


if __name__ == "__main__":
    main()
