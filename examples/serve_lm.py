"""Batched serving demo: continuous batching over prefill/decode.

    PYTHONPATH=src python examples/serve_lm.py [--arch yi-6b]

Serves a reduced-config model (any of the 10 assigned architectures) with
the slot-based continuous-batching server — the same prefill/decode
surface the decode_32k / long_500k dry-run cells lower for the production
mesh.
"""
import argparse
import time

import numpy as np

from repro.configs import ARCHS, get_arch
from repro.launch.serve import BatchedServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    config = get_arch(args.arch).smoke_config()
    print(f"serving reduced {args.arch} "
          f"({config.n_layers}L d={config.d_model}) with 2 slots")
    server = BatchedServer(config, n_slots=2,
                           max_len=args.prompt_len + args.max_new + 4)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        0, config.vocab_size,
                        rng.integers(4, args.prompt_len + 1)
                    ).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    out = server.serve(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(f"served {len(reqs)} ragged requests -> {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s incl. compile)")
    for rid, toks in sorted(out.items()):
        print(f"  req {rid} ({len(reqs[rid].prompt):2d}-token prompt): "
              f"{toks}")


if __name__ == "__main__":
    main()
