"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python examples/train_lm.py                 # ~8M demo
    PYTHONPATH=src python examples/train_lm.py --preset 100m   # ~110M model

Trains a GQA transformer on the deterministic synthetic pipeline for a few
hundred steps with periodic atomic checkpoints, *injects a crash* two
thirds of the way through, restarts from the latest checkpoint, and
verifies the recovered run continues exactly (the paper-adjacent
fault-tolerance story: seekable data + atomic checkpoints => restart-exact
training).
"""
import argparse
import shutil
import tempfile

from repro.launch.train import train_loop
from repro.models.common import ModelConfig
from repro.optim.adamw import OptConfig

PRESETS = {
    # ~8M params: fast on 1 CPU core
    "demo": ModelConfig(
        name="demo-8m", family="dense", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=1024, vocab_size=2048, vocab_pad_multiple=128,
        remat="none"),
    # ~110M params (GPT-2-small class), the assignment's "~100M" driver
    "100m": ModelConfig(
        name="train-110m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=3072, vocab_size=32_000,
        remat="none"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    config = PRESETS[args.preset]
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    opt = OptConfig(peak_lr=3e-4, warmup_steps=max(args.steps // 20, 1),
                    decay_steps=args.steps)
    crash_at = 2 * args.steps // 3

    print(f"== training {config.name} for {args.steps} steps "
          f"(crash injected at step {crash_at}) ==")

    class Crash(Exception):
        pass

    def crasher(k, state, metrics):
        if k == crash_at:
            raise Crash

    try:
        train_loop(config, steps=args.steps, batch=args.batch, seq=args.seq,
                   ckpt_dir=ckpt, checkpoint_every=25, opt=opt,
                   log_every=20, on_step=crasher)
        crashed = False
    except Crash:
        crashed = True
        print(f"\n!! simulated node failure at step {crash_at} — "
              "restarting from the latest checkpoint\n")

    out = train_loop(config, steps=args.steps, batch=args.batch,
                     seq=args.seq, ckpt_dir=ckpt, checkpoint_every=25,
                     opt=opt, log_every=20)
    print(f"\ncrashed={crashed} resumed_and_ran={out['steps_run']} steps, "
          f"final loss {out['last_loss']:.4f} "
          f"(first loss this run {out['first_loss']:.4f})")
    if args.ckpt_dir is None:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
