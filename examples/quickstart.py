"""Quickstart: connected components with the Contour algorithm.

    PYTHONPATH=src python examples/quickstart.py

Builds a few graphs, runs every Contour variant plus the FastSV /
ConnectIt baselines through the public API, and prints labels, iteration
counts and timings.
"""
import time

import numpy as np

from repro.core import contour, fastsv, label_propagation
from repro.core.contour import VARIANTS, connected_components
from repro.core.unionfind import rem_union_find
from repro.graphs import generators as gen
from repro.graphs.structs import Graph


def main():
    # -- 1. tiny hand-made graph -------------------------------------------
    #   0-1-2   3-4   5 (isolated)
    g = Graph.from_numpy(np.array([0, 1, 3]), np.array([1, 2, 4]), 6)
    labels = np.asarray(connected_components(g))
    print("tiny graph labels:", labels.tolist())   # [0,0,0,3,3,5]

    # -- 2. variants on a long-diameter graph ------------------------------
    path = gen.path(100_000, seed=0)
    print(f"\npath graph: n={path.n_vertices:,} m={path.n_edges:,} "
          "(diameter ~1e5 — label propagation would need ~1e5 iterations)")
    for variant in VARIANTS:
        if variant == "C-1":
            print(f"  {variant:7s}: skipped here (O(d) iterations on a "
                  "path — that is the point of the paper)")
            continue
        t0 = time.perf_counter()
        labels, iters = contour(path, variant=variant)
        labels.block_until_ready()
        dt = time.perf_counter() - t0
        print(f"  {variant:7s}: {int(iters):3d} iterations, {dt*1e3:7.1f} ms")

    # -- 3. baselines -------------------------------------------------------
    rmat = gen.rmat(14, seed=1)
    print(f"\nrmat graph: n={rmat.n_vertices:,} m={rmat.n_edges:,}")
    t0 = time.perf_counter()
    _, it = contour(rmat, variant="C-2")
    print(f"  Contour C-2 : {int(it)} iterations, "
          f"{(time.perf_counter()-t0)*1e3:6.1f} ms")
    t0 = time.perf_counter()
    _, it = fastsv(rmat)
    print(f"  FastSV      : {int(it)} iterations, "
          f"{(time.perf_counter()-t0)*1e3:6.1f} ms")
    t0 = time.perf_counter()
    rem_union_find(*rmat.to_numpy())
    print(f"  ConnectIt   : 1 pass,        "
          f"{(time.perf_counter()-t0)*1e3:6.1f} ms (host union-find)")
    t0 = time.perf_counter()
    _, it = label_propagation(rmat)
    print(f"  LabelProp   : {int(it)} iterations, "
          f"{(time.perf_counter()-t0)*1e3:6.1f} ms")


if __name__ == "__main__":
    main()
