"""Quickstart: connected components through the unified solve() API.

    PYTHONPATH=src python examples/quickstart.py

One facade covers every algorithm family: all Contour variants, FastSV,
label propagation and the host-side ConnectIt stand-in run through
``repro.solve`` with typed options and a typed result — then the demo
warm-starts an incremental solve after adding edges, and batch-solves a
fleet of graphs in one vmapped program.
"""
import time

import numpy as np

from repro import Graph, SolveOptions, list_solvers, solve, solve_batch
from repro.connectivity import VARIANTS
from repro.graphs import generators as gen


def main():
    # -- 1. tiny hand-made graph -------------------------------------------
    #   0-1-2   3-4   5 (isolated)
    g = Graph.from_numpy(np.array([0, 1, 3]), np.array([1, 2, 4]), 6)
    result = solve(g)
    print("tiny graph labels:", np.asarray(result.labels).tolist())  # [0,0,0,3,3,5]
    print(f"  {result.n_components} components, sizes "
          f"{result.component_sizes().tolist()}, "
          f"same_component(0, 2)={result.same_component(0, 2)}")

    # -- 2. variants on a long-diameter graph ------------------------------
    path = gen.path(100_000, seed=0)
    print(f"\npath graph: n={path.n_vertices:,} m={path.n_edges:,} "
          "(diameter ~1e5 — label propagation would need ~1e5 iterations)")
    for variant in VARIANTS:
        if variant == "C-1":
            print(f"  {variant:7s}: skipped here (O(d) iterations on a "
                  "path — that is the point of the paper)")
            continue
        t0 = time.perf_counter()
        r = solve(path, variant=variant)
        dt = time.perf_counter() - t0
        print(f"  {variant:7s}: {int(r.iterations):3d} iterations, "
              f"{dt*1e3:7.1f} ms, converged={bool(r.converged)}")

    # -- 3. every registered solver family, one signature -------------------
    rmat = gen.rmat(14, seed=1)
    print(f"\nrmat graph: n={rmat.n_vertices:,} m={rmat.n_edges:,} — "
          f"registered solvers: {', '.join(list_solvers())}")
    for algorithm in ("contour", "fastsv", "label_propagation", "union_find"):
        t0 = time.perf_counter()
        r = solve(rmat, SolveOptions(algorithm=algorithm))
        dt = time.perf_counter() - t0
        print(f"  {algorithm:17s}: {int(r.iterations):3d} iterations, "
              f"{dt*1e3:6.1f} ms, {r.n_components} components")

    # -- 4. warm-start / incremental solving --------------------------------
    base = gen.components_mix(
        [gen.path(30_000, seed=2), gen.rmat(13, seed=3)], seed=4)
    r0 = solve(base)
    # connect the two halves with a handful of new edges
    rng = np.random.default_rng(5)
    grown = base.add_edges(rng.integers(0, 30_000, 4),
                           rng.integers(30_000, base.n_vertices, 4))
    r1 = solve(grown, warm_start=r0)
    print(f"\nincremental: {r0.n_components} components "
          f"-> {r1.n_components} after add_edges; "
          f"warm-started solve took {int(r1.iterations)} iterations "
          f"(cold start: {int(solve(grown).iterations)})")

    # -- 5. batched multi-graph solving -------------------------------------
    fleet = [gen.rmat(10, seed=s) for s in range(8)]
    t0 = time.perf_counter()
    batch = solve_batch(fleet)
    dt = time.perf_counter() - t0
    comps = [r.n_components for r in batch.unstack()]
    print(f"\nbatched: {len(fleet)} rmat graphs in one vmapped solve "
          f"({dt*1e3:.1f} ms): components per graph {comps}")

    # -- 6. work-adaptive frontier contraction (DESIGN.md §10) --------------
    ra = solve(grown, sampling=2, compact_every=2)
    assert np.array_equal(np.asarray(ra.labels), np.asarray(solve(grown).labels))
    dense = int(ra.iterations) * grown.n_edges
    print(f"\nfrontier: sampled+compacted C-2 visited "
          f"{int(ra.edges_visited):,} edges vs {dense:,} dense "
          f"({1 - float(ra.edges_visited)/dense:.0%} less), "
          "labels bit-identical")


if __name__ == "__main__":
    main()
