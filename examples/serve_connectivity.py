"""Connectivity-as-a-service demo: concurrent clients, one engine.

Spins up a :class:`ConnectivityEngine` (single-writer event loop over a
``StreamingConnectivity``), then hits it from several query threads
while an ingest thread streams edges in — showing coalesced batched
answers, read-your-writes after an ingest ack, backpressure retries,
deadlines/cancellation, and the metrics the engine records.

Run:
  PYTHONPATH=src python examples/serve_connectivity.py
"""
from __future__ import annotations

import threading

import numpy as np

from repro.serving import ConnectivityClient, ConnectivityEngine

N = 10_000
RING_CHUNKS = 8          # ingest connects N/RING_CHUNKS-sized chains


def main():
    rng = np.random.default_rng(0)
    with ConnectivityEngine(N, max_pending_queries=4096) as engine:
        client = ConnectivityClient(engine)

        # -- ingest thread: stream chain edges in chunks --------------------
        def ingest():
            step = N // RING_CHUNKS
            for lo in range(0, N - step, step):
                src = np.arange(lo, lo + step - 1)
                ack = client.ingest(src, src + 1)
                print(f"  ingest ack: batch {ack.batch_index}, "
                      f"{ack.n_edges} total edges, visibility lag "
                      f"{ack.visibility_lag_s * 1e3:.1f} ms")

        # -- query threads: hammer the read path ----------------------------
        # the client retries through QueueFull backpressure with the
        # engine's suggested retry_after sleeps
        def query(seed: int, hits: list):
            r = np.random.default_rng(seed)
            futs = [client.same_component_async(int(r.integers(N)),
                                                int(r.integers(N)))
                    for _ in range(2_000)]
            hits.append(sum(f.result() for f in futs))

        hits: list = []
        threads = [threading.Thread(target=ingest)] + [
            threading.Thread(target=query, args=(s, hits)) for s in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        engine.flush()

        # -- read-your-writes: acked edges are immediately queryable --------
        assert client.same_component(0, N // RING_CHUNKS - 2)
        print(f"connected(0, {N // RING_CHUNKS - 2}) -> True "
              "(read-your-writes after ack)")
        print(f"n_components = {client.n_components()}")
        print(f"random-pair hits per thread: {hits}")

        # -- out-of-range ids are rejected, not clamped ---------------------
        try:
            client.component_of(N + 5)
        except IndexError as e:
            print(f"component_of({N + 5}) -> IndexError: {e}")

        m = engine.metrics.summary()
        print(f"answered {m['counters']['queries_answered']} queries in "
              f"{m['counters']['query_batches']} coalesced batches; "
              f"p50 latency {m['latency_ms']['p50']:.2f} ms, "
              f"batch-size histogram {m['batch_size_hist']}")


if __name__ == "__main__":
    main()
