"""Production integration of the paper: corpus near-dup removal.

    PYTHONPATH=src python examples/dedup_pipeline.py

Builds a synthetic corpus with planted near-duplicate clusters, runs the
MinHash-LSH -> similarity-graph -> Contour connected-components pipeline
(DESIGN.md §2: the CC step is where RefinedWeb/SlimPajama-scale dedup
needs a scalable parallel algorithm), and reports recovered clusters +
which Contour variant converged fastest.
"""
import time

import numpy as np

from repro.data.dedup import minhash_dedup
from repro.data.pipeline import make_corpus


def main():
    n_docs = 800
    docs = make_corpus(n_docs=n_docs, doc_len=250, vocab_size=2000,
                       dup_fraction=0.35, near_dup_noise=0.04, seed=13)
    print(f"corpus: {n_docs} docs, ~35% planted near-duplicates\n")

    for variant in ("C-1", "C-2", "C-m"):
        t0 = time.perf_counter()
        report = minhash_dedup(docs, n_hashes=64, bands=16, variant=variant)
        dt = time.perf_counter() - t0
        print(f"variant {variant:4s}: {report.n_clusters:4d} clusters "
              f"({int(report.keep.sum())} docs kept), "
              f"{report.n_candidate_pairs} LSH pairs, "
              f"CC converged in {report.cc_iterations} iterations, "
              f"total {dt:.2f}s")

    report = minhash_dedup(docs, n_hashes=64, bands=16)
    sizes = np.bincount(report.labels)
    sizes = np.sort(sizes[sizes > 0])[::-1]
    print(f"\nlargest duplicate clusters: {sizes[:8].tolist()}")
    print(f"kept representative = min doc id per cluster "
          f"(Contour's min-label fixed point): "
          f"{np.flatnonzero(report.keep)[:8].tolist()} ...")


if __name__ == "__main__":
    main()
