"""Chaos demo: a streaming solve that survives crashes and stragglers.

    PYTHONPATH=src python examples/chaos_streaming.py

Streams a graph's edges in micro-batches through the crash-restart
driver (``stream_with_recovery``, DESIGN.md §12) while a
``FaultInjector`` kills ingest batches — including one *after* its
ring-buffer write but before the commit — and a ``StragglerMonitor``
flags a persistently slow batch, forcing an out-of-cadence checkpoint.
Recovery is bit-exact: the final labels match both a fault-free stream
and the one-shot ``solve()`` over the same edges.  Then the same graph
is solved on a distributed mesh that loses a shard mid-solve and
elastically shrinks.
"""
import os
import tempfile
import time

# a demo-sized multi-device "cluster" (must precede any jax import)
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.connectivity import (FaultInjector, SolveOptions, solve,
                                resilient_distributed_contour,
                                stream_with_recovery)
from repro.graphs import generators as gen
from repro.runtime.straggler import StragglerMonitor


def main():
    g = gen.components_mix([gen.path(30_000, seed=1),
                            gen.rmat(13, seed=2)], seed=3)
    src, dst, n = g.to_numpy()
    print(f"graph: n={n:,} m={len(src):,}")

    n_batches = 32
    perm = np.random.default_rng(0).permutation(len(src))
    src, dst = src[perm], dst[perm]
    batches = [(src[b * len(src) // n_batches:
                    (b + 1) * len(src) // n_batches],
                dst[b * len(src) // n_batches:
                    (b + 1) * len(src) // n_batches])
               for b in range(n_batches)]
    oracle = np.asarray(solve(g, SolveOptions(backend="xla")).labels)

    # -- 1. crash-riddled stream ------------------------------------------
    # kill batch 5 (before any work), batch 13 *after* its ring write but
    # before the commit, and batch 21 — three process crashes
    injector = FaultInjector(fail_at=(5, (13, "post_write"), (21, "pre")))
    monitor = StragglerMonitor(threshold=2.0, evict_after=3)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        manager = CheckpointManager(ckpt_dir, keep=3, async_save=False)
        t0 = time.perf_counter()
        eng, stats = stream_with_recovery(
            batches, n, manager, SolveOptions(backend="xla"),
            checkpoint_every=8, fault_injector=injector, straggler=monitor,
            on_event=lambda ev, b: print(f"  [event] {ev} at batch {b}"))
        dt = time.perf_counter() - t0

    labels = np.asarray(eng.snapshot().labels)
    print(f"\nstreamed {n_batches} batches in {dt:.2f}s surviving "
          f"{stats['restarts']} crashes:")
    print(f"  checkpoints written : {stats['checkpoints']}")
    print(f"  batches replayed    : {stats['replayed_batches']}")
    print(f"  straggler events    : {stats['straggler_events']}")
    print(f"  labels == one-shot solve: {bool((labels == oracle).all())}")
    print(f"  converged: {bool(eng.snapshot().converged)}")

    # -- 2. elastic shrink on shard loss ----------------------------------
    import jax
    from repro.runtime.recovery import ShardLossFault
    injector = FaultInjector(fail_at=((1, "round"),),
                             exc_factory=lambda s, site: ShardLossFault(1))
    res, rstats = resilient_distributed_contour(
        g, options=SolveOptions(backend="xla"), block_rounds=4,
        fault_injector=injector,
        on_event=lambda ev, blk: print(f"  [event] {ev} at block {blk}"))
    print(f"\ndistributed solve on {len(jax.devices())} shards lost one "
          "mid-solve:")
    print(f"  mesh history : {rstats['mesh_history']}")
    print(f"  provenance   : {res.provenance}")
    print(f"  labels == one-shot solve: "
          f"{bool((np.asarray(res.labels) == oracle).all())}")
    print(f"  converged: {bool(res.converged)}")


if __name__ == "__main__":
    main()
