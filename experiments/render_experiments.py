"""Insert the rendered dry-run/roofline tables into EXPERIMENTS.md.

Replaces the <!-- DRYRUN_TABLE --> and <!-- ROOFLINE_TABLE --> markers
(idempotent: regenerating overwrites the previous render between marker
fences).

Usage: PYTHONPATH=src python experiments/render_experiments.py
"""
import os
import re
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(HERE, ".."))

from benchmarks.roofline_report import load, render, summarize  # noqa: E402

EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")


def splice(text: str, marker: str, payload: str) -> str:
    fence_start = f"<!-- {marker} -->"
    fence_end = f"<!-- /{marker} -->"
    block = f"{fence_start}\n```\n{payload}\n```\n{fence_end}"
    if fence_end in text:
        pat = re.compile(re.escape(fence_start) + r".*?" + re.escape(fence_end),
                         re.S)
        return pat.sub(block, text)
    return text.replace(fence_start, block)


def main():
    rows = load()
    if not rows:
        raise SystemExit("no dry-run JSONs; run the dry-run first")
    with open(EXP) as f:
        text = f.read()

    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    dryrun_summary = [
        f"cells: ok={len(ok)} skipped={len(skipped)} "
        f"error={sum(r['status'] == 'error' for r in rows)}",
        "",
        "per-device peak memory (arguments + temp - aliased), GB, by cell:",
    ]
    for r in ok:
        m = r["memory"]
        peak = (m["argument_bytes"] + m["temp_bytes"] - m["alias_bytes"]) / 2**30
        flag = "  (!)" if peak > 16 else ""
        dryrun_summary.append(
            f"  {r['arch']:<24} {r['shape']:<12} {r['mesh']:<11} "
            f"{peak:7.2f}{flag}")
    dryrun_summary.append("")
    dryrun_summary.append("(!) = exceeds a 16 GB v5e chip under CPU XLA's "
                          "buffer assignment — causes analysed in §Roofline")
    text = splice(text, "DRYRUN_TABLE", "\n".join(dryrun_summary))

    roof = render(rows) + "\n\n" + summarize(rows)
    text = splice(text, "ROOFLINE_TABLE", roof)

    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated "
          f"({len(ok)} ok cells, {len(skipped)} skips rendered)")


if __name__ == "__main__":
    main()
