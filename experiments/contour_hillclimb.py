import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (must precede any jax import — production mesh needs 512 placeholders)

"""§Perf hillclimb (b): contour-cc on the production meshes.

Lowers the paper-faithful distributed Contour solve and the beyond-paper
variants against the 2^28-vertex / 2^31-edge graph, and reports the
three roofline terms *per solve*:

  base      local_rounds=1, check_every=1, max_iters=8   (paper Alg.1+§III-B)
  lr2       local_rounds=2, check_every=1, max_iters=5   (stale local merges)
  lr2+ce2   local_rounds=2, check_every=2, max_iters=5
  lr4+ce2   local_rounds=4, check_every=2, max_iters=4

max_iters per variant = measured convergence rounds on representative
8-way-sharded graphs (benchmarks/distributed_scaling.py): path-class
diameters converge in 13/8/8/6 rounds at lr=1/2/2/4 scaled to the
Theorem-1 budget for the dry-run graph (8/5/5/4).

Usage: PYTHONPATH=src python experiments/contour_hillclimb.py [--mesh multi]
"""
import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.connectivity.distributed import distributed_contour_step_fn
from repro.launch.dryrun import CONTOUR_N_EDGES, CONTOUR_N_VERTICES
from repro.launch.mesh import make_production_mesh
from repro.roofline import analyze_compiled

VARIANTS = [
    ("base_lr1_ce1", dict(local_rounds=1, check_every=1), 8),
    ("lr2_ce1", dict(local_rounds=2, check_every=1), 5),
    ("lr2_ce2", dict(local_rounds=2, check_every=2), 5),
    ("lr4_ce2", dict(local_rounds=4, check_every=2), 4),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out",
                    default=os.path.join(os.path.dirname(__file__),
                                         "contour_hillclimb.json"))
    args = ap.parse_args()
    multi = args.mesh == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    mesh_name = "pod2x16x16" if multi else "pod1x16x16"
    edge_axes = ("pod", "data") if multi else ("data",)
    spec = P(edge_axes if len(edge_axes) > 1 else edge_axes[0])
    shard = NamedSharding(mesh, spec)
    sds = jax.ShapeDtypeStruct((CONTOUR_N_EDGES,), jnp.int32)

    results = []
    for name, kw, iters in VARIANTS:
        fn = lambda s, d: distributed_contour_step_fn(
            s, d, CONTOUR_N_VERTICES, mesh, edge_axes=edge_axes,
            max_iters=iters, **kw)
        compiled = jax.jit(fn, in_shardings=(shard, shard)).lower(
            sds, sds).compile()
        rep = analyze_compiled(
            compiled, arch="contour-cc", shape=f"graph_2e31[{name}]",
            mesh_name=mesh_name, kind="contour", n_devices=mesh.size,
            note=f"{kw}, {iters} rounds/solve")
        print(f"{name:14s} rounds={iters}  "
              f"t_mem={rep.t_memory*1e3:8.1f}ms  "
              f"t_coll={rep.t_collective*1e3:8.1f}ms  "
              f"coll_GB/dev={rep.collective_link_bytes/2**30:6.2f}  "
              f"dominant={rep.dominant}")
        results.append({"variant": name, "mesh": mesh_name,
                        "rounds": iters, **rep.to_dict()})
    prev = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            prev = json.load(f)
    with open(args.out, "w") as f:
        json.dump(prev + results, f, indent=1, default=str)


if __name__ == "__main__":
    main()
