"""§Roofline table generator: reads experiments/dryrun/*.json, renders the
per-(arch x shape x mesh) three-term roofline table with dominant-term
analysis and one-line improvement notes."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

HERE = os.path.dirname(os.path.abspath(__file__))
DRYRUN_DIR = os.path.join(HERE, "..", "experiments", "dryrun")

IMPROVEMENT_NOTE = {
    # dominant term -> what moves it down
    "compute": ("already compute-limited: raise MXU utilisation "
                "(larger per-chip tiles, bf16 everywhere, fewer relayouts)"),
    "memory": ("cut HBM round-trips: fuse norm/residual chains (Pallas "
               "fused_rmsnorm / flash kernels on TPU), raise remat "
               "selectivity so recompute stops re-reading weights"),
    "collective": ("cut wire bytes: bf16 collectives, overlap via async "
                   "collectives + 2x local compute per exchange; for MoE "
                   "swap GSPMD gather/AR patterns for explicit "
                   "shard_map all-to-all"),
}


def load(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def render(rows: List[Dict], markdown: bool = False) -> str:
    sep = "|" if markdown else " "
    hdr = (f"{'arch':<24}{sep}{'shape':<12}{sep}{'mesh':<11}{sep}"
           f"{'t_comp_ms':>10}{sep}{'t_mem_ms':>10}{sep}{'t_coll_ms':>10}"
           f"{sep}{'dominant':>10}{sep}{'useful':>7}{sep}{'peak_GB':>8}")
    lines = [hdr]
    if markdown:
        lines.append("|".join(["---"] * 9))
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"{r['arch']:<24}{sep}{r['shape']:<12}{sep}{r['mesh']:<11}"
                f"{sep}{'skip: ' + r['reason'][:48]}")
            continue
        if r["status"] != "ok":
            lines.append(f"{r['arch']:<24}{sep}{r['shape']:<12}"
                         f"{sep}{r['mesh']:<11}{sep}ERROR")
            continue
        rf = r["roofline"]
        lines.append(
            f"{r['arch']:<24}{sep}{r['shape']:<12}{sep}{r['mesh']:<11}{sep}"
            f"{rf['t_compute'] * 1e3:>10.1f}{sep}"
            f"{rf['t_memory'] * 1e3:>10.1f}{sep}"
            f"{rf['t_collective'] * 1e3:>10.1f}{sep}"
            f"{rf['dominant']:>10}{sep}"
            f"{rf['flops_ratio']:>7.2f}{sep}"
            f"{r['memory']['peak_bytes'] / 2**30:>8.2f}")
    return "\n".join(lines)


def summarize(rows: List[Dict]) -> str:
    ok = [r for r in rows if r["status"] == "ok"]
    doms: Dict[str, int] = {}
    worst = []
    for r in ok:
        rf = r["roofline"]
        doms[rf["dominant"]] = doms.get(rf["dominant"], 0) + 1
        total = rf["t_compute"] + rf["t_memory"] + rf["t_collective"]
        frac = rf["t_compute"] / total if total else 0
        worst.append((frac, r["arch"], r["shape"], r["mesh"],
                      rf["dominant"]))
    worst.sort()
    out = [f"cells ok={len(ok)} "
           f"skipped={sum(r['status'] == 'skipped' for r in rows)} "
           f"error={sum(r['status'] == 'error' for r in rows)}",
           f"dominant-term counts: {doms}",
           "worst roofline fraction (compute/total):"]
    for frac, a, s, m, d in worst[:5]:
        out.append(f"  {frac:6.3f}  {a} {s} {m}  [{d}-bound] "
                   f"-> {IMPROVEMENT_NOTE[d][:60]}...")
    return "\n".join(out)


def main(fast: bool = False):
    rows = load()
    if not rows:
        print("no dry-run records found; run `python -m repro.launch.dryrun "
              "--all` first")
        return
    print(render(rows))
    print()
    print(summarize(rows))


if __name__ == "__main__":
    main()
