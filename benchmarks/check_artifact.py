"""Sanity-gate a ``BENCH_connectivity.json`` artifact.

Run in CI (and locally after ``python -m benchmarks.run``) so the
committed perf artifact cannot silently rot::

    python benchmarks/check_artifact.py [BENCH_connectivity.json]

Fails (exit 1) when:

* ``summary.all_correct`` is false — some method diverged from the
  connectivity oracle;
* ``summary.blocked_path_hlo_identical`` regressed — off-TPU the blocked
  kernel path must lower to the exact same program as the XLA C-2 path
  (the noise-free form of the "no slower" gate, DESIGN.md §6);
* the frontier gate regressed — the work-adaptive ``C-2-cmp`` schedule
  must visit strictly fewer edges than dense ``iterations × m`` on every
  suite graph while reaching a bit-identical fixed point (DESIGN.md §10);
* the streaming gate regressed (schema 3) — a 64-micro-batch shuffled
  stream through ``StreamingConnectivity`` must land bit-identical to the
  one-shot solve with cumulative ``edges_visited`` under 2x the dense
  sweep on every suite graph (DESIGN.md §11);
* the recovery gate regressed (schema 4) — a stream surviving two
  injected crashes (restore + replay through the crash-restart driver)
  must land bit-identical to the fault-free stream with cumulative
  ``edges_visited`` under 2x the clean run (DESIGN.md §12).

Stdlib-only on purpose: the gate must run before (or without) the package
environment, e.g. as a bare CI step.
"""
from __future__ import annotations

import json
import sys


def check(payload: dict) -> list:
    """Return a list of gate-violation messages (empty = artifact sane)."""
    errors = []
    summary = payload.get("summary", {})
    if not summary:
        return ["artifact has no summary section"]
    if not summary.get("all_correct", False):
        bad = [f"{r['graph']}/{r['method']}"
               for r in payload.get("records", []) if not r.get("correct")]
        errors.append(f"summary.all_correct is false (bad rows: {bad})")
    if "blocked_path_hlo_identical" in summary and \
            not summary["blocked_path_hlo_identical"]:
        errors.append(
            "blocked_path_hlo_identical regressed: the dispatched kernel "
            "path no longer lowers to the XLA C-2 program off-TPU")
    for key in ("frontier_visits_fewer_edges", "frontier_bit_identical"):
        if key in summary and not summary[key]:
            # bit_identical None = not measured in that run, not a failure
            bad = [g for g, row in payload.get("frontier_gate", {}).items()
                   if not row.get("fewer_than_dense")
                   or row.get("bit_identical") is False]
            errors.append(f"{key} regressed (graphs: {bad})")
    if "frontier_visits_fewer_edges" not in summary and \
            int(payload.get("schema", 0)) >= 2:
        errors.append("schema >= 2 artifact is missing the frontier gate")
    for key, field in (("streaming_bit_identical", "bit_identical"),
                       ("streaming_visits_lt_2x_dense", "lt_2x_dense")):
        if key in summary and not summary[key]:
            bad = [g for g, row in payload.get("streaming_gate", {}).items()
                   if not row.get(field)]
            errors.append(f"{key} regressed (graphs: {bad})")
    if "streaming_bit_identical" not in summary and \
            int(payload.get("schema", 0)) >= 3:
        errors.append("schema >= 3 artifact is missing the streaming gate")
    for key, field in (("recovery_bit_identical", "bit_identical"),
                       ("recovery_work_lt_2x_clean", "lt_2x_clean")):
        if key in summary and not summary[key]:
            bad = [g for g, row in payload.get("recovery", {}).items()
                   if not row.get(field)]
            errors.append(f"{key} regressed (graphs: {bad})")
    if "recovery_bit_identical" not in summary and \
            int(payload.get("schema", 0)) >= 4:
        errors.append("schema >= 4 artifact is missing the recovery gate")
    return errors


def main(argv) -> int:
    path = argv[1] if len(argv) > 1 else "BENCH_connectivity.json"
    with open(path) as f:
        payload = json.load(f)
    errors = check(payload)
    if errors:
        for e in errors:
            print(f"ARTIFACT GATE FAILED: {e}", file=sys.stderr)
        return 1
    summary = payload["summary"]
    print(f"artifact gate ok: {path} "
          f"(schema {payload.get('schema')}, {summary.get('n_graphs')} "
          f"graphs, all_correct={summary.get('all_correct')}, "
          f"frontier_visits_fewer_edges="
          f"{summary.get('frontier_visits_fewer_edges')}, "
          f"streaming_bit_identical="
          f"{summary.get('streaming_bit_identical')}, "
          f"recovery_bit_identical="
          f"{summary.get('recovery_bit_identical')})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
