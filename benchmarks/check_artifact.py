"""Sanity-gate committed benchmark artifacts.

Run in CI (and locally after ``python -m benchmarks.run``) so the
committed perf artifacts cannot silently rot::

    python benchmarks/check_artifact.py [BENCH_connectivity.json ...]

Each path dispatches on its ``artifact`` field: ``"connectivity"``
(default when absent) or ``"serving"`` (``BENCH_serving.json``).

Fails (exit 1) when:

* ``summary.all_correct`` is false — some method diverged from the
  connectivity oracle;
* ``summary.blocked_path_hlo_identical`` regressed — off-TPU the blocked
  kernel path must lower to the exact same program as the XLA C-2 path
  (the noise-free form of the "no slower" gate, DESIGN.md §6);
* the frontier gate regressed — the work-adaptive ``C-2-cmp`` schedule
  must visit strictly fewer edges than dense ``iterations × m`` on every
  suite graph while reaching a bit-identical fixed point (DESIGN.md §10);
* the streaming gate regressed (schema 3) — a 64-micro-batch shuffled
  stream through ``StreamingConnectivity`` must land bit-identical to the
  one-shot solve with cumulative ``edges_visited`` under 2x the dense
  sweep on every suite graph (DESIGN.md §11);
* the recovery gate regressed (schema 4) — a stream surviving two
  injected crashes (restore + replay through the crash-restart driver)
  must land bit-identical to the fault-free stream with cumulative
  ``edges_visited`` under 2x the clean run (DESIGN.md §12);
* the wall-clock gates regressed (schema 5, DESIGN.md §14) — both
  re-derived here from the raw per-side seconds in the artifact, never
  trusted from the summary booleans:

  - ``frontier_wallclock_gate``: some frontier schedule (masked or
    physically staged) must beat the dense sweep's wall time
    (ratio < 1.0) on at least one (graph, schedule) pair;
  - ``autotune_gate``: the autotuned plan must be >= the heuristic
    prior at geomean over the suite (a row where the tuner kept the
    prior counts as exactly 1.0 — equal configs trace to the identical
    program);

* the out-of-core gate regressed (schema 6, DESIGN.md §15) — all three
  verdicts re-derived from the raw per-row numbers, never from summary
  booleans:

  - every chunk-streamed solve must be bit-identical to the in-core
    oracle;
  - the per-round surviving-edge chain ``n_edges -> s_0 -> s_1 -> ...``
    must strictly decrease at every link, with each round's ``edges_in``
    equal to the previous round's survivors;
  - some stress row with ``n_edges >= 4 * chunk_bucket`` must keep
    ``peak_bytes`` under ``8 * n_edges`` (the int32 edge-pair bytes the
    in-core path would materialise), and some row must take >= 2 rounds
    (the multi-round path is actually exercised);

* the strategy gate regressed (schema 7, DESIGN.md §16) — both verdicts
  re-derived from the raw per-side rows:

  - every sampling strategy *and* ``solver="auto"`` must land
    bit-identical to the dense oracle on every matrix graph;
  - auto's best-of-k wall clock must stay within 1.1x the best single
    fixed strategy at geomean across the matrix.

For serving artifacts, fails when:

* the SLO gate regressed — p50/p99 latency above threshold, throughput
  below the floor, or any request failed (DESIGN.md §13);
* a non-``fast`` artifact answered fewer than 1M queries;
* the recovery gate regressed — the crash-restarted engine lost an
  acknowledged ingest, produced labels that are not bit-identical to
  the clean run, or never actually restarted;
* the coalescer stopped coalescing — the batch-size histogram shows no
  batch beyond a single request.

Stdlib-only on purpose: the gate must run before (or without) the package
environment, e.g. as a bare CI step.
"""
from __future__ import annotations

import json
import math
import sys


def check(payload: dict) -> list:
    """Return a list of gate-violation messages (empty = artifact sane)."""
    errors = []
    summary = payload.get("summary", {})
    if not summary:
        return ["artifact has no summary section"]
    if not summary.get("all_correct", False):
        bad = [f"{r['graph']}/{r['method']}"
               for r in payload.get("records", []) if not r.get("correct")]
        errors.append(f"summary.all_correct is false (bad rows: {bad})")
    if "blocked_path_hlo_identical" in summary and \
            not summary["blocked_path_hlo_identical"]:
        errors.append(
            "blocked_path_hlo_identical regressed: the dispatched kernel "
            "path no longer lowers to the XLA C-2 program off-TPU")
    for key in ("frontier_visits_fewer_edges", "frontier_bit_identical"):
        if key in summary and not summary[key]:
            # bit_identical None = not measured in that run, not a failure
            bad = [g for g, row in payload.get("frontier_gate", {}).items()
                   if not row.get("fewer_than_dense")
                   or row.get("bit_identical") is False]
            errors.append(f"{key} regressed (graphs: {bad})")
    if "frontier_visits_fewer_edges" not in summary and \
            int(payload.get("schema", 0)) >= 2:
        errors.append("schema >= 2 artifact is missing the frontier gate")
    for key, field in (("streaming_bit_identical", "bit_identical"),
                       ("streaming_visits_lt_2x_dense", "lt_2x_dense")):
        if key in summary and not summary[key]:
            bad = [g for g, row in payload.get("streaming_gate", {}).items()
                   if not row.get(field)]
            errors.append(f"{key} regressed (graphs: {bad})")
    if "streaming_bit_identical" not in summary and \
            int(payload.get("schema", 0)) >= 3:
        errors.append("schema >= 3 artifact is missing the streaming gate")
    for key, field in (("recovery_bit_identical", "bit_identical"),
                       ("recovery_work_lt_2x_clean", "lt_2x_clean")):
        if key in summary and not summary[key]:
            bad = [g for g, row in payload.get("recovery", {}).items()
                   if not row.get(field)]
            errors.append(f"{key} regressed (graphs: {bad})")
    if "recovery_bit_identical" not in summary and \
            int(payload.get("schema", 0)) >= 4:
        errors.append("schema >= 4 artifact is missing the recovery gate")
    if int(payload.get("schema", 0)) >= 5:
        errors.extend(check_wallclock_gates(payload))
    if int(payload.get("schema", 0)) >= 6:
        errors.extend(check_oocore_gate(payload))
    if int(payload.get("schema", 0)) >= 7:
        errors.extend(check_strategy_gate(payload))
    return errors


# auto's allowed geomean overhead over the best single fixed strategy —
# mirrors benchmarks.connectivity.STRATEGY_AUTO_TOLERANCE (duplicated:
# this checker must stay stdlib-only / importable bare)
STRATEGY_AUTO_TOLERANCE = 1.1


def check_strategy_gate(payload: dict) -> list:
    """Re-derive the schema-7 strategy-matrix verdicts from raw rows.

    Both halves are recomputed from per-side data — bit-identity flags
    per (graph, strategy), and the auto-vs-best-fixed geomean from the
    raw per-round seconds — so a hand-edited summary cannot pass a
    failing artifact.
    """
    errors = []
    gate = payload.get("strategy_gate", {})
    if not gate:
        return ["schema >= 7 artifact is missing the strategy gate"]
    logs = []
    for name, row in gate.items():
        sides = row.get("sides", {})
        if not sides:
            errors.append(f"strategy row {name!r} recorded no sides")
            continue
        for side, d in sides.items():
            if d.get("bit_identical") is not True:
                errors.append(
                    f"strategy row {name!r} side {side!r} labels differ "
                    f"from the dense oracle")
        fixed = [min(d["seconds"]) for s, d in sides.items()
                 if s != "auto" and d.get("seconds")]
        auto = sides.get("auto", {}).get("seconds")
        if not fixed or not auto:
            errors.append(
                f"strategy row {name!r} has no raw timings to re-derive "
                f"the auto-vs-best-fixed ratio from")
            continue
        logs.append(math.log(min(auto) / min(fixed)))
    if logs:
        geomean = math.exp(sum(logs) / len(logs))
        if geomean > STRATEGY_AUTO_TOLERANCE:
            errors.append(
                f"strategy gate regressed: solver='auto' geomean wall "
                f"clock {geomean:.4f}x the best fixed strategy "
                f"(> {STRATEGY_AUTO_TOLERANCE}x)")
    return errors


# one int32 (src, dst) pair — mirrors repro.connectivity.oocore.EDGE_BYTES
# (duplicated: this checker must stay stdlib-only / importable bare)
OOCORE_EDGE_BYTES = 8


def check_oocore_gate(payload: dict) -> list:
    """Re-derive the schema-6 out-of-core verdicts from the raw rows.

    Equivalence, decay and the memory bound are each recomputed from the
    per-row numbers (``rounds`` chain, ``peak_bytes``, ``n_edges``,
    ``chunk_bucket``) so a hand-edited summary cannot pass a failing
    artifact.
    """
    errors = []
    oo = payload.get("oocore_gate", {})
    if not oo:
        return ["schema >= 6 artifact is missing the out-of-core gate"]
    stress_proven = False
    for name, row in oo.items():
        if row.get("bit_identical") is not True:
            errors.append(
                f"oocore row {name!r} labels differ from the in-core "
                f"oracle")
        m = int(row.get("n_edges", 0))
        rounds = row.get("rounds", [])
        if not rounds:
            errors.append(f"oocore row {name!r} recorded no rounds")
            continue
        expect_in = m
        for r in rounds:
            if r.get("edges_in") != expect_in:
                errors.append(
                    f"oocore row {name!r} round {r.get('round')}: "
                    f"edges_in={r.get('edges_in')} breaks the survivor "
                    f"chain (expected {expect_in})")
                break
            if not (r.get("survivors", m) < r.get("edges_in", 0)):
                errors.append(
                    f"oocore row {name!r} round {r.get('round')} did not "
                    f"strictly shrink: survivors={r.get('survivors')} >= "
                    f"edges_in={r.get('edges_in')}")
                break
            expect_in = r.get("survivors")
        if row.get("stress"):
            bucket = int(row.get("chunk_bucket", 0))
            peak = row.get("peak_bytes")
            if bucket <= 0 or m < 4 * bucket:
                errors.append(
                    f"oocore stress row {name!r} is not >= 4x the chunk "
                    f"budget (m={m}, bucket={bucket})")
            elif peak is None or peak >= OOCORE_EDGE_BYTES * m:
                errors.append(
                    f"oocore stress row {name!r}: peak_bytes={peak} not "
                    f"below total edge bytes {OOCORE_EDGE_BYTES * m}")
            else:
                stress_proven = True
    if not stress_proven:
        errors.append(
            "no oocore stress row proves peak device bytes < total edge "
            "bytes on a graph >= 4x the chunk budget")
    if not any(len(r.get("rounds", [])) >= 2 for r in oo.values()):
        errors.append(
            "no oocore row exercised a genuine multi-round contraction "
            "(>= 2 rounds)")
    return errors


def check_wallclock_gates(payload: dict) -> list:
    """Re-derive the schema-5 wall-clock verdicts from raw timings.

    The summary booleans are recomputed here from the per-graph seconds
    so a hand-edited summary cannot pass a failing artifact.
    """
    errors = []
    fw = payload.get("frontier_wallclock_gate", {})
    if not fw:
        errors.append(
            "schema >= 5 artifact is missing the frontier wall-clock gate")
    else:
        ratios = []
        for row in fw.values():
            dense = row.get("dense_s") or 0.0
            if dense <= 0:
                continue
            for side in ("masked_s", "staged_s"):
                if row.get(side):
                    ratios.append(row[side] / dense)
        if not ratios:
            errors.append("frontier wall-clock gate has no usable timings")
        elif min(ratios) >= 1.0:
            errors.append(
                f"frontier wall-clock gate regressed: no schedule beats "
                f"dense on any graph (best ratio {min(ratios):.3f} >= 1.0)")
    at = payload.get("autotune_gate", {})
    if not at:
        errors.append("schema >= 5 artifact is missing the autotune gate")
    else:
        logs = []
        for name, row in at.items():
            if not row.get("plan_differs"):
                logs.append(0.0)         # prior kept: identical program
                continue
            h, t = row.get("heuristic_s"), row.get("tuned_s")
            if not h or not t:
                errors.append(
                    f"autotune gate row {name!r} differs from the prior "
                    f"but has no raw timings to re-derive the ratio from")
                continue
            logs.append(math.log(h / t))
        if logs:
            geomean = math.exp(sum(logs) / len(logs))
            if geomean < 1.0 - 1e-9:
                errors.append(
                    f"autotune gate regressed: tuned-vs-heuristic geomean "
                    f"{geomean:.4f} < 1.0")
    return errors


def check_serving(payload: dict) -> list:
    """Gate a ``BENCH_serving.json`` artifact (empty list = sane)."""
    errors = []
    summary = payload.get("summary", {})
    slo = payload.get("slo", {})
    results = payload.get("results", {})
    recovery = payload.get("recovery", {})
    if not summary or not slo or not results:
        return ["serving artifact is missing summary/slo/results sections"]
    if not slo.get("passed", False):
        errors.append(
            f"serving SLO gate failed: p50={summary.get('p50_ms')}ms "
            f"(<= {slo.get('p50_ms')}), p99={summary.get('p99_ms')}ms "
            f"(<= {slo.get('p99_ms')}), qps={summary.get('throughput_qps')} "
            f"(>= {slo.get('min_qps')}), failures={results.get('failures')}")
    # re-derive instead of trusting the stored boolean
    lat = results.get("latency_ms", {})
    if lat.get("p50", 1e18) > slo.get("p50_ms", 0) or \
            lat.get("p99", 1e18) > slo.get("p99_ms", 0):
        errors.append(
            f"serving latency exceeds SLO: p50={lat.get('p50')}ms, "
            f"p99={lat.get('p99')}ms vs {slo}")
    if results.get("throughput_qps", 0) < slo.get("min_qps", 1e18):
        errors.append(
            f"serving throughput {results.get('throughput_qps')} qps below "
            f"SLO floor {slo.get('min_qps')}")
    if results.get("failures", 1):
        errors.append(
            f"serving workload had {results.get('failures')} failed requests")
    if not payload.get("fast") and \
            summary.get("n_queries", 0) < 1_000_000:
        errors.append(
            f"non-fast serving artifact answered only "
            f"{summary.get('n_queries')} queries (< 1,000,000)")
    if recovery.get("acked_ingest_loss", 1) != 0:
        errors.append(
            f"serving recovery lost {recovery.get('acked_ingest_loss')} "
            f"acknowledged ingests "
            f"({recovery.get('acked_ingests')}/"
            f"{recovery.get('expected_ingests')})")
    if not recovery.get("bit_identical", False):
        errors.append(
            "serving recovery labels are not bit-identical to the clean run "
            f"(crc32 clean={recovery.get('labels_crc32_clean')} vs "
            f"recovered={recovery.get('labels_crc32_recovered')})")
    if recovery.get("restarts", 0) < 1:
        errors.append(
            "serving recovery gate never restarted the engine — the crash "
            "injection is not exercising the recovery path")
    hist = results.get("batch_size_hist", {})
    if not any(int(k) > 1 for k, v in hist.items() if v):
        errors.append(
            f"serving coalescer produced no multi-request batch "
            f"(batch_size_hist={hist})")
    return errors


CHECKERS = {"connectivity": check, "serving": check_serving}


def check_path(path: str) -> int:
    with open(path) as f:
        payload = json.load(f)
    kind = payload.get("artifact", "connectivity")
    checker = CHECKERS.get(kind)
    if checker is None:
        print(f"ARTIFACT GATE FAILED: {path}: unknown artifact kind "
              f"{kind!r}", file=sys.stderr)
        return 1
    errors = checker(payload)
    if errors:
        for e in errors:
            print(f"ARTIFACT GATE FAILED: {path}: {e}", file=sys.stderr)
        return 1
    summary = payload["summary"]
    if kind == "serving":
        print(f"artifact gate ok: {path} "
              f"(schema {payload.get('schema')}, "
              f"{summary.get('n_queries'):,} queries, "
              f"p50={summary.get('p50_ms'):.1f}ms, "
              f"p99={summary.get('p99_ms'):.1f}ms, "
              f"qps={summary.get('throughput_qps'):,.0f}, "
              f"recovery_bit_identical="
              f"{summary.get('recovery_bit_identical')}, "
              f"acked_ingest_loss={summary.get('acked_ingest_loss')})")
    else:
        print(f"artifact gate ok: {path} "
              f"(schema {payload.get('schema')}, {summary.get('n_graphs')} "
              f"graphs, all_correct={summary.get('all_correct')}, "
              f"frontier_visits_fewer_edges="
              f"{summary.get('frontier_visits_fewer_edges')}, "
              f"frontier_best_wallclock_ratio="
              f"{summary.get('frontier_best_wallclock_ratio')}, "
              f"autotune_vs_heuristic_geomean="
              f"{summary.get('autotune_vs_heuristic_geomean')}, "
              f"streaming_bit_identical="
              f"{summary.get('streaming_bit_identical')}, "
              f"recovery_bit_identical="
              f"{summary.get('recovery_bit_identical')}, "
              f"oocore_bit_identical="
              f"{summary.get('oocore_bit_identical')}, "
              f"oocore_peak_below_edge_bytes="
              f"{summary.get('oocore_peak_below_edge_bytes')}, "
              f"auto_vs_best_fixed_geomean="
              f"{summary.get('auto_vs_best_fixed_geomean')})")
    return 0


def main(argv) -> int:
    paths = argv[1:] or ["BENCH_connectivity.json"]
    return max(check_path(p) for p in paths)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
