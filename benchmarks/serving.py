"""Serving-engine benchmark: heavy traffic + recovery -> BENCH_serving.json.

Two measurements feed the artifact:

* **Traffic** — ``repro.serving.simulate`` drives a real
  :class:`ConnectivityEngine` with a million-query Zipf-skewed, bursty,
  mixed read/write workload (open-loop at capacity, bounded in-flight
  window) and records p50/p95/p99 latency, throughput,
  ingest-to-visibility lag, coalesced-batch-size and queue-depth
  histograms.  The SLO gate (``SLO``) turns the committed artifact into
  a regression tripwire: a PR that tanks coalescing or serialises the
  worker loop fails ``check_artifact.py`` in CI.

* **Recovery** — the same ingest schedule runs twice: clean, and with
  injected engine crashes mid-load (checkpoint manager + WAL replay).
  The gate demands **zero acknowledged-ingest loss** and final labels
  **bit-identical** to the uninterrupted run (DESIGN.md §13).

Run standalone::

    PYTHONPATH=src python -m benchmarks.serving [--fast]

or as the ``serving_engine`` section of ``python -m benchmarks.run``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import tempfile

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.recovery import FaultInjector, SimulatedFault
from repro.serving.simulate import WorkloadSpec, run_simulation

SCHEMA = 1

# The committed-artifact SLO.  Thresholds carry ~10x headroom over the
# reference CPU run (p50 ~77ms, p99 ~170ms, ~36k qps at a 4x1024
# in-flight window) — the gate exists to catch collapses (a serialised
# coalescer, a per-query device sync), not hardware jitter.
SLO = {"p50_ms": 1_000.0, "p99_ms": 2_500.0, "min_qps": 2_000.0}

FULL_SPEC = WorkloadSpec(
    n_vertices=200_000,
    n_queries=1_000_000,
    zipf_a=1.3,
    burst_mean=64.0,
    write_ratio=0.001,        # 1000 ingest batches x 256 edges
    edges_per_batch=256,
    n_query_threads=4,
    window=1024,
    seed=0,
)

FAST_SPEC = dataclasses.replace(
    FULL_SPEC, n_vertices=20_000, n_queries=20_000, write_ratio=0.002,
    edges_per_batch=64, window=256)

# recovery runs a lighter query load (queries never change the committed
# state; the gate compares ingest outcomes), same-shape ingest schedule
RECOVERY_SPEC = dataclasses.replace(
    FULL_SPEC, n_queries=20_000, write_ratio=0.002, window=256,
    n_vertices=50_000, edges_per_batch=128)
RECOVERY_FAST_SPEC = dataclasses.replace(
    FAST_SPEC, n_queries=4_000, write_ratio=0.005)

# injected engine crashes, as (committed-batch, site) ingest faults:
# one early, one mid-load
RECOVERY_FAIL_AT = ((3, "pre"), (17, "pre"))
RECOVERY_CHECKPOINT_EVERY = 8


def run_traffic(fast: bool = False) -> dict:
    spec = FAST_SPEC if fast else FULL_SPEC
    # Warm the process-wide jit caches first (coalescer gather buckets at
    # this label capacity, the ingest delta-solve programs) with a short
    # same-shape run, so the measured tail reflects steady-state serving
    # rather than first-touch compiles — on the small fast spec a single
    # ~1s cold compile lands straight in p99.
    warm = dataclasses.replace(spec, n_queries=2_000,
                               write_ratio=10 / 2_000)
    run_simulation(warm)
    report, _ = run_simulation(spec)
    return report


def run_recovery_gate(fast: bool = False) -> dict:
    """Clean vs crash-restarted run of the same ingest schedule."""
    spec = RECOVERY_FAST_SPEC if fast else RECOVERY_SPEC
    clean, clean_labels = run_simulation(spec)
    with tempfile.TemporaryDirectory(prefix="serving_recovery_") as ckdir:
        manager = CheckpointManager(ckdir, async_save=False)
        injector = FaultInjector(fail_at=list(RECOVERY_FAIL_AT))
        faulty, faulty_labels = run_simulation(
            spec, manager=manager, fault_injector=injector,
            checkpoint_every=RECOVERY_CHECKPOINT_EVERY,
            recoverable=(SimulatedFault,))
    bit_identical = bool(np.array_equal(clean_labels, faulty_labels))
    expected = spec.n_ingest_batches
    return {
        "spec": dataclasses.asdict(spec),
        "fail_at": [list(f) for f in RECOVERY_FAIL_AT],
        "checkpoint_every": RECOVERY_CHECKPOINT_EVERY,
        "restarts": faulty["counters"]["restarts"],
        "checkpoints": faulty["counters"]["checkpoints"],
        "replayed_batches": faulty["counters"]["replayed_batches"],
        "expected_ingests": expected,
        "acked_ingests": faulty["acked_batches"],
        "acked_ingest_loss": expected - faulty["acked_batches"],
        "bit_identical": bit_identical,
        "labels_crc32_clean": clean["final"]["labels_crc32"],
        "labels_crc32_recovered": faulty["final"]["labels_crc32"],
        "clean_acked_ingests": clean["acked_batches"],
    }


def build_artifact(fast: bool = False) -> dict:
    traffic = run_traffic(fast)
    recovery = run_recovery_gate(fast)
    lat = traffic["latency_ms"]
    slo_passed = (lat["p50"] <= SLO["p50_ms"]
                  and lat["p99"] <= SLO["p99_ms"]
                  and traffic["throughput_qps"] >= SLO["min_qps"]
                  and traffic["failures"] == 0)
    return {
        "artifact": "serving",
        "schema": SCHEMA,
        "fast": bool(fast),
        "workload": traffic["spec"],
        "results": {k: traffic[k] for k in
                    ("latency_ms", "ingest_visibility_ms", "throughput_qps",
                     "ingest_batches_per_s", "wall_s", "batch_size_hist",
                     "queue_depth_hist", "counters", "final", "failures")},
        "slo": dict(SLO, passed=bool(slo_passed)),
        "recovery": recovery,
        "summary": {
            "n_queries": traffic["counters"]["queries_answered"],
            "p50_ms": lat["p50"],
            "p99_ms": lat["p99"],
            "throughput_qps": traffic["throughput_qps"],
            "slo_passed": bool(slo_passed),
            "recovery_bit_identical": recovery["bit_identical"],
            "acked_ingest_loss": recovery["acked_ingest_loss"],
        },
    }


def main(fast: bool = False, json_path: str = "BENCH_serving.json") -> dict:
    payload = build_artifact(fast)
    s = payload["summary"]
    print(f"serving traffic: {s['n_queries']:,} queries at "
          f"{s['throughput_qps']:,.0f} qps | p50 {s['p50_ms']:.1f} ms, "
          f"p99 {s['p99_ms']:.1f} ms | SLO passed: {s['slo_passed']}")
    rec = payload["recovery"]
    print(f"serving recovery: {rec['restarts']} restarts, "
          f"{rec['replayed_batches']} replayed batches, acked-ingest loss "
          f"{rec['acked_ingest_loss']}/{rec['expected_ingests']}, "
          f"bit_identical={rec['bit_identical']}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {json_path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default="BENCH_serving.json")
    args = ap.parse_args()
    main(fast=args.fast, json_path=args.json)
