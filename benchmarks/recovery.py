"""Recovery-overhead benchmark: the chaos gate (DESIGN.md §12).

    PYTHONPATH=src python -m benchmarks.recovery [--fast]
    PYTHONPATH=src python -m benchmarks.recovery --update-artifact BENCH_connectivity.json

For each suite graph: stream the shuffled edge list twice through
:class:`repro.connectivity.StreamingConnectivity` — once clean, once
under the crash-restart driver (``stream_with_recovery``) with two
injected process crashes (one before any work of its batch, one after
the batch's ring-buffer write but before the commit).  Two gated
properties (``BENCH_connectivity.json`` schema 4, checked by
``benchmarks/check_artifact.py``):

* **bit_identical** — the recovered labels equal the fault-free stream's
  labels exactly (restore + replay-of-the-uncommitted-suffix is exact,
  not approximate; the cumulative ``edges_visited`` counter itself also
  lands bit-identical, being checkpointed state);
* **lt_2x_clean** — the *executed* device work stays under 2x the clean
  stream's.  Because recovery is bit-exact, the engine's own counter
  cannot show the overhead (the replayed trajectory reproduces it
  exactly); the executed total is the clean total plus the work
  *discarded* by each restore — recomputed from the clean run's
  per-batch counter trajectory and the restart/resume points, which are
  all deterministic.  (The failed attempt's own pre-crash solve work —
  at most one batch per fault — is not counted.)

Work is the gated measure because both runs are deterministic — the
injection points and checkpoint cadence are fixed — so the ratio is
platform-independent and noise-free; wall time is recorded for honesty,
not gated (same policy as the frontier and streaming gates).

``--update-artifact`` merges the recovery block into an existing
artifact in place (bumping it to schema 4), so the committed perf
trajectory picks up the gate without re-running the full figure suite.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from typing import Dict

import numpy as np

from benchmarks import connectivity as bench_conn
from repro.checkpoint.manager import CheckpointManager
from repro.connectivity import (FaultInjector, SolveOptions,
                                StreamingConnectivity, stream_with_recovery)

DEFAULT_BATCHES = 32


def recovered_vs_clean(graph, *, n_batches: int = DEFAULT_BATCHES,
                       seed: int = 0) -> Dict[str, float]:
    """One clean-vs-recovered comparison row."""
    src, dst, n = graph.to_numpy()
    m = len(src)
    perm = np.random.default_rng(seed).permutation(m)
    src, dst = src[perm], dst[perm]
    batches = [(src[b * m // n_batches:(b + 1) * m // n_batches],
                dst[b * m // n_batches:(b + 1) * m // n_batches])
               for b in range(n_batches)]
    opts = SolveOptions(variant="C-2", backend="xla")

    t0 = time.perf_counter()
    clean = StreamingConnectivity(n, opts)
    cum = [0.0]                      # counter trajectory after each batch
    for b in batches:
        clean.ingest(*b)
        cum.append(float(clean.snapshot().edges_visited))
    clean_snap = clean.snapshot()
    clean_labels = np.asarray(clean_snap.labels)
    clean_s = time.perf_counter() - t0

    # two process crashes: one before its batch does any work, one after
    # the ring write but before the commit (the atomicity-critical site)
    injector = FaultInjector(fail_at=(n_batches // 3,
                                      (2 * n_batches // 3, "post_write")))
    # each restart discards the work of batches [resume, b): committed
    # since the last checkpoint, re-executed after the restore
    replays = []
    with tempfile.TemporaryDirectory() as ckpt_dir:
        manager = CheckpointManager(ckpt_dir, keep=3, async_save=False)

        def on_event(event, b):
            if event == "restart":
                replays.append((manager.latest_step() or 0, b))

        t0 = time.perf_counter()
        eng, stats = stream_with_recovery(
            batches, n, manager, opts,
            checkpoint_every=max(1, n_batches // 4),
            fault_injector=injector, on_event=on_event)
        recovered_s = time.perf_counter() - t0
    snap = eng.snapshot()

    clean_visited = float(clean_snap.edges_visited)
    recovered_visited = float(snap.edges_visited)
    discarded = sum(cum[b] - cum[resume] for resume, b in replays)
    executed = clean_visited + discarded
    return {
        "n_vertices": n,
        "n_edges": m,
        "n_batches": n_batches,
        "restarts": stats["restarts"],
        "checkpoints": stats["checkpoints"],
        "replayed_batches": stats["replayed_batches"],
        "clean_edges_visited": clean_visited,
        "recovered_edges_visited": recovered_visited,
        "executed_edges_visited": executed,
        "overhead_ratio": (executed / clean_visited
                           if clean_visited else 0.0),
        "lt_2x_clean": bool(executed < 2.0 * clean_visited),
        "bit_identical": bool(
            (np.asarray(snap.labels) == clean_labels).all()
            and recovered_visited == clean_visited),
        "converged": bool(snap.converged),
        "clean_s": clean_s,
        "recovered_s": recovered_s,
    }


_GATE_CACHE: Dict[str, Dict[str, Dict[str, float]]] = {}


def run_gate(fast: bool = False,
             n_batches: int = DEFAULT_BATCHES) -> Dict[str, Dict[str, float]]:
    """graph name -> clean-vs-recovered row, over the benchmark suite.

    Memoized like ``streaming.run_gate``: the default ``benchmarks.run``
    invocation hits this twice (section print + artifact emission).
    """
    key = f"fast={fast},n_batches={n_batches}"
    if key not in _GATE_CACHE:
        _GATE_CACHE[key] = {
            name: recovered_vs_clean(g, n_batches=n_batches)
            for name, g in bench_conn.suite_graphs(fast).items()}
    return _GATE_CACHE[key]


def summarise(gate: Dict[str, Dict[str, float]]) -> Dict[str, bool]:
    """The two schema-4 summary keys the artifact check enforces."""
    return {
        "recovery_bit_identical": all(r["bit_identical"]
                                      for r in gate.values()),
        "recovery_work_lt_2x_clean": all(r["lt_2x_clean"]
                                         for r in gate.values()),
    }


def merge_into_artifact(payload: dict,
                        gate: Dict[str, Dict[str, float]]) -> dict:
    """Attach the recovery gate to an artifact payload (schema -> 4)."""
    payload["schema"] = max(4, int(payload.get("schema", 0)))
    payload["recovery"] = gate
    payload.setdefault("summary", {}).update(summarise(gate))
    return payload


def main(fast: bool = False,
         n_batches: int = DEFAULT_BATCHES) -> Dict[str, Dict[str, float]]:
    gate = run_gate(fast=fast, n_batches=n_batches)
    header = (f"{'graph':16s}{'restarts':>9s}{'replayed':>9s}"
              f"{'clean_ev':>12s}{'exec_ev':>12s}{'ratio':>8s}{'<2x':>5s}"
              f"{'bitid':>7s}{'time_s':>8s}")
    print("\n== recovered vs clean stream (executed edges_visited) ==")
    print(header)
    for name, r in gate.items():
        print(f"{name:16s}{r['restarts']:9d}{r['replayed_batches']:9d}"
              f"{r['clean_edges_visited']:12.0f}"
              f"{r['executed_edges_visited']:12.0f}"
              f"{r['overhead_ratio']:8.3f}"
              f"{str(r['lt_2x_clean']):>5s}{str(r['bit_identical']):>7s}"
              f"{r['recovered_s']:8.2f}")
    summary = summarise(gate)
    print(f"summary: {summary}")
    if not all(summary.values()):
        # plain Exception so benchmarks.run's section loop collects the
        # failure and still writes the artifact
        raise RuntimeError(f"recovery gate failed: {summary}")
    return gate


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--n-batches", type=int, default=DEFAULT_BATCHES)
    ap.add_argument("--update-artifact", metavar="PATH",
                    help="merge the gate into an existing artifact in "
                         "place (schema 4)")
    args = ap.parse_args()
    gate = main(fast=args.fast, n_batches=args.n_batches)
    if args.update_artifact:
        with open(args.update_artifact) as f:
            payload = json.load(f)
        merge_into_artifact(payload, gate)
        with open(args.update_artifact, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"updated {args.update_artifact} (schema {payload['schema']})")
