"""Paper Fig. 1: number of iterations per method per graph.

Validated paper claims: iters(C-m) <= iters(C-2) <= iters(C-1);
C-1 explodes on long-diameter graphs; C-Syn ~ FastSV; averages ordered
C-m < C-2 < C-11mm ~ C-1m1m < C-Syn ~ FastSV << C-1 (paper §IV-C).
"""
from __future__ import annotations

import numpy as np

from benchmarks.connectivity import METHODS, pivot, print_table, run_suite


def main(fast: bool = False):
    records = run_suite(fast=fast)
    table = pivot(records, "iterations")
    print_table("Fig. 1 — iterations to convergence", table,
                fmt="{:>11.0f}")
    means = {m: np.mean([row[m] for row in table.values() if m in row])
             for m in METHODS}
    print("\naverage iterations: " + "  ".join(
        f"{m}={means[m]:.2f}" for m in METHODS))
    order = ["C-m", "C-2", "C-Syn", "C-1"]
    vals = [means[m] for m in order]
    assert vals == sorted(vals), f"iteration ordering violated: {means}"
    print("paper ordering C-m <= C-2 <= C-Syn <= C-1: OK")
    return records


if __name__ == "__main__":
    main()
