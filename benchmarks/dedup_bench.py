"""Production-integration benchmark: MinHash-LSH dedup with Contour CC.

Measures the CC stage (the paper's contribution) inside the end-to-end
dedup pass and verifies cluster recovery quality on a planted corpus.
"""
from __future__ import annotations

import time

import numpy as np

from repro.data.dedup import minhash_dedup
from repro.data.pipeline import make_corpus


def main(fast: bool = False):
    n_docs = 400 if fast else 1500
    docs = make_corpus(n_docs=n_docs, doc_len=200, vocab_size=1000,
                       dup_fraction=0.35, near_dup_noise=0.03, seed=7)
    t0 = time.perf_counter()
    report = minhash_dedup(docs, n_hashes=64, bands=16)
    dt = time.perf_counter() - t0
    kept = int(report.keep.sum())
    print(f"dedup: {n_docs} docs -> {report.n_clusters} clusters "
          f"({kept} kept, {n_docs - kept} near-dups removed) "
          f"in {dt:.2f}s; CC pairs={report.n_candidate_pairs} "
          f"cc_iterations={report.cc_iterations}")
    assert report.n_clusters < n_docs
    return report


if __name__ == "__main__":
    main()
