"""Out-of-core contraction benchmark: the oocore solver's acceptance gate.

    PYTHONPATH=src python -m benchmarks.oocore [--fast]
    PYTHONPATH=src python -m benchmarks.oocore --update-artifact BENCH_connectivity.json

Three gated properties (``BENCH_connectivity.json`` schema 6, every
verdict re-derived from the raw per-row numbers by
``benchmarks/check_artifact.py`` — never trusted from a summary boolean):

* **bit_identical** — streaming the suite graphs through
  :class:`repro.connectivity.OutOfCoreContraction` chunk by chunk lands
  labels elementwise-equal to the one-shot in-core ``solve()`` (both are
  the canonical min-vertex-id fixed point);
* **decay** — the deduped surviving-edge count strictly decreases every
  round: each round record stores ``edges_in`` and ``survivors`` and the
  checker walks the chain ``n_edges -> s_0 -> s_1 -> ...`` requiring
  ``survivors < edges_in`` at every link (DESIGN.md §15's termination
  argument, measured);
* **memory** — on a *stress* graph at least 4x the chunk budget, the
  peak device bytes (allocator ``peak_bytes_in_use`` where the backend
  exposes it, the deterministic resident-set estimate otherwise) stay
  below ``EDGE_BYTES * m`` — the bytes the in-core path would have to
  materialise.  The stress row feeds the solver from the chunked R-MAT
  generator (no full edge list during the gated run; the in-core oracle
  materialises one afterwards, past the peak measurement).

The ``multiround`` row is adversarial by construction: a disjoint star
forest, one star per chunk with the hub at the chunk's *maximum* vertex
id, streamed with a single local sweep per chunk — each chunk's
scatter-min resolves essentially one edge per star, so round 0 leaves
far more survivors than the bucket and forces a genuine second round
(most natural graphs collapse in one round because the sequential fold
accumulates global label state, like a union-find pass).

``--update-artifact`` merges the gate into an existing artifact in place
(bumping it to schema 6) so the committed perf trajectory can pick up
the gate without re-running the full figure suite.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional

import numpy as np

from benchmarks import connectivity as bench_conn
from repro.connectivity import SolveOptions, solve
from repro.connectivity.oocore import (
    EDGE_BYTES,
    OutOfCoreContraction,
    device_peak_bytes,
)
from repro.connectivity import planner as _planner
from repro.graphs.generators import (
    ArrayChunks,
    rmat_chunks,
    star_forest_chunks,
)
from repro.graphs.structs import Graph

# one star per chunk, hub at the chunk's max id (star_forest_chunks)
STAR_CHUNK = 1024
STAR_COUNT = 16


def oocore_row(chunks, *, oracle_graph: Optional[Graph] = None,
               **opt_overrides) -> Dict:
    """One gate row: drive the round loop, record everything raw.

    ``oracle_graph`` lets callers reuse an already-materialised graph;
    when absent the chunk source is materialised once for the in-core
    oracle solve (host-side only — the oocore run itself still never
    holds more than one chunk on device).
    """
    opts = SolveOptions(algorithm="oocore", variant="C-2", backend="xla",
                        **opt_overrides)
    peak_before = device_peak_bytes()

    t0 = time.perf_counter()
    eng = OutOfCoreContraction(chunks, opts)
    rounds = []
    while not eng.finished_streaming:
        rounds.append(eng.run_round())
    labels, iterations, converged, visited = eng.finish()
    oo_labels = np.asarray(labels)
    dt = time.perf_counter() - t0

    peak_after = device_peak_bytes()
    est = eng.peak_bytes_estimate()
    # the allocator peak is process-wide and monotone: it is attributable
    # to this row only when this row *raised* it; otherwise fall back to
    # the deterministic resident-set estimate (always an over-count of
    # what the oocore run itself keeps resident)
    if peak_after is not None and (peak_before is None
                                   or peak_after > peak_before):
        peak, peak_src = int(peak_after), "measured"
    else:
        peak, peak_src = int(est), "estimated"

    graph = oracle_graph if oracle_graph is not None else \
        chunks.materialize()
    one = solve(graph, SolveOptions(variant="C-2", backend="xla"))

    m = int(chunks.n_edges)
    bucket = int(eng.bucket)
    return {
        "n_vertices": int(chunks.n_vertices),
        "n_edges": m,
        "chunk_bucket": bucket,
        "n_chunks": int(chunks.n_chunks),
        "edges_over_bucket": m / bucket,
        "rounds": rounds,
        "decay": [int(c) for c in eng.round_counts],
        "round_cap_exhausted": bool(eng.round_cap_exhausted),
        "bit_identical": bool(np.array_equal(oo_labels,
                                             np.asarray(one.labels))),
        "converged": bool(converged),
        "iterations": int(iterations),
        "edges_visited": float(visited),
        "time_s": dt,
        "peak_bytes": peak,
        "peak_bytes_source": peak_src,
        "peak_bytes_estimate": int(est),
        "total_edge_bytes": EDGE_BYTES * m,
        "peak_lt_edge_bytes": bool(peak < EDGE_BYTES * m),
        "provenance": list(eng.provenance()),
    }


def _suite_bucket(m: int) -> int:
    """A bucket that forces a real multi-chunk stream on a suite graph."""
    return max(1024, _planner.next_pow2(m) // 16)


_GATE_CACHE: Dict[str, Dict[str, Dict]] = {}


def run_gate(fast: bool = False) -> Dict[str, Dict]:
    """name -> gate row.  Memoized like ``connectivity.run_suite`` (the
    default ``benchmarks.run`` hits this twice: section print + artifact).

    Rows: every suite graph streamed as chunks (equivalence), the
    ``stress:rmat_*`` row — generator-fed, >= 4x the chunk budget, the
    memory gate's subject — and the adversarial ``multiround:stars`` row.
    """
    key = f"fast={fast}"
    if key in _GATE_CACHE:
        return _GATE_CACHE[key]
    gate: Dict[str, Dict] = {}
    for name, g in bench_conn.suite_graphs(fast).items():
        src, dst, n = g.to_numpy()
        chunks = ArrayChunks(src, dst, n, _suite_bucket(len(src)))
        gate[name] = oocore_row(chunks, oracle_graph=g)
    scale = 16 if fast else 18
    stress = rmat_chunks(scale=scale, edge_factor=8, seed=7,
                         chunk_edges=(1 << scale) // 4)
    row = oocore_row(stress)
    row["stress"] = True
    gate[f"stress:rmat_{scale}"] = row
    gate["multiround:stars"] = oocore_row(star_forest_chunks(),
                                          oocore_local_iters=1)
    _GATE_CACHE[key] = gate
    return gate


def summarise(gate: Dict[str, Dict]) -> Dict[str, bool]:
    """The schema-6 summary keys (the artifact check re-derives each
    from the raw rows; these exist for the human-readable summary)."""
    decay_ok = True
    for row in gate.values():
        chain = [row["n_edges"]] + [r["survivors"] for r in row["rounds"]]
        decay_ok &= all(b < a for a, b in zip(chain, chain[1:]))
    stress = [r for r in gate.values() if r.get("stress")]
    return {
        "oocore_bit_identical": all(r["bit_identical"]
                                    for r in gate.values()),
        "oocore_decay_strictly_decreasing": bool(decay_ok),
        "oocore_peak_below_edge_bytes": bool(
            stress and all(r["peak_lt_edge_bytes"]
                           and r["n_edges"] >= 4 * r["chunk_bucket"]
                           for r in stress)),
        "oocore_multiround": any(len(r["rounds"]) >= 2
                                 for r in gate.values()),
    }


def merge_into_artifact(payload: dict, gate: Dict[str, Dict]) -> dict:
    """Attach the out-of-core gate to an artifact payload (schema -> 6)."""
    payload["schema"] = max(6, int(payload.get("schema", 0)))
    payload["oocore_gate"] = gate
    payload.setdefault("summary", {}).update(summarise(gate))
    return payload


def main(fast: bool = False) -> Dict[str, Dict]:
    gate = run_gate(fast=fast)
    header = (f"{'graph':18s}{'m':>9s}{'bucket':>8s}{'rounds':>7s}"
              f"{'decay':>20s}{'peak_MB':>9s}{'edge_MB':>9s}{'bitid':>7s}"
              f"{'time_s':>8s}")
    print("\n== out-of-core contraction vs in-core oracle ==")
    print(header)
    for name, r in gate.items():
        decay = ",".join(str(c) for c in r["decay"])
        print(f"{name:18s}{r['n_edges']:9d}{r['chunk_bucket']:8d}"
              f"{len(r['rounds']):7d}{decay:>20s}"
              f"{r['peak_bytes'] / 1e6:9.2f}"
              f"{r['total_edge_bytes'] / 1e6:9.2f}"
              f"{str(r['bit_identical']):>7s}{r['time_s']:8.2f}")
    summary = summarise(gate)
    print(f"summary: {summary}")
    if not all(summary.values()):
        # plain Exception so benchmarks.run's section loop collects the
        # failure and still writes the artifact
        raise RuntimeError(f"out-of-core gate failed: {summary}")
    return gate


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--update-artifact", metavar="PATH",
                    help="merge the gate into an existing artifact in "
                         "place (schema 6)")
    args = ap.parse_args()
    gate = main(fast=args.fast)
    if args.update_artifact:
        with open(args.update_artifact) as f:
            payload = json.load(f)
        merge_into_artifact(payload, gate)
        with open(args.update_artifact, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"updated {args.update_artifact} (schema {payload['schema']})")
