"""Paper Fig. 3: speedup of Contour variants (and ConnectIt) over FastSV.

Paper result: average speedups C-m=7.3, C-11mm=6.6, ConnectIt=6.49,
C-1m1m=6.33, C-2=6.33, C-1=4.62, C-Syn=2.87 on their 32-node Chapel
cluster.  We reproduce the *relative ordering and >1 speedups* under one
runtime (XLA CPU) — see EXPERIMENTS.md §Paper for the comparison table.
"""
from __future__ import annotations

import numpy as np

from benchmarks.connectivity import pivot, print_table, run_suite

VARIANT_COLS = ["C-Syn", "C-1", "C-2", "C-m", "C-11mm", "C-1m1m", "ConnectIt"]


def main(fast: bool = False):
    records = run_suite(fast=fast)
    times = pivot(records, "time_s")
    speedups = {
        g: {m: row["FastSV"] / row[m] for m in VARIANT_COLS if m in row}
        for g, row in times.items()
    }
    print_table("Fig. 3 — speedup vs FastSV", speedups, fmt="{:>11.2f}",
                methods=VARIANT_COLS)
    means = {m: float(np.mean([s[m] for s in speedups.values()]))
             for m in VARIANT_COLS}
    print("\naverage speedup vs FastSV: " + "  ".join(
        f"{m}={means[m]:.2f}x" for m in VARIANT_COLS))
    print("regime note: 1 CPU core = the paper's parallelism-starved "
          "regime (§IV-F): per-iteration work dominates, so absolute "
          "speedups shrink vs the 640-core cluster (7.3x); the paper's "
          "*orderings* are the reproducible claim here.")
    # regime-robust paper claims:
    assert means["C-2"] > means["C-Syn"], \
        "async C-2 must beat the synchronous variant (paper §IV-E)"
    assert means["C-m"] > means["C-Syn"], \
        "high-order C-m must beat C-Syn (paper §IV-E)"
    assert means["C-m"] >= 0.9, \
        "C-m should be at least competitive with FastSV on any host"
    return means


if __name__ == "__main__":
    main()
