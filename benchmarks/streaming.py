"""Stream-vs-scratch benchmark: the streaming engine's acceptance gate.

    PYTHONPATH=src python -m benchmarks.streaming [--fast]
    PYTHONPATH=src python -m benchmarks.streaming --update-artifact BENCH_connectivity.json

For each suite graph: shuffle the edge list, stream it through
:class:`repro.connectivity.StreamingConnectivity` in ``n_batches``
micro-batches, and compare against the one-shot dense ``solve()`` on the
final graph.  Two gated properties (``BENCH_connectivity.json`` schema 3,
checked by ``benchmarks/check_artifact.py``):

* **bit_identical** — the streamed labels equal the one-shot labels
  exactly (both are the canonical min-vertex-id fixed point);
* **lt_2x_dense** — the *cumulative* ``edges_visited`` across every
  batch stays under 2x the one-shot dense sweep's ``iterations x m``
  (the ISSUE-5 acceptance bound; in practice the delta path visits a
  small fraction — each batch sweeps only its own supervertex-rewritten
  edges under the §10 contraction schedule).

Wall time is recorded for honesty, not gated: like the frontier gate, on
a CPU host the per-batch dispatch overhead dominates the counter savings;
``edges_visited`` is the platform-independent work measure.

``--update-artifact`` merges the streaming gate into an existing artifact
in place (bumping it to schema 3) so the committed perf trajectory can
pick up the gate without re-running the full multi-minute figure suite.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

import numpy as np

from benchmarks import connectivity as bench_conn
from repro.connectivity import SolveOptions, StreamingConnectivity, solve

DEFAULT_BATCHES = 64


def stream_vs_scratch(graph, *, n_batches: int = DEFAULT_BATCHES,
                      seed: int = 0) -> Dict[str, float]:
    """One stream-vs-scratch comparison row."""
    src, dst, n = graph.to_numpy()
    m = len(src)
    perm = np.random.default_rng(seed).permutation(m)
    src, dst = src[perm], dst[perm]

    one = solve(graph, SolveOptions(variant="C-2", backend="xla"))
    np.asarray(one.labels)              # force; keep timing stream-only

    t0 = time.perf_counter()
    eng = StreamingConnectivity(n, SolveOptions(variant="C-2",
                                                backend="xla"))
    for b in range(n_batches):
        sl = slice(b * m // n_batches, (b + 1) * m // n_batches)
        eng.ingest(src[sl], dst[sl])
    snap = eng.snapshot()
    stream_labels = np.asarray(snap.labels)
    stream_s = time.perf_counter() - t0

    stream_visited = float(snap.edges_visited)
    dense_visited = float(one.edges_visited)
    return {
        "n_vertices": n,
        "n_edges": m,
        "n_batches": n_batches,
        "stream_edges_visited": stream_visited,
        "oneshot_edges_visited": dense_visited,
        "visited_ratio": (stream_visited / dense_visited
                          if dense_visited else 0.0),
        "lt_2x_dense": bool(stream_visited < 2.0 * dense_visited),
        "bit_identical": bool(
            (stream_labels == np.asarray(one.labels)).all()),
        "stream_iterations": int(snap.iterations),
        "oneshot_iterations": int(one.iterations),
        "converged": bool(snap.converged),
        "stream_s": stream_s,
    }


_GATE_CACHE: Dict[str, Dict[str, Dict[str, float]]] = {}


def run_gate(fast: bool = False,
             n_batches: int = DEFAULT_BATCHES) -> Dict[str, Dict[str, float]]:
    """graph name -> stream-vs-scratch row, over the benchmark suite.

    Memoized like ``connectivity.run_suite``: the default ``benchmarks.run``
    invocation hits this twice (the section print and the artifact
    emission) and must not stream every suite graph twice.
    """
    key = f"fast={fast},n_batches={n_batches}"
    if key not in _GATE_CACHE:
        _GATE_CACHE[key] = {
            name: stream_vs_scratch(g, n_batches=n_batches)
            for name, g in bench_conn.suite_graphs(fast).items()}
    return _GATE_CACHE[key]


def summarise(gate: Dict[str, Dict[str, float]]) -> Dict[str, bool]:
    """The two schema-3 summary keys the artifact check enforces."""
    return {
        "streaming_bit_identical": all(r["bit_identical"]
                                       for r in gate.values()),
        "streaming_visits_lt_2x_dense": all(r["lt_2x_dense"]
                                            for r in gate.values()),
    }


def merge_into_artifact(payload: dict,
                        gate: Dict[str, Dict[str, float]]) -> dict:
    """Attach the streaming gate to an artifact payload (schema -> 3)."""
    payload["schema"] = max(3, int(payload.get("schema", 0)))
    payload["streaming_gate"] = gate
    payload.setdefault("summary", {}).update(summarise(gate))
    return payload


def main(fast: bool = False,
         n_batches: int = DEFAULT_BATCHES) -> Dict[str, Dict[str, float]]:
    gate = run_gate(fast=fast, n_batches=n_batches)
    header = (f"{'graph':16s}{'batches':>8s}{'stream_ev':>12s}"
              f"{'oneshot_ev':>12s}{'ratio':>8s}{'<2x':>5s}{'bitid':>7s}"
              f"{'time_s':>8s}")
    print("\n== streaming vs scratch (cumulative edges_visited) ==")
    print(header)
    for name, r in gate.items():
        print(f"{name:16s}{r['n_batches']:8d}"
              f"{r['stream_edges_visited']:12.0f}"
              f"{r['oneshot_edges_visited']:12.0f}"
              f"{r['visited_ratio']:8.3f}"
              f"{str(r['lt_2x_dense']):>5s}{str(r['bit_identical']):>7s}"
              f"{r['stream_s']:8.2f}")
    summary = summarise(gate)
    print(f"summary: {summary}")
    if not all(summary.values()):
        # a plain Exception so benchmarks.run's section loop collects the
        # failure and still writes the artifact (SystemExit would escape
        # its `except Exception` and abort the remaining sections)
        raise RuntimeError(f"streaming gate failed: {summary}")
    return gate


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--n-batches", type=int, default=DEFAULT_BATCHES)
    ap.add_argument("--update-artifact", metavar="PATH",
                    help="merge the gate into an existing artifact in "
                         "place (schema 3)")
    args = ap.parse_args()
    gate = main(fast=args.fast, n_batches=args.n_batches)
    if args.update_artifact:
        with open(args.update_artifact) as f:
            payload = json.load(f)
        merge_into_artifact(payload, gate)
        with open(args.update_artifact, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"updated {args.update_artifact} (schema {payload['schema']})")
