"""Shared harness for the paper's connectivity experiments (Figs. 1-4).

Runs every method on every suite graph once, measuring converged wall time
(after jit warmup) and iteration counts; the fig_* modules slice this table
into the paper's four figures.  ``ConnectIt`` is Rem's union-find (the
algorithm ConnectIt found fastest on shared memory), host-side per
DESIGN.md §8.5, with iteration count 1 by the paper's convention (§IV-C).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.contour import VARIANTS, contour_labels
from repro.core.fastsv import fastsv_labels
from repro.core.unionfind import rem_union_find
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle, labels_equivalent

METHODS = list(VARIANTS) + ["FastSV", "ConnectIt"]


@dataclasses.dataclass
class Record:
    graph: str
    graph_id: int
    n_vertices: int
    n_edges: int
    method: str
    iterations: int
    time_s: float
    correct: bool


def _time_jax(fn, repeats: int = 3):
    """Best-of-k wall time for a jit'd callable returning jax arrays."""
    out = fn()                      # warmup / compile
    jtree = [x for x in (out if isinstance(out, tuple) else (out,))]
    for x in jtree:
        x.block_until_ready()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        for x in (out if isinstance(out, tuple) else (out,)):
            x.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return out, best


def bench_graph(name: str, gid: int, graph, *, repeats: int = 2,
                methods: Optional[List[str]] = None) -> List[Record]:
    src, dst, n = graph.src, graph.dst, graph.n_vertices
    oracle = connected_components_oracle(*graph.to_numpy())
    records = []
    for method in methods or METHODS:
        # C-1 needs O(diameter) iterations (paper Fig. 1: up to 2369) —
        # one timed run is plenty on long-diameter graphs
        reps = 1 if method == "C-1" else repeats
        if method == "FastSV":
            fn = lambda: fastsv_labels(src, dst, n)
            (labels, iters), dt = _time_jax(fn, repeats)
            iters = int(iters)
        elif method == "ConnectIt":
            s_np, d_np, _ = graph.to_numpy()
            t0 = time.perf_counter()
            labels = rem_union_find(s_np, d_np, n)
            dt = time.perf_counter() - t0
            iters = 1               # paper §IV-C convention
        else:
            fn = lambda m=method: contour_labels(src, dst, n, variant=m)
            (labels, iters), dt = _time_jax(fn, reps)
            iters = int(iters)
        ok = labels_equivalent(np.asarray(labels), oracle)
        records.append(Record(
            graph=name, graph_id=gid, n_vertices=n,
            n_edges=graph.n_edges, method=method,
            iterations=iters, time_s=dt, correct=bool(ok)))
    return records


_CACHE: Dict[str, List[Record]] = {}


def run_suite(fast: bool = False, repeats: int = 2) -> List[Record]:
    key = f"fast={fast}"
    if key in _CACHE:
        return _CACHE[key]
    suite = gen.paper_suite(small=True)
    if fast:
        keep = ("path_64k", "grid_256x256", "rmat_16", "delaunay_n16",
                "mix_3comp")
        suite = {k: v for k, v in suite.items() if k in keep}
    records: List[Record] = []
    for gid, (name, g) in enumerate(suite.items()):
        records.extend(bench_graph(name, gid, g, repeats=repeats))
    _CACHE[key] = records
    return records


def pivot(records: List[Record], field: str) -> Dict[str, Dict[str, float]]:
    """graph -> method -> field value."""
    out: Dict[str, Dict[str, float]] = {}
    for r in records:
        out.setdefault(r.graph, {})[r.method] = getattr(r, field)
    return out


def print_table(title: str, table: Dict[str, Dict[str, float]],
                fmt: str = "{:>10.4f}", methods: Optional[List[str]] = None):
    methods = methods or METHODS
    print(f"\n== {title} ==")
    print(f"{'graph':18s}" + "".join(f"{m:>11s}" for m in methods))
    for gname, row in table.items():
        cells = "".join(
            fmt.format(row[m]) if m in row else " " * 11 for m in methods)
        print(f"{gname:18s}{cells}")
