"""Shared harness for the paper's connectivity experiments (Figs. 1-4).

Runs every method on every suite graph once, measuring converged wall time
(after jit warmup) and iteration counts; the fig_* modules slice this table
into the paper's four figures.  ``ConnectIt`` is Rem's union-find (the
algorithm ConnectIt found fastest on shared memory), host-side per
DESIGN.md §8.5, with iteration count 1 by the paper's convention (§IV-C).

``C-2-blk`` is the kernel-subsystem path (DESIGN.md §3.4): the dispatched
contour_mm backend (label-blocked Pallas on TPU, scatter-min under XLA on
CPU hosts) iterated by the on-device ``lax.while_loop`` fixpoint of
``contour_cc_fixpoint`` — zero per-iteration host syncs.  ``C-2-cmp`` is
C-2 under the work-adaptive frontier contraction schedule (DESIGN.md §10:
sampling prefix, largest-component filter, periodic active-edge
contraction) — its ``edges_visited`` counter must come in strictly under
the dense ``iterations × m`` and its labels must be bit-identical to
uncompacted C-2 (both gated in the artifact summary).  ``run_suite``
results serialise to ``BENCH_connectivity.json`` (see ``records_to_json``)
so the perf trajectory is machine-readable across PRs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

import jax

from repro.connectivity import SolveOptions, solve
from repro.connectivity import oocore as _oocore
from repro.connectivity import planner as _planner
from repro.connectivity.contour import VARIANTS, contour_labels
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle, labels_equivalent
from repro.kernels.contour_mm.ops import contour_cc_fixpoint

METHODS = list(VARIANTS) + ["C-2-blk", "C-2-cmp", "C-2-stg", "FastSV",
                            "ConnectIt"]

# Every method (except the raw kernel-path fixpoint) runs through the
# unified repro.connectivity.solve facade — the bench doubles as an
# integration check that one signature covers all families, and every
# row uniformly includes the facade's (small) per-call overhead: option
# resolution plus, for the host-side ConnectIt row, the edge-array
# host/device conversions a real caller pays.  Contour variants pin
# backend="xla" so the C-2 vs C-2-blk comparison isolates the
# kernel-dispatch path.
_METHOD_OPTIONS = {
    m: SolveOptions(algorithm="contour", variant=m, backend="xla")
    for m in VARIANTS
}
_METHOD_OPTIONS["FastSV"] = SolveOptions(algorithm="fastsv")
_METHOD_OPTIONS["ConnectIt"] = SolveOptions(algorithm="union_find")
# the work-adaptive rows: 2 sampling-prefix sweeps, largest-component
# filter, then contraction every 2 iterations (backend pinned like the
# other Contour rows so C-2 vs C-2-cmp/C-2-stg isolates the schedule).
# Each pins its frontier realisation explicitly — "masked" keeps the
# seed's single while_loop over full-shape masked tiles, "staged" is the
# planner's physically sliced stage driver (the launched shapes actually
# shrink with the frontier, DESIGN.md §14) — so the two rows measure the
# two compact schedules instead of whatever the heuristic resolves to.
_METHOD_OPTIONS["C-2-cmp"] = SolveOptions(
    algorithm="contour", variant="C-2", backend="xla",
    sampling=2, compact_every=2,
    plan=_planner.ExecutionPlan(backend="xla", compact_schedule="masked",
                                origin="pinned"))
_METHOD_OPTIONS["C-2-stg"] = SolveOptions(
    algorithm="contour", variant="C-2", backend="xla",
    sampling=2, compact_every=2,
    plan=_planner.ExecutionPlan(backend="xla", compact_schedule="staged",
                                origin="pinned"))


@dataclasses.dataclass
class Record:
    graph: str
    graph_id: int
    n_vertices: int
    n_edges: int
    method: str
    iterations: int
    time_s: float
    correct: bool
    # cumulative edges swept (None for solvers that do not count);
    # iterations*m on the dense schedule, strictly less under the
    # C-2-cmp frontier contraction — see DESIGN.md §10
    edges_visited: Optional[float] = None
    # labels elementwise-equal to this graph's uncompacted C-2 row
    # (recorded for C-2-cmp only: the bit-identical frontier gate)
    bit_identical: Optional[bool] = None
    # peak device bytes for the row: the allocator's peak_bytes_in_use
    # where the backend exposes one (TPU/GPU), else a host-side resident
    # set estimate (edge list + label working set) — schema 6 addition
    peak_bytes: Optional[int] = None
    peak_bytes_source: Optional[str] = None


def row_peak_bytes(n_vertices: int, n_edges: int):
    """(peak_bytes, source) for an in-core bench row.

    ``measured`` is the process-wide allocator peak (monotone across the
    run — an upper bound for every row); the ``estimated`` fallback is
    the in-core resident set: the int32 edge list plus the label working
    set, using the same per-array model as the out-of-core solver.
    """
    measured = _oocore.device_peak_bytes()
    if measured is not None:
        return int(measured), "measured"
    return (_oocore.EDGE_BYTES * int(n_edges)
            + 4 * _oocore.LABEL_ARRAYS * int(n_vertices)), "estimated"


def _block(out):
    for x in jax.tree_util.tree_leaves(out):
        if hasattr(x, "block_until_ready"):
            x.block_until_ready()


def _time_jax(fn, repeats: int = 3):
    """Best-of-k wall time for a callable returning a pytree of arrays."""
    out = fn()                      # warmup / compile
    _block(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        _block(out)
        best = min(best, time.perf_counter() - t0)
    return out, best


def bench_graph(name: str, gid: int, graph, *, repeats: int = 2,
                methods: Optional[List[str]] = None) -> List[Record]:
    n = graph.n_vertices
    oracle = connected_components_oracle(*graph.to_numpy())
    records = []
    method_labels = {}
    for method in methods or METHODS:
        # C-1 needs O(diameter) iterations (paper Fig. 1: up to 2369) —
        # one timed run is plenty on long-diameter graphs; ConnectIt is a
        # sequential host loop, also timed once.
        reps = 1 if method in ("C-1", "ConnectIt") else repeats
        visited = None
        if method == "C-2-blk":
            fn = lambda: contour_cc_fixpoint(graph, backend="auto")
            (labels, iters, _, visited), dt = _time_jax(fn, reps)
            iters = int(iters)
            visited = float(visited)
        elif method == "ConnectIt":
            # pure-NumPy host loop: nothing jit-compiles on its path
            # (solvers report their own converged flag), so time the one
            # run without a warmup pass
            t0 = time.perf_counter()
            result = solve(graph, _METHOD_OPTIONS[method])
            _block(result)
            dt = time.perf_counter() - t0
            labels, iters = result.labels, int(result.iterations)
        else:
            opts = _METHOD_OPTIONS[method]
            fn = lambda o=opts: solve(graph, o)
            result, dt = _time_jax(fn, reps)
            labels, iters = result.labels, int(result.iterations)
            if result.edges_visited is not None:
                visited = float(result.edges_visited)
        method_labels[method] = np.asarray(labels)
        ok = labels_equivalent(np.asarray(labels), oracle)
        # the frontier gate's bit-identical half: the compacted fixed
        # point must equal uncompacted C-2 elementwise, not just as a
        # partition (both follow the min-vertex-id convention)
        bit_identical = None
        if method in ("C-2-cmp", "C-2-stg") and "C-2" in method_labels:
            bit_identical = bool(np.array_equal(method_labels[method],
                                                method_labels["C-2"]))
        peak, peak_src = row_peak_bytes(n, graph.n_edges)
        records.append(Record(
            graph=name, graph_id=gid, n_vertices=n,
            n_edges=graph.n_edges, method=method,
            iterations=iters, time_s=dt, correct=bool(ok),
            edges_visited=visited, bit_identical=bit_identical,
            peak_bytes=peak, peak_bytes_source=peak_src))
    return records


_CACHE: Dict[str, List[Record]] = {}
_GATE_CACHE: Dict[str, Dict[str, Dict[str, float]]] = {}


def suite_graphs(fast: bool = False):
    suite = gen.paper_suite(small=True)
    if fast:
        keep = ("path_64k", "grid_256x256", "rmat_16", "delaunay_n16",
                "mix_3comp")
        suite = {k: v for k, v in suite.items() if k in keep}
    return suite


def run_suite(fast: bool = False, repeats: int = 3) -> List[Record]:
    key = f"fast={fast}"
    if key in _CACHE:
        return _CACHE[key]
    records: List[Record] = []
    for gid, (name, g) in enumerate(suite_graphs(fast).items()):
        records.extend(bench_graph(name, gid, g, repeats=repeats))
    _CACHE[key] = records
    return records


def _hlo_op_histogram(compiled) -> Dict[str, int]:
    """Opcode histogram of a compiled program (naming-insensitive)."""
    import re as _re
    ops = _re.findall(r"= \S+ (\w+)\(", compiled.as_text())
    hist: Dict[str, int] = {}
    for op in ops:
        hist[op] = hist.get(op, 0) + 1
    return hist


def blocked_vs_xla_gate(fast: bool = False,
                        repeats: int = 7) -> Dict[str, Dict[str, float]]:
    """Paired perf gate: kernel-path fixpoint vs the seed XLA C-2.

    The figure suite times each method in a separate block, minutes apart —
    on a shared CPU host that drift swamps a comparison whose true ratio is
    ~1.  Here the two are timed *interleaved* (A/B order alternating per
    round, best-of-k per side, jit caches warm), and additionally the two
    compiled programs are compared op-for-op: on a non-TPU host the
    dispatch resolves the blocked path to the same scatter-min sweep, so
    ``hlo_identical`` is the noise-free form of "no slower" (the TPU
    kernel path can only be timed on TPU hardware).
    """
    from repro.kernels.contour_mm.ops import contour_cc_fixpoint

    cache_key = f"gate:fast={fast}"
    if cache_key in _GATE_CACHE:
        return _GATE_CACHE[cache_key]
    out: Dict[str, Dict[str, float]] = {}
    for name, g in suite_graphs(fast).items():
        fn_xla = lambda: contour_labels(g.src, g.dst, g.n_vertices,
                                        variant="C-2")
        fn_blk = lambda: contour_cc_fixpoint(g, backend="auto")
        best = {"xla": float("inf"), "blk": float("inf")}
        for fn in (fn_xla, fn_blk):        # warmup / compile both first
            for x in fn():
                x.block_until_ready()
        pairs = [("xla", fn_xla), ("blk", fn_blk)]
        for r in range(repeats):
            for side, fn in (pairs if r % 2 == 0 else pairs[::-1]):
                t0 = time.perf_counter()
                for x in fn():
                    x.block_until_ready()
                best[side] = min(best[side], time.perf_counter() - t0)
        hlo_same = _hlo_op_histogram(
            contour_labels.lower(g.src, g.dst, g.n_vertices,
                                 variant="C-2").compile()
        ) == _hlo_op_histogram(
            contour_cc_fixpoint.lower(g, backend="auto").compile())
        out[name] = {"xla_s": best["xla"], "blk_s": best["blk"],
                     "speedup": best["xla"] / best["blk"],
                     "hlo_identical": bool(hlo_same)}
    _GATE_CACHE[cache_key] = out
    return out


def frontier_gate(records: List[Record]) -> Dict[str, Dict[str, float]]:
    """Per-graph work-adaptivity gate from the ``C-2-cmp`` rows.

    For every graph: the frontier schedule must *visit strictly fewer
    edges* than the dense ``iterations × m`` equivalent, while reaching a
    fixed point *bit-identical* to uncompacted C-2 (``Record.bit_identical``
    — computed elementwise in ``bench_graph``; ``None`` when the C-2 row
    was not benchmarked alongside, recorded as not-measured rather than a
    failure).

    ``time_ratio_vs_dense`` is recorded for honesty, *not* gated: on the
    XLA backend (this CPU host) the frontier limit is realised as
    full-shape masked tiles plus an O(m log m) partition per compaction,
    so the counter savings do **not** translate into wall time here —
    C-2-cmp typically runs slower than C-2 on CPU.  The wall-time payoff
    is the TPU blocked-kernel path, where the live-chunk count skips
    whole grid steps (DESIGN.md §10); ``edges_visited`` is the
    platform-independent work measure this gate certifies.
    """
    times = pivot(records, "time_s")
    iters = pivot(records, "iterations")
    out: Dict[str, Dict[str, float]] = {}
    for r in records:
        if r.method != "C-2-cmp" or r.edges_visited is None:
            continue
        # baseline = the *dense C-2 row's* iterations x m — using the
        # compacted row's own (sampling-inflated) iteration count would
        # let a schedule pass by beating a weaker baseline than the run
        # it claims to improve on
        dense_iters = iters.get(r.graph, {}).get("C-2", r.iterations)
        dense = float(dense_iters) * r.n_edges
        dense_t = times.get(r.graph, {}).get("C-2")
        out[r.graph] = {
            "edges_visited": r.edges_visited,
            "dense_equiv": dense,
            "work_saved_frac": 1.0 - r.edges_visited / dense if dense else 0.0,
            "fewer_than_dense": bool(r.edges_visited < dense),
            "bit_identical": r.bit_identical,
            "time_ratio_vs_dense": (r.time_s / dense_t if dense_t else None),
        }
    return out


def frontier_wallclock_gate(fast: bool = False,
                            repeats: int = 7) -> Dict[str, Dict[str, float]]:
    """Paired wall-clock gate: frontier schedules vs the dense C-2 sweep.

    The schema-5 flip of the frontier gate (ISSUE 8): counted edge visits
    already drop 23-83% under contraction, but the paper's claim is wall
    time, so the gate now requires the frontier schedule to *run faster
    than dense* (ratio < 1.0) on at least one (graph, schedule) pair.
    Both realisations are timed — ``masked`` (the seed's full-shape
    masked while_loop) and ``staged`` (the planner's physically sliced
    stage driver whose launched shapes shrink with the frontier) —
    interleaved with the dense baseline, best-of-k per side, jit caches
    warm, exactly like :func:`blocked_vs_xla_gate`.  Raw per-side
    seconds are recorded so ``check_artifact.py`` re-derives the ratios
    instead of trusting the summary booleans.
    """
    cache_key = f"fw_gate:fast={fast}"
    if cache_key in _GATE_CACHE:
        return _GATE_CACHE[cache_key]
    out: Dict[str, Dict[str, float]] = {}
    sides = (("dense", _METHOD_OPTIONS["C-2"]),
             ("masked", _METHOD_OPTIONS["C-2-cmp"]),
             ("staged", _METHOD_OPTIONS["C-2-stg"]))
    for name, g in suite_graphs(fast).items():
        fns = [(side, lambda o=o: solve(g, o)) for side, o in sides]
        best = {side: float("inf") for side, _ in fns}
        for _, fn in fns:                  # warmup / compile all first
            _block(fn())
        for r in range(repeats):
            for side, fn in (fns if r % 2 == 0 else fns[::-1]):
                t0 = time.perf_counter()
                _block(fn())
                best[side] = min(best[side], time.perf_counter() - t0)
        out[name] = {
            "backend": "xla",
            "dense_s": best["dense"],
            "masked_s": best["masked"],
            "staged_s": best["staged"],
            "ratio_masked": best["masked"] / best["dense"],
            "ratio_staged": best["staged"] / best["dense"],
            "best_ratio": min(best["masked"], best["staged"]) / best["dense"],
        }
    _GATE_CACHE[cache_key] = out
    return out


# The strategy matrix sweeps one graph per family regime: long diameter
# (path), regular mesh (grid), power-law (rmat), disconnected mix, and
# hub-dominated (star) — star_64k deliberately included even in --fast
# runs since skew is the cost model's separating feature.
STRATEGY_GATE_GRAPHS = ("path_64k", "grid_256x256", "rmat_16",
                        "mix_3comp", "star_64k")

# --strategy restriction (None = all registered strategies + auto); set
# through set_strategy_sides so caches are invalidated with it
_STRATEGY_SIDES: Optional[tuple] = None


def set_strategy_sides(sides) -> None:
    """Restrict the strategy-matrix gate to the named sides.

    ``benchmarks.run --strategy`` calls this after validating the names
    against the frontier strategy registry (+ ``"auto"``); gate caches
    are dropped because cached rows covered a different side set.
    """
    global _STRATEGY_SIDES
    _STRATEGY_SIDES = tuple(sides) if sides else None
    _GATE_CACHE.clear()


def strategy_matrix_gate(fast: bool = False,
                         repeats: int = 5) -> Dict[str, Dict[str, object]]:
    """ConnectIt-style strategy matrix: every sampling strategy x graph
    family, plus ``solver="auto"`` (schema 7, DESIGN.md §16).

    Each fixed side is the work-adaptive C-2 solve pinned to one
    registered sampling strategy; the ``auto`` side is the full
    ``solver="auto"`` dispatch (cost model + delegation), timed
    end-to-end so its measured seconds *include* the feature extraction
    and model lookup a real caller pays.  All sides are timed
    interleaved (best-of-k, jit caches warm, same pattern as
    :func:`frontier_wallclock_gate`) and every side's labels must be
    bit-identical to the dense oracle.  Raw per-round seconds are
    recorded per side so ``check_artifact.py`` re-derives both verdicts
    (bit-identity, auto <= 1.1x the best fixed strategy at geomean)
    from the rows instead of trusting summary booleans.
    """
    from repro.connectivity import frontier as _frontier
    from repro.graphs import stats as _stats

    cache_key = f"strategy_gate:fast={fast}"
    if cache_key in _GATE_CACHE:
        return _GATE_CACHE[cache_key]
    del fast  # one graph per regime is already the fast set
    suite = gen.paper_suite(small=True)
    out: Dict[str, Dict[str, object]] = {}
    for name in STRATEGY_GATE_GRAPHS:
        g = suite[name]
        src_np, dst_np, n = g.to_numpy()
        oracle = connected_components_oracle(src_np, dst_np, n)
        skew = _stats.degree_skew(src_np, dst_np, n)
        sides = [(s, SolveOptions(algorithm="contour", variant="C-2",
                                  backend="xla", sampling=2,
                                  compact_every=2, sampling_strategy=s))
                 for s in _frontier.SAMPLING_STRATEGIES]
        sides.append(("auto", SolveOptions(algorithm="auto",
                                           backend="xla")))
        if _STRATEGY_SIDES is not None:
            sides = [sd for sd in sides if sd[0] in _STRATEGY_SIDES]
        fns = [(side, lambda o=o: solve(g, o)) for side, o in sides]
        row_sides: Dict[str, Dict[str, object]] = {}
        for side, fn in fns:               # warmup / compile + labels
            result = fn()
            _block(result)
            row_sides[side] = {
                "bit_identical": bool(np.array_equal(
                    np.asarray(result.labels), oracle)),
                "iterations": int(result.iterations),
                "seconds": [],
            }
            if side == "auto":
                row_sides[side]["provenance"] = list(result.provenance
                                                     or ())
        for r in range(repeats):
            for side, fn in (fns if r % 2 == 0 else fns[::-1]):
                t0 = time.perf_counter()
                _block(fn())
                row_sides[side]["seconds"].append(
                    time.perf_counter() - t0)
        out[name] = {"n": int(n), "m": int(len(src_np)),
                     "degree_skew": float(skew), "sides": row_sides}
    _GATE_CACHE[cache_key] = out
    return out


def strategy_summary(gate: Dict[str, Dict[str, object]]) -> Dict[str, object]:
    """Re-derive the two strategy-gate verdicts from the raw rows."""
    bit_ok = True
    ratios = []
    for row in gate.values():
        sides = row["sides"]
        for d in sides.values():
            bit_ok = bit_ok and bool(d.get("bit_identical"))
        fixed = [min(d["seconds"]) for s, d in sides.items()
                 if s != "auto" and d.get("seconds")]
        auto = sides.get("auto", {}).get("seconds")
        if fixed and auto:
            ratios.append(min(auto) / min(fixed))
    geo = float(np.exp(np.mean(np.log(ratios)))) if ratios else 1.0
    return {
        "strategy_all_bit_identical": bool(bit_ok),
        "auto_vs_best_fixed_geomean": geo,
        "auto_within_tolerance": bool(geo <= STRATEGY_AUTO_TOLERANCE),
    }


# auto may pay feature extraction + dispatch on top of the winning
# strategy's own solve; the gate allows 10% at geomean across the matrix
STRATEGY_AUTO_TOLERANCE = 1.1


def autotune_gate(fast: bool = False, repeats: int = 5,
                  retune: bool = False,
                  cache_path: Optional[str] = None
                  ) -> Dict[str, Dict[str, object]]:
    """Measure the autotuner against its heuristic prior, per suite graph.

    For each graph the measuring autotuner (``planner.autotune``) tunes
    the work-adaptive C-2 solve; the tuned plan and the heuristic prior
    are then *re-measured* interleaved (best-of-k, warm caches).  The
    recorded ``ratio`` is heuristic/tuned seconds — defined as exactly
    1.0 when the tuner kept the prior (``config_equal``), since equal
    configs trace to the identical program.  If a differing tuned plan
    fails to hold up under re-measurement it is demoted back to the
    prior *and written back to the cache* (that is what retuning means);
    the rejected candidate's time stays in the row
    (``rejected_candidate_s``) for honesty.  The gate therefore
    certifies what ``solve(backend="auto")`` will actually deploy.
    """
    cache_key = f"tune_gate:fast={fast}:retune={retune}"
    if cache_key in _GATE_CACHE:
        return _GATE_CACHE[cache_key]
    if cache_path is None:
        cache_path = _planner.cache.cache_path()
    if retune:
        _planner.cache.clear(cache_path)
    platform = jax.default_backend()
    out: Dict[str, Dict[str, object]] = {}
    for name, g in suite_graphs(fast).items():
        opts = SolveOptions(algorithm="contour", variant="C-2",
                            sampling=2, compact_every=2)
        heur = _planner.heuristic_plan(g.n_vertices, g.n_edges, platform)
        tuned, timings = _planner.autotune(g, opts, platform=platform,
                                           repeats=3, cache_path=cache_path)
        differs = not tuned.config_equal(heur)
        row: Dict[str, object] = {
            "tuner_timings": timings,
            "heuristic_config": heur.to_config(),
            "tuned_config": tuned.to_config(),
        }
        if differs:
            # re-measure both interleaved — the deployment-time check
            plans = [("heur", heur), ("tuned", tuned)]
            best = {"heur": float("inf"), "tuned": float("inf")}

            def run(p):
                _block(solve(g, opts.replace(
                    plan=p.replace(origin="pinned"), backend=p.backend)))

            for _, p in plans:
                run(p)                     # warmup / compile
            for r in range(repeats):
                for side, p in (plans if r % 2 == 0 else plans[::-1]):
                    t0 = time.perf_counter()
                    run(p)
                    best[side] = min(best[side],
                                     time.perf_counter() - t0)
            if best["tuned"] >= best["heur"]:
                # the candidate did not hold up: deploy (and cache) the
                # prior — the row records the demotion and the rejected
                # candidate's measured time
                _planner.cache.store(g.n_vertices, g.n_edges, platform,
                                     heur.replace(origin="tuned"),
                                     time_s=best["heur"], timings=timings,
                                     origin="tuned", path=cache_path)
                row.update(plan_differs=False, demoted_at_gate=True,
                           rejected_candidate_s=best["tuned"],
                           tuned_config=heur.to_config(),
                           heuristic_s=best["heur"],
                           tuned_s=best["heur"], ratio=1.0)
            else:
                row.update(plan_differs=True,
                           heuristic_s=best["heur"],
                           tuned_s=best["tuned"],
                           ratio=best["heur"] / best["tuned"])
        else:
            t = timings.get(_planner.plan_label(heur))
            row.update(plan_differs=False, heuristic_s=t, tuned_s=t,
                       ratio=1.0)
        out[name] = row
    _GATE_CACHE[cache_key] = out
    return out


def autotune_geomean(gate: Dict[str, Dict[str, object]]) -> float:
    """Geomean of heuristic/tuned ratios (1.0 where the prior was kept)."""
    ratios = [float(row.get("ratio", 1.0)) for row in gate.values()]
    return float(np.exp(np.mean(np.log(ratios)))) if ratios else 1.0


def validate_backend(backend: str) -> None:
    """Fail fast (``SystemExit``) when ``backend`` cannot run on this host.

    ``benchmarks.run --backend`` probes the requested backend on a
    4-vertex graph through the real ``solve`` facade (fallback disabled)
    *before* the suite starts, so a backend that cannot compile on the
    host platform — e.g. a non-interpreted Pallas TPU kernel on a CPU
    host — dies with one clear sentence instead of a raw lowering error
    mid-suite.
    """
    if backend not in _planner.BACKENDS:
        raise SystemExit(
            f"unknown backend {backend!r}: choose from {_planner.BACKENDS}")
    if backend == "auto":
        return
    from repro.graphs.structs import Graph
    probe = Graph.from_numpy(np.array([0, 1, 2]), np.array([1, 2, 3]),
                             n_vertices=4)
    try:
        solve(probe, backend=backend, kernel_fallback=False)
    except Exception as exc:  # noqa: BLE001 — any compile/launch failure
        raise SystemExit(
            f"backend {backend!r} cannot run on platform "
            f"{jax.default_backend()!r}: {type(exc).__name__}: "
            f"{str(exc)[:200]}\n"
            "hint: Pallas kernels need TPU hardware (or interpret mode); "
            "on a CPU host use --backend xla or auto.") from None


def set_backend(backend: str) -> None:
    """Pin every Contour method row (and its pinned plan) to ``backend``.

    ``benchmarks.run --backend`` calls this after
    :func:`validate_backend`, so one flag retargets the whole suite;
    result caches are dropped because cached rows were measured under
    the previous backend.
    """
    platform = jax.default_backend()
    for m, o in list(_METHOD_OPTIONS.items()):
        if o.algorithm != "contour":
            continue
        plan = getattr(o, "plan", None)
        if plan is not None:
            plan = plan.replace(
                backend=backend,
                interpret=(platform != "tpu"
                           and backend.startswith("pallas")))
        _METHOD_OPTIONS[m] = o.replace(backend=backend, plan=plan)
    _CACHE.clear()
    _GATE_CACHE.clear()


def records_to_json(records: List[Record], fast: bool = False,
                    gate: Optional[Dict[str, Dict[str, float]]] = None,
                    streaming: Optional[Dict[str, Dict[str, float]]] = None,
                    frontier_wallclock: Optional[Dict] = None,
                    autotune: Optional[Dict] = None,
                    tuning_cache: Optional[Dict] = None,
                    oocore: Optional[Dict] = None,
                    strategy: Optional[Dict] = None,
                    ) -> Dict:
    """Machine-readable benchmark artifact (``BENCH_connectivity.json``).

    One entry per (graph, method) with time/iterations (plus the
    ``edges_visited`` work counter where the solver reports one — schema 2
    addition), and a summary with three gates:

    * the kernel-subsystem gate comparing ``C-2-blk`` (dispatched backend +
      on-device fixpoint) against the seed XLA scatter-min path (``C-2``).
      ``gate`` is the paired interleaved measurement from
      :func:`blocked_vs_xla_gate` (drift-robust); when absent the summary
      falls back to the figure-suite times;
    * the frontier gate (:func:`frontier_gate`): the work-adaptive
      ``C-2-cmp`` row must visit strictly fewer edges than dense
      ``iterations × m`` with a bit-identical fixed point, per graph;
    * the streaming gate (``benchmarks.streaming.run_gate`` — schema 3
      addition): a 64-micro-batch shuffled stream must land bit-identical
      to the one-shot solve with cumulative ``edges_visited`` under 2x
      the dense sweep.  The artifact stays schema 2 when ``streaming`` is
      not supplied;
    * the **wall-clock gates** (schema 5): ``frontier_wallclock`` (from
      :func:`frontier_wallclock_gate`) must show a frontier schedule
      beating dense wall time (ratio < 1.0) on at least one
      (graph, schedule) pair, and ``autotune`` (from
      :func:`autotune_gate`) must show the autotuned plan at geomean
      >= 1.0x the heuristic prior.  Both store raw per-side seconds;
      ``check_artifact.py`` re-derives the verdicts from those instead of
      trusting the summary.  ``tuning_cache`` embeds the on-disk tuning
      cache entries so the artifact records *which* plans were deployed;
    * the **out-of-core gate** (``benchmarks.oocore.run_gate`` — schema 6
      addition): chunk-streamed solves must land bit-identical to the
      in-core oracle, shrink the surviving edge set strictly every round,
      and — on a stress graph at least 4x the chunk budget — keep peak
      device bytes below the total edge bytes the in-core path would
      materialise.  All three verdicts are re-derived from the raw
      per-row numbers by ``check_artifact.py``;
    * the **strategy gate** (:func:`strategy_matrix_gate` — schema 7
      addition): every sampling strategy and ``solver="auto"`` must land
      bit-identical to the dense oracle on every matrix graph, and
      auto's best-of-k wall clock must stay within
      ``STRATEGY_AUTO_TOLERANCE`` (1.1x) of the best single fixed
      strategy at geomean — both re-derived from the raw per-side
      seconds by ``check_artifact.py``.
    """
    times = pivot(records, "time_s")
    if gate:
        ratios = [row["speedup"] for row in gate.values()]
    else:
        ratios = [row["C-2"] / row["C-2-blk"]
                  for row in times.values()
                  if "C-2" in row and "C-2-blk" in row and row["C-2-blk"] > 0]
    summary = {
        "n_graphs": len(times),
        "all_correct": all(r.correct for r in records),
    }
    if ratios:
        summary["blocked_vs_xla_speedup_geomean"] = float(
            np.exp(np.mean(np.log(ratios))))
        summary["blocked_vs_xla_speedup_min"] = float(min(ratios))
    if gate:
        summary["blocked_path_hlo_identical"] = all(
            row.get("hlo_identical", False) for row in gate.values())
    frontier = frontier_gate(records)
    if frontier:
        summary["frontier_visits_fewer_edges"] = all(
            row["fewer_than_dense"] for row in frontier.values())
        # None = not measured (C-2 row absent from the run) — only a
        # computed False is a regression
        summary["frontier_bit_identical"] = all(
            row["bit_identical"] is not False for row in frontier.values())
    if streaming:
        from benchmarks.streaming import summarise as _stream_summary
        summary.update(_stream_summary(streaming))
    if frontier_wallclock:
        best = min(row["best_ratio"] for row in frontier_wallclock.values())
        summary["frontier_beats_dense_wallclock"] = bool(best < 1.0)
        summary["frontier_best_wallclock_ratio"] = float(best)
    if autotune:
        geo = autotune_geomean(autotune)
        summary["autotune_vs_heuristic_geomean"] = geo
        summary["autotune_ge_heuristic"] = bool(geo >= 1.0 - 1e-9)
    if oocore:
        from benchmarks.oocore import summarise as _oocore_summary
        summary.update(_oocore_summary(oocore))
    if strategy:
        summary.update(strategy_summary(strategy))
    schema = 2
    if streaming:
        schema = 3
    if frontier_wallclock and autotune:
        schema = 5
    if oocore:
        schema = 6
    if strategy:
        schema = 7
    return {
        "schema": schema,
        "suite": "paper_connectivity",
        "fast": fast,
        "summary": summary,
        "blocked_gate": gate or {},
        "frontier_gate": frontier,
        "streaming_gate": streaming or {},
        "frontier_wallclock_gate": frontier_wallclock or {},
        "autotune_gate": autotune or {},
        "oocore_gate": oocore or {},
        "strategy_gate": strategy or {},
        "tuning_cache": tuning_cache or {},
        "records": [dataclasses.asdict(r) for r in records],
    }


def pivot(records: List[Record], field: str) -> Dict[str, Dict[str, float]]:
    """graph -> method -> field value."""
    out: Dict[str, Dict[str, float]] = {}
    for r in records:
        out.setdefault(r.graph, {})[r.method] = getattr(r, field)
    return out


def print_table(title: str, table: Dict[str, Dict[str, float]],
                fmt: str = "{:>10.4f}", methods: Optional[List[str]] = None):
    methods = methods or METHODS
    print(f"\n== {title} ==")
    print(f"{'graph':18s}" + "".join(f"{m:>11s}" for m in methods))
    for gname, row in table.items():
        cells = "".join(
            fmt.format(row[m]) if m in row else " " * 11 for m in methods)
        print(f"{gname:18s}{cells}")
