"""Shared harness for the paper's connectivity experiments (Figs. 1-4).

Runs every method on every suite graph once, measuring converged wall time
(after jit warmup) and iteration counts; the fig_* modules slice this table
into the paper's four figures.  ``ConnectIt`` is Rem's union-find (the
algorithm ConnectIt found fastest on shared memory), host-side per
DESIGN.md §8.5, with iteration count 1 by the paper's convention (§IV-C).

``C-2-blk`` is the kernel-subsystem path (DESIGN.md §3.4): the dispatched
contour_mm backend (label-blocked Pallas on TPU, scatter-min under XLA on
CPU hosts) iterated by the on-device ``lax.while_loop`` fixpoint of
``contour_cc_fixpoint`` — zero per-iteration host syncs.  ``run_suite``
results serialise to ``BENCH_connectivity.json`` (see ``records_to_json``)
so the perf trajectory is machine-readable across PRs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

import jax

from repro.connectivity import SolveOptions, solve
from repro.connectivity.contour import VARIANTS, contour_labels
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle, labels_equivalent
from repro.kernels.contour_mm.ops import contour_cc_fixpoint

METHODS = list(VARIANTS) + ["C-2-blk", "FastSV", "ConnectIt"]

# Every method (except the raw kernel-path fixpoint) runs through the
# unified repro.connectivity.solve facade — the bench doubles as an
# integration check that one signature covers all families, and every
# row uniformly includes the facade's (small) per-call overhead: option
# resolution plus, for the host-side ConnectIt row, the edge-array
# host/device conversions a real caller pays.  Contour variants pin
# backend="xla" so the C-2 vs C-2-blk comparison isolates the
# kernel-dispatch path.
_METHOD_OPTIONS = {
    m: SolveOptions(algorithm="contour", variant=m, backend="xla")
    for m in VARIANTS
}
_METHOD_OPTIONS["FastSV"] = SolveOptions(algorithm="fastsv")
_METHOD_OPTIONS["ConnectIt"] = SolveOptions(algorithm="union_find")


@dataclasses.dataclass
class Record:
    graph: str
    graph_id: int
    n_vertices: int
    n_edges: int
    method: str
    iterations: int
    time_s: float
    correct: bool


def _block(out):
    for x in jax.tree_util.tree_leaves(out):
        if hasattr(x, "block_until_ready"):
            x.block_until_ready()


def _time_jax(fn, repeats: int = 3):
    """Best-of-k wall time for a callable returning a pytree of arrays."""
    out = fn()                      # warmup / compile
    _block(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        _block(out)
        best = min(best, time.perf_counter() - t0)
    return out, best


def bench_graph(name: str, gid: int, graph, *, repeats: int = 2,
                methods: Optional[List[str]] = None) -> List[Record]:
    n = graph.n_vertices
    oracle = connected_components_oracle(*graph.to_numpy())
    records = []
    for method in methods or METHODS:
        # C-1 needs O(diameter) iterations (paper Fig. 1: up to 2369) —
        # one timed run is plenty on long-diameter graphs; ConnectIt is a
        # sequential host loop, also timed once.
        reps = 1 if method in ("C-1", "ConnectIt") else repeats
        if method == "C-2-blk":
            fn = lambda: contour_cc_fixpoint(graph, backend="auto")
            (labels, iters, _), dt = _time_jax(fn, reps)
            iters = int(iters)
        elif method == "ConnectIt":
            # pure-NumPy host loop: nothing jit-compiles on its path
            # (solvers report their own converged flag), so time the one
            # run without a warmup pass
            t0 = time.perf_counter()
            result = solve(graph, _METHOD_OPTIONS[method])
            _block(result)
            dt = time.perf_counter() - t0
            labels, iters = result.labels, int(result.iterations)
        else:
            opts = _METHOD_OPTIONS[method]
            fn = lambda o=opts: solve(graph, o)
            result, dt = _time_jax(fn, reps)
            labels, iters = result.labels, int(result.iterations)
        ok = labels_equivalent(np.asarray(labels), oracle)
        records.append(Record(
            graph=name, graph_id=gid, n_vertices=n,
            n_edges=graph.n_edges, method=method,
            iterations=iters, time_s=dt, correct=bool(ok)))
    return records


_CACHE: Dict[str, List[Record]] = {}
_GATE_CACHE: Dict[str, Dict[str, Dict[str, float]]] = {}


def suite_graphs(fast: bool = False):
    suite = gen.paper_suite(small=True)
    if fast:
        keep = ("path_64k", "grid_256x256", "rmat_16", "delaunay_n16",
                "mix_3comp")
        suite = {k: v for k, v in suite.items() if k in keep}
    return suite


def run_suite(fast: bool = False, repeats: int = 3) -> List[Record]:
    key = f"fast={fast}"
    if key in _CACHE:
        return _CACHE[key]
    records: List[Record] = []
    for gid, (name, g) in enumerate(suite_graphs(fast).items()):
        records.extend(bench_graph(name, gid, g, repeats=repeats))
    _CACHE[key] = records
    return records


def _hlo_op_histogram(compiled) -> Dict[str, int]:
    """Opcode histogram of a compiled program (naming-insensitive)."""
    import re as _re
    ops = _re.findall(r"= \S+ (\w+)\(", compiled.as_text())
    hist: Dict[str, int] = {}
    for op in ops:
        hist[op] = hist.get(op, 0) + 1
    return hist


def blocked_vs_xla_gate(fast: bool = False,
                        repeats: int = 7) -> Dict[str, Dict[str, float]]:
    """Paired perf gate: kernel-path fixpoint vs the seed XLA C-2.

    The figure suite times each method in a separate block, minutes apart —
    on a shared CPU host that drift swamps a comparison whose true ratio is
    ~1.  Here the two are timed *interleaved* (A/B order alternating per
    round, best-of-k per side, jit caches warm), and additionally the two
    compiled programs are compared op-for-op: on a non-TPU host the
    dispatch resolves the blocked path to the same scatter-min sweep, so
    ``hlo_identical`` is the noise-free form of "no slower" (the TPU
    kernel path can only be timed on TPU hardware).
    """
    from repro.kernels.contour_mm.ops import contour_cc_fixpoint

    cache_key = f"gate:fast={fast}"
    if cache_key in _GATE_CACHE:
        return _GATE_CACHE[cache_key]
    out: Dict[str, Dict[str, float]] = {}
    for name, g in suite_graphs(fast).items():
        fn_xla = lambda: contour_labels(g.src, g.dst, g.n_vertices,
                                        variant="C-2")
        fn_blk = lambda: contour_cc_fixpoint(g, backend="auto")
        best = {"xla": float("inf"), "blk": float("inf")}
        for fn in (fn_xla, fn_blk):        # warmup / compile both first
            for x in fn():
                x.block_until_ready()
        pairs = [("xla", fn_xla), ("blk", fn_blk)]
        for r in range(repeats):
            for side, fn in (pairs if r % 2 == 0 else pairs[::-1]):
                t0 = time.perf_counter()
                for x in fn():
                    x.block_until_ready()
                best[side] = min(best[side], time.perf_counter() - t0)
        hlo_same = _hlo_op_histogram(
            contour_labels.lower(g.src, g.dst, g.n_vertices,
                                 variant="C-2").compile()
        ) == _hlo_op_histogram(
            contour_cc_fixpoint.lower(g, backend="auto").compile())
        out[name] = {"xla_s": best["xla"], "blk_s": best["blk"],
                     "speedup": best["xla"] / best["blk"],
                     "hlo_identical": bool(hlo_same)}
    _GATE_CACHE[cache_key] = out
    return out


def records_to_json(records: List[Record], fast: bool = False,
                    gate: Optional[Dict[str, Dict[str, float]]] = None) -> Dict:
    """Machine-readable benchmark artifact (``BENCH_connectivity.json``).

    One entry per (graph, method) with time/iterations, plus a summary
    comparing the kernel-subsystem path (``C-2-blk``: dispatched backend +
    on-device fixpoint) against the seed XLA scatter-min path (``C-2``) —
    the perf gate for the label-blocked refactor.  ``gate`` is the paired
    interleaved measurement from :func:`blocked_vs_xla_gate` (drift-robust);
    when absent the summary falls back to the figure-suite times.
    """
    times = pivot(records, "time_s")
    if gate:
        ratios = [row["speedup"] for row in gate.values()]
    else:
        ratios = [row["C-2"] / row["C-2-blk"]
                  for row in times.values()
                  if "C-2" in row and "C-2-blk" in row and row["C-2-blk"] > 0]
    summary = {
        "n_graphs": len(times),
        "all_correct": all(r.correct for r in records),
    }
    if ratios:
        summary["blocked_vs_xla_speedup_geomean"] = float(
            np.exp(np.mean(np.log(ratios))))
        summary["blocked_vs_xla_speedup_min"] = float(min(ratios))
    if gate:
        summary["blocked_path_hlo_identical"] = all(
            row.get("hlo_identical", False) for row in gate.values())
    return {
        "schema": 1,
        "suite": "paper_connectivity",
        "fast": fast,
        "summary": summary,
        "blocked_gate": gate or {},
        "records": [dataclasses.asdict(r) for r in records],
    }


def pivot(records: List[Record], field: str) -> Dict[str, Dict[str, float]]:
    """graph -> method -> field value."""
    out: Dict[str, Dict[str, float]] = {}
    for r in records:
        out.setdefault(r.graph, {})[r.method] = getattr(r, field)
    return out


def print_table(title: str, table: Dict[str, Dict[str, float]],
                fmt: str = "{:>10.4f}", methods: Optional[List[str]] = None):
    methods = methods or METHODS
    print(f"\n== {title} ==")
    print(f"{'graph':18s}" + "".join(f"{m:>11s}" for m in methods))
    for gname, row in table.items():
        cells = "".join(
            fmt.format(row[m]) if m in row else " " * 11 for m in methods)
        print(f"{gname:18s}{cells}")
