"""Shared harness for the paper's connectivity experiments (Figs. 1-4).

Runs every method on every suite graph once, measuring converged wall time
(after jit warmup) and iteration counts; the fig_* modules slice this table
into the paper's four figures.  ``ConnectIt`` is Rem's union-find (the
algorithm ConnectIt found fastest on shared memory), host-side per
DESIGN.md §8.5, with iteration count 1 by the paper's convention (§IV-C).

``C-2-blk`` is the kernel-subsystem path (DESIGN.md §3.4): the dispatched
contour_mm backend (label-blocked Pallas on TPU, scatter-min under XLA on
CPU hosts) iterated by the on-device ``lax.while_loop`` fixpoint of
``contour_cc_fixpoint`` — zero per-iteration host syncs.  ``C-2-cmp`` is
C-2 under the work-adaptive frontier contraction schedule (DESIGN.md §10:
sampling prefix, largest-component filter, periodic active-edge
contraction) — its ``edges_visited`` counter must come in strictly under
the dense ``iterations × m`` and its labels must be bit-identical to
uncompacted C-2 (both gated in the artifact summary).  ``run_suite``
results serialise to ``BENCH_connectivity.json`` (see ``records_to_json``)
so the perf trajectory is machine-readable across PRs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

import jax

from repro.connectivity import SolveOptions, solve
from repro.connectivity.contour import VARIANTS, contour_labels
from repro.graphs import generators as gen
from repro.graphs.oracle import connected_components_oracle, labels_equivalent
from repro.kernels.contour_mm.ops import contour_cc_fixpoint

METHODS = list(VARIANTS) + ["C-2-blk", "C-2-cmp", "FastSV", "ConnectIt"]

# Every method (except the raw kernel-path fixpoint) runs through the
# unified repro.connectivity.solve facade — the bench doubles as an
# integration check that one signature covers all families, and every
# row uniformly includes the facade's (small) per-call overhead: option
# resolution plus, for the host-side ConnectIt row, the edge-array
# host/device conversions a real caller pays.  Contour variants pin
# backend="xla" so the C-2 vs C-2-blk comparison isolates the
# kernel-dispatch path.
_METHOD_OPTIONS = {
    m: SolveOptions(algorithm="contour", variant=m, backend="xla")
    for m in VARIANTS
}
_METHOD_OPTIONS["FastSV"] = SolveOptions(algorithm="fastsv")
_METHOD_OPTIONS["ConnectIt"] = SolveOptions(algorithm="union_find")
# the work-adaptive row: 2 sampling-prefix sweeps, largest-component
# filter, then contraction every 2 iterations (backend pinned like the
# other Contour rows so C-2 vs C-2-cmp isolates the schedule)
_METHOD_OPTIONS["C-2-cmp"] = SolveOptions(
    algorithm="contour", variant="C-2", backend="xla",
    sampling=2, compact_every=2)


@dataclasses.dataclass
class Record:
    graph: str
    graph_id: int
    n_vertices: int
    n_edges: int
    method: str
    iterations: int
    time_s: float
    correct: bool
    # cumulative edges swept (None for solvers that do not count);
    # iterations*m on the dense schedule, strictly less under the
    # C-2-cmp frontier contraction — see DESIGN.md §10
    edges_visited: Optional[float] = None
    # labels elementwise-equal to this graph's uncompacted C-2 row
    # (recorded for C-2-cmp only: the bit-identical frontier gate)
    bit_identical: Optional[bool] = None


def _block(out):
    for x in jax.tree_util.tree_leaves(out):
        if hasattr(x, "block_until_ready"):
            x.block_until_ready()


def _time_jax(fn, repeats: int = 3):
    """Best-of-k wall time for a callable returning a pytree of arrays."""
    out = fn()                      # warmup / compile
    _block(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        _block(out)
        best = min(best, time.perf_counter() - t0)
    return out, best


def bench_graph(name: str, gid: int, graph, *, repeats: int = 2,
                methods: Optional[List[str]] = None) -> List[Record]:
    n = graph.n_vertices
    oracle = connected_components_oracle(*graph.to_numpy())
    records = []
    method_labels = {}
    for method in methods or METHODS:
        # C-1 needs O(diameter) iterations (paper Fig. 1: up to 2369) —
        # one timed run is plenty on long-diameter graphs; ConnectIt is a
        # sequential host loop, also timed once.
        reps = 1 if method in ("C-1", "ConnectIt") else repeats
        visited = None
        if method == "C-2-blk":
            fn = lambda: contour_cc_fixpoint(graph, backend="auto")
            (labels, iters, _, visited), dt = _time_jax(fn, reps)
            iters = int(iters)
            visited = float(visited)
        elif method == "ConnectIt":
            # pure-NumPy host loop: nothing jit-compiles on its path
            # (solvers report their own converged flag), so time the one
            # run without a warmup pass
            t0 = time.perf_counter()
            result = solve(graph, _METHOD_OPTIONS[method])
            _block(result)
            dt = time.perf_counter() - t0
            labels, iters = result.labels, int(result.iterations)
        else:
            opts = _METHOD_OPTIONS[method]
            fn = lambda o=opts: solve(graph, o)
            result, dt = _time_jax(fn, reps)
            labels, iters = result.labels, int(result.iterations)
            if result.edges_visited is not None:
                visited = float(result.edges_visited)
        method_labels[method] = np.asarray(labels)
        ok = labels_equivalent(np.asarray(labels), oracle)
        # the frontier gate's bit-identical half: the compacted fixed
        # point must equal uncompacted C-2 elementwise, not just as a
        # partition (both follow the min-vertex-id convention)
        bit_identical = None
        if method == "C-2-cmp" and "C-2" in method_labels:
            bit_identical = bool(np.array_equal(method_labels["C-2-cmp"],
                                                method_labels["C-2"]))
        records.append(Record(
            graph=name, graph_id=gid, n_vertices=n,
            n_edges=graph.n_edges, method=method,
            iterations=iters, time_s=dt, correct=bool(ok),
            edges_visited=visited, bit_identical=bit_identical))
    return records


_CACHE: Dict[str, List[Record]] = {}
_GATE_CACHE: Dict[str, Dict[str, Dict[str, float]]] = {}


def suite_graphs(fast: bool = False):
    suite = gen.paper_suite(small=True)
    if fast:
        keep = ("path_64k", "grid_256x256", "rmat_16", "delaunay_n16",
                "mix_3comp")
        suite = {k: v for k, v in suite.items() if k in keep}
    return suite


def run_suite(fast: bool = False, repeats: int = 3) -> List[Record]:
    key = f"fast={fast}"
    if key in _CACHE:
        return _CACHE[key]
    records: List[Record] = []
    for gid, (name, g) in enumerate(suite_graphs(fast).items()):
        records.extend(bench_graph(name, gid, g, repeats=repeats))
    _CACHE[key] = records
    return records


def _hlo_op_histogram(compiled) -> Dict[str, int]:
    """Opcode histogram of a compiled program (naming-insensitive)."""
    import re as _re
    ops = _re.findall(r"= \S+ (\w+)\(", compiled.as_text())
    hist: Dict[str, int] = {}
    for op in ops:
        hist[op] = hist.get(op, 0) + 1
    return hist


def blocked_vs_xla_gate(fast: bool = False,
                        repeats: int = 7) -> Dict[str, Dict[str, float]]:
    """Paired perf gate: kernel-path fixpoint vs the seed XLA C-2.

    The figure suite times each method in a separate block, minutes apart —
    on a shared CPU host that drift swamps a comparison whose true ratio is
    ~1.  Here the two are timed *interleaved* (A/B order alternating per
    round, best-of-k per side, jit caches warm), and additionally the two
    compiled programs are compared op-for-op: on a non-TPU host the
    dispatch resolves the blocked path to the same scatter-min sweep, so
    ``hlo_identical`` is the noise-free form of "no slower" (the TPU
    kernel path can only be timed on TPU hardware).
    """
    from repro.kernels.contour_mm.ops import contour_cc_fixpoint

    cache_key = f"gate:fast={fast}"
    if cache_key in _GATE_CACHE:
        return _GATE_CACHE[cache_key]
    out: Dict[str, Dict[str, float]] = {}
    for name, g in suite_graphs(fast).items():
        fn_xla = lambda: contour_labels(g.src, g.dst, g.n_vertices,
                                        variant="C-2")
        fn_blk = lambda: contour_cc_fixpoint(g, backend="auto")
        best = {"xla": float("inf"), "blk": float("inf")}
        for fn in (fn_xla, fn_blk):        # warmup / compile both first
            for x in fn():
                x.block_until_ready()
        pairs = [("xla", fn_xla), ("blk", fn_blk)]
        for r in range(repeats):
            for side, fn in (pairs if r % 2 == 0 else pairs[::-1]):
                t0 = time.perf_counter()
                for x in fn():
                    x.block_until_ready()
                best[side] = min(best[side], time.perf_counter() - t0)
        hlo_same = _hlo_op_histogram(
            contour_labels.lower(g.src, g.dst, g.n_vertices,
                                 variant="C-2").compile()
        ) == _hlo_op_histogram(
            contour_cc_fixpoint.lower(g, backend="auto").compile())
        out[name] = {"xla_s": best["xla"], "blk_s": best["blk"],
                     "speedup": best["xla"] / best["blk"],
                     "hlo_identical": bool(hlo_same)}
    _GATE_CACHE[cache_key] = out
    return out


def frontier_gate(records: List[Record]) -> Dict[str, Dict[str, float]]:
    """Per-graph work-adaptivity gate from the ``C-2-cmp`` rows.

    For every graph: the frontier schedule must *visit strictly fewer
    edges* than the dense ``iterations × m`` equivalent, while reaching a
    fixed point *bit-identical* to uncompacted C-2 (``Record.bit_identical``
    — computed elementwise in ``bench_graph``; ``None`` when the C-2 row
    was not benchmarked alongside, recorded as not-measured rather than a
    failure).

    ``time_ratio_vs_dense`` is recorded for honesty, *not* gated: on the
    XLA backend (this CPU host) the frontier limit is realised as
    full-shape masked tiles plus an O(m log m) partition per compaction,
    so the counter savings do **not** translate into wall time here —
    C-2-cmp typically runs slower than C-2 on CPU.  The wall-time payoff
    is the TPU blocked-kernel path, where the live-chunk count skips
    whole grid steps (DESIGN.md §10); ``edges_visited`` is the
    platform-independent work measure this gate certifies.
    """
    times = pivot(records, "time_s")
    iters = pivot(records, "iterations")
    out: Dict[str, Dict[str, float]] = {}
    for r in records:
        if r.method != "C-2-cmp" or r.edges_visited is None:
            continue
        # baseline = the *dense C-2 row's* iterations x m — using the
        # compacted row's own (sampling-inflated) iteration count would
        # let a schedule pass by beating a weaker baseline than the run
        # it claims to improve on
        dense_iters = iters.get(r.graph, {}).get("C-2", r.iterations)
        dense = float(dense_iters) * r.n_edges
        dense_t = times.get(r.graph, {}).get("C-2")
        out[r.graph] = {
            "edges_visited": r.edges_visited,
            "dense_equiv": dense,
            "work_saved_frac": 1.0 - r.edges_visited / dense if dense else 0.0,
            "fewer_than_dense": bool(r.edges_visited < dense),
            "bit_identical": r.bit_identical,
            "time_ratio_vs_dense": (r.time_s / dense_t if dense_t else None),
        }
    return out


def records_to_json(records: List[Record], fast: bool = False,
                    gate: Optional[Dict[str, Dict[str, float]]] = None,
                    streaming: Optional[Dict[str, Dict[str, float]]] = None,
                    ) -> Dict:
    """Machine-readable benchmark artifact (``BENCH_connectivity.json``).

    One entry per (graph, method) with time/iterations (plus the
    ``edges_visited`` work counter where the solver reports one — schema 2
    addition), and a summary with three gates:

    * the kernel-subsystem gate comparing ``C-2-blk`` (dispatched backend +
      on-device fixpoint) against the seed XLA scatter-min path (``C-2``).
      ``gate`` is the paired interleaved measurement from
      :func:`blocked_vs_xla_gate` (drift-robust); when absent the summary
      falls back to the figure-suite times;
    * the frontier gate (:func:`frontier_gate`): the work-adaptive
      ``C-2-cmp`` row must visit strictly fewer edges than dense
      ``iterations × m`` with a bit-identical fixed point, per graph;
    * the streaming gate (``benchmarks.streaming.run_gate`` — schema 3
      addition): a 64-micro-batch shuffled stream must land bit-identical
      to the one-shot solve with cumulative ``edges_visited`` under 2x
      the dense sweep.  The artifact stays schema 2 when ``streaming`` is
      not supplied.
    """
    times = pivot(records, "time_s")
    if gate:
        ratios = [row["speedup"] for row in gate.values()]
    else:
        ratios = [row["C-2"] / row["C-2-blk"]
                  for row in times.values()
                  if "C-2" in row and "C-2-blk" in row and row["C-2-blk"] > 0]
    summary = {
        "n_graphs": len(times),
        "all_correct": all(r.correct for r in records),
    }
    if ratios:
        summary["blocked_vs_xla_speedup_geomean"] = float(
            np.exp(np.mean(np.log(ratios))))
        summary["blocked_vs_xla_speedup_min"] = float(min(ratios))
    if gate:
        summary["blocked_path_hlo_identical"] = all(
            row.get("hlo_identical", False) for row in gate.values())
    frontier = frontier_gate(records)
    if frontier:
        summary["frontier_visits_fewer_edges"] = all(
            row["fewer_than_dense"] for row in frontier.values())
        # None = not measured (C-2 row absent from the run) — only a
        # computed False is a regression
        summary["frontier_bit_identical"] = all(
            row["bit_identical"] is not False for row in frontier.values())
    if streaming:
        from benchmarks.streaming import summarise as _stream_summary
        summary.update(_stream_summary(streaming))
    return {
        "schema": 3 if streaming else 2,
        "suite": "paper_connectivity",
        "fast": fast,
        "summary": summary,
        "blocked_gate": gate or {},
        "frontier_gate": frontier,
        "streaming_gate": streaming or {},
        "records": [dataclasses.asdict(r) for r in records],
    }


def pivot(records: List[Record], field: str) -> Dict[str, Dict[str, float]]:
    """graph -> method -> field value."""
    out: Dict[str, Dict[str, float]] = {}
    for r in records:
        out.setdefault(r.graph, {})[r.method] = getattr(r, field)
    return out


def print_table(title: str, table: Dict[str, Dict[str, float]],
                fmt: str = "{:>10.4f}", methods: Optional[List[str]] = None):
    methods = methods or METHODS
    print(f"\n== {title} ==")
    print(f"{'graph':18s}" + "".join(f"{m:>11s}" for m in methods))
    for gname, row in table.items():
        cells = "".join(
            fmt.format(row[m]) if m in row else " " * 11 for m in methods)
        print(f"{gname:18s}{cells}")
