"""Paper Fig. 4: speedup of Contour variants over ConnectIt (Rem's
union-find).

Paper: C-m beats ConnectIt on 31/36 graphs (avg 1.41x), C-2 on 26 (1.2x);
ConnectIt wins when parallel resources are scarce relative to graph size —
which is exactly this container (1 core), so the *expected* reproduction
here is ConnectIt-favourable on big graphs and Contour-favourable on
small/parallel-friendly ones.  The work-depth analysis in EXPERIMENTS.md
§Paper reconciles the two regimes.
"""
from __future__ import annotations

import numpy as np

from benchmarks.connectivity import pivot, print_table, run_suite

VARIANT_COLS = ["C-Syn", "C-1", "C-2", "C-m", "C-11mm", "C-1m1m"]


def main(fast: bool = False):
    records = run_suite(fast=fast)
    times = pivot(records, "time_s")
    speedups = {
        g: {m: row["ConnectIt"] / row[m] for m in VARIANT_COLS if m in row}
        for g, row in times.items()
    }
    print_table("Fig. 4 — speedup vs ConnectIt (Rem's union-find)",
                speedups, fmt="{:>11.2f}", methods=VARIANT_COLS)
    means = {m: float(np.mean([s[m] for s in speedups.values()]))
             for m in VARIANT_COLS}
    wins = {m: sum(1 for s in speedups.values() if s[m] > 1.0)
            for m in VARIANT_COLS}
    n = len(speedups)
    print("\naverage speedup vs ConnectIt: " + "  ".join(
        f"{m}={means[m]:.2f}x({wins[m]}/{n})" for m in VARIANT_COLS))
    return means


if __name__ == "__main__":
    main()
