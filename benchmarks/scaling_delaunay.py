"""Paper §IV-D scaling trend: Delaunay family, time growth vs graph size.

The paper reports growth factors over delaunay_n10 -> n24 (16384x edges):
C-2 x895, C-1m1m x1072, C-m x1268, ConnectIt x1303, C-11mm x1329,
C-Syn x2705, FastSV x4096 — i.e. the async Contour variants scale
*sub-linearly in relative cost* vs FastSV.  We reproduce the trend on
n10..n18 (CPU-bounded) and check the ordering of growth factors.
"""
from __future__ import annotations

from benchmarks.connectivity import bench_graph, print_table
from repro.graphs import generators as gen

SCALES = (10, 12, 14, 16, 18)
METHODS = ("C-Syn", "C-2", "C-m", "FastSV", "ConnectIt")


def main(fast: bool = False):
    scales = SCALES[:3] if fast else SCALES
    rows = {}
    for s in scales:
        g = gen.delaunay_like(s)
        recs = bench_graph(f"delaunay_n{s}", s, g, repeats=2,
                           methods=list(METHODS))
        rows[f"delaunay_n{s}"] = {r.method: r.time_s for r in recs}
    print_table("Delaunay scaling — execution time (s)", rows,
                fmt="{:>11.4f}", methods=list(METHODS))
    lo, hi = f"delaunay_n{scales[0]}", f"delaunay_n{scales[-1]}"
    growth = {m: rows[hi][m] / rows[lo][m] for m in METHODS}
    print("\ngrowth factor "
          f"n{scales[0]}->n{scales[-1]}: " + "  ".join(
              f"{m}=x{growth[m]:.0f}" for m in METHODS))
    assert growth["C-2"] <= growth["FastSV"] * 1.5, \
        "C-2 must not scale worse than FastSV (paper: 895 vs 4096)"
    return growth


if __name__ == "__main__":
    main()
