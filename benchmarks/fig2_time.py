"""Paper Fig. 2: execution time per method per graph (one shared-memory
host; absolute values are this container's CPU, the comparisons are the
reproduction target)."""
from __future__ import annotations

from benchmarks.connectivity import pivot, print_table, run_suite


def main(fast: bool = False):
    records = run_suite(fast=fast)
    table = pivot(records, "time_s")
    print_table("Fig. 2 — execution time (s)", table, fmt="{:>11.4f}")
    # paper §IV-D: C-Syn consistently slower than the async variants
    worse = sum(1 for row in table.values()
                if row["C-Syn"] >= row["C-2"])
    print(f"\nC-Syn slower-or-equal than C-2 on {worse}/{len(table)} graphs "
          "(paper: consistently slower)")
    return records


if __name__ == "__main__":
    main()
