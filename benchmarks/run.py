"""Benchmark entry: ``python -m benchmarks.run [--fast]``.

One section per paper table/figure plus the production-integration and
roofline reports:

  fig1  iterations per method              (paper Fig. 1)
  fig2  execution time                     (paper Fig. 2)
  fig3  speedup vs FastSV                  (paper Fig. 3)
  fig4  speedup vs ConnectIt               (paper Fig. 4)
  scale Delaunay scaling trend             (paper §IV-D)
  dist  distributed shard_map contour      (paper §IV-G analogue)
  dedup MinHash+Contour dedup integration
  roof  dry-run roofline tables            (EXPERIMENTS.md §Roofline)
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (
    dedup_bench,
    distributed_scaling,
    fig1_iterations,
    fig2_time,
    fig3_speedup_fastsv,
    fig4_speedup_connectit,
    roofline_report,
    scaling_delaunay,
)

SECTIONS = [
    ("fig1_iterations", fig1_iterations.main),
    ("fig2_time", fig2_time.main),
    ("fig3_speedup_vs_fastsv", fig3_speedup_fastsv.main),
    ("fig4_speedup_vs_connectit", fig4_speedup_connectit.main),
    ("delaunay_scaling", scaling_delaunay.main),
    ("distributed_contour", distributed_scaling.main),
    ("dedup_integration", dedup_bench.main),
    ("roofline_report", roofline_report.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="subsampled suite for quick runs")
    ap.add_argument("--only", help="comma-separated section prefixes")
    args = ap.parse_args()

    failures = []
    for name, fn in SECTIONS:
        if args.only and not any(name.startswith(p)
                                 for p in args.only.split(",")):
            continue
        print(f"\n{'=' * 72}\n[{name}]\n{'=' * 72}")
        t0 = time.time()
        try:
            fn(fast=args.fast)
            print(f"[{name}] done in {time.time() - t0:.1f}s")
        except Exception:  # noqa: BLE001 — report all sections
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")
    print("\nall benchmark sections passed")


if __name__ == "__main__":
    main()
