"""Benchmark entry: ``python -m benchmarks.run [--fast]``.

One section per paper table/figure plus the production-integration and
roofline reports:

  fig1  iterations per method              (paper Fig. 1)
  fig2  execution time                     (paper Fig. 2)
  fig3  speedup vs FastSV                  (paper Fig. 3)
  fig4  speedup vs ConnectIt               (paper Fig. 4)
  scale Delaunay scaling trend             (paper §IV-D)
  dist  distributed shard_map contour      (paper §IV-G analogue)
  dedup MinHash+Contour dedup integration
  ooc   out-of-core contraction gate       (DESIGN.md §15)
  roof  dry-run roofline tables            (EXPERIMENTS.md §Roofline)
  serve serving-engine traffic + recovery  (DESIGN.md §13)

After the sections run, the connectivity suite records (per-method wall
time + iteration counts, including the ``C-2-blk`` kernel path) are
written to ``BENCH_connectivity.json`` so the perf trajectory stays
machine-readable across PRs; disable with ``--json ''``.
"""
from __future__ import annotations

import argparse
import json
import time
import traceback

from benchmarks import (
    connectivity,
    dedup_bench,
    distributed_scaling,
    fig1_iterations,
    fig2_time,
    fig3_speedup_fastsv,
    fig4_speedup_connectit,
    oocore,
    recovery,
    roofline_report,
    scaling_delaunay,
    serving,
    streaming,
)

SECTIONS = [
    ("fig1_iterations", fig1_iterations.main),
    ("fig2_time", fig2_time.main),
    ("fig3_speedup_vs_fastsv", fig3_speedup_fastsv.main),
    ("fig4_speedup_vs_connectit", fig4_speedup_connectit.main),
    ("delaunay_scaling", scaling_delaunay.main),
    ("distributed_contour", distributed_scaling.main),
    ("dedup_integration", dedup_bench.main),
    ("streaming_vs_scratch", streaming.main),
    ("oocore_gate", oocore.main),
    ("recovery_overhead", recovery.main),
    ("roofline_report", roofline_report.main),
    # writes BENCH_serving.json itself (traffic SLO + recovery gate)
    ("serving_engine", serving.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="subsampled suite for quick runs")
    ap.add_argument("--only", help="comma-separated section prefixes "
                                   "('bench' = artifact-only regen)")
    ap.add_argument("--json", default="BENCH_connectivity.json",
                    help="connectivity artifact path ('' disables)")
    ap.add_argument("--backend", default="auto",
                    help="kernel backend for the suite (validated up "
                         "front: a backend that cannot compile on this "
                         "host fails fast with a clear error)")
    ap.add_argument("--retune", action="store_true",
                    help="clear the plan tuning cache and re-run the "
                         "measuring autotuner from scratch")
    ap.add_argument("--strategy", default=None,
                    help="comma-separated sampling strategies (or 'auto') "
                         "to restrict the strategy-matrix gate to; "
                         "default: all registered strategies + auto")
    args = ap.parse_args()

    # Fail fast on an impossible backend request *before* any section
    # runs — a raw Pallas lowering error mid-suite helps nobody.
    connectivity.validate_backend(args.backend)
    if args.strategy is not None:
        from repro.connectivity.frontier import SAMPLING_STRATEGIES
        known = tuple(SAMPLING_STRATEGIES) + ("auto",)
        requested = tuple(s for s in args.strategy.split(",") if s)
        for s in requested:
            if s not in known:
                raise SystemExit(
                    f"unknown strategy {s!r}: choose from {known}\n"
                    "hint: strategies are registered in "
                    "repro.connectivity.frontier "
                    "(register_sampling_strategy); 'auto' is the cost-"
                    "model dispatch, not a sampling strategy name")
        connectivity.set_strategy_sides(requested)
    if args.backend != "auto":
        connectivity.set_backend(args.backend)

    failures = []
    for name, fn in SECTIONS:
        if args.only and not any(name.startswith(p)
                                 for p in args.only.split(",")):
            continue
        print(f"\n{'=' * 72}\n[{name}]\n{'=' * 72}")
        t0 = time.time()
        try:
            fn(fast=args.fast)
            print(f"[{name}] done in {time.time() - t0:.1f}s")
        except Exception:  # noqa: BLE001 — report all sections
            failures.append(name)
            traceback.print_exc()
    # Emit the artifact when the connectivity suite is in play (no --only,
    # a fig section selected — then run_suite() is already cached — or
    # the explicit 'bench' pseudo-section for artifact-only regen);
    # `--only roof --json x` should not trigger a full suite run.
    want_json = args.json and (
        not args.only
        or any(p.startswith(("fig", "bench"))
               for p in args.only.split(",")))
    if want_json:
        try:
            records = connectivity.run_suite(fast=args.fast)
            gate = connectivity.blocked_vs_xla_gate(fast=args.fast)
            stream_gate = streaming.run_gate(fast=args.fast)
            fw_gate = connectivity.frontier_wallclock_gate(fast=args.fast)
            tune_gate = connectivity.autotune_gate(fast=args.fast,
                                                   retune=args.retune)
            oo_gate = oocore.run_gate(fast=args.fast)
            strat_gate = connectivity.strategy_matrix_gate(fast=args.fast)
            from repro.connectivity import planner as _planner
            payload = connectivity.records_to_json(
                records, fast=args.fast, gate=gate, streaming=stream_gate,
                frontier_wallclock=fw_gate, autotune=tune_gate,
                tuning_cache=_planner.cache.entries(),
                oocore=oo_gate, strategy=strat_gate)
            recovery.merge_into_artifact(payload,
                                         recovery.run_gate(fast=args.fast))
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=2)
            print(f"\nwrote {args.json}: {payload['summary']}")
        except Exception:  # noqa: BLE001 — keep the failure report intact
            failures.append("bench_json")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")
    print("\nall benchmark sections passed")


if __name__ == "__main__":
    main()
